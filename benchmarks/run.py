"""Benchmark orchestrator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call = wall time of the
benchmark; derived = the figure's headline metric) and dumps all figure
data to benchmarks/results/paper_figs.json.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig5,...]
"""

from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks import paper_figs, roofline_report

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

BENCHES = [
    ("fig4a_latency_cdf", paper_figs.fig4a_latency_cdf),
    ("fig4b_accuracy_cdf", paper_figs.fig4b_accuracy_cdf),
    ("fig5_loss_robustness", paper_figs.fig5_loss_robustness),
    ("fig6_compression", paper_figs.fig6_compression),
    ("fig7_compression_loss", paper_figs.fig7_compression_loss),
    ("fig8_msgsize_loss", paper_figs.fig8_msgsize_loss),
    ("beyond_packet_granularity", paper_figs.beyond_packet_granularity),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    os.makedirs(RESULTS_DIR, exist_ok=True)
    all_rows = {}
    print("name,us_per_call,derived")
    for name, fn in BENCHES:
        if only and name not in only:
            continue
        t0 = time.time()
        rows, derived = fn()
        dt_us = (time.time() - t0) * 1e6
        all_rows[name] = {"rows": rows, "derived": derived}
        print(f"{name},{dt_us:.0f},{derived:.4f}")

    if not args.skip_roofline:
        t0 = time.time()
        summary = roofline_report.run()
        dt_us = (time.time() - t0) * 1e6
        all_rows["roofline"] = summary
        print(
            f"roofline_report,{dt_us:.0f},"
            f"{summary['single_pod_pairs'] + summary['multi_pod_pairs']}"
        )

    with open(os.path.join(RESULTS_DIR, "paper_figs.json"), "w") as f:
        json.dump(all_rows, f, indent=2, default=float)


if __name__ == "__main__":
    main()
