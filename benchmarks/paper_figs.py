"""One benchmark per paper table/figure (arXiv:2112.09407 §IV).

Each function returns (rows, derived) where rows are the figure's data
points and ``derived`` is the headline metric checked against the paper's
qualitative claim.  `python -m benchmarks.run` executes all of them and
emits the name,us_per_call,derived CSV plus a JSON dump.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core import link as link_lib
from repro.paper import experiment as E

LOSS_GRID = [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9]
N_SEEDS = 10


# ---------------------------------------------------------------------------
# Fig. 4a — latency CDF, reliable vs unreliable protocol (analytic, exact)
# ---------------------------------------------------------------------------

def fig4a_latency_cdf() -> Tuple[List[Dict], float]:
    cfg = link_lib.ChannelConfig(loss_rate=0.5)
    msg_bytes = E.uncompressed_bytes()            # our 16 kB analog of 65.5 kB
    n_t = cfg.num_packets_for_bytes(msg_bytes)
    unrel = link_lib.unreliable_latency_s(n_t, cfg)
    lat, pmf = link_lib.reliable_latency_pmf(n_t, cfg)
    lat_s, cdf = link_lib.latency_cdf(lat, pmf)
    median_rel = float(lat_s[np.searchsorted(cdf, 0.5)])
    p95_rel = float(lat_s[np.searchsorted(cdf, 0.95)])
    rows = [
        {"protocol": "unreliable", "latency_ms": unrel * 1e3, "cdf": 1.0},
        {"protocol": "reliable", "latency_ms": median_rel * 1e3, "cdf": 0.5},
        {"protocol": "reliable", "latency_ms": p95_rel * 1e3, "cdf": 0.95},
    ]
    # paper claim: unreliable latency is lower AND deterministic
    derived = median_rel / unrel
    return rows, derived


# ---------------------------------------------------------------------------
# Fig. 4b — accuracy CDF at p = 0.5, COMtune vs previous DI
# ---------------------------------------------------------------------------

def fig4b_accuracy_cdf() -> Tuple[List[Dict], float]:
    rows = []
    gains = {}
    for name, r in [("previous_DI", 0.0), ("COMtune", 0.5)]:
        params, state, _ = E.finetuned(r)
        for proto, p in [("reliable", 0.0), ("unreliable", 0.5)]:
            mean, std, accs = E.accuracy_stats(params, state, None, p, N_SEEDS)
            rows.append(
                {"method": name, "protocol": proto, "acc_mean": mean,
                 "acc_std": std, "acc_sorted": sorted(accs)}
            )
            gains[(name, proto)] = mean
    derived = gains[("COMtune", "unreliable")] - gains[("previous_DI", "unreliable")]
    return rows, derived


# ---------------------------------------------------------------------------
# Fig. 5 — accuracy vs packet loss rate for r in {0, 0.2, 0.5}
# ---------------------------------------------------------------------------

def fig5_loss_robustness() -> Tuple[List[Dict], float]:
    rows = []
    curves = {}
    for r in [0.0, 0.2, 0.5]:
        params, state, _ = E.finetuned(r)
        curve = []
        for p in LOSS_GRID:
            mean, std, _ = E.accuracy_stats(params, state, None, p, N_SEEDS)
            rows.append({"r": r, "p": p, "acc_mean": mean, "acc_std": std})
            curve.append(mean)
        curves[r] = curve
    # paper: at p=0.7 the r=0.5 model degrades ~3.8 pts, previous DI >10 pts
    i07 = LOSS_GRID.index(0.7)
    degr_r5 = curves[0.5][0] - curves[0.5][i07]
    degr_r0 = curves[0.0][0] - curves[0.0][i07]
    return rows, degr_r0 - degr_r5


# ---------------------------------------------------------------------------
# Fig. 6 — accuracy vs message size, NO loss (quant vs PCA)
# ---------------------------------------------------------------------------

MSG_SIZES = None  # filled lazily from the uncompressed size


def _msg_sizes():
    full = E.uncompressed_bytes()          # 16 kB fp32
    return [full, full // 4, full // 8, full // 16, full // 32]


def fig6_compression() -> Tuple[List[Dict], float]:
    rows = []
    worst = {}
    for kind in ["quant", "pca"]:
        for m in _msg_sizes():
            if m == E.uncompressed_bytes():
                params, state, comp = E.finetuned(0.0)
                comp = None
            else:
                params, state, comp = E.finetuned(0.0, kind, float(m))
            mean, std, _ = E.accuracy_stats(params, state, comp, 0.0, 3)
            rows.append(
                {"kind": kind if m != E.uncompressed_bytes() else "none",
                 "message_kB": m / 1e3, "acc_mean": mean, "acc_std": std}
            )
            worst[(kind, m)] = mean
    full = E.uncompressed_bytes()
    # paper: compressed accuracy stays comparable to uncompressed
    derived = min(worst[("quant", full // 16)], worst[("pca", full // 16)]) - worst[
        ("quant", full)
    ]
    return rows, derived


# ---------------------------------------------------------------------------
# Fig. 7 — accuracy vs loss rate with compression (quant vs PCA, 1/4 size)
# ---------------------------------------------------------------------------

def fig7_compression_loss() -> Tuple[List[Dict], float]:
    rows = []
    acc_at_05 = {}
    m = E.uncompressed_bytes() // 4       # the paper's 4 kB-of-64 kB analog
    for kind in ["quant", "pca"]:
        for name, r in [("previous_DI", 0.0), ("COMtune", 0.5)]:
            params, state, comp = E.finetuned(r, kind, float(m))
            for p in LOSS_GRID[::2]:
                mean, std, _ = E.accuracy_stats(params, state, comp, p, N_SEEDS)
                rows.append(
                    {"kind": kind, "method": name, "p": p,
                     "acc_mean": mean, "acc_std": std}
                )
                if p == 0.4 or p == 0.6:
                    acc_at_05.setdefault((kind, name), []).append(mean)
    # paper: quantization is much more loss-robust than PCA (Fig. 7a vs 7b)
    q = np.mean(acc_at_05[("quant", "COMtune")])
    pc = np.mean(acc_at_05[("pca", "COMtune")])
    return rows, q - pc


# ---------------------------------------------------------------------------
# Fig. 8 — accuracy vs message size under loss (quant, r = 0.2)
# ---------------------------------------------------------------------------

def fig8_msgsize_loss() -> Tuple[List[Dict], float]:
    rows = []
    curves = {0.2: [], 0.5: []}
    sizes = _msg_sizes()
    for m in sizes:
        if m == E.uncompressed_bytes():
            params, state, comp = E.finetuned(0.2)
            comp = None
        else:
            params, state, comp = E.finetuned(0.2, "quant", float(m))
        for p in [0.2, 0.5]:
            mean, std, _ = E.accuracy_stats(params, state, comp, p, N_SEEDS)
            rows.append(
                {"message_kB": m / 1e3, "p": p, "acc_mean": mean, "acc_std": std}
            )
            curves[p].append(mean)
    # paper: smaller messages -> less redundancy -> worse loss robustness
    derived = curves[0.5][0] - curves[0.5][-1]  # acc drop from full to 1/32
    return rows, derived


# ---------------------------------------------------------------------------
# Beyond-paper: packet-granular channel vs the paper's element abstraction
# ---------------------------------------------------------------------------

def beyond_packet_granularity() -> Tuple[List[Dict], float]:
    """The paper argues the sender-side shuffle makes whole-packet loss
    equivalent to element-wise loss (Eq. 2-3).  We measure it: accuracy with
    the physical packet channel (with/without shuffle) vs Eq. 1."""
    params, state, _ = E.finetuned(0.5)
    rows = []
    acc = {}
    for gran, label in [("element", "element(Eq.1)"), ("packet", "packet+shuffle")]:
        mean, std, _ = E.accuracy_stats(
            params, state, None, 0.5, N_SEEDS, granularity=gran
        )
        rows.append({"channel": label, "p": 0.5, "acc_mean": mean, "acc_std": std})
        acc[gran] = mean
    derived = abs(acc["element"] - acc["packet"])
    return rows, derived
