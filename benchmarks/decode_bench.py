"""Decode throughput benchmark: seed per-token loop vs scan-compiled engine.

Times repeated ``generate()`` calls through the ``repro.serve`` engine
(one jitted ``lax.scan`` program per signature, compile-cached) against the
seed per-token Python loop (``generate_reference``, one jit dispatch per
token), and emits ``BENCH_decode.json`` with tokens/s, per-call p50/p99,
and the engine's trace count — the perf-trajectory artifact CI uploads.

    PYTHONPATH=src python -m benchmarks.decode_bench \
        [--arch qwen1.5-0.5b] [--iters 5] [--out BENCH_decode.json] \
        [--assert-min-tokens-per-s 1.0] [--assert-single-trace]
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import ARCHITECTURES, get_config
from repro.launch.serve import generate_reference
from repro.models import cache as cache_lib, lm
from repro.obs.stats import latency_summary
from repro.serve import DecodeEngine

logger = obs.get_logger("decode_bench")


def run_bench(
    arch: str = "qwen1.5-0.5b",
    batch: int = 4,
    prompt_len: int = 16,
    tokens: int = 32,
    iters: int = 5,
    loss_rate: float = 0.1,
    channel: str = "iid",
    full_size: bool = False,
    reference_iters: int = 2,
) -> dict:
    cfg = get_config(arch)
    if not full_size:
        cfg = cfg.reduced()
    import dataclasses

    cfg = cfg.with_updates(
        link=dataclasses.replace(cfg.link, loss_rate=loss_rate, channel=channel)
    )
    key = jax.random.PRNGKey(0)
    params = lm.init_lm(key, cfg)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab_size, jnp.int32
    )

    engine = DecodeEngine()
    # First call warms up internally (trace + compile, reported as
    # compile_s) and then times a pure execution, like every later call.
    call_times = []
    compile_s = 0.0
    for i in range(iters):
        _, t = engine.generate(
            params, cfg, prompts, tokens, key=jax.random.PRNGKey(i)
        )
        call_times.append(t["generate_s"])
        compile_s += t["compile_s"]
    stats = engine.stats()
    eng_stats = {
        "tokens_per_s": batch * tokens / float(np.median(call_times)),
        "compile_s": compile_s,
        "traces": stats["traces"],
        "calls": stats["calls"],
        **latency_summary(call_times),
    }

    # Like-for-like with the engine: whole-call time (prefill + decode).
    ref_times = []
    for i in range(max(reference_iters, 1)):
        _, t = generate_reference(
            params, cfg, prompts, tokens, key=jax.random.PRNGKey(i)
        )
        ref_times.append(t["prefill_s"] + t["decode_s_per_token"] * tokens)
    ref_stats = {
        "tokens_per_s": batch * tokens / float(np.median(ref_times)),
        **latency_summary(ref_times),
    }

    return {
        "bench": "decode",
        "arch": arch,
        "batch": batch,
        "prompt_len": prompt_len,
        "tokens": tokens,
        "iters": iters,
        "loss_rate": loss_rate,
        "channel": channel,
        "full_size": full_size,
        "cache_bytes": cache_lib.cache_bytes(cfg, batch, prompt_len + tokens),
        "backend": jax.default_backend(),
        "engine": eng_stats,
        "reference": ref_stats,
        "speedup": eng_stats["tokens_per_s"] / max(ref_stats["tokens_per_s"], 1e-9),
        # With REPRO_OBS=1 the engine's registry-side metrics ride along.
        "obs": (
            obs.registry().histogram("decode_engine.generate_s").summary()
            if obs.registry().enabled else None
        ),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=sorted(ARCHITECTURES))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--reference-iters", type=int, default=2)
    ap.add_argument("--loss-rate", type=float, default=0.1)
    ap.add_argument(
        "--channel", default="iid",
        choices=["iid", "ge", "gilbert_elliott", "fading"],
    )
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--out", default="BENCH_decode.json")
    ap.add_argument(
        "--assert-min-tokens-per-s", type=float, default=None,
        help="fail (exit 1) if engine tokens/s is below this",
    )
    ap.add_argument(
        "--assert-single-trace", action="store_true",
        help="fail if the engine traced more than once across all calls",
    )
    args = ap.parse_args()

    result = run_bench(
        arch=args.arch,
        batch=args.batch,
        prompt_len=args.prompt_len,
        tokens=args.tokens,
        iters=args.iters,
        loss_rate=args.loss_rate,
        channel=args.channel,
        full_size=args.full_size,
        reference_iters=args.reference_iters,
    )
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    eng, ref = result["engine"], result["reference"]
    logger.info(
        f"decode_bench[{args.arch} b={args.batch} s={args.prompt_len}"
        f"+{args.tokens}]: engine {eng['tokens_per_s']:.1f} tok/s "
        f"(p50 {eng['p50_s']*1e3:.1f} ms, p99 {eng['p99_s']*1e3:.1f} ms, "
        f"traces={eng['traces']}/{eng['calls']} calls) | "
        f"reference {ref['tokens_per_s']:.1f} tok/s | "
        f"speedup {result['speedup']:.1f}x -> {args.out}"
    )

    ok = True
    if args.assert_min_tokens_per_s is not None:
        if eng["tokens_per_s"] < args.assert_min_tokens_per_s:
            logger.error(
                f"ASSERT FAILED: {eng['tokens_per_s']:.2f} tok/s < "
                f"{args.assert_min_tokens_per_s}"
            )
            ok = False
    if args.assert_single_trace and eng["traces"] != 1:
        logger.error(f"ASSERT FAILED: engine traced {eng['traces']} times (want 1)")
        ok = False
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
