"""Decode-attention step-cost benchmark: full-cache naive vs length-masked
flash decode.

Two views, emitted to ``BENCH_decode_attn.json`` (the CI artifact):

* **micro** — one attention layer's decode step at fixed ``max_seq``,
  sweeping the valid length: the legacy path (full-cache dequantize +
  masked naive softmax, exactly what ``attn_impl="naive"`` runs under jit)
  against ``repro.kernels.decode_attention`` (O(valid) blocks, inline int8
  dequant), for int8 and model-dtype caches.  ``n_valid`` rides as a
  traced argument so XLA cannot constant-fold the mask.  Each row also
  reports the analytic bytes touched (``models.cache.decode_read_bytes``
  semantics at layer scope).
* **engine** — tokens/s of the continuous-batching slot pool on a
  mixed-length workload with ``attn_impl="naive"`` vs ``"flash_decode"``
  (same params, same keys; outputs are compared for drift).

CI smoke asserts the masked path beats the full-cache path by
``--assert-min-speedup`` (default gate 2x) at every swept valid length
<= max_seq/8 on the int8 cache — the acceptance bar for "decode cost
scales with valid tokens, not max_seq".

    PYTHONPATH=src python -m benchmarks.decode_attn_bench \
        [--max-seq 1024] [--assert-min-speedup 2.0] \
        [--out BENCH_decode_attn.json]
"""

from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import ARCHITECTURES
from repro.kernels.decode_attention import decode_attention, decode_block_kv
from repro.models import cache as cache_lib, lm
from repro.models.attention import _naive_attn, _read_cache
from repro.serve import ContinuousEngine, PoolConfig

logger = obs.get_logger("decode_attn_bench")


def _full_cache_step(q, cache, n_valid, softcap=0.0):
    """The legacy decode attention: dequantize the WHOLE cache, mask, softmax
    over all max_seq positions (what attn_impl="naive" compiles under jit)."""
    k, v = _read_cache(cache, q.dtype)
    c = k.shape[1]
    mask = (jnp.arange(c)[None, :] < n_valid)[:, None, None, None, :]
    return _naive_attn(q, k, v, mask, softcap)


def _make_cache(key, b, c, kvh, hd, kv_dtype, dtype):
    ks = jax.random.split(key, 4)
    if kv_dtype == "int8":
        return {
            "k": jax.random.randint(ks[0], (b, c, kvh, hd), -127, 128, jnp.int8),
            "v": jax.random.randint(ks[1], (b, c, kvh, hd), -127, 128, jnp.int8),
            "k_scale": (jax.random.uniform(ks[2], (b, c, kvh)) * 0.05 + 0.01
                        ).astype(jnp.bfloat16),
            "v_scale": (jax.random.uniform(ks[3], (b, c, kvh)) * 0.05 + 0.01
                        ).astype(jnp.bfloat16),
        }
    return {
        "k": jax.random.normal(ks[0], (b, c, kvh, hd), dtype),
        "v": jax.random.normal(ks[1], (b, c, kvh, hd), dtype),
    }


def _time_step(fn, args, reps: int, rounds: int) -> float:
    """Median wall seconds of one call (blocked), over ``rounds`` batches
    of ``reps`` back-to-back dispatches."""
    jax.block_until_ready(fn(*args))      # warm (compile)
    times = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) / reps)
    return float(np.median(times))


def _layer_read_bytes(c, kvh, hd, kv_dtype, valid, block_kv, masked):
    itemsize = 1 if kv_dtype == "int8" else 4
    if masked:
        bkv = decode_block_kv(c, block_kv)
        rows = min(-(-min(valid, c) // bkv) * bkv, c)
    else:
        rows = c
    row = 2 * kvh * hd * itemsize + (2 * kvh * 2 if kv_dtype == "int8" else 0)
    return rows * row


def micro_bench(
    max_seq: int, valids, b: int, kvh: int, groups: int, hd: int,
    block_kv: int, reps: int, rounds: int,
) -> dict:
    out = {}
    for kv_dtype in ("int8", "f32"):
        dtype = jnp.float32
        q = jax.random.normal(
            jax.random.PRNGKey(0), (b, 1, kvh, groups, hd), dtype
        )
        cache = _make_cache(
            jax.random.PRNGKey(1), b, max_seq, kvh, hd, kv_dtype, dtype
        )
        old_fn = jax.jit(_full_cache_step)  # noqa: RPA001 — one deliberate compile per kv_dtype config
        new_fn = jax.jit(  # noqa: RPA001 — one deliberate compile per kv_dtype config
            functools.partial(decode_attention, block_kv=block_kv)
        )
        rows = []
        for v in valids:
            n = jnp.int32(v)
            t_old = _time_step(old_fn, (q, cache, n), reps, rounds)
            t_new = _time_step(new_fn, (q, cache, n), reps, rounds)
            rows.append({
                "valid": int(v),
                "old_ms": t_old * 1e3,
                "masked_ms": t_new * 1e3,
                "speedup": t_old / max(t_new, 1e-12),
                "read_bytes_old": _layer_read_bytes(
                    max_seq, kvh, hd, kv_dtype, v, block_kv, masked=False),
                "read_bytes_masked": _layer_read_bytes(
                    max_seq, kvh, hd, kv_dtype, v, block_kv, masked=True),
            })
        out[kv_dtype] = {
            "max_seq": max_seq, "batch": b, "kv_heads": kvh,
            "groups": groups, "head_dim": hd, "block_kv": block_kv,
            "rows": rows,
        }
    return out


def engine_bench(tokens: int = 12, n_requests: int = 8) -> dict:
    """Slot-pool tokens/s, naive vs flash_decode, identical greedy output."""
    import dataclasses

    base = ARCHITECTURES["qwen1.5-0.5b"].reduced(kv_cache_dtype="int8")
    base = base.with_updates(
        link=dataclasses.replace(base.link, loss_rate=0.1, channel="iid")
    )
    params = lm.init_lm(jax.random.PRNGKey(0), base)
    lengths = [4 + (3 * i) % 24 for i in range(n_requests)]
    prompts = [
        np.asarray(jax.random.randint(
            jax.random.fold_in(jax.random.PRNGKey(7), i), (L,), 0,
            base.vocab_size, jnp.int32,
        ))
        for i, L in enumerate(lengths)
    ]
    results = {}
    outputs = {}
    for impl in ("naive", "flash_decode"):
        eng = ContinuousEngine(
            base,
            PoolConfig(max_slots=4, max_new=tokens, max_prompt=32, min_bucket=8),
            attn_impl=impl,
        )
        key = jax.random.PRNGKey(3)

        def serve():
            reqs = [
                eng.submit(p, tokens, key=jax.random.fold_in(key, i))
                for i, p in enumerate(prompts)
            ]
            t0 = time.perf_counter()
            eng.run(params)
            return time.perf_counter() - t0, reqs

        serve()                                   # warm: AOT builds
        wall, reqs = serve()
        outputs[impl] = np.stack([r.tokens for r in reqs])
        results[impl] = {
            "tokens_per_s": n_requests * tokens / wall,
            "wall_s": wall,
            "compiles": eng.compiles,
        }
    results["outputs_identical"] = bool(
        (outputs["naive"] == outputs["flash_decode"]).all()
    )
    results["speedup"] = (
        results["flash_decode"]["tokens_per_s"]
        / max(results["naive"]["tokens_per_s"], 1e-9)
    )
    results["pool_max_seq"] = 32 + tokens
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-seq", type=int, default=1024)
    ap.add_argument("--valids", default="16,64,128,256,512,1024",
                    help="comma-separated valid lengths to sweep")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--kv-heads", type=int, default=4)
    ap.add_argument("--groups", type=int, default=2)
    ap.add_argument("--head-dim", type=int, default=64)
    ap.add_argument("--block-kv", type=int, default=64)
    ap.add_argument("--reps", type=int, default=20)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--no-engine", action="store_true",
                    help="skip the slot-pool engine comparison")
    ap.add_argument("--out", default="BENCH_decode_attn.json")
    ap.add_argument(
        "--assert-min-speedup", type=float, default=None,
        help="fail unless masked/full speedup >= this at every int8 sweep "
        "point with valid <= max_seq/8",
    )
    args = ap.parse_args()

    valids = [int(v) for v in args.valids.split(",") if v]
    micro = micro_bench(
        args.max_seq, valids, args.batch, args.kv_heads, args.groups,
        args.head_dim, args.block_kv, args.reps, args.rounds,
    )
    qwen8 = ARCHITECTURES["qwen1.5-0.5b"].with_updates(kv_cache_dtype="int8")
    result = {
        "bench": "decode_attn",
        "backend": jax.default_backend(),
        "micro": micro,
        "model_read_bytes_example": {
            "arch": "qwen1.5-0.5b+int8", "max_seq": 1024, "valid": 128,
            "full": cache_lib.decode_read_bytes(qwen8, 1024, 128, masked=False),
            "masked": cache_lib.decode_read_bytes(qwen8, 1024, 128, masked=True),
        },
    }
    if not args.no_engine:
        result["engine"] = engine_bench()

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)

    for kv_dtype, m in micro.items():
        logger.info(f"[{kv_dtype} cache, max_seq={m['max_seq']}]")
        for r in m["rows"]:
            logger.info(
                f"  valid={r['valid']:>5}: full {r['old_ms']:7.3f} ms | "
                f"masked {r['masked_ms']:7.3f} ms | {r['speedup']:5.2f}x | "
                f"bytes {r['read_bytes_old']:>9} -> {r['read_bytes_masked']:>9}"
            )
    if "engine" in result:
        e = result["engine"]
        logger.info(
            f"[slot pool, int8] naive {e['naive']['tokens_per_s']:.1f} tok/s"
            f" | flash_decode {e['flash_decode']['tokens_per_s']:.1f} tok/s"
            f" | {e['speedup']:.2f}x | identical={e['outputs_identical']}"
        )
    logger.info(f"-> {args.out}")

    ok = True
    if args.assert_min_speedup is not None:
        gate = [r for r in micro["int8"]["rows"]
                if r["valid"] * 8 <= args.max_seq]
        if not gate:
            logger.error("ASSERT FAILED: no sweep point with valid <= max_seq/8")
            ok = False
        for r in gate:
            if r["speedup"] < args.assert_min_speedup:
                logger.info(
                    f"ASSERT FAILED: int8 valid={r['valid']} speedup "
                    f"{r['speedup']:.2f}x < {args.assert_min_speedup}x"
                )
                ok = False
    if "engine" in result and not result["engine"]["outputs_identical"]:
        logger.error("ASSERT FAILED: naive vs flash_decode engine outputs differ")
        ok = False
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
