"""Channel-aware COMtune robustness benchmark (the paper's Fig. 6
generalized to bursty / FEC-protected links) + scan-compiled trainer
throughput, emitted as ``BENCH_comtune.json``.

Part A — robustness sweep: fine-tune the split CNN once per *training*
link emulation (``core.comtune.emulate_link``):

* ``dropout``     — the paper's Eq. 7 i.i.d. inverted dropout;
* ``channel_ge``  — the deployment channel: Gilbert–Elliott bursts with a
  ``shuffle=False`` sender (no anti-burst interleaving);
* ``channel_ge_fec`` (full mode) — same, FEC-protected, so training sees
  the *residual* post-decode loss pattern;

then evaluate every model on every *serving* channel (iid / GE bursts /
GE+FEC) at each loss rate.  The paper's claim, taken seriously: training
against the channel you deploy on (not its i.i.d. approximation) wins on
matched-channel accuracy — ``--assert-channel-wins`` enforces it.

Part B — trainer throughput: steps/s of the scan-compiled epoch
(``launch.steps.make_train_epoch``; K steps per dispatch) vs the per-step
jit loop on a dispatch-bound reduced LM config, both async-dispatch and
the seed driver's per-step ``float(loss)`` host-sync loop.

    PYTHONPATH=src python -m benchmarks.comtune_robustness \
        [--smoke] [--out BENCH_comtune.json] \
        [--assert-finite] [--assert-min-speedup 1.0] [--assert-channel-wins]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.data as data
from repro import obs
from repro.core import comtune
from repro.models import cnn
from repro.optim import AdamConfig, adam_update, init_adam

logger = obs.get_logger("comtune_robustness")

CNN_CFG = cnn.CNNConfig(
    blocks=((1, 16), (1, 32)), fc=(32,), num_classes=10,
    image_size=16, split_block=1,
)
BURST_LEN = 8.0                     # mean GE bad-sojourn, packets


# ---------------------------------------------------------------------------
# Part A: train-channel x eval-channel accuracy sweep
# ---------------------------------------------------------------------------

def train_specs(loss_rate: float, smoke: bool):
    """Training-link emulations, all routed through emulate_link."""
    ge = dict(
        train_link="channel", channel="ge", shuffle=False,
        loss_rate=loss_rate, channel_params=(("burst_len", BURST_LEN),),
    )
    out = {
        "dropout": comtune.LinkSpec(dropout_rate=loss_rate),
        "channel_ge": comtune.LinkSpec(**ge),
    }
    if not smoke:
        out["channel_ge_fec"] = comtune.LinkSpec(**ge, fec_k=10, fec_m=2)
    return out


def eval_specs(loss_rate: float, smoke: bool):
    """Serving channels (Eq. 12 path of emulate_link)."""
    out = {
        "iid": comtune.LinkSpec(loss_rate=loss_rate),
        "ge": comtune.LinkSpec(
            loss_rate=loss_rate, channel="ge", shuffle=False,
            channel_params=(("burst_len", BURST_LEN),),
        ),
    }
    if not smoke:
        out["ge_fec"] = comtune.LinkSpec(
            loss_rate=loss_rate, channel="ge", shuffle=False,
            channel_params=(("burst_len", BURST_LEN),), fec_k=10, fec_m=2,
        )
    return out


def finetune(dataset, spec, steps: int, seed: int = 0):
    (xtr, ytr), _ = dataset
    adam_cfg = AdamConfig(lr=2e-3)
    key = jax.random.PRNGKey(seed)
    params, state = cnn.init_cnn(key, CNN_CFG)
    opt = init_adam(params, adam_cfg)
    it = data.batch_iterator(xtr, ytr, 64, seed=seed)

    @jax.jit
    def step(params, state, opt, xb, yb, k):
        def loss_fn(p):
            link = lambda a: comtune.emulate_link(k, a, spec, "train")
            logits, new_state = cnn.forward(
                p, state, xb, CNN_CFG, train=True, link_fn=link
            )
            ll = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(ll, yb[:, None], axis=-1).mean(), new_state

        (l, new_state), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt, _ = adam_update(g, params, opt, adam_cfg)
        return params, new_state, opt, l

    for _ in range(steps):
        xb, yb = next(it)
        key, sub = jax.random.split(key)
        params, state, opt, _ = step(
            params, state, opt, jnp.asarray(xb), jnp.asarray(yb), sub
        )
    return params, state


def di_accuracy(dataset, model, spec, n_seeds: int) -> float:
    _, (xte, yte) = dataset
    params, state = model
    accs = []
    for s in range(n_seeds):
        key = jax.random.PRNGKey(1000 + s)
        link = lambda a: comtune.emulate_link(key, a, spec, "serve")
        logits, _ = cnn.forward(
            params, state, jnp.asarray(xte), CNN_CFG, train=False, link_fn=link
        )
        accs.append(float((jnp.argmax(logits, -1) == jnp.asarray(yte)).mean()))
    return float(np.mean(accs))


def robustness_sweep(smoke: bool) -> dict:
    loss_rates = [0.5] if smoke else [0.3, 0.5, 0.7]
    steps = 160 if smoke else 300
    n_seeds = 3 if smoke else 5
    dataset = data.make_image_dataset(
        n_train=1500, n_test=300 if smoke else 600, num_classes=10,
        image_size=16, noise=1.2,
    )
    matrix: dict = {}
    for p in loss_rates:
        models = {
            name: finetune(dataset, spec, steps)
            for name, spec in train_specs(p, smoke).items()
        }
        cell = {}
        for tname, model in models.items():
            cell[tname] = {"clean": di_accuracy(
                dataset, model, comtune.LinkSpec(), 1
            )}
            for ename, espec in eval_specs(p, smoke).items():
                cell[tname][ename] = di_accuracy(dataset, model, espec, n_seeds)
        matrix[str(p)] = cell
    return {
        "loss_rates": loss_rates,
        "train_steps": steps,
        "eval_seeds": n_seeds,
        "burst_len": BURST_LEN,
        "accuracy": matrix,
    }


# ---------------------------------------------------------------------------
# Part B: scan-compiled trainer vs per-step loop
# ---------------------------------------------------------------------------

def trainer_bench(smoke: bool, arch: str = "qwen1.5-0.5b") -> dict:
    from repro.configs import get_config
    from repro.launch.steps import make_train_epoch, make_train_step
    from repro.models import lm

    # Dispatch-bound reduced config: the regime the scan targets (same as
    # the PR-2 decode engine) — per-step XLA dispatch is a large fraction
    # of step wall time, so fusing K steps into one program pays.
    cfg = get_config(arch).reduced(
        d_model=32, num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
        vocab_size=64, num_units=1,
    )
    cfg = cfg.with_updates(num_layers=len(cfg.prologue) + len(cfg.unit_pattern))
    B, S, K = 2, 16, 100 if smoke else 200
    repeats = 3
    adam_cfg = AdamConfig(lr=3e-4, grad_clip_norm=1.0)
    toks = jax.random.randint(
        jax.random.PRNGKey(7), (K, B, S), 0, cfg.vocab_size, jnp.int32
    )

    def fresh():
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        return params, init_adam(params, adam_cfg)

    step_fn = jax.jit(make_train_step(cfg, adam_cfg))
    p, o = fresh()
    _, sub = jax.random.split(jax.random.PRNGKey(42))
    p, o, m = step_fn(p, o, {"tokens": toks[0]}, sub)
    jax.block_until_ready(m["loss"])

    def run_loop(sync_every_step: bool):
        nonlocal p, o
        key = jax.random.PRNGKey(42)
        t0 = time.perf_counter()
        for i in range(K):
            key, sub = jax.random.split(key)
            p, o, m = step_fn(p, o, {"tokens": toks[i]}, sub)
            if sync_every_step:
                float(m["loss"])      # the seed driver's per-step host sync
        jax.block_until_ready((p, o))
        return time.perf_counter() - t0

    t_loop = min(run_loop(False) for _ in range(repeats))
    t_loop_synced = min(run_loop(True) for _ in range(repeats))

    epoch_fn = make_train_epoch(cfg, adam_cfg)
    p2, o2 = fresh()
    t0 = time.perf_counter()
    r = epoch_fn(p2, o2, {"tokens": toks}, jax.random.PRNGKey(42))
    jax.block_until_ready(r[0])
    compile_s = time.perf_counter() - t0
    p2, o2 = r[0], r[1]

    def run_scan():
        nonlocal p2, o2
        t0 = time.perf_counter()
        r = epoch_fn(p2, o2, {"tokens": toks}, jax.random.PRNGKey(43))
        jax.block_until_ready((r[0], r[3]["loss"]))
        p2, o2 = r[0], r[1]
        return time.perf_counter() - t0

    t_scan = min(run_scan() for _ in range(repeats))
    return {
        "arch": cfg.name,
        "batch": B,
        "seq": S,
        "steps_per_epoch": K,
        "loop_steps_per_s": K / t_loop,
        "loop_synced_steps_per_s": K / t_loop_synced,
        "scan_steps_per_s": K / t_scan,
        "scan_compile_s": compile_s,
        "speedup_scan_vs_loop": t_loop / t_scan,
        "speedup_scan_vs_synced_loop": t_loop_synced / t_scan,
    }


# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_comtune.json")
    ap.add_argument(
        "--assert-finite", action="store_true",
        help="fail if any sweep accuracy is non-finite",
    )
    ap.add_argument(
        "--assert-min-speedup", type=float, default=None,
        help="fail if scan/loop trainer speedup is below this",
    )
    ap.add_argument(
        "--assert-channel-wins", action="store_true",
        help="fail unless channel_ge-tuned beats dropout-tuned on the "
             "matched GE eval at every swept loss rate",
    )
    args = ap.parse_args()

    sweep = robustness_sweep(args.smoke)
    trainer = trainer_bench(args.smoke)
    result = {
        "bench": "comtune_robustness",
        "smoke": args.smoke,
        "backend": jax.default_backend(),
        "sweep": sweep,
        "trainer": trainer,
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)

    for p, cell in sweep["accuracy"].items():
        row = " | ".join(
            f"{t}: ge {a['ge']:.3f} iid {a['iid']:.3f}" for t, a in cell.items()
        )
        logger.info(f"p={p}: {row}")
    logger.info(
        f"trainer[{trainer['arch']} b={trainer['batch']} s={trainer['seq']} "
        f"K={trainer['steps_per_epoch']}]: "
        f"scan {trainer['scan_steps_per_s']:.0f} steps/s vs "
        f"loop {trainer['loop_steps_per_s']:.0f} "
        f"(synced {trainer['loop_synced_steps_per_s']:.0f}) -> "
        f"{trainer['speedup_scan_vs_loop']:.2f}x -> {args.out}"
    )

    ok = True
    accs = [
        v for cell in sweep["accuracy"].values()
        for a in cell.values() for v in a.values()
    ]
    if args.assert_finite and not np.all(np.isfinite(accs)):
        logger.error("ASSERT FAILED: non-finite accuracy in sweep")
        ok = False
    if args.assert_min_speedup is not None and (
        trainer["speedup_scan_vs_loop"] < args.assert_min_speedup
    ):
        logger.info(
            f"ASSERT FAILED: speedup {trainer['speedup_scan_vs_loop']:.2f} < "
            f"{args.assert_min_speedup}"
        )
        ok = False
    if args.assert_channel_wins:
        for p, cell in sweep["accuracy"].items():
            if cell["channel_ge"]["ge"] <= cell["dropout"]["ge"]:
                logger.info(
                    f"ASSERT FAILED: p={p} channel_ge {cell['channel_ge']['ge']:.3f}"
                    f" <= dropout {cell['dropout']['ge']:.3f} on matched GE eval"
                )
                ok = False
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
