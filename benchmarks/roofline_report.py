"""Roofline report: renders the dry-run JSONL sweeps into the per-(arch x
mesh) table used by EXPERIMENTS.md §Roofline, with bottleneck and one-line
recommendation per pair."""

from __future__ import annotations

import json
import os
from typing import Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def load(path: str) -> List[Dict]:
    rows = []
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                rows.append(json.loads(line))
    return rows


def recommendation(r: Dict) -> str:
    b = r["bottleneck"]
    if b == "collective":
        kinds = r.get("collective_breakdown", {})
        top = max(kinds, key=kinds.get) if kinds else "all-reduce"
        return (
            f"dominant {top}: reshard to avoid cross-'data' contractions "
            f"(fsdp off / activation-stationary layout) or overlap with compute"
        )
    if b == "memory":
        return "decode is HBM-bound: shrink cache dtype (int8 KV) or batch more"
    return "compute-bound: good — push MXU utilization (block shapes, bf16)"


def table(rows: List[Dict]) -> str:
    hdr = (
        f"{'arch':26s} {'shape':12s} {'mesh':9s} {'compute_s':>10s} "
        f"{'memory_s':>10s} {'collect_s':>10s} {'bound':>10s} {'MF/HLO':>7s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("error"):
            lines.append(f"{r['arch']:26s} {r['shape']:12s} ERROR")
            continue
        lines.append(
            f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:9s} "
            f"{r['compute_s']:10.3e} {r['memory_s']:10.3e} "
            f"{r['collective_s']:10.3e} {r['bottleneck']:>10s} "
            f"{r['useful_flops_frac']:7.3f}"
        )
    return "\n".join(lines)


def run() -> Dict:
    single = load(os.path.join(RESULTS_DIR, "dryrun_single_pod.jsonl"))
    multi = load(os.path.join(RESULTS_DIR, "dryrun_multi_pod.jsonl"))
    print("== single-pod (16x16 = 256 chips) ==")
    print(table(single))
    if multi:
        print("\n== multi-pod (2x16x16 = 512 chips) ==")
        print(table(multi))
    ok_s = [r for r in single if not r.get("error")]
    ok_m = [r for r in multi if not r.get("error")]
    return {
        "single_pod_pairs": len(ok_s),
        "single_pod_errors": len(single) - len(ok_s),
        "multi_pod_pairs": len(ok_m),
        "multi_pod_errors": len(multi) - len(ok_m),
        "bottlenecks": {
            b: sum(1 for r in ok_s if r["bottleneck"] == b)
            for b in ("compute", "memory", "collective")
        },
    }


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
