"""Serving benchmark: continuous-batching slot pool vs whole-generation engine.

Builds a mixed-length Poisson workload (``--clients`` Poisson processes,
prompt lengths spread over >= 3 power-of-two buckets), replays it in
arrival order through

* the **continuous engine** (``repro.serve.continuous``): slot-pooled,
  bucketed prefill, one fused decode step — after the per-bucket warm-up
  the whole run executes with ZERO new XLA builds (AOT ``Compiled``
  programs cannot retrace; ``engine.compiles`` proves it), and
* the **whole-generation engine** (``repro.serve.DecodeEngine``) serving
  each request at its exact (prompt_len, num_tokens) signature, batch 1 —
  the recompile-storm baseline: one AOT build per distinct signature,
  then sequential per-request execution.

Emits ``BENCH_serving.json`` with sustained tokens/s, request completion
p50/p99 under saturated replay, total/steady-state compile counts, slot
occupancy, and the old-engine baseline (warm and cold).

    PYTHONPATH=src python -m benchmarks.serving_bench [--smoke] \
        [--out BENCH_serving.json] [--assert-max-compiles N] \
        [--assert-zero-steady-compiles] [--assert-min-rps 1.0] \
        [--assert-min-speedup 2.0]

``--paged`` switches to the density comparison instead: a contiguous slot
pool vs a PAGED block pool holding no more cache HBM, replaying one
saturated workload through both.  The contiguous engine can only hold as
many requests as worst-case ``max_seq`` slots fit; the paged engine
reserves per-request blocks, so the same bytes sustain several times the
in-flight requests (``active_median`` per decode step) and admission
writes scale with the prompt's bucket instead of ``max_seq``.  Emits
``BENCH_serving_paged.json``; greedy outputs are cross-checked
token-for-token between the two engines, and both keep
``compiles == num_buckets + 1``.

    PYTHONPATH=src python -m benchmarks.serving_bench --paged \
        [--assert-min-sustained-ratio 2.0] [--out BENCH_serving_paged.json]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.analysis.guards import no_recompile
from repro.configs import ARCHITECTURES, get_config
from repro.models import cache as cache_lib, lm
from repro.obs import exporters
from repro.obs.stats import latency_summary
from repro.serve import ContinuousEngine, DecodeEngine, PoolConfig

logger = obs.get_logger("serving_bench")


def build_workload(
    n_clients: int,
    rate_hz: float,
    duration_s: float,
    lengths,
    vocab: int,
    seed: int = 0,
    min_requests: int = 8,
):
    """Poisson arrivals per client, merged and sorted; each request gets a
    prompt whose length cycles through ``lengths`` (>= 3 buckets)."""
    rng = np.random.RandomState(seed)
    arrivals = []
    for c in range(n_clients):
        t = rng.exponential(1.0 / rate_hz)
        while t < duration_s:
            arrivals.append((t, c))
            t += rng.exponential(1.0 / rate_hz)
    arrivals.sort()
    while len(arrivals) < min_requests:          # tiny-duration safety net
        arrivals.append((duration_s, len(arrivals) % n_clients))
    prompts = []
    for i, (t, c) in enumerate(arrivals):
        L = int(lengths[i % len(lengths)])
        prompts.append(rng.randint(0, vocab, size=(L,)).astype(np.int32))
    return arrivals, prompts


def run_bench(
    arch: str = "qwen1.5-0.5b",
    n_clients: int = 24,
    rate_hz: float = 1.0,
    duration_s: float = 1.0,
    lengths=(5, 7, 11, 14, 22, 28),
    tokens: int = 16,
    max_slots: int = 8,
    loss_rate: float = 0.1,
    channel: str = "iid",
    seed: int = 0,
    full_size: bool = False,
) -> dict:
    import dataclasses

    cfg = get_config(arch)
    if not full_size:
        cfg = cfg.reduced()
    cfg = cfg.with_updates(
        link=dataclasses.replace(cfg.link, loss_rate=loss_rate, channel=channel)
    )
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    arrivals, prompts = build_workload(
        n_clients, rate_hz, duration_s, lengths, cfg.vocab_size, seed=seed
    )
    n_req = len(prompts)
    base_key = jax.random.PRNGKey(seed)

    # ---- continuous engine -------------------------------------------------
    pool = PoolConfig(
        max_slots=max_slots,
        max_new=max(16, tokens),
        max_prompt=max(int(max(lengths)), 8),
    )
    eng = ContinuousEngine(cfg, pool)
    buckets = sorted({eng.bucket_for(len(p)) for p in prompts})

    # Warm-up: one throwaway request per bucket compiles every program the
    # workload can touch (num_buckets prefills + 1 decode step).
    for i, b in enumerate(buckets):
        p = next(p for p in prompts if eng.bucket_for(len(p)) == b)
        eng.submit(p, 1, key=jax.random.fold_in(base_key, 10_000 + i))
    eng.run(params)
    warm_compiles = eng.compiles
    warm_compile_s = eng.compile_s

    t0 = time.perf_counter()
    # The steady-state contract, enforced at runtime: the warmed replay
    # performs zero new XLA builds (guard watches jax.monitoring AND
    # eng.compiles; a violation raises instead of silently skewing stats).
    with no_recompile(engines=(eng,)):
        reqs = [
            eng.submit(p, tokens, key=jax.random.fold_in(base_key, i))
            for i, p in enumerate(prompts)
        ]
        eng.run(params)
    t_eng = time.perf_counter() - t0
    completion = [r.t_done - t0 for r in reqs]
    eng_stats = {
        "tokens_per_s": n_req * tokens / t_eng,
        "requests_per_s": n_req / t_eng,
        "wall_s": t_eng,
        "compiles_total": eng.compiles,
        "compiles_warmup": warm_compiles,
        "compiles_steady": eng.compiles - warm_compiles,
        "compile_s": eng.compile_s,
        "num_buckets": eng.num_buckets,
        "traces": eng.traces,
        "slot_occupancy": eng.stats()["slot_occupancy"],
        "max_slots": max_slots,
        **latency_summary(completion),
        "device": eng.device_counters(),
        **{f"request_{k}": v for k, v in eng.request_stats().items()},
    }
    eng.publish_device_counters()

    # ---- whole-generation baseline ----------------------------------------
    # Each request served at its exact signature, batch 1 — under the mixed
    # workload that is one AOT build per distinct (prompt_len, tokens).
    old = DecodeEngine()
    t0 = time.perf_counter()
    for i, p in enumerate(prompts):          # cold pass: the recompile storm
        old.generate(params, cfg, jnp.asarray(p)[None], tokens,
                     key=jax.random.fold_in(base_key, i))
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    done_at = []
    for i, p in enumerate(prompts):          # warm pass: steady-state
        old.generate(params, cfg, jnp.asarray(p)[None], tokens,
                     key=jax.random.fold_in(base_key, i))
        done_at.append(time.perf_counter() - t0)
    t_warm = time.perf_counter() - t0
    ref_stats = {
        "tokens_per_s": n_req * tokens / t_warm,
        "tokens_per_s_cold": n_req * tokens / t_cold,
        "wall_s": t_warm,
        "wall_s_cold": t_cold,
        "signatures_compiled": old.num_compiled,
        "compile_s": sum(e.compile_s for e in old._compiled.values()),
        **latency_summary(done_at),
    }

    return {
        "bench": "serving",
        "arch": arch,
        "n_clients": n_clients,
        "rate_hz": rate_hz,
        "n_requests": n_req,
        "tokens": tokens,
        "prompt_lengths": sorted(set(int(len(p)) for p in prompts)),
        "buckets": [int(b) for b in buckets],
        "loss_rate": loss_rate,
        "channel": channel,
        "backend": jax.default_backend(),
        "engine": eng_stats,
        "whole_generation": ref_stats,
        "speedup": eng_stats["tokens_per_s"] / max(ref_stats["tokens_per_s"], 1e-9),
        "speedup_vs_cold": eng_stats["tokens_per_s"]
        / max(ref_stats["tokens_per_s_cold"], 1e-9),
    }


def _replay(eng, params, prompts, tokens, base_key):
    """Warm the engine's programs on one throwaway request per bucket,
    then replay the saturated workload under the no-recompile guard.
    Returns (requests, wall_s) with the concurrency window reset so
    ``active_median`` measures the replay only."""
    buckets = sorted({eng.bucket_for(len(p)) for p in prompts})
    for i, b in enumerate(buckets):
        p = next(p for p in prompts if eng.bucket_for(len(p)) == b)
        eng.submit(p, 1, key=jax.random.fold_in(base_key, 10_000 + i))
    eng.run(params)
    eng.active_per_step.clear()
    t0 = time.perf_counter()
    with no_recompile(engines=(eng,)):
        reqs = [
            eng.submit(p, tokens, key=jax.random.fold_in(base_key, i))
            for i, p in enumerate(prompts)
        ]
        eng.run(params)
    return reqs, time.perf_counter() - t0


def run_paged_bench(
    arch: str = "qwen1.5-0.5b",
    n_requests: int = 24,
    tokens: int = 8,
    loss_rate: float = 0.1,
    channel: str = "iid",
    seed: int = 0,
    full_size: bool = False,
) -> dict:
    """Contiguous slot pool vs paged block pool at equal (or less) cache
    HBM, one saturated replay each.  The contiguous pool's HBM budget
    (``max_slots`` worst-case ``max_seq`` caches) is converted into pool
    blocks; short requests then reserve only their own blocks, so the
    paged engine keeps several times the requests in flight per step."""
    import dataclasses

    cfg = get_config(arch)
    if not full_size:
        cfg = cfg.reduced()
    cfg = cfg.with_updates(
        link=dataclasses.replace(cfg.link, loss_rate=loss_rate, channel=channel)
    )
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    base_key = jax.random.PRNGKey(seed)

    # Contiguous baseline: 2 worst-case slots.
    pool_c = PoolConfig(max_slots=2, max_new=32, max_prompt=24)
    contig_hbm = cache_lib.cache_bytes(cfg, pool_c.max_slots, pool_c.max_seq)
    # Paged pool holding AT MOST the same bytes: block_pool_bytes is
    # linear in num_blocks with zero intercept, so size by the per-block
    # cost (block 0, the trash block, pays for itself out of the budget).
    block_size = 8
    per_block = cache_lib.block_pool_bytes(cfg, 3, block_size) \
        - cache_lib.block_pool_bytes(cfg, 2, block_size)
    num_blocks = contig_hbm // per_block
    pool_p = PoolConfig(
        max_slots=8, max_new=32, max_prompt=24,
        paged=True, block_size=block_size, num_blocks=int(num_blocks),
    )
    paged_hbm = cache_lib.block_pool_bytes(cfg, pool_p.total_blocks, block_size)
    assert paged_hbm <= contig_hbm, (paged_hbm, contig_hbm)

    # Saturated workload: everything submitted up front.  Short prompts
    # (one power-of-two bucket) keep the reservation arithmetic visible —
    # each request needs ceil(max(8, len+tokens) / 8) blocks vs a whole
    # contiguous max_seq slot.
    rng = np.random.RandomState(seed)
    prompts = [
        rng.randint(0, cfg.vocab_size, size=(int(3 + i % 4),)).astype(np.int32)
        for i in range(n_requests)
    ]

    results = {}
    engines = {}
    for name, pool in (("contiguous", pool_c), ("paged", pool_p)):
        eng = ContinuousEngine(cfg, pool)
        reqs, wall = _replay(eng, params, prompts, tokens, base_key)
        s = eng.stats()
        results[name] = {
            "wall_s": wall,
            "tokens_per_s": n_requests * tokens / wall,
            "max_slots": pool.max_slots,
            "cache_hbm_bytes": contig_hbm if name == "contiguous" else paged_hbm,
            "sustained_in_flight": s["active_median"],
            "active_peak": s["active_peak"],
            "active_mean": s["active_mean"],
            "compiles": eng.compiles,
            "num_buckets": eng.num_buckets,
            **{k: s[k] for k in
               ("pool_blocks_total", "peak_blocks_used", "blocks_written")
               if k in s},
        }
        engines[name] = (eng, reqs)
        assert eng.compiles == eng.num_buckets + 1, (
            name, eng.compiles, eng.num_buckets
        )

    # Same request keys through both engines -> identical greedy tokens
    # (each engine is separately pinned to generate_reference in tests;
    # the cross-check here keeps the bench honest end-to-end).
    for rc, rp in zip(engines["contiguous"][1], engines["paged"][1]):
        np.testing.assert_array_equal(rc.tokens, rp.tokens)

    # Admission-copy bytes: the paged write scales with the bucket, the
    # contiguous write is a constant full slot.
    admission = {
        "contiguous_any_bucket": cache_lib.admission_write_bytes(
            cfg, pool_c.max_seq, pool_c.max_bucket
        ),
        "paged_bucket_8": cache_lib.admission_write_bytes(
            cfg, pool_p.max_seq, 8, paged=True, block_size=block_size
        ),
        "paged_bucket_16": cache_lib.admission_write_bytes(
            cfg, pool_p.max_seq, 16, paged=True, block_size=block_size
        ),
        "paged_bucket_32": cache_lib.admission_write_bytes(
            cfg, pool_p.max_seq, 32, paged=True, block_size=block_size
        ),
    }
    assert admission["contiguous_any_bucket"] == cache_lib.cache_bytes(
        cfg, 1, pool_c.max_seq
    )
    assert (admission["paged_bucket_8"] < admission["paged_bucket_16"]
            < admission["paged_bucket_32"]
            <= admission["contiguous_any_bucket"])

    ratio = results["paged"]["sustained_in_flight"] / max(
        results["contiguous"]["sustained_in_flight"], 1e-9
    )
    return {
        "bench": "serving_paged",
        "arch": arch,
        "n_requests": n_requests,
        "tokens": tokens,
        "block_size": block_size,
        "loss_rate": loss_rate,
        "channel": channel,
        "backend": jax.default_backend(),
        "equal_hbm_bytes": {"contiguous": contig_hbm, "paged": paged_hbm},
        "admission_write_bytes": admission,
        "contiguous": results["contiguous"],
        "paged": results["paged"],
        "sustained_ratio": ratio,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=sorted(ARCHITECTURES))
    ap.add_argument("--clients", type=int, default=24)
    ap.add_argument("--rate", type=float, default=1.0)
    ap.add_argument("--duration", type=float, default=1.0)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--loss-rate", type=float, default=0.1)
    ap.add_argument("--channel", default="iid",
                    choices=["iid", "ge", "gilbert_elliott", "fading"])
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument(
        "--smoke", action="store_true",
        help="reduced CPU preset: 3 prompt lengths (3 buckets), 8 tokens",
    )
    ap.add_argument(
        "--paged", action="store_true",
        help="density mode: contiguous vs paged block pool at equal cache "
             "HBM (writes BENCH_serving_paged.json by default)",
    )
    ap.add_argument(
        "--assert-min-sustained-ratio", type=float, default=None,
        help="[--paged] fail unless paged sustains >= RATIO x the "
             "contiguous engine's median in-flight requests",
    )
    ap.add_argument("--out", default=None)
    ap.add_argument("--assert-max-compiles", type=int, default=None,
                    help="fail if the engine built more XLA programs than this")
    ap.add_argument("--assert-zero-steady-compiles", action="store_true")
    ap.add_argument("--assert-min-rps", type=float, default=None)
    ap.add_argument("--assert-min-speedup", type=float, default=None)
    ap.add_argument(
        "--obs-dir", default=None,
        help="enable the obs registry and write obs_events.jsonl / "
             "obs_metrics.prom / obs_trace.json artifacts here",
    )
    ap.add_argument(
        "--profile-dir", default=None,
        help="wrap the run in jax.profiler.trace (TensorBoard dump)",
    )
    ap.add_argument(
        "--assert-obs-span-chain", action="store_true",
        help="fail unless >= 1 request has a complete submit->retire "
             "span chain in the obs event log (implies --obs-dir)",
    )
    ap.add_argument(
        "--assert-obs-drop-rate", action="store_true",
        help="fail unless the engine's realized on-device drop rate is > 0",
    )
    args = ap.parse_args()
    if args.out is None:
        args.out = "BENCH_serving_paged.json" if args.paged else \
            "BENCH_serving.json"

    if args.obs_dir or args.assert_obs_span_chain:
        obs.enable()
    if args.obs_dir:
        import os

        os.makedirs(args.obs_dir, exist_ok=True)

    if args.paged:
        result = run_paged_bench(
            arch=args.arch,
            n_requests=args.clients,
            tokens=8 if args.smoke else args.tokens,
            loss_rate=args.loss_rate,
            channel=args.channel,
            full_size=args.full_size,
        )
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
        c, p = result["contiguous"], result["paged"]
        logger.info(
            f"serving_bench --paged[{result['arch']} "
            f"reqs={result['n_requests']}]: equal-HBM "
            f"{result['equal_hbm_bytes']['paged'] / 1e6:.2f} MB — contiguous "
            f"sustains {c['sustained_in_flight']:.0f} in-flight "
            f"({c['max_slots']} slots), paged {p['sustained_in_flight']:.0f} "
            f"({p['max_slots']} slots, {p['pool_blocks_total']:.0f} blocks) "
            f"-> {result['sustained_ratio']:.1f}x density | admission copy "
            f"{result['admission_write_bytes']['contiguous_any_bucket']} B "
            f"-> {result['admission_write_bytes']['paged_bucket_8']} B "
            f"(bucket 8) | compiles {c['compiles']}/{p['compiles']} "
            f"-> {args.out}"
        )
        ok = True
        if args.assert_min_sustained_ratio is not None and \
                result["sustained_ratio"] < args.assert_min_sustained_ratio:
            logger.error(
                f"ASSERT FAILED: sustained ratio "
                f"{result['sustained_ratio']:.2f}x < "
                f"{args.assert_min_sustained_ratio}"
            )
            ok = False
        raise SystemExit(0 if ok else 1)

    kw = {}
    if args.smoke:
        kw = dict(lengths=(6, 12, 24), tokens=8, duration_s=0.5)
    with exporters.jax_profile(args.profile_dir):
        result = run_bench(
            arch=args.arch,
            n_clients=args.clients,
            rate_hz=args.rate,
            duration_s=kw.pop("duration_s", args.duration),
            tokens=kw.pop("tokens", args.tokens),
            max_slots=args.max_slots,
            loss_rate=args.loss_rate,
            channel=args.channel,
            full_size=args.full_size,
            **kw,
        )
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    eng, ref = result["engine"], result["whole_generation"]
    logger.info(
        f"serving_bench[{result['arch']} reqs={result['n_requests']} "
        f"buckets={result['buckets']}]: engine {eng['tokens_per_s']:.1f} tok/s "
        f"({eng['requests_per_s']:.1f} req/s, occ {eng['slot_occupancy']:.2f}, "
        f"compiles {eng['compiles_total']} = {eng['compiles_warmup']} warm-up "
        f"+ {eng['compiles_steady']} steady) | whole-gen "
        f"{ref['tokens_per_s']:.1f} tok/s warm / {ref['tokens_per_s_cold']:.1f} "
        f"cold ({ref['signatures_compiled']} signatures) | speedup "
        f"{result['speedup']:.1f}x warm, {result['speedup_vs_cold']:.1f}x cold "
        f"-> {args.out}"
    )

    if args.obs_dir:
        import os

        os.makedirs(args.obs_dir, exist_ok=True)
        reg = obs.registry()
        exporters.write_jsonl(reg, os.path.join(args.obs_dir, "obs_events.jsonl"))
        exporters.write_prometheus(
            reg, os.path.join(args.obs_dir, "obs_metrics.prom")
        )
        exporters.write_chrome_trace(
            reg, os.path.join(args.obs_dir, "obs_trace.json")
        )
        logger.info(f"obs artifacts -> {args.obs_dir}/")

    ok = True
    if args.assert_max_compiles is not None and \
            eng["compiles_total"] > args.assert_max_compiles:
        logger.error(f"ASSERT FAILED: {eng['compiles_total']} compiles > "
              f"{args.assert_max_compiles}")
        ok = False
    if args.assert_zero_steady_compiles and eng["compiles_steady"] != 0:
        logger.error(f"ASSERT FAILED: {eng['compiles_steady']} steady-state compiles")
        ok = False
    if args.assert_min_rps is not None and \
            eng["requests_per_s"] < args.assert_min_rps:
        logger.error(f"ASSERT FAILED: {eng['requests_per_s']:.2f} req/s < "
              f"{args.assert_min_rps}")
        ok = False
    if args.assert_min_speedup is not None and \
            result["speedup"] < args.assert_min_speedup:
        logger.error(f"ASSERT FAILED: speedup {result['speedup']:.2f}x < "
              f"{args.assert_min_speedup}")
        ok = False
    if args.assert_obs_span_chain:
        chains = exporters.request_chain_rids(obs.registry())
        if not chains:
            logger.error("ASSERT FAILED: no complete submit->retire span chain")
            ok = False
        else:
            logger.info(f"obs span chains: {len(chains)} complete requests")
    if args.assert_obs_drop_rate:
        rate = result["engine"]["device"]["realized_drop_rate"]
        if not rate > 0.0:
            logger.error(
                f"ASSERT FAILED: realized on-device drop rate {rate} not > 0"
            )
            ok = False
        else:
            logger.info(f"realized on-device drop rate: {rate:.4f}")
    raise SystemExit(0 if ok else 1)


def run_bench_entry():  # console-script style alias
    main()


if __name__ == "__main__":
    main()
