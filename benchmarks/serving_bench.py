"""Serving benchmark: continuous-batching slot pool vs whole-generation engine.

Builds a mixed-length Poisson workload (``--clients`` Poisson processes,
prompt lengths spread over >= 3 power-of-two buckets), replays it in
arrival order through

* the **continuous engine** (``repro.serve.continuous``): slot-pooled,
  bucketed prefill, one fused decode step — after the per-bucket warm-up
  the whole run executes with ZERO new XLA builds (AOT ``Compiled``
  programs cannot retrace; ``engine.compiles`` proves it), and
* the **whole-generation engine** (``repro.serve.DecodeEngine``) serving
  each request at its exact (prompt_len, num_tokens) signature, batch 1 —
  the recompile-storm baseline: one AOT build per distinct signature,
  then sequential per-request execution.

Emits ``BENCH_serving.json`` with sustained tokens/s, request completion
p50/p99 under saturated replay, total/steady-state compile counts, slot
occupancy, and the old-engine baseline (warm and cold).

    PYTHONPATH=src python -m benchmarks.serving_bench [--smoke] \
        [--out BENCH_serving.json] [--assert-max-compiles N] \
        [--assert-zero-steady-compiles] [--assert-min-rps 1.0] \
        [--assert-min-speedup 2.0]

``--paged`` switches to the density comparison instead: a contiguous slot
pool vs a PAGED block pool holding no more cache HBM, replaying one
saturated workload through both.  The contiguous engine can only hold as
many requests as worst-case ``max_seq`` slots fit; the paged engine
reserves per-request blocks, so the same bytes sustain several times the
in-flight requests (``active_median`` per decode step) and admission
writes scale with the prompt's bucket instead of ``max_seq``.  Emits
``BENCH_serving_paged.json``; greedy outputs are cross-checked
token-for-token between the two engines, and both keep
``compiles == num_buckets + 1``.

    PYTHONPATH=src python -m benchmarks.serving_bench --paged \
        [--assert-min-sustained-ratio 2.0] [--out BENCH_serving_paged.json]

``--sla`` is the SLA/chaos headline: a mixed-class Poisson workload
(interactive / standard / batch priorities with per-class deadlines) in
**virtual time** (a ``VirtualClock`` advanced a fixed ``dt`` per engine
step, so deadline hit-rates are deterministic and CI-gateable), with a
mid-run ``channel_collapse`` killing uplinks and a ``block_pool_squeeze``
starving the paged pool — run twice through the SAME engine shape, once
FIFO (no scheduler) and once under ``SLAScheduler`` (EDF-within-priority,
preemption, expiry, bounded retry).  Emits ``BENCH_serving_sla.json``
with per-class p50/p99 and deadline-hit-rate for both arms; the CI gate
asserts every submitted request resolves terminally and the scheduled
high-priority hit-rate beats the unscheduled one.

    PYTHONPATH=src python -m benchmarks.serving_bench --sla \
        [--assert-all-terminal] [--assert-min-hi-hit-rate 0.6] \
        [--assert-scheduled-beats-unscheduled] [--out BENCH_serving_sla.json]

``--sharded-serve`` is the mesh-scaling comparison: the same saturated
mixed-length replay through ONE slot pool vs the sharded router
(``repro.serve.router``) with an identically sized pool per device —
token outputs are cross-checked identical between the arms, every shard
must hold ``compiles == num_buckets + 1``, and a second phase runs the
mixed-SLA virtual-time workload through the router-fronted scheduler.
Emits ``BENCH_serving_sharded.json``; the aggregate-throughput gate
needs real parallel devices (CI forces 4 with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``).

    XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
        python -m benchmarks.serving_bench --sharded-serve \
        [--num-shards N] [--assert-min-sharded-speedup 1.8] \
        [--out BENCH_serving_sharded.json]
"""

from __future__ import annotations

import argparse
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.analysis.guards import no_recompile
from repro.configs import ARCHITECTURES, get_config
from repro.core import link as link_lib
from repro.models import cache as cache_lib, lm
from repro.net.chaos import (
    ChaosSchedule,
    EngineChaos,
    _OverrideChannel,
    block_pool_squeeze,
    channel_collapse,
)
from repro.net.channels import make_channel
from repro.net.protocol import make_protocol
from repro.obs import exporters
from repro.obs.stats import latency_summary
from repro.serve import (
    SLA,
    ContinuousEngine,
    DecodeEngine,
    PoolConfig,
    PoolExhausted,
    ShardedEngine,
    SLAScheduler,
    VirtualClock,
)

logger = obs.get_logger("serving_bench")


def build_workload(
    n_clients: int,
    rate_hz: float,
    duration_s: float,
    lengths,
    vocab: int,
    seed: int = 0,
    min_requests: int = 8,
):
    """Poisson arrivals per client, merged and sorted; each request gets a
    prompt whose length cycles through ``lengths`` (>= 3 buckets)."""
    rng = np.random.RandomState(seed)
    arrivals = []
    for c in range(n_clients):
        t = rng.exponential(1.0 / rate_hz)
        while t < duration_s:
            arrivals.append((t, c))
            t += rng.exponential(1.0 / rate_hz)
    arrivals.sort()
    while len(arrivals) < min_requests:          # tiny-duration safety net
        arrivals.append((duration_s, len(arrivals) % n_clients))
    prompts = []
    for i, (t, c) in enumerate(arrivals):
        L = int(lengths[i % len(lengths)])
        prompts.append(rng.randint(0, vocab, size=(L,)).astype(np.int32))
    return arrivals, prompts


def run_bench(
    arch: str = "qwen1.5-0.5b",
    n_clients: int = 24,
    rate_hz: float = 1.0,
    duration_s: float = 1.0,
    lengths=(5, 7, 11, 14, 22, 28),
    tokens: int = 16,
    max_slots: int = 8,
    loss_rate: float = 0.1,
    channel: str = "iid",
    seed: int = 0,
    full_size: bool = False,
) -> dict:
    import dataclasses

    cfg = get_config(arch)
    if not full_size:
        cfg = cfg.reduced()
    cfg = cfg.with_updates(
        link=dataclasses.replace(cfg.link, loss_rate=loss_rate, channel=channel)
    )
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    arrivals, prompts = build_workload(
        n_clients, rate_hz, duration_s, lengths, cfg.vocab_size, seed=seed
    )
    n_req = len(prompts)
    base_key = jax.random.PRNGKey(seed)

    # ---- continuous engine -------------------------------------------------
    pool = PoolConfig(
        max_slots=max_slots,
        max_new=max(16, tokens),
        max_prompt=max(int(max(lengths)), 8),
    )
    eng = ContinuousEngine(cfg, pool)
    buckets = sorted({eng.bucket_for(len(p)) for p in prompts})

    # Warm-up: one throwaway request per bucket compiles every program the
    # workload can touch (num_buckets prefills + 1 decode step).
    for i, b in enumerate(buckets):
        p = next(p for p in prompts if eng.bucket_for(len(p)) == b)
        eng.submit(p, 1, key=jax.random.fold_in(base_key, 10_000 + i))
    eng.run(params)
    warm_compiles = eng.compiles
    warm_compile_s = eng.compile_s

    t0 = time.perf_counter()
    # The steady-state contract, enforced at runtime: the warmed replay
    # performs zero new XLA builds (guard watches jax.monitoring AND
    # eng.compiles; a violation raises instead of silently skewing stats).
    with no_recompile(engines=(eng,)):
        reqs = [
            eng.submit(p, tokens, key=jax.random.fold_in(base_key, i))
            for i, p in enumerate(prompts)
        ]
        eng.run(params)
    t_eng = time.perf_counter() - t0
    completion = [r.t_done - t0 for r in reqs]
    eng_stats = {
        "tokens_per_s": n_req * tokens / t_eng,
        "requests_per_s": n_req / t_eng,
        "wall_s": t_eng,
        "compiles_total": eng.compiles,
        "compiles_warmup": warm_compiles,
        "compiles_steady": eng.compiles - warm_compiles,
        "compile_s": eng.compile_s,
        "num_buckets": eng.num_buckets,
        "traces": eng.traces,
        "slot_occupancy": eng.stats()["slot_occupancy"],
        "max_slots": max_slots,
        **latency_summary(completion),
        "device": eng.device_counters(),
        **{f"request_{k}": v for k, v in eng.request_stats().items()},
    }
    eng.publish_device_counters()

    # ---- whole-generation baseline ----------------------------------------
    # Each request served at its exact signature, batch 1 — under the mixed
    # workload that is one AOT build per distinct (prompt_len, tokens).
    old = DecodeEngine()
    t0 = time.perf_counter()
    for i, p in enumerate(prompts):          # cold pass: the recompile storm
        old.generate(params, cfg, jnp.asarray(p)[None], tokens,
                     key=jax.random.fold_in(base_key, i))
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    done_at = []
    for i, p in enumerate(prompts):          # warm pass: steady-state
        old.generate(params, cfg, jnp.asarray(p)[None], tokens,
                     key=jax.random.fold_in(base_key, i))
        done_at.append(time.perf_counter() - t0)
    t_warm = time.perf_counter() - t0
    ref_stats = {
        "tokens_per_s": n_req * tokens / t_warm,
        "tokens_per_s_cold": n_req * tokens / t_cold,
        "wall_s": t_warm,
        "wall_s_cold": t_cold,
        "signatures_compiled": old.num_compiled,
        "compile_s": sum(e.compile_s for e in old._compiled.values()),
        **latency_summary(done_at),
    }

    return {
        "bench": "serving",
        "arch": arch,
        "n_clients": n_clients,
        "rate_hz": rate_hz,
        "n_requests": n_req,
        "tokens": tokens,
        "prompt_lengths": sorted(set(int(len(p)) for p in prompts)),
        "buckets": [int(b) for b in buckets],
        "loss_rate": loss_rate,
        "channel": channel,
        "backend": jax.default_backend(),
        "engine": eng_stats,
        "whole_generation": ref_stats,
        "speedup": eng_stats["tokens_per_s"] / max(ref_stats["tokens_per_s"], 1e-9),
        "speedup_vs_cold": eng_stats["tokens_per_s"]
        / max(ref_stats["tokens_per_s_cold"], 1e-9),
    }


def _replay(eng, params, prompts, tokens, base_key):
    """Warm the engine's programs on one throwaway request per bucket,
    then replay the saturated workload under the no-recompile guard.
    Returns (requests, wall_s) with the concurrency window reset so
    ``active_median`` measures the replay only."""
    buckets = sorted({eng.bucket_for(len(p)) for p in prompts})
    for i, b in enumerate(buckets):
        p = next(p for p in prompts if eng.bucket_for(len(p)) == b)
        eng.submit(p, 1, key=jax.random.fold_in(base_key, 10_000 + i))
    eng.run(params)
    eng.active_per_step.clear()
    t0 = time.perf_counter()
    with no_recompile(engines=(eng,)):
        reqs = [
            eng.submit(p, tokens, key=jax.random.fold_in(base_key, i))
            for i, p in enumerate(prompts)
        ]
        eng.run(params)
    return reqs, time.perf_counter() - t0


def run_paged_bench(
    arch: str = "qwen1.5-0.5b",
    n_requests: int = 24,
    tokens: int = 8,
    loss_rate: float = 0.1,
    channel: str = "iid",
    seed: int = 0,
    full_size: bool = False,
) -> dict:
    """Contiguous slot pool vs paged block pool at equal (or less) cache
    HBM, one saturated replay each.  The contiguous pool's HBM budget
    (``max_slots`` worst-case ``max_seq`` caches) is converted into pool
    blocks; short requests then reserve only their own blocks, so the
    paged engine keeps several times the requests in flight per step."""
    import dataclasses

    cfg = get_config(arch)
    if not full_size:
        cfg = cfg.reduced()
    cfg = cfg.with_updates(
        link=dataclasses.replace(cfg.link, loss_rate=loss_rate, channel=channel)
    )
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    base_key = jax.random.PRNGKey(seed)

    # Contiguous baseline: 2 worst-case slots.
    pool_c = PoolConfig(max_slots=2, max_new=32, max_prompt=24)
    contig_hbm = cache_lib.cache_bytes(cfg, pool_c.max_slots, pool_c.max_seq)
    # Paged pool holding AT MOST the same bytes: block_pool_bytes is
    # linear in num_blocks with zero intercept, so size by the per-block
    # cost (block 0, the trash block, pays for itself out of the budget).
    block_size = 8
    per_block = cache_lib.block_pool_bytes(cfg, 3, block_size) \
        - cache_lib.block_pool_bytes(cfg, 2, block_size)
    num_blocks = contig_hbm // per_block
    pool_p = PoolConfig(
        max_slots=8, max_new=32, max_prompt=24,
        paged=True, block_size=block_size, num_blocks=int(num_blocks),
    )
    paged_hbm = cache_lib.block_pool_bytes(cfg, pool_p.total_blocks, block_size)
    assert paged_hbm <= contig_hbm, (paged_hbm, contig_hbm)

    # Saturated workload: everything submitted up front.  Short prompts
    # (one power-of-two bucket) keep the reservation arithmetic visible —
    # each request needs ceil(max(8, len+tokens) / 8) blocks vs a whole
    # contiguous max_seq slot.
    rng = np.random.RandomState(seed)
    prompts = [
        rng.randint(0, cfg.vocab_size, size=(int(3 + i % 4),)).astype(np.int32)
        for i in range(n_requests)
    ]

    results = {}
    engines = {}
    for name, pool in (("contiguous", pool_c), ("paged", pool_p)):
        eng = ContinuousEngine(cfg, pool)
        reqs, wall = _replay(eng, params, prompts, tokens, base_key)
        s = eng.stats()
        results[name] = {
            "wall_s": wall,
            "tokens_per_s": n_requests * tokens / wall,
            "max_slots": pool.max_slots,
            "cache_hbm_bytes": contig_hbm if name == "contiguous" else paged_hbm,
            "sustained_in_flight": s["active_median"],
            "active_peak": s["active_peak"],
            "active_mean": s["active_mean"],
            "compiles": eng.compiles,
            "num_buckets": eng.num_buckets,
            **{k: s[k] for k in
               ("pool_blocks_total", "peak_blocks_used", "blocks_written")
               if k in s},
        }
        engines[name] = (eng, reqs)
        assert eng.compiles == eng.num_buckets + 1, (
            name, eng.compiles, eng.num_buckets
        )

    # Same request keys through both engines -> identical greedy tokens
    # (each engine is separately pinned to generate_reference in tests;
    # the cross-check here keeps the bench honest end-to-end).
    for rc, rp in zip(engines["contiguous"][1], engines["paged"][1]):
        np.testing.assert_array_equal(rc.tokens, rp.tokens)

    # Admission-copy bytes: the paged write scales with the bucket, the
    # contiguous write is a constant full slot.
    admission = {
        "contiguous_any_bucket": cache_lib.admission_write_bytes(
            cfg, pool_c.max_seq, pool_c.max_bucket
        ),
        "paged_bucket_8": cache_lib.admission_write_bytes(
            cfg, pool_p.max_seq, 8, paged=True, block_size=block_size
        ),
        "paged_bucket_16": cache_lib.admission_write_bytes(
            cfg, pool_p.max_seq, 16, paged=True, block_size=block_size
        ),
        "paged_bucket_32": cache_lib.admission_write_bytes(
            cfg, pool_p.max_seq, 32, paged=True, block_size=block_size
        ),
    }
    assert admission["contiguous_any_bucket"] == cache_lib.cache_bytes(
        cfg, 1, pool_c.max_seq
    )
    assert (admission["paged_bucket_8"] < admission["paged_bucket_16"]
            < admission["paged_bucket_32"]
            <= admission["contiguous_any_bucket"])

    ratio = results["paged"]["sustained_in_flight"] / max(
        results["contiguous"]["sustained_in_flight"], 1e-9
    )
    return {
        "bench": "serving_paged",
        "arch": arch,
        "n_requests": n_requests,
        "tokens": tokens,
        "block_size": block_size,
        "loss_rate": loss_rate,
        "channel": channel,
        "backend": jax.default_backend(),
        "equal_hbm_bytes": {"contiguous": contig_hbm, "paged": paged_hbm},
        "admission_write_bytes": admission,
        "contiguous": results["contiguous"],
        "paged": results["paged"],
        "sustained_ratio": ratio,
    }


# ---------------------------------------------------------------------------
# --sla mode: mixed-SLA chaos workload, scheduled vs FIFO, in virtual time
# ---------------------------------------------------------------------------

# Class mix cycles i % 3 → interactive / standard / batch.  Deadlines are
# VIRTUAL seconds (the driver advances the clock dt_step per engine step,
# so "one decode step" is the time unit scaled by dt_step — deterministic
# on any machine) expressed as multiples of the nominal unqueued service
# time ((tokens + 1 steps) * dt_step): 2x for interactive (meetable only
# with immediate admission), 5x for standard, best-effort for batch.
_SLA_CLASS_NAMES = ("interactive", "standard", "batch")


def sla_classes(tokens: int, dt_step: float):
    service_s = (tokens + 1) * dt_step
    return (
        ("interactive", 2, 2.0 * service_s),
        ("standard", 1, 5.0 * service_s),
        ("batch", 0, math.inf),
    )


def build_sla_workload(
    n_requests: int,
    span_s: float,
    chaos: ChaosSchedule,
    vocab: int,
    classes,
    seed: int = 0,
    n_packets: int = 12,
):
    """Poisson arrivals in virtual time, each crossing a lossy ARQ uplink
    BEFORE reaching the engine.  A ``channel_collapse`` window overrides
    the uplink loss (the real channel's burst state is not advanced —
    same semantics as ``net.simulator``): requests arriving inside a
    total collapse exhaust the ARQ budget and are dropped at the uplink,
    never submitted.  Returns per-request dicts shared by both arms."""
    rng = np.random.RandomState(seed)
    rate = n_requests / span_s
    t, arrivals = 0.0, []
    while len(arrivals) < n_requests:
        t += rng.exponential(1.0 / rate)
        arrivals.append(t)
    protocol = make_protocol("arq", max_rounds=4)
    channel = make_channel("ge", loss_rate=0.1)
    ch_state = channel.init_state(rng)
    slot_t = link_lib.ChannelConfig().slot_time_s()
    items = []
    for i, t in enumerate(arrivals):
        name, pri, deadline = classes[i % len(classes)]
        override = chaos.loss_override(t)
        if override is None:
            result, ch_state = protocol.run_round(
                rng, channel, ch_state, n_packets
            )
        else:
            result, _ = protocol.run_round(
                rng, _OverrideChannel(override), None, n_packets
            )
        length = int(4 + i % 4)          # one power-of-two bucket (8)
        items.append({
            "idx": i,
            "cls": name,
            "sla": SLA(deadline_s=deadline, priority=pri, class_name=name),
            "deadline_s": deadline,
            "prompt": rng.randint(0, vocab, size=(length,)).astype(np.int32),
            "vt": t + result.slots * slot_t,       # uplink latency shifts it
            "dropped": result.delivered_fraction < 0.2,
        })
    return items


def _drive_sla_arm(
    cfg, params, pool: PoolConfig, items, chaos: ChaosSchedule,
    tokens: int, dt_step: float, base_key, scheduled: bool,
    make_engine=None,
):
    """One virtual-time replay: submit arrivals as the clock passes them,
    one engine step + one ``dt_step`` advance per iteration, chaos applied
    at each step's virtual now.  Returns (per-item bookkeeping, engine,
    scheduler).  ``make_engine`` swaps the engine under the same driver —
    the sharded mode passes a ``ShardedEngine`` factory so the identical
    workload runs through the router-fronted scheduler."""
    items = [dict(it) for it in sorted(items, key=lambda it: it["vt"])]
    eng = make_engine() if make_engine else ContinuousEngine(cfg, pool)
    clock = VirtualClock()
    sched = None
    if scheduled:
        sched = SLAScheduler(
            clock=clock, backoff_s=dt_step, backoff_cap_s=4 * dt_step,
            max_retries=256,
        )
        eng.attach_scheduler(sched)
    # Warm every bucket + the decode step before the guarded replay.  The
    # router warms EVERY shard through its admit-and-preempt warm();
    # the single engine warms through one throwaway request per bucket
    # (trivially admissible regardless of pool size).
    if hasattr(eng, "warm"):
        eng.warm(params, [len(it["prompt"]) for it in items])
    else:
        for i, b in enumerate(sorted(
                {eng.bucket_for(len(it["prompt"])) for it in items})):
            p = next(it["prompt"] for it in items
                     if eng.bucket_for(len(it["prompt"])) == b)
            eng.submit(p, 1, key=jax.random.fold_in(base_key, 50_000 + i))
            eng.run(params)
    echaos = EngineChaos(eng, chaos)
    i = 0
    exhausted = 0
    submitted = []
    with no_recompile(engines=(eng, *getattr(eng, "shards", ()))):
        for _ in range(200_000):
            now = clock.now
            echaos.apply(now)
            while i < len(items) and items[i]["vt"] <= now:
                it = items[i]
                i += 1
                if it["dropped"]:
                    continue
                it["req"] = eng.submit(
                    it["prompt"], tokens,
                    key=jax.random.fold_in(base_key, it["idx"]),
                    sla=it["sla"] if scheduled else None,
                )
                submitted.append(it)
            try:
                eng.step(params)
            except PoolExhausted:
                # Unscheduled backpressure: nothing to shed here — the
                # squeeze window eventually closes; count and carry on.
                exhausted += 1
            clock.advance(dt_step)
            for it in submitted:
                if "vt_done" not in it and it["req"].terminal:
                    it["vt_done"] = clock.now
            idle = not eng.active and not eng._queue and not (
                sched is not None and sched.pending
            )
            if idle and i >= len(items):
                break
            if idle and items[i]["vt"] > clock.now:
                clock.now = items[i]["vt"]       # idle skip-ahead
        else:
            raise RuntimeError("sla bench driver did not drain")
    eng.harvest()
    return items, eng, sched, exhausted


def _sla_class_summary(items, tokens_deadline_from="vt"):
    """Per-class served/completed/hit accounting from the driver's own
    virtual-time bookkeeping (identical metric for both arms)."""
    out = {}
    for name in _SLA_CLASS_NAMES:
        rows = [it for it in items if it["cls"] == name]
        served = [it for it in rows if not it["dropped"]]
        completed = [
            it for it in served if it.get("req") is not None
            and it["req"].state == "completed"
        ]
        hits = [
            it for it in completed
            if it["vt_done"] <= it["vt"] + it["deadline_s"]
        ]
        lat = sorted(it["vt_done"] - it["vt"] for it in completed)
        out[name] = {
            "submitted": len(rows),
            "uplink_dropped": sum(it["dropped"] for it in rows),
            "served": len(served),
            "completed": len(completed),
            "expired": sum(
                it.get("req") is not None and it["req"].state == "expired"
                for it in served
            ),
            "rejected": sum(
                it.get("req") is not None and it["req"].state == "rejected"
                for it in served
            ),
            "deadline_hit_rate": len(hits) / len(served) if served else 1.0,
            "latency_p50_vs": lat[len(lat) // 2] if lat else None,
            "latency_p99_vs": lat[min(len(lat) - 1,
                                      int(0.99 * len(lat)))] if lat else None,
        }
    return out


def run_sla_bench(
    arch: str = "qwen1.5-0.5b",
    n_requests: int = 30,
    tokens: int = 6,
    span_s: float = 20.0,
    dt_step: float = 0.25,
    seed: int = 0,
    full_size: bool = False,
) -> dict:
    """Scheduled vs FIFO under chaos, same workload, same engine shape.

    The pool is deliberately tight (2 slots, derived block pool) and the
    offered load exceeds its service rate, so queueing is real; mid-run a
    total channel collapse kills uplinks and a 60% block squeeze starves
    the allocator.  FIFO head-of-line makes interactive requests wait
    behind batch ones; the scheduler preempts/expires instead."""
    import dataclasses

    cfg = get_config(arch)
    if not full_size:
        cfg = cfg.reduced()
    cfg = cfg.with_updates(
        link=dataclasses.replace(cfg.link, loss_rate=0.1, channel="ge"),
        attn_impl="flash_decode",
    )
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    base_key = jax.random.PRNGKey(seed)
    chaos = ChaosSchedule([
        channel_collapse(0.40 * span_s, 0.60 * span_s, loss_rate=1.0),
        block_pool_squeeze(0.30 * span_s, 0.70 * span_s, fraction=0.6),
    ])
    items = build_sla_workload(
        n_requests, span_s, chaos, cfg.vocab_size,
        sla_classes(tokens, dt_step), seed=seed,
    )
    pool = PoolConfig(
        max_slots=2, max_new=max(8, tokens), max_prompt=8, min_bucket=8,
        paged=True, block_size=4, exhaust_wait_steps=64,
    )
    arms = {}
    for name, scheduled in (("unscheduled", False), ("scheduled", True)):
        booked, eng, sched, exhausted = _drive_sla_arm(
            cfg, params, pool, items, chaos, tokens, dt_step, base_key,
            scheduled,
        )
        served = [it for it in booked if not it["dropped"]]
        arms[name] = {
            "classes": _sla_class_summary(booked),
            "pool_exhausted_signals": exhausted,
            "all_terminal": all(it["req"].terminal for it in served),
            "compiles": eng.compiles,
            "num_buckets": eng.num_buckets,
            "preemptions": sched.stats["preemptions"] if sched else 0,
            "resumes": sched.stats["resumes"] if sched else 0,
            "expired": sched.stats["expired"] if sched else 0,
            "rejected": sched.stats["rejected"] if sched else 0,
            "scheduler_class_report": sched.class_report() if sched else None,
        }
        assert eng.compiles == eng.num_buckets + 1, (
            name, eng.compiles, eng.num_buckets
        )
    hi = "interactive"
    return {
        "bench": "serving_sla",
        "arch": arch,
        "n_requests": n_requests,
        "tokens": tokens,
        "span_virtual_s": span_s,
        "dt_step_virtual_s": dt_step,
        "backend": jax.default_backend(),
        "chaos": [dataclasses.asdict(f) for f in chaos.faults],
        "uplink_dropped": sum(it["dropped"] for it in items),
        "unscheduled": arms["unscheduled"],
        "scheduled": arms["scheduled"],
        "hi_class": hi,
        "hi_hit_rate_unscheduled":
            arms["unscheduled"]["classes"][hi]["deadline_hit_rate"],
        "hi_hit_rate_scheduled":
            arms["scheduled"]["classes"][hi]["deadline_hit_rate"],
        "all_terminal": (arms["unscheduled"]["all_terminal"]
                         and arms["scheduled"]["all_terminal"]),
    }


# ---------------------------------------------------------------------------
# --sharded-serve mode: one logical slot pool over the host mesh
# ---------------------------------------------------------------------------


def run_sharded_bench(
    arch: str = "qwen1.5-0.5b",
    n_requests: int = 24,
    tokens: int = 8,
    lengths=(5, 7, 11, 14),
    loss_rate: float = 0.1,
    channel: str = "ge",
    seed: int = 0,
    full_size: bool = False,
    num_shards: int = 0,
    span_s: float = 12.0,
    dt_step: float = 0.25,
) -> dict:
    """Single slot pool vs the sharded router at EQUAL per-shard pool
    size, plus a mixed-SLA Poisson workload through the router-fronted
    scheduler.

    Phase 1 (throughput): a saturated mixed-length replay through (a) one
    ``ContinuousEngine`` and (b) a ``ShardedEngine`` with one identically
    sized pool per device — same request keys, so the two arms must emit
    IDENTICAL greedy tokens (cross-checked), and each shard must hold the
    engine's compile contract (``compiles == num_buckets + 1``; the
    replay itself runs under ``no_recompile``).  The aggregate-throughput
    gate (``--assert-min-sharded-speedup``) needs real parallel devices —
    CI forces them with ``--xla_force_host_platform_device_count``.

    Phase 2 (SLA through the router): the ``--sla`` driver's virtual-time
    Poisson workload (interactive / standard / batch classes), scheduler
    attached to the ROUTER — per-class p50/p99 and deadline hit-rates
    come out of the identical bookkeeping as the single-engine SLA bench.
    """
    import dataclasses

    from repro.launch.mesh import host_devices

    cfg = get_config(arch)
    if not full_size:
        cfg = cfg.reduced()
    cfg = cfg.with_updates(
        link=dataclasses.replace(cfg.link, loss_rate=loss_rate,
                                 channel=channel),
        attn_impl="flash_decode",
    )
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    base_key = jax.random.PRNGKey(seed)
    devices = host_devices()
    if num_shards:
        devices = [devices[i % len(devices)] for i in range(num_shards)]

    rng = np.random.RandomState(seed)
    prompts = [
        rng.randint(0, cfg.vocab_size,
                    size=(int(lengths[i % len(lengths)]),)).astype(np.int32)
        for i in range(n_requests)
    ]
    pool = PoolConfig(
        max_slots=4, max_new=max(8, tokens),
        max_prompt=max(int(max(lengths)), 8),
    )

    # ---- single-pool arm (equal per-shard size) ---------------------------
    single = ContinuousEngine(cfg, pool)
    reqs_single, wall_single = _replay(single, params, prompts, tokens,
                                       base_key)
    assert single.compiles == single.num_buckets + 1, (
        single.compiles, single.num_buckets
    )

    # ---- sharded arm ------------------------------------------------------
    sharded = ShardedEngine(cfg, pool, devices=devices)
    sharded.warm(params, [len(p) for p in prompts])
    t0 = time.perf_counter()
    with no_recompile(engines=(sharded, *sharded.shards)):
        reqs_sharded = [
            sharded.submit(p, tokens, key=jax.random.fold_in(base_key, i))
            for i, p in enumerate(prompts)
        ]
        sharded.run(params)
    wall_sharded = time.perf_counter() - t0
    for i, sh in enumerate(sharded.shards):
        assert sh.compiles == sh.num_buckets + 1, (
            i, sh.compiles, sh.num_buckets
        )
    # Same keys -> placement-invariant greedy outputs: the router must
    # emit exactly the single pool's tokens, whatever shard served each.
    for rs, rr in zip(reqs_single, reqs_sharded):
        np.testing.assert_array_equal(rs.tokens, rr.tokens)

    tps_single = n_requests * tokens / wall_single
    tps_sharded = n_requests * tokens / wall_sharded
    shard_stats = sharded.stats()

    # ---- SLA workload through the router-fronted scheduler ----------------
    chaos = ChaosSchedule([])
    items = build_sla_workload(
        n_requests, span_s, chaos, cfg.vocab_size,
        sla_classes(tokens, dt_step), seed=seed,
    )
    pool_sla = PoolConfig(
        max_slots=2, max_new=max(8, tokens), max_prompt=8, min_bucket=8,
        paged=True, block_size=4, exhaust_wait_steps=64,
    )
    booked, eng_sla, sched, _ = _drive_sla_arm(
        cfg, params, pool_sla, items, chaos, tokens, dt_step, base_key,
        scheduled=True,
        make_engine=lambda: ShardedEngine(cfg, pool_sla, devices=devices),
    )
    served = [it for it in booked if not it["dropped"]]
    for i, sh in enumerate(eng_sla.shards):
        assert sh.compiles == sh.num_buckets + 1, (
            i, sh.compiles, sh.num_buckets
        )

    return {
        "bench": "serving_sharded",
        "arch": arch,
        "n_requests": n_requests,
        "tokens": tokens,
        "num_shards": sharded.num_shards,
        "devices": [str(d) for d in devices],
        "prompt_lengths": sorted(set(int(len(p)) for p in prompts)),
        "loss_rate": loss_rate,
        "channel": channel,
        "backend": jax.default_backend(),
        "pool_per_shard": {
            "max_slots": pool.max_slots, "max_new": pool.max_new,
            "max_prompt": pool.max_prompt,
        },
        "single": {
            "tokens_per_s": tps_single,
            "wall_s": wall_single,
            "compiles": single.compiles,
            "num_buckets": single.num_buckets,
        },
        "sharded": {
            "tokens_per_s": tps_sharded,
            "wall_s": wall_sharded,
            "compiles_total": sharded.compiles,
            "per_shard": {
                f"shard{i}": {
                    "compiles": sh.compiles,
                    "num_buckets": sh.num_buckets,
                    "placements": sharded.placement_counts[i],
                }
                for i, sh in enumerate(sharded.shards)
            },
            **{k: v for k, v in shard_stats.items()
               if not k.startswith("shard")},
        },
        "sharded_speedup": tps_sharded / max(tps_single, 1e-9),
        "tokens_identical_across_arms": True,
        "sla_through_router": {
            "classes": _sla_class_summary(booked),
            "all_terminal": all(it["req"].terminal for it in served),
            "preemptions": sched.stats["preemptions"],
            "resumes": sched.stats["resumes"],
            "expired": sched.stats["expired"],
            "rejected": sched.stats["rejected"],
            "placements_per_shard": list(eng_sla.placement_counts),
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=sorted(ARCHITECTURES))
    ap.add_argument("--clients", type=int, default=24)
    ap.add_argument("--rate", type=float, default=1.0)
    ap.add_argument("--duration", type=float, default=1.0)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--loss-rate", type=float, default=0.1)
    ap.add_argument("--channel", default="iid",
                    choices=["iid", "ge", "gilbert_elliott", "fading"])
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument(
        "--smoke", action="store_true",
        help="reduced CPU preset: 3 prompt lengths (3 buckets), 8 tokens",
    )
    ap.add_argument(
        "--paged", action="store_true",
        help="density mode: contiguous vs paged block pool at equal cache "
             "HBM (writes BENCH_serving_paged.json by default)",
    )
    ap.add_argument(
        "--assert-min-sustained-ratio", type=float, default=None,
        help="[--paged] fail unless paged sustains >= RATIO x the "
             "contiguous engine's median in-flight requests",
    )
    ap.add_argument(
        "--sharded-serve", action="store_true",
        help="sharded-router mode: single pool vs one pool per device at "
             "equal per-shard size (cross-checked token-identical), plus "
             "the mixed-SLA workload through the router-fronted scheduler "
             "(writes BENCH_serving_sharded.json by default)",
    )
    ap.add_argument(
        "--num-shards", type=int, default=0,
        help="[--sharded-serve] shard count (0 = one per visible device; "
             "force devices with XLA_FLAGS="
             "--xla_force_host_platform_device_count=N)",
    )
    ap.add_argument(
        "--assert-min-sharded-speedup", type=float, default=None,
        help="[--sharded-serve] fail unless the sharded arm's aggregate "
             "tokens/s is >= RATIO x the single pool's (needs real "
             "parallel devices — a CI gate, meaningless on one core)",
    )
    ap.add_argument(
        "--sla", action="store_true",
        help="SLA/chaos mode: mixed-class virtual-time workload with a "
             "mid-run channel collapse + block squeeze, scheduled vs FIFO "
             "(writes BENCH_serving_sla.json by default)",
    )
    ap.add_argument("--span", type=float, default=20.0,
                    help="[--sla] virtual arrival span in seconds")
    ap.add_argument("--dt-step", type=float, default=0.25,
                    help="[--sla] virtual seconds per engine step")
    ap.add_argument(
        "--assert-all-terminal", action="store_true",
        help="[--sla] fail unless every served request resolves as "
             "completed|expired|rejected in BOTH arms",
    )
    ap.add_argument(
        "--assert-min-hi-hit-rate", type=float, default=None,
        help="[--sla] fail unless the scheduled arm's high-priority "
             "deadline-hit-rate is >= this floor",
    )
    ap.add_argument(
        "--assert-scheduled-beats-unscheduled", action="store_true",
        help="[--sla] fail unless the scheduled high-priority hit-rate "
             "strictly beats the unscheduled arm's",
    )
    ap.add_argument("--out", default=None)
    ap.add_argument("--assert-max-compiles", type=int, default=None,
                    help="fail if the engine built more XLA programs than this")
    ap.add_argument("--assert-zero-steady-compiles", action="store_true")
    ap.add_argument("--assert-min-rps", type=float, default=None)
    ap.add_argument("--assert-min-speedup", type=float, default=None)
    ap.add_argument(
        "--obs-dir", default=None,
        help="enable the obs registry and write obs_events.jsonl / "
             "obs_metrics.prom / obs_trace.json artifacts here",
    )
    ap.add_argument(
        "--profile-dir", default=None,
        help="wrap the run in jax.profiler.trace (TensorBoard dump)",
    )
    ap.add_argument(
        "--assert-obs-span-chain", action="store_true",
        help="fail unless >= 1 request has a complete submit->retire "
             "span chain in the obs event log (implies --obs-dir)",
    )
    ap.add_argument(
        "--assert-obs-drop-rate", action="store_true",
        help="fail unless the engine's realized on-device drop rate is > 0",
    )
    args = ap.parse_args()
    if args.out is None:
        args.out = (
            "BENCH_serving_sharded.json" if args.sharded_serve
            else "BENCH_serving_sla.json" if args.sla
            else "BENCH_serving_paged.json" if args.paged
            else "BENCH_serving.json"
        )

    if args.obs_dir or args.assert_obs_span_chain:
        obs.enable()
    if args.obs_dir:
        import os

        os.makedirs(args.obs_dir, exist_ok=True)

    if args.sharded_serve:
        result = run_sharded_bench(
            arch=args.arch,
            n_requests=args.clients,
            tokens=8 if args.smoke else args.tokens,
            full_size=args.full_size,
            num_shards=args.num_shards,
            span_s=args.span,
            dt_step=args.dt_step,
        )
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
        sh, sg = result["sharded"], result["single"]
        sla = result["sla_through_router"]
        logger.info(
            f"serving_bench --sharded-serve[{result['arch']} "
            f"reqs={result['n_requests']} shards={result['num_shards']}]: "
            f"single {sg['tokens_per_s']:.1f} tok/s "
            f"({sg['compiles']} compiles) -> sharded "
            f"{sh['tokens_per_s']:.1f} tok/s "
            f"({result['sharded_speedup']:.2f}x, per-shard compiles "
            + "/".join(str(v["compiles"])
                       for v in sh["per_shard"].values())
            + f") | SLA via router: preempt {sla['preemptions']}, "
            f"resume {sla['resumes']}, placements "
            f"{sla['placements_per_shard']} -> {args.out}"
        )
        ok = True
        if args.assert_min_sharded_speedup is not None and \
                result["sharded_speedup"] < args.assert_min_sharded_speedup:
            logger.error(
                f"ASSERT FAILED: sharded speedup "
                f"{result['sharded_speedup']:.2f}x < "
                f"{args.assert_min_sharded_speedup}"
            )
            ok = False
        if not result["sla_through_router"]["all_terminal"]:
            logger.error("ASSERT FAILED: some router-scheduled requests "
                         "never resolved terminally")
            ok = False
        raise SystemExit(0 if ok else 1)

    if args.sla:
        result = run_sla_bench(
            arch=args.arch,
            n_requests=args.clients,
            tokens=8 if args.smoke else args.tokens,
            span_s=args.span,
            dt_step=args.dt_step,
            full_size=args.full_size,
        )
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
        sc, un = result["scheduled"], result["unscheduled"]
        logger.info(
            f"serving_bench --sla[{result['arch']} "
            f"reqs={result['n_requests']}]: uplink dropped "
            f"{result['uplink_dropped']} in collapse | "
            f"{result['hi_class']} hit-rate FIFO "
            f"{result['hi_hit_rate_unscheduled']:.2f} -> scheduled "
            f"{result['hi_hit_rate_scheduled']:.2f} "
            f"(preempt {sc['preemptions']}, resume {sc['resumes']}, "
            f"expire {sc['expired']}, reject {sc['rejected']}; FIFO "
            f"PoolExhausted x{un['pool_exhausted_signals']}) | compiles "
            f"{un['compiles']}/{sc['compiles']} -> {args.out}"
        )
        ok = True
        if args.assert_all_terminal and not result["all_terminal"]:
            logger.error("ASSERT FAILED: some served requests never "
                         "resolved terminally")
            ok = False
        if args.assert_min_hi_hit_rate is not None and \
                result["hi_hit_rate_scheduled"] < args.assert_min_hi_hit_rate:
            logger.error(
                f"ASSERT FAILED: scheduled {result['hi_class']} hit-rate "
                f"{result['hi_hit_rate_scheduled']:.2f} < "
                f"{args.assert_min_hi_hit_rate}"
            )
            ok = False
        if args.assert_scheduled_beats_unscheduled and not (
                result["hi_hit_rate_scheduled"]
                > result["hi_hit_rate_unscheduled"]):
            logger.error(
                f"ASSERT FAILED: scheduled hit-rate "
                f"{result['hi_hit_rate_scheduled']:.2f} does not beat "
                f"unscheduled {result['hi_hit_rate_unscheduled']:.2f}"
            )
            ok = False
        raise SystemExit(0 if ok else 1)

    if args.paged:
        result = run_paged_bench(
            arch=args.arch,
            n_requests=args.clients,
            tokens=8 if args.smoke else args.tokens,
            loss_rate=args.loss_rate,
            channel=args.channel,
            full_size=args.full_size,
        )
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
        c, p = result["contiguous"], result["paged"]
        logger.info(
            f"serving_bench --paged[{result['arch']} "
            f"reqs={result['n_requests']}]: equal-HBM "
            f"{result['equal_hbm_bytes']['paged'] / 1e6:.2f} MB — contiguous "
            f"sustains {c['sustained_in_flight']:.0f} in-flight "
            f"({c['max_slots']} slots), paged {p['sustained_in_flight']:.0f} "
            f"({p['max_slots']} slots, {p['pool_blocks_total']:.0f} blocks) "
            f"-> {result['sustained_ratio']:.1f}x density | admission copy "
            f"{result['admission_write_bytes']['contiguous_any_bucket']} B "
            f"-> {result['admission_write_bytes']['paged_bucket_8']} B "
            f"(bucket 8) | compiles {c['compiles']}/{p['compiles']} "
            f"-> {args.out}"
        )
        ok = True
        if args.assert_min_sustained_ratio is not None and \
                result["sustained_ratio"] < args.assert_min_sustained_ratio:
            logger.error(
                f"ASSERT FAILED: sustained ratio "
                f"{result['sustained_ratio']:.2f}x < "
                f"{args.assert_min_sustained_ratio}"
            )
            ok = False
        raise SystemExit(0 if ok else 1)

    kw = {}
    if args.smoke:
        kw = dict(lengths=(6, 12, 24), tokens=8, duration_s=0.5)
    with exporters.jax_profile(args.profile_dir):
        result = run_bench(
            arch=args.arch,
            n_clients=args.clients,
            rate_hz=args.rate,
            duration_s=kw.pop("duration_s", args.duration),
            tokens=kw.pop("tokens", args.tokens),
            max_slots=args.max_slots,
            loss_rate=args.loss_rate,
            channel=args.channel,
            full_size=args.full_size,
            **kw,
        )
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    eng, ref = result["engine"], result["whole_generation"]
    logger.info(
        f"serving_bench[{result['arch']} reqs={result['n_requests']} "
        f"buckets={result['buckets']}]: engine {eng['tokens_per_s']:.1f} tok/s "
        f"({eng['requests_per_s']:.1f} req/s, occ {eng['slot_occupancy']:.2f}, "
        f"compiles {eng['compiles_total']} = {eng['compiles_warmup']} warm-up "
        f"+ {eng['compiles_steady']} steady) | whole-gen "
        f"{ref['tokens_per_s']:.1f} tok/s warm / {ref['tokens_per_s_cold']:.1f} "
        f"cold ({ref['signatures_compiled']} signatures) | speedup "
        f"{result['speedup']:.1f}x warm, {result['speedup_vs_cold']:.1f}x cold "
        f"-> {args.out}"
    )

    if args.obs_dir:
        import os

        os.makedirs(args.obs_dir, exist_ok=True)
        reg = obs.registry()
        exporters.write_jsonl(reg, os.path.join(args.obs_dir, "obs_events.jsonl"))
        exporters.write_prometheus(
            reg, os.path.join(args.obs_dir, "obs_metrics.prom")
        )
        exporters.write_chrome_trace(
            reg, os.path.join(args.obs_dir, "obs_trace.json")
        )
        logger.info(f"obs artifacts -> {args.obs_dir}/")

    ok = True
    if args.assert_max_compiles is not None and \
            eng["compiles_total"] > args.assert_max_compiles:
        logger.error(f"ASSERT FAILED: {eng['compiles_total']} compiles > "
              f"{args.assert_max_compiles}")
        ok = False
    if args.assert_zero_steady_compiles and eng["compiles_steady"] != 0:
        logger.error(f"ASSERT FAILED: {eng['compiles_steady']} steady-state compiles")
        ok = False
    if args.assert_min_rps is not None and \
            eng["requests_per_s"] < args.assert_min_rps:
        logger.error(f"ASSERT FAILED: {eng['requests_per_s']:.2f} req/s < "
              f"{args.assert_min_rps}")
        ok = False
    if args.assert_min_speedup is not None and \
            result["speedup"] < args.assert_min_speedup:
        logger.error(f"ASSERT FAILED: speedup {result['speedup']:.2f}x < "
              f"{args.assert_min_speedup}")
        ok = False
    if args.assert_obs_span_chain:
        chains = exporters.request_chain_rids(obs.registry())
        if not chains:
            logger.error("ASSERT FAILED: no complete submit->retire span chain")
            ok = False
        else:
            logger.info(f"obs span chains: {len(chains)} complete requests")
    if args.assert_obs_drop_rate:
        rate = result["engine"]["device"]["realized_drop_rate"]
        if not rate > 0.0:
            logger.error(
                f"ASSERT FAILED: realized on-device drop rate {rate} not > 0"
            )
            ok = False
        else:
            logger.info(f"realized on-device drop rate: {rate:.4f}")
    raise SystemExit(0 if ok else 1)


def run_bench_entry():  # console-script style alias
    main()


if __name__ == "__main__":
    main()
