"""Channel x protocol x loss-rate scenario sweep (repro.net).

For every cell of the grid the sweep reports, against ONE trained COMtune
model:

* analytic per-round link latency (mean + p99) from the protocol policy's
  latency PMF (``repro.net.protocol``, generalizing paper Eq. 4-5),
* Monte-Carlo delivered fraction from stateful protocol rounds over the
  *bursty* channel (state carried across the test set), and
* DI accuracy with those exact per-sample delivery masks applied at the
  split (``repro.net.evalhook``).

Reduced-size by default — the full grid runs end-to-end on CPU in a couple
of minutes.  Results go to benchmarks/results/net_sweep.json.

    PYTHONPATH=src python -m benchmarks.net_sweep [--full] [--loss-rates ...]
"""

from __future__ import annotations

import argparse
import json
import os
import time
import zlib

import numpy as np

from repro.core.link import ChannelConfig
from repro.net import (
    FECSpec,
    ARQProtocol,
    HybridFECARQProtocol,
    UnreliableProtocol,
    accuracy_with_packet_masks,
    make_channel,
    train_tiny_model,
)
from repro.net.evalhook import split_activations
from repro.net.protocol import latency_quantile

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

ELEMENTS_PER_PACKET = 25   # 100 B packets / 4 B floats


def build_channels(loss_rate: float):
    """The >=3-channel axis, all parameterized to comparable loss."""
    return {
        "iid": make_channel("iid", loss_rate),
        "ge": make_channel("ge", loss_rate),  # burst_len=4 Gilbert
        "fading": _fading_at(loss_rate),
    }


def _fading_at(loss_rate: float):
    """Pick a distance whose stationary fading loss is close to the target
    (bisection on the monotone distance -> loss curve)."""
    lo, hi = 5.0, 400.0
    for _ in range(40):
        mid = 0.5 * (lo + hi)
        ch = make_channel("fading", distance_m=mid)
        if ch.stationary_loss_rate < loss_rate:
            lo = mid
        else:
            hi = mid
    return make_channel("fading", distance_m=0.5 * (lo + hi))


def build_protocols():
    """The >=2-protocol axis."""
    return {
        "unreliable": UnreliableProtocol(),
        "arq": ARQProtocol(max_rounds=3),
        "fec_arq": HybridFECARQProtocol(fec=FECSpec(k=4, m=2), max_rounds=2),
    }


def sweep(loss_rates, n_eval: int, train_steps: int):
    model = train_tiny_model(steps=train_steps)
    acts = split_activations(model)
    n_elem = acts.shape[1]
    n_packets = -(-n_elem // ELEMENTS_PER_PACKET)
    n_eval = min(n_eval, acts.shape[0])
    model_eval = model
    if n_eval < acts.shape[0]:
        import dataclasses as _dc

        model_eval = _dc.replace(
            model, x_test=model.x_test[:n_eval], y_test=model.y_test[:n_eval]
        )
        acts = acts[:n_eval]

    rows = []
    for p in loss_rates:
        channels = build_channels(p)
        cfg = ChannelConfig(loss_rate=p)
        for ch_name, ch in channels.items():
            for pr_name, proto in build_protocols().items():
                t0 = time.time()
                lat, pmf = proto.latency_pmf(
                    n_packets, cfg, loss_rate=ch.stationary_loss_rate
                )
                mean_lat = float(np.dot(lat, pmf))
                p99_lat = latency_quantile(lat, pmf, 0.99)
                # Stateful MC rounds: one per eval sample, burst state
                # carried across the test set like consecutive requests.
                rng = np.random.RandomState(
                    zlib.crc32(f"{p}/{ch_name}/{pr_name}".encode()) % 2**31
                )
                state = ch.init_state(rng)
                masks = np.zeros((n_eval, n_packets), dtype=bool)
                slots = []
                for i in range(n_eval):
                    res, state = proto.run_round(rng, ch, state, n_packets)
                    masks[i] = res.delivered
                    slots.append(res.slots)
                acc = accuracy_with_packet_masks(
                    model_eval, masks, ELEMENTS_PER_PACKET, activations=acts
                )
                row = {
                    "loss_rate": p,
                    "channel": ch_name,
                    "protocol": pr_name,
                    "stationary_loss": ch.stationary_loss_rate,
                    "latency_mean_ms": mean_lat * 1e3,
                    "latency_p99_ms": p99_lat * 1e3,
                    "mc_slots_mean": float(np.mean(slots)),
                    "delivered_fraction": float(masks.mean()),
                    "accuracy": acc,
                    "wall_s": time.time() - t0,
                }
                rows.append(row)
                print(
                    f"p={p:.2f} {ch_name:>7s} x {pr_name:<10s} "
                    f"lat={row['latency_mean_ms']:7.3f}ms "
                    f"p99={row['latency_p99_ms']:7.3f}ms "
                    f"frac={row['delivered_fraction']:.3f} "
                    f"acc={acc:.3f}"
                )
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--loss-rates", type=float, nargs="+",
                    default=[0.1, 0.3, 0.6])
    ap.add_argument("--full", action="store_true",
                    help="more eval samples + longer training")
    args = ap.parse_args()

    n_eval = 400 if args.full else 160
    train_steps = 300 if args.full else 120

    t0 = time.time()
    rows = sweep(args.loss_rates, n_eval=n_eval, train_steps=train_steps)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out = os.path.join(RESULTS_DIR, "net_sweep.json")
    with open(out, "w") as f:
        json.dump({"rows": rows, "wall_s": time.time() - t0}, f, indent=2,
                  default=float)
    print(f"\n{len(rows)} grid cells in {time.time() - t0:.1f}s -> {out}")


if __name__ == "__main__":
    main()
