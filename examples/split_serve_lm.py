"""End-to-end serving driver (the paper is an inference paper, so this is
the primary example): serve a small LM with BATCHED requests where every
decode step's split activation crosses the emulated lossy IoT link —
quantized (8-bit), packet-masked, compensated — exactly the DI round of
paper Eq. 12, generalized to autoregressive decoding with KV/SSM caches.

    PYTHONPATH=src python examples/split_serve_lm.py [--arch xlstm-350m]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHITECTURES
from repro.launch.serve import generate
from repro.launch.train import train
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m", choices=sorted(ARCHITECTURES))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    # 1. COMtune fine-tune a reduced model so serving has a real model
    #    (the link-dropout is active during training = paper Eq. 8).
    print(f"== COMtune fine-tuning reduced {args.arch} ==")
    params, losses, cfg = train(
        args.arch, steps=120, batch=8, seq=64, lr=1e-3, link_mode="train",
        log_every=40,
    )
    print(f"loss: {np.mean(losses[:5]):.3f} -> {np.mean(losses[-5:]):.3f}")

    # 2. Serve batched requests across a sweep of loss rates.
    prompts = jax.random.randint(
        jax.random.PRNGKey(7), (args.batch, args.prompt_len), 0,
        cfg.vocab_size, jnp.int32,
    )
    print(f"\n== serving batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.tokens} over the lossy link ==")
    for p in [0.0, 0.3, 0.6]:
        toks, t = generate(params, cfg, prompts, args.tokens, loss_rate=p)
        print(
            f"p={p:.1f}: {t['decode_s_per_token']*1e3:7.1f} ms/token compute, "
            f"link {t['link_latency_s_per_round']*1e3:6.2f} ms/round "
            f"({t['message_kb_per_token']:.1f} kB/token), "
            f"sample: {np.asarray(toks)[0, :8].tolist()}"
        )
    print("\nNOTE: with the unreliable protocol the link latency above is "
          "CONSTANT in p — the accuracy/robustness cost is what COMtune "
          "training removes (see examples/quickstart.py).")


if __name__ == "__main__":
    main()
