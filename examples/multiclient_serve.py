"""Multi-client serving demo: N IoT devices -> one edge server (repro.net).

Trains the tiny COMtune split CNN, measures its accuracy-vs-delivered-
fraction curve, then drives the event-driven simulator with a heterogeneous
client population (iid / Gilbert-Elliott burst / fading channels, Poisson
arrivals, server-side batching) at several offered loads, reporting
throughput, p50/p99 round latency, and accuracy under load.

With ``--model-in-the-loop`` the accuracy column is computed by pushing
each served batch's *realized* per-request packet delivery masks through
the server half of the model (repro.net.evalhook) instead of the offline
interpolation curve — burst patterns and partial FEC recovery show up
directly in the number.

With ``--ckpt-dir DIR`` (implies model-in-the-loop) the model under load
is a *channel-tuned LM checkpoint* from ``launch/train.py --ckpt-dir``:
each request's realized packet mask is forced at the LM's split point and
correctness is next-token prediction (repro.net.evalhook
``make_lm_request_eval_fn``), so COMtune'd checkpoints are scored under
the simulator's actual burst patterns.

With ``--live-engine`` the server's batch compute time is no longer the
analytic model: every served batch runs through the live continuous-
batching engine (``repro.serve.continuous``), so the reported p50/p99
include real compute and real (first-bucket-only) compile behavior.

    PYTHONPATH=src python examples/multiclient_serve.py [--clients 24] \
        [--model-in-the-loop] [--ckpt-dir runs/ge --ckpt-arch qwen1.5-0.5b] \
        [--live-engine]
"""

from __future__ import annotations

import argparse

from repro.core.link import ChannelConfig
from repro.net import (
    ARQProtocol,
    SimConfig,
    accuracy_curve_fn,
    accuracy_vs_delivery_curve,
    make_channel,
    run_sim,
    train_tiny_model,
)


def client_population(n_clients: int, loss_rate: float):
    """A heterogeneous fleet: one third each iid / burst / fading (near,
    mid, far devices)."""
    channels = []
    for i in range(n_clients):
        kind = i % 3
        if kind == 0:
            channels.append(make_channel("iid", loss_rate))
        elif kind == 1:
            channels.append(make_channel("ge", loss_rate))
        else:
            channels.append(
                make_channel("fading", distance_m=40.0 + 15.0 * (i % 5))
            )
    return channels


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=24)
    ap.add_argument("--loss-rate", type=float, default=0.3)
    ap.add_argument("--duration", type=float, default=8.0)
    ap.add_argument("--train-steps", type=int, default=120)
    ap.add_argument(
        "--model-in-the-loop", action="store_true",
        help="accuracy from realized per-request packet masks through the "
             "real model (instead of the interpolation curve)",
    )
    ap.add_argument(
        "--ckpt-dir", default=None,
        help="evaluate a channel-tuned LM checkpoint from launch/train.py "
             "in model-in-the-loop mode (next-token correctness under the "
             "realized masks); implies --model-in-the-loop",
    )
    ap.add_argument("--ckpt-arch", default="qwen1.5-0.5b")
    ap.add_argument("--ckpt-full-size", action="store_true")
    ap.add_argument("--ckpt-seq-len", type=int, default=16)
    ap.add_argument(
        "--live-engine", action="store_true",
        help="server batch compute time measured on the live continuous-"
             "batching serve engine instead of the analytic model",
    )
    args = ap.parse_args()
    assert args.clients >= 16, "demo is about many concurrent clients"

    print(f"== multi-client serving: {args.clients} clients, "
          f"p={args.loss_rate} ==")
    print("training tiny COMtune model + measuring accuracy curve...")
    model = train_tiny_model(steps=args.train_steps)
    fracs, accs = accuracy_vs_delivery_curve(model)
    acc_fn = accuracy_curve_fn(fracs, accs)
    print("  delivered-fraction -> accuracy: "
          + ", ".join(f"{f:.2f}:{a:.3f}" for f, a in zip(fracs, accs)))

    request_eval_fn = None
    lm_params = lm_cfg = None
    if args.ckpt_dir:
        import jax
        from repro.checkpoint import restore_checkpoint
        from repro.configs import get_config
        from repro.models import lm as lm_lib
        from repro.net.evalhook import make_lm_request_eval_fn
        from repro.optim import AdamConfig, init_adam

        args.model_in_the_loop = True
        lm_cfg = get_config(args.ckpt_arch)
        if not args.ckpt_full_size:
            lm_cfg = lm_cfg.reduced()
        lm_params = lm_lib.init_lm(jax.random.PRNGKey(0), lm_cfg)
        template = {
            "params": lm_params,
            "opt_state": init_adam(lm_params, AdamConfig()),
            "key": jax.random.PRNGKey(0),
        }
        restored, at_step = restore_checkpoint(
            args.ckpt_dir, template, name="train"
        )
        lm_params = restored["params"]
        print(f"  restored {args.ckpt_arch} checkpoint @ step {at_step} "
              f"from {args.ckpt_dir}")
        # The LM request message is the whole prompt activation.
        n_packets = -(-(args.ckpt_seq_len * lm_cfg.d_model) // 25)
        request_eval_fn = make_lm_request_eval_fn(
            lm_params, lm_cfg, n_packets, seq_len=args.ckpt_seq_len
        )
    else:
        n_packets = -(-model.split_dim // 25)   # 100 B packets / 4 B floats
    channel_cfg = ChannelConfig(loss_rate=args.loss_rate)
    protocol = ARQProtocol(max_rounds=3)
    print(f"  uplink: {n_packets} packets/request, "
          f"slot={channel_cfg.slot_time_s()*1e6:.0f}us, protocol=arq(3)")

    sim_engine = None
    if args.live_engine:
        import jax
        from repro.configs import get_config
        from repro.models import lm as lm_lib
        from repro.serve import ContinuousEngine, PoolConfig, make_sim_server

        eng_cfg = lm_cfg or get_config(args.ckpt_arch).reduced()
        eng_params = lm_params
        if eng_params is None:
            eng_params = lm_lib.init_lm(jax.random.PRNGKey(0), eng_cfg)
        eng = ContinuousEngine(
            eng_cfg, PoolConfig(max_slots=8, max_new=16, max_prompt=32)
        )
        sim_engine = make_sim_server(
            eng, eng_params, prompt_lens=(8, 16, 32), num_tokens=8
        )
        print("  server compute: LIVE continuous-batching engine "
              f"({eng_cfg.name}, 8 slots)")

    header = (f"{'load rps/client':>16s} {'arrived':>8s} {'served':>7s} "
              f"{'dropped':>8s} {'rps':>7s} {'p50 ms':>8s} {'p99 ms':>8s} "
              f"{'frac':>6s} {'acc@load':>9s}")
    print("\n" + header)
    for rate in (2.0, 8.0, 20.0):
        rep = run_sim(
            SimConfig(
                n_clients=args.clients,
                arrival_rate_hz=rate,
                duration_s=args.duration,
                n_packets=n_packets,
                server_batch_max=8,
                min_delivered_fraction=0.25,
                seed=0,
            ),
            channels=client_population(args.clients, args.loss_rate),
            protocol=protocol,
            channel_cfg=channel_cfg,
            accuracy_fn=acc_fn,
            model_in_the_loop=args.model_in_the_loop,
            model=model,
            request_eval_fn=request_eval_fn,
            engine=sim_engine,
        )
        assert rep.arrived == rep.served + rep.dropped
        print(f"{rate:16.1f} {rep.arrived:8d} {rep.served:7d} "
              f"{rep.dropped:8d} {rep.throughput_rps:7.1f} "
              f"{rep.latency_p50_s*1e3:8.2f} {rep.latency_p99_s*1e3:8.2f} "
              f"{rep.mean_delivered_fraction:6.3f} "
              f"{rep.accuracy_under_load:9.3f}")

    src = "realized packet masks through the model" \
        if args.model_in_the_loop else "interpolated accuracy curve"
    print(f"\np99 grows with offered load (queueing + client-radio "
          f"serialization); accuracy tracks delivered fraction "
          f"(source: {src}).")


if __name__ == "__main__":
    main()
