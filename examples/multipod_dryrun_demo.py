"""Demo: lower + compile one (architecture x shape) pair on the production
mesh with placeholder devices, and print its roofline terms.  This is a thin
wrapper over launch/dryrun.py — run that module directly for the full sweep.

    PYTHONPATH=src python examples/multipod_dryrun_demo.py \
        [--arch gemma3-12b] [--shape decode_32k] [--multi-pod]
"""

# The dry-run needs 512 placeholder devices BEFORE any jax import — dryrun.py
# sets this itself as its first two lines; we just exec it with args.
import runpy
import sys

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not any(a.startswith("--arch") for a in argv):
        argv += ["--arch", "gemma3-12b"]
    if not any(a.startswith("--shape") for a in argv):
        argv += ["--shape", "decode_32k"]
    sys.argv = ["repro.launch.dryrun"] + argv
    runpy.run_module("repro.launch.dryrun", run_name="__main__")
