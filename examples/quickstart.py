"""Quickstart: the paper in miniature (~3 min on CPU).

Fine-tunes the split CNN with COMtune (dropout link layer at the split,
paper Eq. 8), then runs distributed inference through the simulated lossy
IoT channel (Eq. 12) and prints accuracy vs packet-loss-rate for COMtune
vs the 'previous DI' baseline — the paper's Fig. 5 in one screen.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.link import ChannelConfig, unreliable_latency_s
from repro.paper import experiment as E


def main():
    print("== COMtune quickstart (synthetic CIFAR stand-in) ==")
    print(f"split activation: {E.CNN_CFG.split_activation_dim} dims "
          f"({E.uncompressed_bytes()/1e3:.1f} kB fp32)")

    print("\ntraining 'previous DI' baseline (r=0)...")
    p0, s0, _ = E.finetuned(0.0)
    print("training COMtune (r=0.5)...")
    p5, s5, _ = E.finetuned(0.5)

    ch = ChannelConfig()
    n_t = ch.num_packets_for_bytes(E.uncompressed_bytes())
    print(f"\nunreliable-protocol upload latency: "
          f"{unreliable_latency_s(n_t, ch)*1e3:.1f} ms "
          f"({n_t} packets @ {ch.throughput_bps/1e6:.1f} Mbit/s)")

    print(f"\n{'loss rate':>10s} {'previous DI':>12s} {'COMtune r=0.5':>14s}")
    for p in [0.0, 0.2, 0.4, 0.6, 0.8]:
        a0, _, _ = E.accuracy_stats(p0, s0, None, p, n_seeds=5)
        a5, _, _ = E.accuracy_stats(p5, s5, None, p, n_seeds=5)
        marker = "  <-- COMtune wins" if a5 > a0 + 0.01 else ""
        print(f"{p:10.1f} {a0:12.3f} {a5:14.3f}{marker}")


if __name__ == "__main__":
    main()
