"""COMtune for LMs: fine-tune the same reduced model three ways — no link
emulation (baseline), the paper's dropout emulation (Eq. 7), and this
repo's channel-aware emulation (fine-tuning against the bursty deployment
channel: Gilbert–Elliott, shuffle=False) — then compare held-out perplexity
when serving over both an i.i.d. and a bursty lossy channel.  The LM analog
of the paper's Fig. 5, generalized to bursty links.

    PYTHONPATH=src python examples/finetune_lm_comtune.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import comtune
from repro.data import lm_batch_iterator, make_lm_dataset
from repro.launch.train import train
from repro.models import lm


def eval_nll(params, cfg, tokens, link_spec, key):
    """Held-out next-token NLL with the serve-path link (Eq. 12) active."""
    logits, _, aux = lm.forward(
        params, tokens, cfg,
        link_key=key,
        link_mode="serve" if link_spec is not None else "clean",
        link_spec=link_spec, mode="prefill",
    )
    return float(lm.lm_loss(logits, tokens, aux, 0.0))


def main():
    arch = "qwen1.5-0.5b"
    kw = dict(steps=200, batch=8, seq=64, lr=1e-3, log_every=100, seed=0)
    print(f"== fine-tuning reduced {arch}: baseline vs COMtune variants ==")
    params_bl, _, cfg = train(arch, link_mode="off", **kw)
    params_dr, _, _ = train(arch, link_mode="train", **kw)
    params_ch, _, _ = train(
        arch, link_mode="train", train_link="channel", train_channel="ge",
        shuffle=False, curriculum=(0.1, 0.5), **kw
    )

    toks = make_lm_dataset(cfg.vocab_size, 40_000, seed=9)
    batch = jnp.asarray(next(lm_batch_iterator(toks, 16, 64, seed=9)))

    models = [("baseline", params_bl), ("dropout", params_dr), ("channel", params_ch)]
    for ch_name, eval_channel in [("iid", "iid"), ("ge-burst", "ge")]:
        print(f"\n-- serve channel: {ch_name} --")
        print(f"{'loss rate':>10s} " + " ".join(f"{n:>10s}" for n, _ in models))
        for p in [0.0, 0.2, 0.5, 0.7]:
            spec = (
                comtune.LinkSpec(
                    loss_rate=p, channel=eval_channel, shuffle=False
                ) if p > 0 else None
            )
            row = []
            for _, params in models:
                nlls = [
                    eval_nll(params, cfg, batch, spec, jax.random.PRNGKey(100 + s))
                    for s in range(3)
                ]
                row.append(np.mean(nlls))
            best = int(np.argmin(row))
            cells = " ".join(
                f"{v:10.3f}" + ("*" if i == best and p > 0 else " ")
                for i, v in enumerate(row)
            )
            print(f"{p:10.1f} {cells}")
    print("\n(* = lowest NLL at that loss rate)")


if __name__ == "__main__":
    main()
