"""COMtune for LMs: fine-tune the same reduced model twice — with and
without the lossy-link emulation — then compare held-out perplexity when
serving over a lossy channel.  The LM analog of the paper's Fig. 5.

    PYTHONPATH=src python examples/finetune_lm_comtune.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import lm_batch_iterator, make_lm_dataset
from repro.launch.train import train
from repro.models import lm


def eval_nll(params, cfg, tokens, loss_rate, key):
    """Held-out next-token NLL with the serve-path link (Eq. 12) active."""
    logits, _, aux = lm.forward(
        params, tokens, cfg,
        link_key=key, link_mode="serve" if loss_rate > 0 else "clean",
        loss_rate=loss_rate, mode="prefill",
    )
    return float(lm.lm_loss(logits, tokens, aux, 0.0))


def main():
    arch = "qwen1.5-0.5b"
    print(f"== fine-tuning reduced {arch}: COMtune vs baseline ==")
    params_ct, losses_ct, cfg = train(
        arch, steps=200, batch=8, seq=64, lr=1e-3, link_mode="train",
        log_every=100, seed=0,
    )
    params_bl, losses_bl, _ = train(
        arch, steps=200, batch=8, seq=64, lr=1e-3, link_mode="off",
        log_every=100, seed=0,
    )

    toks = make_lm_dataset(cfg.vocab_size, 40_000, seed=9)
    batch = next(lm_batch_iterator(toks, 16, 64, seed=9))
    batch = jnp.asarray(batch)

    print(f"\n{'loss rate':>10s} {'baseline NLL':>13s} {'COMtune NLL':>12s}")
    for p in [0.0, 0.2, 0.5, 0.7]:
        nlls_bl, nlls_ct = [], []
        for s in range(3):
            k = jax.random.PRNGKey(100 + s)
            nlls_bl.append(eval_nll(params_bl, cfg, batch, p, k))
            nlls_ct.append(eval_nll(params_ct, cfg, batch, p, k))
        marker = "  <-- COMtune wins" if np.mean(nlls_ct) < np.mean(nlls_bl) - 0.01 else ""
        print(f"{p:10.1f} {np.mean(nlls_bl):13.3f} {np.mean(nlls_ct):12.3f}{marker}")


if __name__ == "__main__":
    main()
