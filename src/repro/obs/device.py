"""On-device link/decode counters: trace-time taps + the counter pytree.

The link layers (``core.link.apply_channel``, ``core.comtune``'s
dropout/channel/streamed paths, ``net.fec``) cannot host-log what they did
— they run inside jit-compiled programs.  Instead they *tap*: whenever a
collector is installed on the module-level stack, each mask draw records
its traced element count / dropped count / FEC recoveries into the
collector, and the caller that installed it turns the totals into extra
program **outputs** (the ``obs.DeviceCounters`` pytree carried by the
slot-pool engine state) or auxiliary metrics (the train step).  Host code
reads them only at existing sync points.

Two invariants this design exists to protect:

* **No program forking on obs state.**  Whether the host registry is
  enabled or disabled never changes what gets traced — the engine installs
  its taps unconditionally, so obs on/off compiles byte-identical programs
  and ``compiles == num_buckets + 1`` holds either way.  With no collector
  installed (reference loops, the whole-generation engine, training without
  the tap) the record calls are dead ``if not _STACK`` branches and the
  traced program is exactly the pre-obs program.
* **vmap safety.**  A tap installed *outside* a ``jax.vmap`` would leak
  batch tracers when read.  Callers that vmap over link draws
  (``streamed_channel_link``, the slot-pool decode step) install an inner
  collector inside the vmapped function and return the totals as vmap
  outputs; ``emit`` re-publishes the (now properly batched) sums to the
  ambient collector.
"""

from __future__ import annotations

import contextlib
from typing import Dict, List

import jax
import jax.numpy as jnp

# Collector stack.  Tracing is single-threaded per program build; the
# stack is module-level and LIFO so nested taps (engine step installing a
# tap around a forward that streams positions) compose.
_STACK: List["LinkTap"] = []

# The counter pytree's float leaves.  "decode_steps" is int32; everything
# else float32 (counts can exceed int32 over long runs, and the link
# totals are already float masks summed on device).
COUNTER_KEYS = (
    "decode_steps",
    "valid_tokens",
    "decode_read_bytes",
    "link_elems",
    "link_dropped",
    "fec_recovered_packets",
)


class LinkTap:
    """One collector frame: accumulates traced link statistics."""

    __slots__ = ("elems", "dropped", "fec_recovered")

    def __init__(self) -> None:
        self.elems: list = []
        self.dropped: list = []
        self.fec_recovered: list = []

    def totals(self) -> Dict[str, jax.Array]:
        """Summed stats as float32 scalars (zeros when nothing was drawn,
        e.g. ``link_mode="off"`` — still valid pytree leaves)."""
        z = jnp.float32(0.0)
        return {
            "elems": sum(self.elems, z),
            "dropped": sum(self.dropped, z),
            "fec_recovered": sum(self.fec_recovered, z),
        }


def tapping() -> bool:
    """True while a collector is installed (decides, at TRACE time,
    whether the extra counting ops exist in the program at all)."""
    return bool(_STACK)


@contextlib.contextmanager
def tap_link_stats():
    """Install a collector for the duration of the block; every link mask
    drawn inside (by this trace) records into it.  Read ``tap.totals()``
    *inside* the same traced scope."""
    tap = LinkTap()
    _STACK.append(tap)
    try:
        yield tap
    finally:
        popped = _STACK.pop()
        assert popped is tap, "unbalanced obs.device collector stack"


def record_mask(mask: jax.Array) -> None:
    """Record one keep-mask draw (0/1, any shape): total elements and the
    dropped (zero) count.  No-op without a collector."""
    if not _STACK:
        return
    m = mask.astype(jnp.float32)
    tap = _STACK[-1]
    tap.elems.append(jnp.float32(m.size))
    tap.dropped.append(jnp.float32(m.size) - jnp.sum(m))


def record_full_keep(num_elements: int) -> None:
    """Record a static zero-loss shortcut (mask of all ones, never drawn)."""
    if not _STACK:
        return
    _STACK[-1].elems.append(jnp.float32(num_elements))


def record_fec_recovered(n_packets: jax.Array) -> None:
    """Record data packets recovered by FEC decoding (lost on the raw
    channel, reconstructed from parity)."""
    if not _STACK:
        return
    _STACK[-1].fec_recovered.append(jnp.asarray(n_packets, jnp.float32))


def emit(totals: Dict[str, jax.Array]) -> None:
    """Re-publish summed stats (a ``LinkTap.totals()`` dict, e.g. brought
    out of a vmap as program outputs and reduced) to the ambient
    collector."""
    if not _STACK:
        return
    tap = _STACK[-1]
    tap.elems.append(jnp.asarray(totals["elems"], jnp.float32))
    tap.dropped.append(jnp.asarray(totals["dropped"], jnp.float32))
    tap.fec_recovered.append(jnp.asarray(totals["fec_recovered"], jnp.float32))


# ---------------------------------------------------------------------------
# DeviceCounters: the pytree threaded through the jitted hot paths
# ---------------------------------------------------------------------------

def counter_zeros() -> Dict[str, jax.Array]:
    """Fresh ``obs.DeviceCounters`` pytree (all zeros)."""
    out: Dict[str, jax.Array] = {}
    for k in COUNTER_KEYS:
        dt = jnp.int32 if k == "decode_steps" else jnp.float32
        out[k] = jnp.zeros((), dt)
    return out


def counters_to_host(counters) -> Dict[str, float]:
    """Device pytree -> plain floats plus the derived realized drop rate
    (one sync; call only at existing sync points)."""
    import numpy as np

    host = {k: float(np.asarray(v)) for k, v in counters.items()}
    host["realized_drop_rate"] = host["link_dropped"] / max(
        host["link_elems"], 1.0
    )
    return host
