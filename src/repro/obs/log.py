"""Logging setup honoring ``REPRO_LOG_LEVEL``.

``get_logger("train")`` returns a ``repro.train`` logger writing bare
messages to stdout (no timestamp/level prefix — at the default INFO level
the output is byte-identical to the ``print`` calls it replaced in
``launch/train.py`` and ``benchmarks/*``).  Set ``REPRO_LOG_LEVEL=DEBUG``
or ``WARNING`` to widen/silence."""

from __future__ import annotations

import logging
import os
import sys

_ROOT = "repro"
_CONFIGURED = False


def _configure() -> None:
    global _CONFIGURED
    if _CONFIGURED:
        return
    _CONFIGURED = True
    root = logging.getLogger(_ROOT)
    level = os.environ.get("REPRO_LOG_LEVEL", "INFO").upper()
    root.setLevel(getattr(logging, level, logging.INFO))
    if not root.handlers:
        h = logging.StreamHandler(sys.stdout)
        h.setFormatter(logging.Formatter("%(message)s"))
        root.addHandler(h)
    root.propagate = False


def get_logger(name: str = "") -> logging.Logger:
    _configure()
    return logging.getLogger(f"{_ROOT}.{name}" if name else _ROOT)
