"""XLA compile-activity counter via ``jax.monitoring``.

jax emits a duration event once per *backend compile* — an actual XLA
build, never a tracing-cache or compilation-cache hit — which makes it
the ground truth for "did anything recompile?".  This module keeps a
process-global count of those events, feeds the ``xla_builds_total``
counter of :mod:`repro.obs` when the registry is enabled, and backs
:func:`repro.analysis.guards.no_recompile`.

``jax.monitoring`` has no unregister API, so the listener is installed
once (idempotently) and never removed; it is a couple of integer adds
per compile, which is noise next to the compile itself.
"""

from __future__ import annotations

import threading

_LOCK = threading.Lock()
_SUBSCRIBED = False
_BUILDS = 0

try:  # the canonical event name lives in a private module; pin a fallback
    from jax._src.dispatch import BACKEND_COMPILE_EVENT  # type: ignore
except Exception:  # pragma: no cover - future jax versions
    BACKEND_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


def _on_event_duration(event: str, duration: float, **kwargs) -> None:
    global _BUILDS
    if event != BACKEND_COMPILE_EVENT:
        return
    with _LOCK:
        _BUILDS += 1
    # feed the metrics registry only when it is enabled; counter() on the
    # disabled registry returns the null singleton, so this stays free.
    from repro.obs.registry import registry

    registry().counter("xla_builds_total").inc()


def ensure_subscribed() -> None:
    """Install the monitoring listener (idempotent, never removed)."""
    global _SUBSCRIBED
    with _LOCK:
        if _SUBSCRIBED:
            return
        _SUBSCRIBED = True
    import jax.monitoring

    jax.monitoring.register_event_duration_secs_listener(_on_event_duration)


def builds_total() -> int:
    """XLA builds observed process-wide since :func:`ensure_subscribed`."""
    with _LOCK:
        return _BUILDS
