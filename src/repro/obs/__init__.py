"""repro.obs — metrics, tracing, and on-device counters.

Three layers (see README "Observability"):

* ``registry()`` — the process-global ``Registry``: counters, gauges,
  streaming histograms, nested spans.  Disabled by default (true no-op);
  enable with ``obs.enable()`` or ``REPRO_OBS=1``.
* ``device`` — trace-time taps that turn link-mask draws inside jitted
  programs into the ``DeviceCounters`` pytree threaded through the
  slot-pool engine state (harvested host-side only at sync points).
* ``exporters`` — JSONL event log, Prometheus text, chrome://tracing
  trace, and the ``jax.profiler.trace`` wrapper.
"""

from repro.obs import device, exporters, stats, xla
from repro.obs.log import get_logger
from repro.obs.registry import Registry, disable, enable, registry

# The DeviceCounters pytree constructor (the engine threads it as state).
DeviceCounters = device.counter_zeros

# Count XLA builds from process start: the xla_builds_total counter and
# the analysis.guards.no_recompile() guard share this one subscription.
xla.ensure_subscribed()

__all__ = [
    "Registry",
    "registry",
    "enable",
    "disable",
    "get_logger",
    "stats",
    "device",
    "exporters",
    "xla",
    "DeviceCounters",
]
