"""Shared latency statistics: exact percentiles + streaming histograms.

Two regimes:

* ``percentile`` / ``latency_summary`` — exact order statistics over a
  sample list (numpy linear interpolation, identical to
  ``numpy.percentile``).  This is THE percentile implementation the
  benchmarks and the simulator report from — it replaces the three
  hand-rolled copies that used to live in ``benchmarks/serving_bench.py``,
  ``benchmarks/decode_bench.py``, and ``net/simulator.py``.
* ``StreamingHistogram`` — p50/p90/p99 *without storing samples*: a
  fixed set of log-spaced buckets over [1e-9, 1e6] (seconds span ~15
  decades; ~497 buckets at 7% ratio per bucket), quantiles by
  cumulative-count walk with log-linear interpolation inside the hit
  bucket.  Exact min/max are tracked separately so the extreme quantiles
  clamp to observed values.  O(1) memory and O(1) observe, which is what
  the always-on registry needs.
"""

from __future__ import annotations

import bisect
import math
from typing import Dict, Sequence

import numpy as np

# Log-spaced bucket edges shared by every StreamingHistogram: worst-case
# relative quantile error is half the bucket ratio (~3.5%).
_EDGE_LO, _EDGE_HI = 1e-9, 1e6
_EDGES_PER_DECADE = 33
_N_EDGES = int(math.log10(_EDGE_HI / _EDGE_LO) * _EDGES_PER_DECADE) + 1
_EDGES = np.geomspace(_EDGE_LO, _EDGE_HI, _N_EDGES)
_LOG_EDGES = np.log(_EDGES)


def percentile(xs: Sequence[float], q: float) -> float:
    """Exact q-th percentile (numpy linear interpolation)."""
    arr = np.asarray(xs, dtype=np.float64)
    assert arr.size > 0, "percentile of an empty sample"
    return float(np.percentile(arr, q))


def latency_summary(xs: Sequence[float]) -> Dict[str, float]:
    """The benchmark/simulator reporting contract: exact p50/p90/p99 and
    mean over a sample list, with the ``*_s`` key names every
    ``BENCH_*.json`` consumer already reads."""
    arr = np.asarray(xs, dtype=np.float64)
    if arr.size == 0:
        return {"p50_s": 0.0, "p90_s": 0.0, "p99_s": 0.0, "mean_s": 0.0}
    return {
        "p50_s": float(np.percentile(arr, 50)),
        "p90_s": float(np.percentile(arr, 90)),
        "p99_s": float(np.percentile(arr, 99)),
        "mean_s": float(arr.mean()),
    }


class StreamingHistogram:
    """Fixed-memory quantile sketch over positive reals.

    ``observe`` increments one bucket; ``quantile(q)`` walks the
    cumulative counts to the target rank and interpolates log-linearly
    within the landing bucket, clamped to the exact observed [min, max].
    Values outside [1e-9, 1e6] clamp into the end buckets (latencies and
    byte counts both live comfortably inside).
    """

    __slots__ = ("counts", "count", "total", "min", "max")

    def __init__(self) -> None:
        self.counts = np.zeros(_N_EDGES - 1, dtype=np.int64)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        c = min(max(v, _EDGE_LO), _EDGE_HI)
        # bisect on the module-level edge list: index of the bucket whose
        # [edge[i], edge[i+1]) interval contains c.
        i = bisect.bisect_right(_EDGES, c) - 1
        self.counts[min(max(i, 0), _N_EDGES - 2)] += 1

    def quantile(self, q: float) -> float:
        assert 0.0 <= q <= 100.0, q
        if self.count == 0:
            return 0.0
        rank = q / 100.0 * (self.count - 1)
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, rank + 1.0 - 1e-9))
        i = min(i, _N_EDGES - 2)
        # Log-linear interpolation inside bucket i by fractional rank.
        lo_rank = cum[i - 1] if i > 0 else 0
        in_bucket = max(int(self.counts[i]), 1)
        frac = min(max((rank - lo_rank + 1.0) / in_bucket, 0.0), 1.0)
        lo, hi = _LOG_EDGES[i], _LOG_EDGES[i + 1]
        v = math.exp(lo + frac * (hi - lo))
        return float(min(max(v, self.min), self.max))

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "count": float(self.count),
            "sum": self.total,
            "mean": self.mean,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "p50": self.quantile(50),
            "p90": self.quantile(90),
            "p99": self.quantile(99),
        }
