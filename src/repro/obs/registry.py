"""Process-global metrics + tracing registry.

One ``Registry`` per process (``obs.registry()``), disabled by default
(enable with ``obs.enable()`` or ``REPRO_OBS=1``).  Disabled, every API is
a true no-op: ``counter()``/``gauge()``/``histogram()`` return shared null
singletons whose methods do nothing, ``span()`` returns a reusable null
context manager, and no events are stored — the hot-path cost is one
attribute load and one branch.

Enabled, it holds:

* **counters / gauges** — plain floats keyed by name;
* **histograms** — ``obs.stats.StreamingHistogram`` (p50/p90/p99 without
  storing samples);
* **events** — a bounded list of dicts: instant events and completed
  spans.  Spans nest via a thread-local stack (``span()``) or explicit
  timestamps (``record_span`` — how the engine reconstructs a request's
  submit→retire chain from stamps taken at sync points).  All timestamps
  are ``time.perf_counter()`` seconds; ``epoch0``/``perf0`` in
  ``snapshot()`` anchor them to wall time.

Exporters (JSONL / Prometheus text / chrome://tracing) live in
``obs.exporters`` and read only ``snapshot()`` + ``events``.
"""

from __future__ import annotations

import contextlib
import itertools
import os
import threading
import time
from typing import Any, Dict, List, Optional

from repro.obs.stats import StreamingHistogram


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class _NullCounter:
    __slots__ = ()
    value = 0.0

    def inc(self, n: float = 1.0) -> None:
        pass


class _NullGauge:
    __slots__ = ()
    value = 0.0

    def set(self, v: float) -> None:
        pass


class _NullHistogram:
    __slots__ = ()
    count = 0

    def observe(self, v: float) -> None:
        pass

    def quantile(self, q: float) -> float:
        return 0.0

    def summary(self) -> Dict[str, float]:
        return {}


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_COUNTER = _NullCounter()
_NULL_GAUGE = _NullGauge()
_NULL_HISTOGRAM = _NullHistogram()
_NULL_SPAN = _NullSpan()


class Registry:
    """Counters + gauges + streaming histograms + span/event log."""

    def __init__(self, enabled: bool = False, max_events: int = 200_000):
        self.enabled = enabled
        self.max_events = max_events
        self.perf0 = time.perf_counter()
        self.epoch0 = time.time()
        self.events: List[Dict[str, Any]] = []
        self.events_dropped = 0
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, StreamingHistogram] = {}
        self._ids = itertools.count(1)
        self._local = threading.local()

    # -- lifecycle ---------------------------------------------------------

    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        """Drop all metrics and events (keeps the enabled flag)."""
        self.events.clear()
        self.events_dropped = 0
        self._counters.clear()
        self._gauges.clear()
        self._histograms.clear()
        self.perf0 = time.perf_counter()
        self.epoch0 = time.time()

    # -- metrics -----------------------------------------------------------

    def counter(self, name: str) -> Counter:
        if not self.enabled:
            return _NULL_COUNTER
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        if not self.enabled:
            return _NULL_GAUGE
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str) -> StreamingHistogram:
        if not self.enabled:
            return _NULL_HISTOGRAM
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = StreamingHistogram()
        return h

    # -- events / spans ----------------------------------------------------

    def _append(self, ev: Dict[str, Any]) -> None:
        if len(self.events) >= self.max_events:
            self.events_dropped += 1
            return
        self.events.append(ev)

    def event(self, name: str, **attrs) -> None:
        """One instant event at now."""
        if not self.enabled:
            return
        ev = {"name": name, "kind": "instant", "t": time.perf_counter()}
        if attrs:
            ev["attrs"] = attrs
        self._append(ev)

    def _span_stack(self) -> list:
        st = getattr(self._local, "spans", None)
        if st is None:
            st = self._local.spans = []
        return st

    @contextlib.contextmanager
    def _live_span(self, name: str, attrs):
        sid = next(self._ids)
        stack = self._span_stack()
        parent = stack[-1] if stack else None
        stack.append(sid)
        t0 = time.perf_counter()
        try:
            yield sid
        finally:
            t1 = time.perf_counter()
            stack.pop()
            ev = {
                "name": name, "kind": "span", "t": t0, "dur": t1 - t0,
                "id": sid,
            }
            if parent is not None:
                ev["parent"] = parent
            if attrs:
                ev["attrs"] = attrs
            self._append(ev)

    def span(self, name: str, **attrs):
        """Context manager: a nested span with monotonic start/stop.  The
        disabled path returns a shared null manager (no allocation)."""
        if not self.enabled:
            return _NULL_SPAN
        return self._live_span(name, attrs)

    def record_span(
        self, name: str, t0: float, t1: float,
        parent: Optional[int] = None, **attrs,
    ) -> Optional[int]:
        """A completed span from explicit ``perf_counter`` stamps — how
        phases measured at sync points (TTFT, decode tail) enter the
        trace after the fact.  Returns the span id (usable as ``parent``
        for its children), or None when disabled."""
        if not self.enabled:
            return None
        sid = next(self._ids)
        ev = {
            "name": name, "kind": "span", "t": t0, "dur": max(t1 - t0, 0.0),
            "id": sid,
        }
        if parent is not None:
            ev["parent"] = parent
        if attrs:
            ev["attrs"] = attrs
        self._append(ev)
        return sid

    # -- reporting ---------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        return {
            "enabled": self.enabled,
            "perf0": self.perf0,
            "epoch0": self.epoch0,
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
            "histograms": {
                k: h.summary() for k, h in sorted(self._histograms.items())
            },
            "num_events": len(self.events),
            "events_dropped": self.events_dropped,
        }


_GLOBAL = Registry(enabled=os.environ.get("REPRO_OBS", "") == "1")


def registry() -> Registry:
    """THE process-global registry."""
    return _GLOBAL


def enable() -> None:
    _GLOBAL.enable()


def disable() -> None:
    _GLOBAL.disable()
