"""Registry exporters: JSONL events, Prometheus text, chrome://tracing.

All three read only ``Registry.snapshot()`` and ``Registry.events``:

* ``write_jsonl`` — one JSON object per line: a header record (wall-clock
  anchor + metric snapshot) followed by every event in emission order.
* ``write_prometheus`` — the text exposition format: counters, gauges,
  and histogram quantiles as ``name{quantile="0.5"}`` summary series.
* ``write_chrome_trace`` — a ``chrome://tracing`` / Perfetto JSON file:
  spans become complete ("ph": "X") events with microsecond timestamps,
  instants become "ph": "i"; load it at chrome://tracing or ui.perfetto.dev.
* ``jax_profile`` — optional ``jax.profiler.trace`` wrapper (the
  ``--profile-dir`` flag): a no-op context when the directory is None.

``request_chain_rids`` is the span-chain checker the CI obs smoke asserts
with: the rids whose submit→retire lifecycle is fully covered.
"""

from __future__ import annotations

import contextlib
import json
import re
from typing import Dict, List, Set

from repro.obs.registry import Registry

# The per-request span taxonomy ContinuousEngine emits at harvest time.
REQUEST_PHASES = (
    "request/queue",      # submit -> admit (scheduler wait)
    "request/prefill",    # admit -> first token (the bucketed prefill)
    "request/decode",     # first token -> last token (decode rounds)
    "request/retire",     # last token -> harvested output
)


def write_jsonl(reg: Registry, path: str) -> None:
    with open(path, "w") as f:
        f.write(json.dumps({"kind": "snapshot", **reg.snapshot()}) + "\n")
        for ev in reg.events:
            f.write(json.dumps(ev) + "\n")


_PROM_BAD = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    return _PROM_BAD.sub("_", name)


def prometheus_text(reg: Registry) -> str:
    snap = reg.snapshot()
    lines: List[str] = []
    for name, v in snap["counters"].items():
        n = _prom_name(name)
        lines.append(f"# TYPE {n} counter")
        lines.append(f"{n} {v}")
    for name, v in snap["gauges"].items():
        n = _prom_name(name)
        lines.append(f"# TYPE {n} gauge")
        lines.append(f"{n} {v}")
    for name, s in snap["histograms"].items():
        if not s:
            continue
        n = _prom_name(name)
        lines.append(f"# TYPE {n} summary")
        for q in (50, 90, 99):
            lines.append(f'{n}{{quantile="0.{q}"}} {s[f"p{q}"]}')
        lines.append(f"{n}_sum {s['sum']}")
        lines.append(f"{n}_count {int(s['count'])}")
    return "\n".join(lines) + "\n"


def write_prometheus(reg: Registry, path: str) -> None:
    with open(path, "w") as f:
        f.write(prometheus_text(reg))


def chrome_trace(reg: Registry) -> Dict:
    """Trace-event JSON: one process, spans on thread 0 with µs stamps
    relative to the registry's perf epoch."""
    t0 = reg.perf0
    trace_events = []
    for ev in reg.events:
        base = {
            "name": ev["name"],
            "pid": 1,
            "tid": 0,
            "ts": (ev["t"] - t0) * 1e6,
            "args": ev.get("attrs", {}),
        }
        if ev["kind"] == "span":
            base["ph"] = "X"
            base["dur"] = ev["dur"] * 1e6
        else:
            base["ph"] = "i"
            base["s"] = "g"
        trace_events.append(base)
    return {"traceEvents": trace_events, "displayTimeUnit": "ms"}


def write_chrome_trace(reg: Registry, path: str) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(reg), f)


def request_chain_rids(reg: Registry) -> Set[int]:
    """rids with a COMPLETE submit→retire chain: a parent ``request``
    span plus all four lifecycle phases pointing at it."""
    phases_by_rid: Dict[int, Set[str]] = {}
    for ev in reg.events:
        if ev.get("kind") != "span":
            continue
        rid = ev.get("attrs", {}).get("rid")
        if rid is None:
            continue
        if ev["name"] == "request" or ev["name"] in REQUEST_PHASES:
            phases_by_rid.setdefault(int(rid), set()).add(ev["name"])
    want = {"request", *REQUEST_PHASES}
    return {rid for rid, names in phases_by_rid.items() if names >= want}


@contextlib.contextmanager
def jax_profile(profile_dir=None):
    """``jax.profiler.trace`` around the block when a directory is given
    (the ``--profile-dir`` flag); identity otherwise."""
    if not profile_dir:
        yield
        return
    import jax

    with jax.profiler.trace(str(profile_dir)):
        yield
