"""repro.analysis — static invariant checker for this repro.

Six AST rules (RPA001–RPA006) encode the invariants the rest of the repo
enforces only at runtime: zero steady-state recompiles, single-use PRNG
keys, donation discipline, the ``pallas_interpret`` policy, sync-point
harvesting, and structured logging.  Run it as::

    PYTHONPATH=src python -m repro.analysis src tests benchmarks

Pure stdlib by design — the CI lint job installs nothing.  The
jax-importing runtime half lives in :mod:`repro.analysis.guards` and
must be imported explicitly.
"""

from repro.analysis import baseline
from repro.analysis.core import (
    Finding,
    analyze_paths,
    analyze_source,
    iter_python_files,
)
from repro.analysis.rules import RULES

__all__ = [
    "Finding",
    "RULES",
    "analyze_paths",
    "analyze_source",
    "baseline",
    "iter_python_files",
]
