"""Runtime compile guard: assert a region performs zero XLA builds.

The static rules in :mod:`repro.analysis.rules` catch retrace hazards the
AST can see; :func:`no_recompile` catches the ones it can't — a shape
that drifted, a weak-type promotion, a donation mismatch — by watching
the actual compiler.  Two independent signals, the guard trips on either:

* ``repro.obs.xla.builds_total()`` — a process-global counter fed by the
  ``jax.monitoring`` backend-compile event, which fires exactly once per
  XLA build and never on a cache hit;
* any engine passed via ``engines=``, through its own ``compiles`` /
  ``total_compiles()`` bookkeeping (covers environments where the
  monitoring event is unavailable).

This module imports jax (indirectly) and is deliberately **not** pulled
in by ``repro.analysis.__init__`` — the static analyzer stays stdlib-only
so the CI lint job runs with nothing installed.

Usage::

    from repro.analysis.guards import no_recompile

    engine.submit(...); engine.run()        # warmup: compiles happen here
    with no_recompile(engines=(engine,)):
        engine.submit(...); engine.run()    # steady state: zero builds

Anything that would trace a *new* program signature inside the region —
including innocuous-looking ``jax.random.randint`` calls with fresh
shapes — trips the guard; precompute such values before entering.
"""

from __future__ import annotations

import contextlib
from typing import Iterator, Sequence


class RecompileError(AssertionError):
    """An XLA build happened inside a ``no_recompile()`` region."""


def _engine_compiles(engine) -> int:
    total = getattr(engine, "total_compiles", None)
    if callable(total):
        return int(total())
    return int(getattr(engine, "compiles", 0))


@contextlib.contextmanager
def no_recompile(
    allowed: int = 0, engines: Sequence[object] = ()
) -> Iterator[None]:
    """Assert at most ``allowed`` XLA builds happen inside the block.

    ``engines`` may hold any objects exposing a ``compiles`` attribute or
    ``total_compiles()`` method (both serve engines do); their deltas are
    checked alongside the process-global monitoring counter.
    """
    from repro.obs import xla

    xla.ensure_subscribed()
    before_builds = xla.builds_total()
    before_engines = [_engine_compiles(e) for e in engines]
    yield
    build_delta = xla.builds_total() - before_builds
    engine_delta = sum(
        _engine_compiles(e) - b for e, b in zip(engines, before_engines)
    )
    worst = max(build_delta, engine_delta)
    if worst > allowed:
        detail = f"{build_delta} XLA build(s) observed via jax.monitoring"
        if engines:
            detail += f", {engine_delta} via engine compile counters"
        raise RecompileError(
            f"no_recompile(allowed={allowed}) violated: {detail}. "
            "Something inside the guarded region traced a new program "
            "signature — check for shape drift, fresh jit wrappers, or "
            "un-warmed code paths."
        )
