"""Baseline file: known findings the analyzer tolerates.

The baseline lets the analyzer land on a codebase with pre-existing
findings and still gate CI on *new* violations only.  It stores one
:attr:`Finding.fingerprint` per line — ``path::code::stripped-line-text``
— deliberately line-number-free, so baselined findings survive edits
elsewhere in the file but resurface as soon as the offending line itself
is touched.

Duplicate fingerprints (two identical violating lines in one file) are
handled with counts: a baseline entry absorbs at most as many findings as
it has occurrences in the file.

Format: plain text, ``#`` comments and blank lines ignored, sorted on
write.  Regenerate with ``python -m repro.analysis --write-baseline``.
"""

from __future__ import annotations

import collections
from typing import Iterable, List, Sequence, Tuple

from repro.analysis.core import Finding

DEFAULT_BASELINE = ".rpa-baseline.txt"

_HEADER = """\
# repro.analysis baseline — known findings tolerated by CI.
# One fingerprint per line: path::code::stripped-line-text
# Regenerate: PYTHONPATH=src python -m repro.analysis src tests benchmarks --write-baseline
"""


def load(path: str) -> collections.Counter:
    """Fingerprint -> tolerated count.  Missing file -> empty baseline."""
    counts: collections.Counter = collections.Counter()
    try:
        with open(path, "r", encoding="utf-8") as fh:
            for raw in fh:
                line = raw.strip()
                if line and not line.startswith("#"):
                    counts[line] += 1
    except FileNotFoundError:
        pass
    return counts


def save(path: str, findings: Iterable[Finding]) -> int:
    """Write the baseline for ``findings``; returns entries written."""
    fps = sorted(f.fingerprint for f in findings)
    with open(path, "w", encoding="utf-8") as fh:
        fh.write(_HEADER)
        for fp in fps:
            fh.write(fp + "\n")
    return len(fps)


def filter_new(
    findings: Sequence[Finding], baseline: collections.Counter
) -> Tuple[List[Finding], int]:
    """Split findings into (new, n_baselined).

    Each baseline fingerprint absorbs up to its count; extra occurrences
    of the same line are new findings.
    """
    budget = collections.Counter(baseline)
    new: List[Finding] = []
    absorbed = 0
    for f in findings:
        if budget[f.fingerprint] > 0:
            budget[f.fingerprint] -= 1
            absorbed += 1
        else:
            new.append(f)
    return new, absorbed
