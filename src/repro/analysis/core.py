"""Analyzer driver: findings, noqa suppression, file walking.

Pure stdlib (``ast`` + ``re``) so ``python -m repro.analysis`` runs in any
environment — including the CI lint job, which deliberately installs
nothing.  The jax-importing runtime half of the package lives in
``repro.analysis.guards`` and is *not* imported here or by
``repro.analysis.__init__``.

A :class:`Finding` is one rule violation.  Its ``fingerprint`` — path,
rule code, and the *stripped source line text* (not the line number) — is
what the baseline file stores, so baselined findings survive unrelated
edits that shift line numbers but resurface the moment the offending line
itself changes.

Suppression: ``# noqa`` on the violation line (or any line of the
violating expression, for multi-line calls) suppresses every rule;
``# noqa: RPA004`` or ``# noqa: RPA002, RPA005`` suppresses just those
codes.  Trailing prose after the codes is allowed and encouraged —
``# noqa: RPA005 — sanctioned sync point (honest TTFT)`` documents *why*
the invariant is waived at this site.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

_NOQA_RE = re.compile(r"#\s*noqa\b(?P<rest>[^#]*)", re.IGNORECASE)
_CODE_RE = re.compile(r"[A-Z]{3}\d{3}")

#: sentinel meaning "a bare ``# noqa`` — every code suppressed"
ALL_CODES = frozenset({"*"})


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    path: str          # normalized, forward-slash, relative path
    line: int          # 1-indexed
    col: int           # 0-indexed
    code: str          # "RPA001".."RPA006" ("RPA000" = unparseable file)
    message: str
    line_text: str = ""   # stripped source line (baseline fingerprint key)

    @property
    def fingerprint(self) -> str:
        return f"{self.path}::{self.code}::{self.line_text}"

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"


def _parse_noqa(lines: Sequence[str]) -> Dict[int, frozenset]:
    """Map 1-indexed line number -> set of suppressed codes (or ALL_CODES)."""
    out: Dict[int, frozenset] = {}
    for i, text in enumerate(lines):
        if "noqa" not in text.lower():
            continue
        m = _NOQA_RE.search(text)
        if not m:
            continue
        rest = m.group("rest") or ""
        codes = frozenset(_CODE_RE.findall(rest)) if ":" in rest else frozenset()
        out[i + 1] = codes or ALL_CODES
    return out


class ModuleContext:
    """One parsed module handed to every rule.

    Rules report through :meth:`emit`, which applies noqa suppression and
    records the finding.  ``path`` is the normalized relative path —
    several rules key their scope off it (kernel layering, sanctioned jit
    factories, benchmark/example allowances).
    """

    def __init__(self, path: str, tree: ast.Module, source: str):
        self.path = path.replace(os.sep, "/")
        self.tree = tree
        self.lines = source.splitlines()
        self.findings: List[Finding] = []
        self._noqa = _parse_noqa(self.lines)

    def _suppressed(self, code: str, line: int, end_line: int) -> bool:
        for ln in range(line, min(end_line, line + 9) + 1):
            codes = self._noqa.get(ln)
            if codes is not None and (codes is ALL_CODES or code in codes
                                      or "*" in codes):
                return True
        return False

    def line_text(self, line: int) -> str:
        if 0 < line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def emit(self, node: ast.AST, code: str, message: str) -> None:
        line = getattr(node, "lineno", 1) or 1
        end = getattr(node, "end_lineno", None) or line
        if self._suppressed(code, line, end):
            return
        self.findings.append(Finding(
            path=self.path, line=line, col=getattr(node, "col_offset", 0) or 0,
            code=code, message=message, line_text=self.line_text(line),
        ))


# ---------------------------------------------------------------------------
# shared AST helpers (used by several rules)
# ---------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def assigned_names(node: ast.AST) -> List[str]:
    """Every plain name bound by an assignment target / loop target."""
    out: List[str] = []
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and isinstance(n.ctx, (ast.Store, ast.Del)):
            out.append(n.id)
    return out


def statement_targets(stmt: ast.stmt) -> List[str]:
    """Names (re)bound by one statement, if it is an assignment."""
    if isinstance(stmt, ast.Assign):
        out: List[str] = []
        for t in stmt.targets:
            out.extend(assigned_names(t))
        return out
    if isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        return assigned_names(stmt.target)
    return []


def statement_exprs(stmt: ast.stmt) -> List[ast.expr]:
    """The expression children of a simple statement, in evaluation order."""
    if isinstance(stmt, ast.Assign):
        return [stmt.value]
    if isinstance(stmt, ast.AnnAssign):
        return [stmt.value] if stmt.value is not None else []
    if isinstance(stmt, ast.AugAssign):
        return [stmt.value]
    return [c for c in ast.iter_child_nodes(stmt) if isinstance(c, ast.expr)]


def walk_no_scope(node: ast.AST) -> Iterable[ast.AST]:
    """ast.walk, but does not descend into nested function/class scopes."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef,
                          ast.Lambda)):
            continue
        yield n
        stack.extend(ast.iter_child_nodes(n))


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def analyze_source(
    path: str, source: str, select: Optional[Sequence[str]] = None
) -> List[Finding]:
    """Run every (selected) rule over one module's source text."""
    from repro.analysis import rules as rules_mod

    norm = path.replace(os.sep, "/")
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(
            path=norm, line=e.lineno or 1, col=(e.offset or 1) - 1,
            code="RPA000", message=f"unparseable module: {e.msg}",
        )]
    ctx = ModuleContext(norm, tree, source)
    for code, rule in rules_mod.RULES.items():
        if select and code not in select:
            continue
        rule.check(ctx)
    return sorted(ctx.findings, key=lambda f: (f.line, f.col, f.code))


def iter_python_files(paths: Sequence[str]) -> List[str]:
    """Expand files/directories to a sorted list of .py files (relative
    paths preserved as given; ``__pycache__`` skipped)."""
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                out.append(p)
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs
                             if d != "__pycache__" and not d.startswith("."))
            for f in sorted(files):
                if f.endswith(".py"):
                    out.append(os.path.join(root, f))
    # de-dup while keeping deterministic order
    seen, uniq = set(), []
    for f in sorted(out):
        n = os.path.normpath(f).replace(os.sep, "/")
        if n not in seen:
            seen.add(n)
            uniq.append(f)
    return uniq


def analyze_paths(
    paths: Sequence[str], select: Optional[Sequence[str]] = None
) -> Tuple[List[Finding], int]:
    """Analyze every .py under ``paths``; returns (findings, files_scanned)."""
    findings: List[Finding] = []
    files = iter_python_files(paths)
    for f in files:
        try:
            with open(f, "r", encoding="utf-8") as fh:
                src = fh.read()
        except (OSError, UnicodeDecodeError) as e:
            findings.append(Finding(
                path=f.replace(os.sep, "/"), line=1, col=0, code="RPA000",
                message=f"unreadable module: {e}",
            ))
            continue
        findings.extend(analyze_source(f, src, select=select))
    return findings, len(files)
