"""CLI driver: ``python -m repro.analysis [paths...]``.

Exit status: 0 when every finding is baselined (or none exist), 1 when
new findings remain, 2 on usage errors.  ``--write-baseline`` records the
current findings as the tolerated set; CI runs without it and therefore
fails only on violations introduced since the baseline was committed.
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

from repro.analysis import baseline as baseline_mod
from repro.analysis.core import analyze_paths
from repro.analysis.rules import RULES


def _discover_baseline(start: str) -> Optional[str]:
    """Walk from ``start`` upward looking for the default baseline file."""
    d = os.path.abspath(start)
    while True:
        cand = os.path.join(d, baseline_mod.DEFAULT_BASELINE)
        if os.path.isfile(cand):
            return cand
        parent = os.path.dirname(d)
        if parent == d:
            return None
        d = parent


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static invariant checker (rules RPA001-RPA006).",
    )
    parser.add_argument(
        "paths", nargs="*", default=["src"],
        help="files or directories to scan (default: src)",
    )
    parser.add_argument(
        "--baseline", metavar="FILE", default=None,
        help=f"baseline file (default: nearest {baseline_mod.DEFAULT_BASELINE}"
             " in cwd or parents)",
    )
    parser.add_argument(
        "--no-baseline", action="store_true",
        help="ignore any baseline file; report every finding",
    )
    parser.add_argument(
        "--write-baseline", action="store_true",
        help="write current findings to the baseline file and exit 0",
    )
    parser.add_argument(
        "--select", metavar="CODES", default=None,
        help="comma-separated rule codes to run (e.g. RPA004,RPA006)",
    )
    parser.add_argument(
        "--report", metavar="FILE", default=None,
        help="also write the findings report to FILE",
    )
    parser.add_argument(
        "--list-rules", action="store_true",
        help="print the rule table and exit",
    )
    args = parser.parse_args(argv)
    out = sys.stdout

    if args.list_rules:
        for code, rule in sorted(RULES.items()):
            out.write(f"{code}  {rule.summary}\n")
        return 0

    select = None
    if args.select:
        select = [c.strip().upper() for c in args.select.split(",") if c.strip()]
        unknown = [c for c in select if c not in RULES]
        if unknown:
            sys.stderr.write(f"unknown rule code(s): {', '.join(unknown)}\n")
            return 2

    missing = [p for p in args.paths if not os.path.exists(p)]
    if missing:
        sys.stderr.write(f"no such path(s): {', '.join(missing)}\n")
        return 2

    findings, n_files = analyze_paths(args.paths, select=select)

    baseline_path = args.baseline
    if baseline_path is None and not args.no_baseline:
        baseline_path = _discover_baseline(os.getcwd())
    if args.write_baseline:
        baseline_path = baseline_path or baseline_mod.DEFAULT_BASELINE
        n = baseline_mod.save(baseline_path, findings)
        out.write(f"wrote {n} fingerprint(s) to {baseline_path}\n")
        return 0

    absorbed = 0
    if baseline_path and not args.no_baseline:
        findings, absorbed = baseline_mod.filter_new(
            findings, baseline_mod.load(baseline_path)
        )

    lines = [f.render() for f in findings]
    report = "\n".join(lines)
    if args.report:
        with open(args.report, "w", encoding="utf-8") as fh:
            fh.write(report + ("\n" if report else ""))
    if report:
        out.write(report + "\n")

    summary = f"{len(findings)} new finding(s) across {n_files} file(s)"
    if absorbed:
        summary += f" ({absorbed} baselined)"
    out.write(summary + "\n")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
