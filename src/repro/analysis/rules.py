"""The seven RPA rules: the repo's runtime invariants as static checks.

| code   | invariant it guards                                               |
|--------|-------------------------------------------------------------------|
| RPA001 | zero steady-state recompiles: no ``jax.jit`` / ``lower().compile``|
|        | inside loops outside the sanctioned AOT factories, no unhashable  |
|        | static args (every call would retrace)                            |
| RPA002 | greedy token identity: a PRNG key is consumed at most once —      |
|        | reuse forks the reference key chain silently                      |
| RPA003 | donated buffers are dead after the call: ``donate_argnums`` args  |
|        | alias the output, reading them afterwards is use-after-free       |
| RPA004 | kernel discipline: every ``pallas_call`` resolves interpret mode  |
|        | through ``kernels.runtime.pallas_interpret``; kernel/ref modules  |
|        | import nothing above the kernels layer                            |
| RPA005 | sync-point harvesting: no hidden host syncs (``.item()``,         |
|        | ``np.asarray``, ``block_until_ready``...) inside traced scopes or |
|        | the engines' steady-state step functions                          |
| RPA006 | structured logging: no bare ``print(`` outside benchmarks/        |
|        | examples/scripts (use ``repro.obs.get_logger``)                   |
| RPA007 | host scheduler/chaos/router layer discipline:                     |
|        | ``serve/scheduler.py``, ``serve/router.py``, and ``net/chaos.py`` |
|        | stay on the engine's public host API — no jitted engine           |
|        | internals, no device syncs outside the sanctioned points          |

Rules are heuristic by design: they encode this repo's conventions (which
factories are sanctioned, which files are the kernel layer), favor few
false positives over completeness, and every finding can be waived with a
``# noqa: RPA###`` carrying its justification.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import (
    ModuleContext,
    assigned_names,
    dotted_name,
    statement_exprs,
    statement_targets,
    walk_no_scope,
)

RULES: Dict[str, "Rule"] = {}


@dataclasses.dataclass(frozen=True)
class Rule:
    code: str
    summary: str
    check: Callable[[ModuleContext], None]


def _rule(code: str, summary: str):
    def deco(fn):
        RULES[code] = Rule(code, summary, fn)
        return fn
    return deco


# ---------------------------------------------------------------------------
# RPA001 — retrace hazards
# ---------------------------------------------------------------------------

_JIT_NAMES = {"jax.jit", "jit", "jax.pjit", "pjit"}
# Modules whose whole job is building jitted programs inside scheduling
# loops (compile-cached / AOT): jit-in-loop is their design, not a hazard.
_SANCTIONED_JIT_FILES = (
    "repro/serve/engine.py",
    "repro/serve/continuous.py",
    "repro/launch/steps.py",
)
_UNHASHABLE_ANNOTATIONS = {"list", "dict", "set", "List", "Dict", "Set",
                           "bytearray"}


def _is_jit_call(call: ast.Call) -> bool:
    return dotted_name(call.func) in _JIT_NAMES


def _is_aot_compile(call: ast.Call) -> bool:
    """``<anything>.lower(...).compile(...)`` — an explicit XLA build."""
    f = call.func
    return (
        isinstance(f, ast.Attribute) and f.attr == "compile"
        and isinstance(f.value, ast.Call)
        and isinstance(f.value.func, ast.Attribute)
        and f.value.func.attr == "lower"
    )


def _static_spec(call: ast.Call) -> Tuple[List[int], List[str]]:
    """(static_argnums, static_argnames) literal values of a jit call."""
    nums: List[int] = []
    names: List[str] = []

    def ints(v):
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return [v.value]
        if isinstance(v, (ast.Tuple, ast.List)):
            return [e.value for e in v.elts
                    if isinstance(e, ast.Constant) and isinstance(e.value, int)]
        return []

    def strs(v):
        if isinstance(v, ast.Constant) and isinstance(v.value, str):
            return [v.value]
        if isinstance(v, (ast.Tuple, ast.List)):
            return [e.value for e in v.elts
                    if isinstance(e, ast.Constant) and isinstance(e.value, str)]
        return []

    for kw in call.keywords:
        if kw.arg == "static_argnums":
            nums = ints(kw.value)
        elif kw.arg == "static_argnames":
            names = strs(kw.value)
    return nums, names


def _unhashable_static_params(
    fn: ast.FunctionDef, nums: Sequence[int], names: Sequence[str]
) -> List[str]:
    """Static params whose default or annotation is an unhashable type."""
    params = list(fn.args.posonlyargs) + list(fn.args.args)
    picked = {params[i].arg for i in nums if 0 <= i < len(params)}
    picked.update(n for n in names if any(p.arg == n for p in params))
    # align defaults to the tail of the positional params
    defaults = {
        params[len(params) - len(fn.args.defaults) + i].arg: d
        for i, d in enumerate(fn.args.defaults)
    }
    bad: List[str] = []
    for p in params:
        if p.arg not in picked:
            continue
        d = defaults.get(p.arg)
        if isinstance(d, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                          ast.DictComp, ast.SetComp)):
            bad.append(p.arg)
            continue
        ann = p.annotation
        base = None
        if isinstance(ann, ast.Name):
            base = ann.id
        elif isinstance(ann, ast.Subscript) and isinstance(ann.value, ast.Name):
            base = ann.value.id
        if base in _UNHASHABLE_ANNOTATIONS:
            bad.append(p.arg)
    return bad


@_rule("RPA001", "retrace hazard: jit/AOT-compile in a loop or "
                 "unhashable static args")
def rule_retrace_hazard(ctx: ModuleContext) -> None:
    defs = {n.name: n for n in ast.walk(ctx.tree)
            if isinstance(n, ast.FunctionDef)}
    sanctioned = ctx.path.endswith(_SANCTIONED_JIT_FILES)

    # (a) jit / lower().compile() lexically inside a loop body — every
    # iteration traces and builds a fresh program.
    def scan(node: ast.AST, loop_depth: int) -> None:
        for child in ast.iter_child_nodes(node):
            depth = loop_depth
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                depth = 0    # a def in a loop runs its body only when called
            elif isinstance(child, (ast.For, ast.AsyncFor, ast.While)):
                depth = loop_depth + 1
            elif depth and isinstance(child, ast.Call) and (
                _is_jit_call(child) or _is_aot_compile(child)
            ):
                if not sanctioned:
                    what = ("jax.jit" if _is_jit_call(child)
                            else "lower().compile()")
                    ctx.emit(
                        child, "RPA001",
                        f"{what} inside a loop — one XLA build per iteration; "
                        "hoist it or route through a sanctioned AOT factory "
                        "(serve/engine.py, serve/continuous.py, "
                        "launch/steps.py)",
                    )
                continue   # one finding per chain: don't re-flag the inner jit
            scan(child, depth)

    scan(ctx.tree, 0)

    # (b) static args that cannot hash: every call is a cache miss.
    def check_spec(call: ast.Call, fn: Optional[ast.FunctionDef]) -> None:
        nums, names = _static_spec(call)
        if not (nums or names) or fn is None:
            return
        for p in _unhashable_static_params(fn, nums, names):
            ctx.emit(
                call, "RPA001",
                f"static arg {p!r} of jitted {fn.name!r} has an unhashable "
                "default/annotation — every call re-traces (static args must "
                "hash)",
            )

    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) and _is_jit_call(node):
            target = None
            if node.args and isinstance(node.args[0], ast.Name):
                target = defs.get(node.args[0].id)
            check_spec(node, target)
        # decorator form: @partial(jax.jit, static_argnames=...)
        if isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                if (isinstance(dec, ast.Call)
                        and dotted_name(dec.func) in ("partial",
                                                      "functools.partial")
                        and dec.args
                        and dotted_name(dec.args[0]) in _JIT_NAMES):
                    check_spec(dec, node)


# ---------------------------------------------------------------------------
# RPA002 — PRNG key reuse
# ---------------------------------------------------------------------------

# jax.random.* calls that do NOT count as consuming their key argument:
# fold_in derives a fresh stream per (key, data) — calling it repeatedly
# with different data is the sanctioned per-request pattern — and the
# constructors/converters don't draw from the stream at all.
_KEY_EXEMPT = {"fold_in", "PRNGKey", "key", "wrap_key_data", "key_data",
               "clone", "key_impl", "typing"}


def _random_prefixes(tree: ast.Module) -> Tuple[str, ...]:
    """Call prefixes that mean jax.random in this module (alias-aware).
    Plain stdlib ``import random`` does NOT register ``random.``."""
    prefixes = ["jax.random."]
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module == "jax":
            for a in node.names:
                if a.name == "random":
                    prefixes.append((a.asname or a.name) + ".")
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "jax.random" and a.asname:
                    prefixes.append(a.asname + ".")
    return tuple(prefixes)


def _key_consumes(expr: ast.AST, prefixes) -> List[Tuple[str, ast.Call]]:
    """(key_name, call) for each consuming jax.random call in the expr,
    in source order.  Only bare-Name first arguments are tracked."""
    out = []
    for node in ast.walk(expr):
        if not isinstance(node, ast.Call):
            continue
        d = dotted_name(node.func)
        if not d or not d.startswith(prefixes):
            continue
        fname = d.rsplit(".", 1)[-1]
        if fname in _KEY_EXEMPT:
            continue
        if node.args and isinstance(node.args[0], ast.Name):
            out.append((node.args[0].id, node))
    out.sort(key=lambda t: (t[1].lineno, t[1].col_offset))
    return out


@_rule("RPA002", "PRNG key consumed twice without split/fold_in")
def rule_key_reuse(ctx: ModuleContext) -> None:
    prefixes = _random_prefixes(ctx.tree)

    def _imports_jax(n: ast.AST) -> bool:
        if isinstance(n, ast.Import):
            return any(a.name.split(".")[0] == "jax" for a in n.names)
        if isinstance(n, ast.ImportFrom):
            return bool(n.module) and n.module.split(".")[0] == "jax"
        return False

    if not any(_imports_jax(n) for n in ast.walk(ctx.tree)):
        return

    def visit_expr(expr: ast.AST, consumed: Dict[str, ast.Call]) -> None:
        # comprehensions are loops: a consume of an outer key inside one
        # runs once per element — reuse unless the key is comp-bound.
        for comp in ast.walk(expr):
            if isinstance(comp, (ast.ListComp, ast.SetComp, ast.DictComp,
                                 ast.GeneratorExp)):
                bound = set()
                for g in comp.generators:
                    bound.update(assigned_names(g.target))
                for name, call in _key_consumes(comp, prefixes):
                    if name not in bound:
                        ctx.emit(
                            call, "RPA002",
                            f"PRNG key {name!r} consumed inside a "
                            "comprehension — one draw per element reuses the "
                            "key; split it or fold_in per element",
                        )
        for name, call in _key_consumes(expr, prefixes):
            if name in consumed:
                prev = consumed[name]
                ctx.emit(
                    call, "RPA002",
                    f"PRNG key {name!r} already consumed on line "
                    f"{prev.lineno} — reuse forks the key chain; "
                    "split/fold_in first",
                )
            else:
                consumed[name] = call

    def process(body: Sequence[ast.stmt], consumed: Dict[str, ast.Call]):
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue       # separate scope, analyzed on its own
            if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
                head = stmt.iter if isinstance(
                    stmt, (ast.For, ast.AsyncFor)) else stmt.test
                visit_expr(head, consumed)
                # a consume inside the loop body of a key neither bound by
                # the loop target nor reassigned in the body repeats the
                # same draw every iteration
                rebound: Set[str] = set()
                if isinstance(stmt, (ast.For, ast.AsyncFor)):
                    rebound.update(assigned_names(stmt.target))
                for s in stmt.body:
                    for n in walk_no_scope(s):
                        if isinstance(n, ast.Name) and isinstance(
                                n.ctx, ast.Store):
                            rebound.add(n.id)
                    rebound.update(statement_targets(s) if isinstance(
                        s, ast.stmt) else [])
                flagged: Set[str] = set()
                for s in stmt.body:
                    if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.ClassDef)):
                        continue
                    for e in statement_exprs(s):
                        for name, call in _key_consumes(e, prefixes):
                            if name not in rebound and name not in flagged:
                                flagged.add(name)
                                ctx.emit(
                                    call, "RPA002",
                                    f"PRNG key {name!r} consumed inside a "
                                    "loop without reassignment — every "
                                    "iteration redraws from the same key",
                                )
                process(stmt.body, consumed)
                process(stmt.orelse, consumed)
            elif isinstance(stmt, ast.If):
                visit_expr(stmt.test, consumed)
                c_then = dict(consumed)
                c_else = dict(consumed)
                process(stmt.body, c_then)
                process(stmt.orelse, c_else)
                consumed.update(c_then)
                consumed.update(c_else)
            elif isinstance(stmt, ast.With):
                for item in stmt.items:
                    visit_expr(item.context_expr, consumed)
                process(stmt.body, consumed)
            elif isinstance(stmt, ast.Try):
                process(stmt.body, consumed)
                for h in stmt.handlers:
                    process(h.body, consumed)
                process(stmt.orelse, consumed)
                process(stmt.finalbody, consumed)
            else:
                for e in statement_exprs(stmt):
                    visit_expr(e, consumed)
                for t in statement_targets(stmt):
                    consumed.pop(t, None)

    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            process(node.body, {})
    process(ctx.tree.body, {})


# ---------------------------------------------------------------------------
# RPA003 — donation after use
# ---------------------------------------------------------------------------

def _donated_positions(call: ast.Call,
                       defs: Dict[str, ast.FunctionDef]) -> List[int]:
    """Literal donate_argnums positions of a jit call (donate_argnames are
    resolved through the wrapped function's signature when it is a
    module-local def)."""
    nums: List[int] = []
    names: List[str] = []
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                nums.append(v.value)
            elif isinstance(v, (ast.Tuple, ast.List)):
                nums.extend(e.value for e in v.elts
                            if isinstance(e, ast.Constant)
                            and isinstance(e.value, int))
        elif kw.arg == "donate_argnames":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                names.append(v.value)
            elif isinstance(v, (ast.Tuple, ast.List)):
                names.extend(e.value for e in v.elts
                             if isinstance(e, ast.Constant)
                             and isinstance(e.value, str))
    if names and call.args and isinstance(call.args[0], ast.Name):
        fn = defs.get(call.args[0].id)
        if fn is not None:
            params = [p.arg for p in
                      list(fn.args.posonlyargs) + list(fn.args.args)]
            nums.extend(params.index(n) for n in names if n in params)
    return sorted(set(nums))


def _innermost_jit(call: ast.Call) -> Optional[ast.Call]:
    """Unwrap ``jax.jit(...)``, ``jax.jit(...).lower(...).compile()``."""
    node: ast.AST = call
    for _ in range(6):
        if isinstance(node, ast.Call):
            if _is_jit_call(node):
                return node
            node = node.func
        elif isinstance(node, ast.Attribute):
            node = node.value
        else:
            return None
    return None


@_rule("RPA003", "buffer referenced after being donated to a jitted call")
def rule_donation_after_use(ctx: ModuleContext) -> None:
    defs = {n.name: n for n in ast.walk(ctx.tree)
            if isinstance(n, ast.FunctionDef)}

    def scan_scope(body: Sequence[ast.stmt]) -> None:
        donators: Dict[str, List[int]] = {}     # fn var -> donated positions
        donated: Dict[str, ast.Call] = {}       # buffer var -> donating call

        def visit_expr(expr: ast.AST) -> None:
            nodes = [n for n in ast.walk(expr)]
            nodes.sort(key=lambda n: (getattr(n, "lineno", 0),
                                      getattr(n, "col_offset", 0)))
            for n in nodes:
                if isinstance(n, ast.Call):
                    positions: List[int] = []
                    if (isinstance(n.func, ast.Name)
                            and n.func.id in donators):
                        positions = donators[n.func.id]
                    else:
                        inner = (_innermost_jit(n.func)
                                 if isinstance(n.func, ast.Call) else None)
                        if inner is not None:
                            positions = _donated_positions(inner, defs)
                    for p in positions:
                        if p < len(n.args) and isinstance(n.args[p], ast.Name):
                            donated.setdefault(n.args[p].id, n)
                elif (isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
                        and n.id in donated):
                    call = donated[n.id]
                    # the donating call's own argument read is not a use-after
                    if (n.lineno, n.col_offset) > (call.lineno,
                                                   call.col_offset) and not (
                        call.lineno <= n.lineno <= (call.end_lineno or
                                                    call.lineno)
                    ):
                        ctx.emit(
                            n, "RPA003",
                            f"{n.id!r} was donated to the jitted call on "
                            f"line {call.lineno} (donate_argnums) — its "
                            "buffer is aliased to the output; reading it "
                            "after the call is use-after-donation",
                        )
                        donated.pop(n.id, None)

        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            for e in statement_exprs(stmt):
                visit_expr(e)
            for t in statement_targets(stmt):
                donated.pop(t, None)
                donators.pop(t, None)
            # record jit-with-donation factories:  f = jax.jit(step, donate...)
            # (after the target pop, so the fresh binding survives)
            if isinstance(stmt, ast.Assign) and isinstance(
                    stmt.value, ast.Call):
                inner = _innermost_jit(stmt.value)
                if inner is not None:
                    pos = _donated_positions(inner, defs)
                    if pos:
                        for t in stmt.targets:
                            if isinstance(t, ast.Name):
                                donators[t.id] = pos
            if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While, ast.If,
                                 ast.With, ast.Try)):
                for sub in (getattr(stmt, "body", []),
                            getattr(stmt, "orelse", []),
                            getattr(stmt, "finalbody", [])):
                    if sub:
                        scan_scope(sub)

    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scan_scope(node.body)
    scan_scope(ctx.tree.body)


# ---------------------------------------------------------------------------
# RPA004 — Pallas discipline
# ---------------------------------------------------------------------------

_KERNEL_FILE_RE = re.compile(r"(?:^|/)kernels/[^/]+/(kernel|ref|ops)\.py$")
# imports allowed per kernel-package layer: kernel/ref are the bottom of
# the stack (jax/pallas/numpy + the shared kernels runtime only); ops.py
# is the model-facing boundary and may additionally reach repro.core
# specs (QuantSpec etc.) — never models/serve/launch/net/obs.
_KERNEL_LAYER_ALLOWED = {
    "kernel": ("repro.kernels",),
    "ref": ("repro.kernels",),
    "ops": ("repro.kernels", "repro.core"),
}


@_rule("RPA004", "pallas_call with literal interpret= or kernel-layer "
                 "import violation")
def rule_pallas_discipline(ctx: ModuleContext) -> None:
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            d = dotted_name(node.func)
            if d and (d == "pallas_call" or d.endswith(".pallas_call")):
                for kw in node.keywords:
                    if kw.arg == "interpret" and isinstance(
                            kw.value, ast.Constant):
                        ctx.emit(
                            kw.value, "RPA004",
                            f"pallas_call(interpret={kw.value.value!r}) "
                            "hardcodes the execution mode — resolve it "
                            "through kernels.runtime.pallas_interpret() so "
                            "backend detection and REPRO_PALLAS_INTERPRET "
                            "keep working",
                        )

    m = _KERNEL_FILE_RE.search(ctx.path)
    if not m:
        return
    allowed = _KERNEL_LAYER_ALLOWED[m.group(1)]
    for node in ast.walk(ctx.tree):
        mods: List[Tuple[str, ast.AST]] = []
        if isinstance(node, ast.Import):
            mods = [(a.name, node) for a in node.names]
        elif isinstance(node, ast.ImportFrom) and node.module:
            mods = [(node.module, node)]
        for mod, n in mods:
            if mod.startswith("repro") and not mod.startswith(allowed):
                ctx.emit(
                    n, "RPA004",
                    f"{m.group(1)}.py imports {mod!r} — kernel packages "
                    "must stay below the model/serve layers "
                    f"(allowed prefixes: {', '.join(allowed)})",
                )


# ---------------------------------------------------------------------------
# RPA005 — hidden host syncs in traced / steady-state scopes
# ---------------------------------------------------------------------------

_TRANSFORM_NAMES = {
    "jax.jit", "jit", "jax.pjit", "pjit", "jax.vmap", "vmap", "jax.pmap",
    "pmap", "jax.grad", "grad", "jax.value_and_grad", "value_and_grad",
    "jax.checkpoint", "jax.remat", "jax.lax.scan", "lax.scan",
    "jax.lax.while_loop", "lax.while_loop", "jax.lax.fori_loop",
    "lax.fori_loop", "jax.lax.cond", "lax.cond", "jax.lax.map", "lax.map",
    "shard_map", "jax.experimental.shard_map.shard_map",
}
_SYNC_CALLS = {
    "np.asarray", "np.array", "numpy.asarray", "numpy.array",
    "jax.device_get", "device_get",
}
_STEADY_STATE = {
    "repro/serve/continuous.py": {
        "_decode_once", "_admit", "try_admit", "preempt_slot", "step",
    },
    "repro/serve/engine.py": set(),
}


def _decorated_as_traced(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        d = dotted_name(dec)
        if d in _TRANSFORM_NAMES:
            return True
        if isinstance(dec, ast.Call):
            d = dotted_name(dec.func)
            if d in _TRANSFORM_NAMES:
                return True
            if d in ("partial", "functools.partial") and dec.args and \
                    dotted_name(dec.args[0]) in _TRANSFORM_NAMES:
                return True
    return False


def _transform_arg_names(tree: ast.Module) -> Set[str]:
    """Function names passed (by name) to a jax transform anywhere in the
    module — their bodies run under trace."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and dotted_name(
                node.func) in _TRANSFORM_NAMES:
            for a in node.args:
                if isinstance(a, ast.Name):
                    out.add(a.id)
    return out


def _steady_state_names(path: str) -> Set[str]:
    for suffix, names in _STEADY_STATE.items():
        if path.endswith(suffix):
            return set(names)
    return set()


@_rule("RPA005", "hidden host sync inside a traced or steady-state scope")
def rule_hidden_host_sync(ctx: ModuleContext) -> None:
    traced_names = _transform_arg_names(ctx.tree)
    steady = _steady_state_names(ctx.path)
    in_steps_factory_file = ctx.path.endswith("repro/launch/steps.py")

    def flag_syncs(fn: ast.FunctionDef, why: str) -> None:
        for node in walk_no_scope(fn):
            if not isinstance(node, ast.Call):
                continue
            d = dotted_name(node.func)
            msg = None
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item" and not node.args:
                msg = ".item() forces a device->host sync"
            elif d in _SYNC_CALLS:
                msg = f"{d}() materializes the value on host"
            elif d and d.endswith("block_until_ready"):
                msg = "block_until_ready blocks the host on device work"
            elif (isinstance(node.func, ast.Name)
                    and node.func.id in ("float", "int")
                    and len(node.args) == 1
                    and isinstance(node.args[0], ast.Name)):
                msg = (f"{node.func.id}() on a traced value forces a "
                       "device->host sync")
            if msg:
                ctx.emit(
                    node, "RPA005",
                    f"{msg} inside {why} — harvest at an existing sync "
                    "point instead (see obs/device.py), or waive with a "
                    "justified noqa",
                )

    def walk_defs(node: ast.AST, traced: bool, factory: bool) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                name = child.name
                child_factory = name.startswith("_make_") or (
                    in_steps_factory_file
                    and (name.startswith("make_")
                         or name.startswith("build_"))
                )
                child_traced = (
                    traced
                    or factory            # defs nested in a step factory
                    or _decorated_as_traced(child)
                    or name in traced_names
                )
                if isinstance(child, ast.FunctionDef):
                    if child_traced:
                        flag_syncs(child, f"jit-traced scope {name!r}")
                    elif name in steady:
                        flag_syncs(
                            child,
                            f"steady-state engine path {name!r}",
                        )
                walk_defs(child, child_traced, child_factory)
            else:
                walk_defs(child, traced, factory)

    walk_defs(ctx.tree, False, False)


# ---------------------------------------------------------------------------
# RPA007 — host scheduler/chaos layer discipline
# ---------------------------------------------------------------------------

# The SLA scheduler, the chaos harness, and the sharded-serving router
# are pure HOST layers over the continuous engine: they read host
# mirrors and drive admission through the public API (try_admit /
# preempt_slot / running_slots / blocks_held / free_block_count /
# blocks_needed).  The whole design depends on that: a scheduler — or a
# router placing requests across per-device shards — that touches
# jitted engine internals can silently add a per-step host sync or an
# XLA build, breaking the zero-steady-state-recompile and per-shard
# compile-count contracts without any test noticing until the guard
# trips in CI.  This rule pins the boundary statically.
_HOST_LAYER_FILES = (
    "repro/serve/scheduler.py",
    "repro/serve/router.py",
    "repro/net/chaos.py",
)
# Engine members that are (or lead to) compiled-program / device-state
# machinery.  NOT listed: ``_free_blocks`` — the host-side block
# allocator IS the chaos squeeze's sanctioned surface (documented in
# net/chaos.py), and touching it moves no device bytes.
_ENGINE_INTERNALS = {
    "_state", "_decode_fn", "_prefill_fns", "_prefill_for", "_ensure",
    "_decode_once", "_deaden_slot", "_aot", "_make_decode_step",
    "_make_paged_decode_step", "_make_prefill",
}


@_rule("RPA007", "host scheduler/chaos/router layer reaching into jitted "
                 "engine internals or forcing device syncs")
def rule_host_layer_discipline(ctx: ModuleContext) -> None:
    if not ctx.path.endswith(_HOST_LAYER_FILES):
        return
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Attribute) and isinstance(
                node.ctx, (ast.Load, ast.Store)) and \
                node.attr in _ENGINE_INTERNALS:
            ctx.emit(
                node, "RPA007",
                f"host scheduling layer touches engine internal "
                f"{node.attr!r} — use the public host API (try_admit / "
                "preempt_slot / running_slots / free_block_count / "
                "blocks_needed); device work belongs in engine methods",
            )
        if not isinstance(node, ast.Call):
            continue
        d = dotted_name(node.func)
        msg = None
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr == "item" and not node.args:
            msg = ".item() forces a device->host sync"
        elif d in _SYNC_CALLS:
            msg = f"{d}() materializes the value on host"
        elif d and d.endswith("block_until_ready"):
            msg = "block_until_ready blocks the host on device work"
        if msg:
            ctx.emit(
                node, "RPA007",
                f"{msg} in the host scheduling layer — scheduling decisions "
                "must come from host mirrors; harvest device values at the "
                "engine's sanctioned sync points only",
            )


# ---------------------------------------------------------------------------
# RPA006 — bare print
# ---------------------------------------------------------------------------

_PRINT_ALLOWED_DIRS = ("benchmarks/", "examples/", "scripts/")


@_rule("RPA006", "bare print() outside benchmarks/examples")
def rule_bare_print(ctx: ModuleContext) -> None:
    parts = ctx.path.split("/")
    for d in _PRINT_ALLOWED_DIRS:
        if d.rstrip("/") in parts:
            return
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "print":
            ctx.emit(
                node, "RPA006",
                "bare print() — use repro.obs.get_logger(...) so "
                "REPRO_LOG_LEVEL and log capture keep working "
                "(benchmarks/ and examples/ are exempt)",
            )
