"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, Mamba:attention 7:1 interleave, MoE 16e top-2 on every other
layer.  [arXiv:2403.19887]

Unit of 8 layers (scanned 4x): mamba x4 / attn at index 4 / mamba x3,
MoE on odd in-unit indices (= every other layer globally).
"""

from repro.configs.base import LayerSpec, LinkConfig, ModelConfig

_M = lambda moe: LayerSpec(kind="mamba", moe=moe)
_A = lambda moe: LayerSpec(kind="attn", moe=moe)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    source="arXiv:2403.19887",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    act="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=False,
    unit_pattern=(
        _M(False), _M(True), _M(False), _M(True),
        _A(False), _M(True), _M(False), _M(True),
    ),
    num_experts=16,
    top_k=2,
    moe_dff=14336,
    capacity_factor=1.25,
    router_aux_coef=0.01,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    link=LinkConfig(split_after_units=1, dropout_rate=0.2, loss_rate=0.1,
                    compression="quant", quant_bits=8),
)
