"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128e top-2 + dense residual FFN (dense-MoE hybrid).
[hf:Snowflake/snowflake-arctic-base]"""

from repro.configs.base import LayerSpec, LinkConfig, ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    arch_type="moe",
    source="hf:Snowflake/snowflake-arctic-base",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    act="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=False,
    unit_pattern=(LayerSpec(kind="attn", moe=True),),
    num_experts=128,
    top_k=2,
    moe_dff=4864,
    dense_residual_dff=4864,   # parallel dense residual path
    capacity_factor=1.25,
    router_aux_coef=0.01,
    link=LinkConfig(split_after_units=4, dropout_rate=0.2, loss_rate=0.1,
                    compression="quant", quant_bits=8),
)
