"""qwen1.5-0.5b [dense] — 24L d_model=1024 16H (GQA kv=16) d_ff=2816
vocab=151936, QKV bias.  [hf:Qwen/Qwen1.5-0.5B]"""

from repro.configs.base import LayerSpec, LinkConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    arch_type="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    act="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    unit_pattern=(LayerSpec(kind="attn"),),
    link=LinkConfig(split_after_units=4, dropout_rate=0.2, loss_rate=0.1,
                    compression="quant", quant_bits=8),
)
