"""musicgen-medium [audio] — 48L d_model=1536 24H (kv=24) d_ff=6144
vocab=2048, decoder-only over EnCodec tokens (codec stubbed: tokens are
precomputed).  [arXiv:2306.05284]"""

from repro.configs.base import LayerSpec, LinkConfig, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    source="arXiv:2306.05284",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    act="gelu",
    gated_mlp=False,      # plain MLP, musicgen uses GELU FFN
    norm="layernorm",
    rope_theta=10000.0,
    tie_embeddings=False,
    unit_pattern=(LayerSpec(kind="attn"),),
    frontend="audio",
    frontend_len=64,      # optional conditioning frames via the adapter stub
    link=LinkConfig(split_after_units=6, dropout_rate=0.2, loss_rate=0.1,
                    compression="quant", quant_bits=8),
)
