"""Architecture registry: the 10 assigned configs + the paper's CNN."""

from __future__ import annotations

from typing import Dict

from repro.configs.base import INPUT_SHAPES, LayerSpec, LinkConfig, ModelConfig, ShapeConfig

from repro.configs.jamba_v0_1_52b import CONFIG as _jamba
from repro.configs.qwen1_5_0_5b import CONFIG as _qwen05
from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi
from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.qwen2_vl_72b import CONFIG as _qwen2vl
from repro.configs.gemma3_12b import CONFIG as _gemma3
from repro.configs.codeqwen1_5_7b import CONFIG as _codeqwen
from repro.configs.musicgen_medium import CONFIG as _musicgen
from repro.configs.gemma_7b import CONFIG as _gemma7b
from repro.configs.xlstm_350m import CONFIG as _xlstm

ARCHITECTURES: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _jamba,
        _qwen05,
        _kimi,
        _arctic,
        _qwen2vl,
        _gemma3,
        _codeqwen,
        _musicgen,
        _gemma7b,
        _xlstm,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHITECTURES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHITECTURES)}")
    return ARCHITECTURES[name]


def get_shape(name: str) -> ShapeConfig:
    return INPUT_SHAPES[name]
