"""codeqwen1.5-7b [dense] — 32L d_model=4096 32H (GQA kv=32) d_ff=13440
vocab=92416, qwen1.5-arch (QKV bias).  [hf:Qwen/CodeQwen1.5-7B]"""

from repro.configs.base import LayerSpec, LinkConfig, ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    arch_type="dense",
    source="hf:Qwen/CodeQwen1.5-7B",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    qkv_bias=True,
    act="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    unit_pattern=(LayerSpec(kind="attn"),),
    link=LinkConfig(split_after_units=4, dropout_rate=0.2, loss_rate=0.1,
                    compression="quant", quant_bits=8),
)
