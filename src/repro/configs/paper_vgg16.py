"""The paper's own DNN (Fig. 3): VGG16-style CNN for CIFAR-10, split after
block 1 (activation 16,384 dims = 65.5 kB fp32).  [arXiv:2112.09407 §IV-A]"""

from repro.models.cnn import CNNConfig

CONFIG = CNNConfig(
    blocks=((2, 64), (2, 128), (3, 256), (3, 512), (3, 512)),
    fc=(256, 128),
    num_classes=10,
    image_size=32,
    in_channels=3,
    split_block=1,
    width_scale=1.0,
)

# Reduced variant for CPU-budget benchmark runs (documented in EXPERIMENTS.md).
REDUCED = CNNConfig(
    blocks=((2, 32), (2, 64), (2, 128), (2, 128)),
    fc=(128, 64),
    num_classes=10,
    image_size=32,
    in_channels=3,
    split_block=1,
    width_scale=1.0,
)
