"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144, 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt (family card), 12B table]"""

from repro.configs.base import LayerSpec, LinkConfig, ModelConfig

_LOCAL = LayerSpec(kind="attn", window=1024)
_GLOBAL = LayerSpec(kind="attn", window=0)

CONFIG = ModelConfig(
    name="gemma3-12b",
    arch_type="dense",
    source="hf:google/gemma-3-1b-pt",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    head_dim=256,
    act="gelu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    embed_scale=True,
    tie_embeddings=True,
    # 5 local : 1 global, scanned as 8 units of 6 layers
    unit_pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    link=LinkConfig(split_after_units=1, dropout_rate=0.2, loss_rate=0.1,
                    compression="quant", quant_bits=8),
)
