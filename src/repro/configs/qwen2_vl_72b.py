"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, M-RoPE, dynamic resolution (vision tower stubbed).
[arXiv:2409.12191]"""

from repro.configs.base import LayerSpec, LinkConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    arch_type="vlm",
    source="arXiv:2409.12191",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    act="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),   # head_dim 128 -> half 64 = 16+24+24
    tie_embeddings=False,
    unit_pattern=(LayerSpec(kind="attn"),),
    frontend="vision",
    frontend_len=256,              # stub ViT patch embeddings
    link=LinkConfig(split_after_units=8, dropout_rate=0.2, loss_rate=0.1,
                    compression="quant", quant_bits=8),
)
