"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 vocab=50304,
mLSTM:sLSTM 7:1 (xLSTM[7:1]).  [arXiv:2405.04517]"""

from repro.configs.base import LayerSpec, LinkConfig, ModelConfig

_ML = LayerSpec(kind="mlstm")
_SL = LayerSpec(kind="slstm")

CONFIG = ModelConfig(
    name="xlstm-350m",
    arch_type="ssm",
    source="arXiv:2405.04517",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,               # xLSTM blocks carry their own projections
    vocab_size=50304,
    act="gelu",
    gated_mlp=False,
    norm="layernorm",
    tie_embeddings=True,
    unit_pattern=(_ML, _ML, _ML, _ML, _ML, _ML, _ML, _SL),
    link=LinkConfig(split_after_units=1, dropout_rate=0.2, loss_rate=0.1,
                    compression="quant", quant_bits=8),
)
