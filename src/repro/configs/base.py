"""Model / shape / link configuration schema.

Every assigned architecture is expressed as a repeating ``unit_pattern`` of
``LayerSpec``s (scanned with ``lax.scan`` across units for compile-time
tractability at 48-80 layers) plus an optional unrolled ``prologue``
(e.g. Kimi-K2's first dense layer).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating unit."""

    kind: str = "attn"      # attn | mamba | mlstm | slstm
    window: int = 0         # 0 = full attention, >0 = sliding window
    moe: bool = False       # MoE FFN instead of dense FFN


@dataclasses.dataclass(frozen=True)
class LinkConfig:
    """COMtune link placement for the LM framework (paper Eq. 8/12).

    The link layer sits after ``split_after_units`` scan units (+ prologue):
    device side = embed + prologue + units[:split]; server side = the rest.
    """

    split_after_units: int = 1
    dropout_rate: float = 0.2       # r used in fine-tuning
    loss_rate: float = 0.1          # p used in serving
    # Fine-tuning channel emulation (core.comtune.emulate_link):
    # "dropout" is the paper's Eq. 7; "channel" trains against the full
    # serving channel below (stateful masks + FEC, straight-through grads).
    train_link: str = "dropout"
    compression: str = "quant"      # identity | quant | pca
    quant_bits: int = 8
    pca_dim: int = 0                # 0 -> d_model // 4
    shuffle: bool = True            # paper's anti-burst interleaving (Eq. 2)

    # Channel process at serve time (repro.net.channels registry):
    # iid | ge | gilbert_elliott | fading | trace.  channel_params is a
    # hashable tuple of (name, value) pairs for make_channel.
    channel: str = "iid"
    channel_params: Tuple = ()

    # Packet-level FEC (repro.net.fec): k data + m parity per block
    # (m = 0 disables).
    fec_k: int = 0
    fec_m: int = 0
    fec_kind: str = "rs"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | hybrid | vlm | audio | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    source: str = ""                # citation for the assigned config

    head_dim: int = 0               # 0 -> d_model // num_heads
    qkv_bias: bool = False
    act: str = "silu"               # silu | gelu
    gated_mlp: bool = True          # SwiGLU / GeGLU vs plain MLP
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()   # Qwen2-VL M-RoPE (sums to head_dim//2)
    logit_softcap: float = 0.0
    embed_scale: bool = False       # Gemma: embeddings * sqrt(d_model)
    tie_embeddings: bool = True

    # Layer layout.
    unit_pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    num_units: int = 0              # 0 -> num_layers // len(unit_pattern)
    prologue: Tuple[LayerSpec, ...] = ()

    # MoE.
    num_experts: int = 0
    top_k: int = 0
    moe_dff: int = 0                # per-expert FFN width
    num_shared_experts: int = 0     # dense "shared" experts (Kimi-K2)
    dense_residual_dff: int = 0     # parallel dense FFN (Arctic)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # Mamba.
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # Modality frontend stub (VLM / audio); embeddings are provided as inputs.
    frontend: str = ""              # "" | vision | audio
    frontend_len: int = 0           # number of leading positions fed by the stub

    # COMtune link.
    link: LinkConfig = dataclasses.field(default_factory=LinkConfig)

    # Numerics / execution.
    dtype: str = "bfloat16"
    kv_cache_dtype: str = ""        # "" = model dtype; "int8" = quantized KV
                                    # (+per-(pos,head) bf16 scales) — §Perf 3
    remat: bool = True
    # naive | blockwise | flash_decode.  Train/prefill: blockwise and
    # flash_decode both run the blocked online-softmax; naive materializes
    # scores.  Decode (s == 1): blockwise and flash_decode run the
    # length-masked flash-decode path (repro.kernels.decode_attention —
    # O(valid) cache blocks, inline int8 dequant); naive keeps the
    # full-cache masked matvec as the oracle.
    attn_impl: str = "blockwise"
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    attn_decode_block_kv: int = 64  # KV block of the masked decode walk —
                                    # decode reads ceil(valid/this) blocks
    scan_chunk: int = 256           # mamba/mlstm chunked-scan length

    # ----- derived -----

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def resolved_num_units(self) -> int:
        if self.num_units:
            return self.num_units
        body = self.num_layers - len(self.prologue)
        assert body % len(self.unit_pattern) == 0, (
            f"{self.name}: {body} layers not divisible by unit of "
            f"{len(self.unit_pattern)}"
        )
        return body // len(self.unit_pattern)

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def mamba_dt_rank(self) -> int:
        return math.ceil(self.d_model / 16)

    @property
    def xlstm_head_dim(self) -> int:
        return self.d_model // self.num_heads

    def all_layers(self) -> Tuple[LayerSpec, ...]:
        return self.prologue + self.unit_pattern * self.resolved_num_units

    def has_kind(self, kind: str) -> bool:
        return any(s.kind == kind for s in self.all_layers())

    @property
    def is_subquadratic(self) -> bool:
        """True if every attention layer is windowed (bounded KV); recurrent
        layers (mamba/mlstm/slstm) carry constant-size state and are always
        fine.  Jamba/gemma3 qualify natively (their FULL-attention layers are
        few but unbounded — see note below)."""
        attn_layers = [s for s in self.all_layers() if s.kind == "attn"]
        return all(s.window > 0 for s in attn_layers)

    @property
    def long_context_ok(self) -> bool:
        """Policy for long_500k: allowed if sub-quadratic per layer, or if the
        unbounded-attention layers are a small minority of a recurrent /
        local-attention stack (jamba 4/32, gemma3 8/48) — their single-token
        decode cost is linear and the big KV is shardable over 'data'."""
        layers = self.all_layers()
        full_attn = sum(1 for s in layers if s.kind == "attn" and s.window == 0)
        return full_attn == 0 or full_attn * 4 <= len(layers)

    def with_updates(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def long_context_variant(self, window: int = 8192) -> "ModelConfig":
        """Beyond-paper sliding-window variant so full-attention archs can
        lower long_500k decode (documented architecture deviation)."""
        pat = tuple(
            dataclasses.replace(s, window=window) if s.kind == "attn" and s.window == 0 else s
            for s in self.unit_pattern
        )
        pro = tuple(
            dataclasses.replace(s, window=window) if s.kind == "attn" and s.window == 0 else s
            for s in self.prologue
        )
        return dataclasses.replace(
            self, unit_pattern=pat, prologue=pro, name=self.name + "+swa"
        )

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: 1 prologue (if any) + 2 units, d_model<=256,
        <=4 experts, small vocab; same family/pattern."""
        d = min(self.d_model, 256)
        heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, heads)
        hd = (self.head_dim and min(self.head_dim, 64)) or (d // heads)
        pat = tuple(
            dataclasses.replace(s, window=min(s.window, 32) if s.window else 0)
            for s in self.unit_pattern
        )
        pro = tuple(
            dataclasses.replace(s, window=min(s.window, 32) if s.window else 0)
            for s in self.prologue
        )
        kw = dict(
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            unit_pattern=pat,
            prologue=pro,
            num_units=2,
            num_layers=len(pro) + 2 * len(pat),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_dff=min(self.moe_dff, 128) if self.moe_dff else 0,
            num_shared_experts=min(self.num_shared_experts, 1),
            dense_residual_dff=min(self.dense_residual_dff, 128),
            mrope_sections=self._reduced_mrope(hd),
            frontend_len=min(self.frontend_len, 8),
            dtype="float32",
            remat=False,
            attn_impl="naive",
            scan_chunk=16,
            name=self.name + "-smoke",
        )
        kw.update(overrides)
        return dataclasses.replace(self, **kw)

    def _reduced_mrope(self, hd: int) -> Tuple[int, ...]:
        if not self.mrope_sections:
            return ()
        half = hd // 2
        s1 = half // 4
        s2 = (half - s1) // 2
        return (s1, s2, half - s1 - s2)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
