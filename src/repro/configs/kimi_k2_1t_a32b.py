"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384e top-8 (+1 shared expert), first layer dense.
Trillion-param MoE (paper-table config).  [arXiv:2501.kimi2]"""

from repro.configs.base import LayerSpec, LinkConfig, ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    source="arXiv:2501.kimi2",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,            # per-expert width (dense first layer uses the same)
    vocab_size=163840,
    act="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=50000.0,
    tie_embeddings=False,
    prologue=(LayerSpec(kind="attn", moe=False),),   # first layer dense
    unit_pattern=(LayerSpec(kind="attn", moe=True),),
    num_experts=384,
    top_k=8,
    moe_dff=2048,
    num_shared_experts=1,
    capacity_factor=1.25,
    router_aux_coef=0.01,
    link=LinkConfig(split_after_units=7, dropout_rate=0.2, loss_rate=0.1,
                    compression="quant", quant_bits=8),
)
