"""gemma-7b [dense] — 28L d_model=3072 16H (GQA kv=16) d_ff=24576
vocab=256000, GeGLU, head_dim=256.  [arXiv:2403.08295]"""

from repro.configs.base import LayerSpec, LinkConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    arch_type="dense",
    source="arXiv:2403.08295",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    d_ff=24576,
    vocab_size=256000,
    head_dim=256,
    act="gelu",          # GeGLU
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=10000.0,
    embed_scale=True,
    tie_embeddings=True,
    unit_pattern=(LayerSpec(kind="attn"),),
    link=LinkConfig(split_after_units=4, dropout_rate=0.2, loss_rate=0.1,
                    compression="quant", quant_bits=8),
)
