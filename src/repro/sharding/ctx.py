"""Trace-time mesh context for modules that opt into explicit shard_map
formulations (currently the MoE layer).

The step functions built in launch/steps.py activate this context around the
model forward; layers query it at trace time.  When no mesh is active (CPU
unit tests, reduced smoke models) layers fall back to their pure-GSPMD
formulations.
"""

from __future__ import annotations

import contextlib
from contextvars import ContextVar
from typing import Optional, Tuple

from jax.sharding import Mesh

_MOE_MESH: ContextVar[Optional[Mesh]] = ContextVar("repro_moe_mesh", default=None)


@contextlib.contextmanager
def use_shard_map_mesh(mesh: Optional[Mesh]):
    token = _MOE_MESH.set(mesh)
    try:
        yield
    finally:
        _MOE_MESH.reset(token)


def shard_map_mesh() -> Optional[Mesh]:
    return _MOE_MESH.get()


def mesh_axes(mesh: Mesh) -> Tuple[Tuple[str, ...], str]:
    """(data-like axes, model axis)."""
    data = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return data, "model"
