"""GSPMD partition rules for parameters, optimizer state, activations and
decode caches over the production mesh.

Axis roles:
  "model"        — tensor/expert parallelism: the fused heads*head_dim or
                   d_ff feature dim, or the MoE expert dim.  The fused
                   (heads*head_dim) layout shards evenly even when the head
                   count doesn't divide the axis (arctic 56H, musicgen 24H,
                   xlstm 4H).
  "data" (+"pod")— batch parallelism, plus FSDP/ZeRO: the d_model dim of
                   every large parameter is sharded over data so parameters,
                   gradients and Adam state all scale down with the data
                   axis.
Every rule is divisibility-guarded: a dim that doesn't divide the axis size
falls back to replication for that dim (never fails to lower).
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import LayerSpec, ModelConfig, ShapeConfig
from repro.models import attention as attention_lib


def data_axes(mesh: Mesh):
    """('pod','data') on multi-pod meshes, ('data',) on single-pod."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _guard(mesh: Mesh, shape, spec: Sequence) -> P:
    """Drop any spec entry whose axis size doesn't divide the dim."""
    out = []
    for dim, axes in zip(shape, spec):
        if axes is None or dim % _axis_size(mesh, axes) != 0:
            out.append(None)
        else:
            out.append(axes)
    return P(*out)


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

# name -> spec template builder(DATA) for the *unstacked* (per-layer) shape.
def _param_template(name: str, ndim: int, data):
    two_d_in = (data, "model")      # (d_model, features)
    two_d_out = ("model", data)     # (features, d_model)
    table = {
        "embed": ("model", data),
        "lm_head": two_d_in,
        "wq": two_d_in, "wk": two_d_in, "wv": two_d_in,
        "wz": two_d_in, "wi": two_d_in, "wf": two_d_in, "wo": two_d_in,
        "in_proj": two_d_in, "proj": two_d_in,
        "w_out": two_d_out, "out_proj": two_d_out,
        "bq": ("model",), "bk": ("model",), "bv": ("model",),
        "f_bias": ("model",), "conv_b": ("model",), "dt_bias": ("model",),
        "D": ("model",),
        "router": (data, None),
        "conv_w": (None, "model"),
        "x_proj": ("model", None),
        "dt_proj": (None, "model"),
        "A_log": ("model", None),
        "rz": (None, None, None), "ri": (None, None, None),
        "rf": (None, None, None), "ro": (None, None, None),
    }
    if name in ("w_up", "w_gate"):
        return ("model", data, None) if ndim == 3 else two_d_in
    if name == "w_down":
        return ("model", None, data) if ndim == 3 else two_d_out
    return table.get(name)  # None -> replicate


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            names.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            names.append(f"[{k.idx}]")
        else:
            names.append(str(k))
    return tuple(names)


def param_pspecs(params_shapes: Any, mesh: Mesh, fsdp="on") -> Any:
    """Map a params pytree (of arrays or ShapeDtypeStructs) to PartitionSpecs.

    fsdp modes (EXPERIMENTS.md §Perf):
      "on" / True    — baseline: d_model dim of every large parameter is
                       sharded over 'data' (ZeRO-3-style).  Measured cost:
                       GSPMD resolves the data-sharded contraction dim with
                       full-batch activation all-reduces over 'data'.
      "off" / False  — replicate over 'data': no FSDP all-reduces, maximal
                       parameter memory (fine for small models).
      "expert"       — non-expert params replicated over 'data'; MoE expert
                       tensors shard the *per-expert FFN dim* over 'data'
                       (w_up/w_gate (E,d,f): E@model + f@data; w_down
                       (E,f,d): E@model + f@data).  Only the w_down
                       contraction pays a (E/m, C, d) all-reduce — ~10x
                       smaller than the baseline's full-batch ARs — while
                       expert memory still scales down with both axes.
    """
    if fsdp is True:
        fsdp = "on"
    if fsdp is False:
        fsdp = "off"
    data = data_axes(mesh)
    data = data if len(data) > 1 else (data[0] if data else None)
    if fsdp == "off":
        data = None

    def assign(path, leaf):
        names = _path_names(path)
        name = names[-1]
        stacked = "units" in names  # leading U scan dim
        shape = leaf.shape
        base_shape = shape[1:] if stacked else shape
        if fsdp == "expert":
            if name in ("w_up", "w_gate") and len(base_shape) == 3:
                tpl = ("model", None, data)
            elif name == "w_down" and len(base_shape) == 3:
                tpl = ("model", data, None)
            else:
                tpl = _param_template(name, len(base_shape), None)
        else:
            tpl = _param_template(name, len(base_shape), data)
        if tpl is None:
            return P()  # replicate (norms, link scales, small vectors)
        tpl = tuple(tpl)[: len(base_shape)]
        tpl = tpl + (None,) * (len(base_shape) - len(tpl))
        spec = ((None,) if stacked else ()) + tpl
        return _guard(mesh, shape, spec)

    return jax.tree_util.tree_map_with_path(assign, params_shapes)


def opt_state_pspecs(opt_shapes: Any, params_specs: Any, mesh: Mesh) -> Any:
    """AdamState(step, mu, nu): mu/nu inherit parameter specs."""
    from repro.optim.adam import AdamState

    return AdamState(step=P(), mu=params_specs, nu=params_specs)


# ---------------------------------------------------------------------------
# Activation / cache rules
# ---------------------------------------------------------------------------

def batch_spec(mesh: Mesh, batch: int) -> Optional[Tuple[str, ...]]:
    """Largest prefix of ('pod','data') that divides the batch."""
    axes = data_axes(mesh)
    if axes and batch % _axis_size(mesh, axes) == 0:
        return axes
    if len(axes) > 1 and batch % _axis_size(mesh, axes[-1:]) == 0:
        return axes[-1:]
    return None


def token_pspec(mesh: Mesh, batch: int) -> P:
    return P(batch_spec(mesh, batch), None)


def _kv_head_axes(mesh: Mesh, kv_heads: int, head_dim: int):
    """(kv_axis, hd_axis): prefer sharding kv heads over 'model', fall back
    to head_dim, else replicate."""
    m = mesh.shape["model"]
    if kv_heads % m == 0:
        return "model", None
    if head_dim % m == 0:
        return None, "model"
    return None, None


def cache_pspecs(cfg: ModelConfig, shape_cfg: ShapeConfig, mesh: Mesh) -> Any:
    """PartitionSpec tree mirroring models.cache.init_cache structure.

    Normal decode: batch over data, kv/head_dim over model.
    long-context (batch not shardable): KV seq dim over data
    (context-parallel decode); recurrent states shard features over model.
    """
    b = shape_cfg.global_batch
    bs = batch_spec(mesh, b)
    seq_ax = None
    if bs is None:
        # batch unshardable (long_500k): context-parallel the KV seq dim
        seq_ax = data_axes(mesh) or None
    kv_ax, hd_ax = _kv_head_axes(mesh, cfg.num_kv_heads, cfg.resolved_head_dim)
    m = mesh.shape["model"]

    def attn_spec(spec: LayerSpec, stacked: bool):
        length = attention_lib.cache_len(spec, shape_cfg.seq_len)
        s_ax = seq_ax if (seq_ax and length % _axis_size(mesh, seq_ax) == 0) else None
        base = (bs, s_ax, kv_ax, hd_ax)
        kv = P(*(((None,) if stacked else ()) + base))
        out = {"k": kv, "v": kv}
        if cfg.kv_cache_dtype == "int8":
            sc = P(*(((None,) if stacked else ()) + (bs, s_ax, kv_ax)))
            out["k_scale"] = sc
            out["v_scale"] = sc
        return out

    def feat_ax(dim):
        return "model" if dim % m == 0 else None

    def mamba_spec(stacked: bool):
        di = cfg.mamba_d_inner
        pre = (None,) if stacked else ()
        return {
            "conv": P(*(pre + (bs, None, feat_ax(di)))),
            "ssm": P(*(pre + (bs, feat_ax(di), None))),
        }

    def mlstm_spec(stacked: bool):
        dh = cfg.xlstm_head_dim
        pre = (None,) if stacked else ()
        return {
            "c": P(*(pre + (bs, None, feat_ax(dh), None))),
            "n": P(*(pre + (bs, None, feat_ax(dh)))),
            "m": P(*(pre + (bs, None))),
        }

    def slstm_spec(stacked: bool):
        dh = cfg.xlstm_head_dim
        pre = (None,) if stacked else ()
        v = P(*(pre + (bs, None, feat_ax(dh))))
        return {"c": v, "n": v, "m": v, "h": v}

    def layer_spec(spec: LayerSpec, stacked: bool):
        if spec.kind == "attn":
            return attn_spec(spec, stacked)
        if spec.kind == "mamba":
            return mamba_spec(stacked)
        if spec.kind == "mlstm":
            return mlstm_spec(stacked)
        if spec.kind == "slstm":
            return slstm_spec(stacked)
        raise ValueError(spec.kind)

    return {
        "prologue": [layer_spec(s, stacked=False) for s in cfg.prologue],
        "units": [layer_spec(s, stacked=True) for s in cfg.unit_pattern],
    }


def to_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def pool_shard_devices(mesh: Mesh) -> list:
    """Device list the sharded serving router builds per-shard slot pools
    over: one shard per data-axis step of ``mesh``, in data-major order.

    The slot axis is a *data* axis (independent batch-1 requests), so the
    router shards it over the mesh's data-like axes only; a ``model`` axis
    wider than 1 would mean tensor-parallel shards, which the per-shard
    ``Compiled``-executable design does not cover yet — refuse loudly
    instead of silently serving from a mis-shaped pool.  Each returned
    device hosts one full ``ContinuousEngine`` slot/block pool (the
    cache layout per shard is exactly the single-device layout that
    :func:`cache_pspecs` replicates along these axes).
    """
    if "model" in mesh.axis_names and mesh.shape["model"] != 1:
        raise ValueError(
            f"sharded serving shards the slot (data) axis only; mesh has "
            f"model axis of size {mesh.shape['model']} — build the host "
            "mesh with model_axis=1 for the serving router"
        )
    return list(mesh.devices.flat)
