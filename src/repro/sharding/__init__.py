from repro.sharding.rules import (  # noqa: F401
    batch_spec,
    cache_pspecs,
    data_axes,
    opt_state_pspecs,
    param_pspecs,
    to_shardings,
    token_pspec,
)
