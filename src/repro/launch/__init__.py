"""Launch layer: production mesh, sharded step builders, multi-pod dry-run,
and the real train/serve drivers."""
