"""Mesh builders for the sharded trainer and the sharded serving router.

``make_host_mesh`` is the local entry point: it builds a ``("data",
"model")`` mesh over the host's devices.  For CPU CI the host normally
exposes ONE device, so multi-device paths (sharded scan-epoch training,
the slot-pool router) force a deterministic N-device host with::

    XLA_FLAGS=--xla_force_host_platform_device_count=4

which must be set BEFORE the JAX backend initializes (i.e. in the job /
subprocess environment, not from test code after ``import jax``).  Two
overrides pick which of those devices the mesh uses:

* ``devices=`` — an explicit device sequence (the sharded-serve bench
  uses this to build equal-sized single-shard and N-shard arms);
* ``REPRO_HOST_DEVICES=N`` — environment override taking the first N
  of ``jax.devices()`` (CI jobs pin the mesh width without code changes).

Both fail loudly — ``ValueError``, not a silent fallback — when the
request cannot be satisfied or the requested ``model_axis`` does not
divide the device count.

Functions, not module-level constants, so importing this module never
touches jax device state.
"""

from __future__ import annotations

import os
from typing import Optional, Sequence

import jax
import numpy as np

HOST_DEVICES_ENV = "REPRO_HOST_DEVICES"


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def host_devices(devices: Optional[Sequence] = None):
    """The device list host meshes (and the serving router) span.

    ``devices=`` wins; otherwise ``$REPRO_HOST_DEVICES`` selects the first
    N of ``jax.devices()``; otherwise every device.  Raises ``ValueError``
    when more devices are requested than the backend exposes (the usual
    cause: ``--xla_force_host_platform_device_count`` missing from
    ``XLA_FLAGS``, or set after the backend already initialized).
    """
    if devices is not None:
        devices = list(devices)
        if not devices:
            raise ValueError("host_devices: empty explicit device list")
        return devices
    devices = list(jax.devices())
    want = int(os.environ.get(HOST_DEVICES_ENV, "0") or 0)
    if want < 0:
        raise ValueError(f"{HOST_DEVICES_ENV}={want} must be >= 0")
    if want:
        if want > len(devices):
            raise ValueError(
                f"{HOST_DEVICES_ENV}={want} but the backend only exposes "
                f"{len(devices)} device(s) — set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={want} in the "
                "environment BEFORE the JAX backend initializes"
            )
        devices = devices[:want]
    return devices


def make_host_mesh(model_axis: int = 1, *, devices: Optional[Sequence] = None):
    """``("data", "model")`` mesh over :func:`host_devices`.

    ``model_axis`` must divide the device count exactly; a remainder is a
    hard error (a silently-truncated mesh would desync the pspecs derived
    from it in ``sharding/rules.py``).
    """
    devices = host_devices(devices)
    n = len(devices)
    if model_axis < 1:
        raise ValueError(f"model_axis={model_axis} must be >= 1")
    if n % model_axis:
        raise ValueError(
            f"model_axis={model_axis} does not divide the {n} available "
            f"device(s) {[str(d) for d in devices]} — pick a divisor or "
            f"adjust {HOST_DEVICES_ENV} / "
            "--xla_force_host_platform_device_count"
        )
    grid = np.array(devices, dtype=object).reshape(n // model_axis, model_axis)
    return jax.sharding.Mesh(grid, ("data", "model"))
