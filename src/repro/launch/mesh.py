"""Production mesh construction (TPU v5e pods; CPU placeholder devices for
the dry-run).

Functions, not module-level constants, so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Degenerate mesh over the locally available devices (tests/examples)."""
    n = jax.device_count()
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
