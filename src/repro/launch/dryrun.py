import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh with 512 placeholder CPU devices, and extract the
roofline terms from the compiled artifact.

The two lines above MUST stay the first two lines of this module — jax locks
the device count on first init, so no repro/jax import may precede them.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b \
        --shape train_4k [--multi-pod] [--out results.json] [--print-hlo]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCHITECTURES, INPUT_SHAPES, get_config, get_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_sharded_step
from repro.obs import get_logger
from repro.optim import AdamConfig
from repro.roofline import analysis as roofline

log = get_logger("repro.launch.dryrun")


def resolve_config(arch: str, shape_name: str, window: int = 8192):
    """long_500k on pure full-attention archs runs the documented
    sliding-window VARIANT (DESIGN.md §5) so every pair lowers."""
    cfg = get_config(arch)
    variant = "original"
    if shape_name == "long_500k" and not cfg.long_context_ok:
        cfg = cfg.long_context_variant(window)
        variant = f"swa{window}"
    return cfg, variant


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            print_hlo: bool = False, adam_cfg=None, overrides=None,
            fsdp="on", moe_shard_map: bool = False):
    shape_cfg = get_shape(shape_name)
    cfg, variant = resolve_config(arch, shape_name)
    if overrides:
        cfg = cfg.with_updates(**overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size
    adam_cfg = adam_cfg or AdamConfig(state_dtype="bfloat16", grad_clip_norm=1.0)

    t0 = time.time()
    with mesh:
        jitted, args = build_sharded_step(cfg, shape_cfg, mesh, adam_cfg=adam_cfg,
                                          fsdp=fsdp, moe_shard_map=moe_shard_map)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    if print_hlo:
        log.info(hlo)

    tokens = shape_cfg.global_batch * (
        1 if shape_cfg.is_decode else shape_cfg.seq_len
    )
    params_shapes = args[0]
    bytes_per_device = None
    try:
        total = (
            mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
        )
        bytes_per_device = total  # memory_analysis is per-device under SPMD
    except Exception:
        pass

    rep = roofline.analyze(
        arch=arch + ("" if variant == "original" else f"({variant})"),
        shape=shape_name,
        mesh_name=mesh_name,
        chips=chips,
        cost=cost,
        hlo_text=hlo,
        cfg=cfg,
        shape_cfg=shape_cfg,
        params_shapes=params_shapes,
        tokens=tokens,
        decode=shape_cfg.is_decode,
        bytes_per_device=bytes_per_device,
    )
    d = rep.to_dict()
    d.update(
        variant=variant,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory_analysis=str(mem),
        n_params=roofline.count_params(params_shapes),
        n_active_params=roofline.active_params(cfg, params_shapes),
    )
    log.info(f"== {arch} x {shape_name} on {mesh_name} ({variant}) ==")
    log.info(f"memory_analysis: {mem}")
    log.info(
        f"analytic: flops={d['flops']:.3e} hbm_bytes={d['hbm_bytes']:.3e} | "
        f"raw cost_analysis (body-once): flops={d['raw_cost_flops']:.3e} "
        f"bytes={d['raw_cost_bytes']:.3e} | "
        f"collective_bytes/dev={d['collective_bytes']:.3e}"
    )
    log.info(
        f"roofline: compute={d['compute_s']:.3e}s memory={d['memory_s']:.3e}s "
        f"collective={d['collective_s']:.3e}s -> bottleneck={d['bottleneck']} "
        f"useful_flops_frac={d['useful_flops_frac']:.3f}"
    )
    return d


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES), default=None)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--print-hlo", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--fsdp-mode", default=None, choices=["on", "off", "expert"])
    ap.add_argument("--moe-shard-map", action="store_true")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    results = []
    pairs = (
        [(a, s) for a in sorted(ARCHITECTURES) for s in INPUT_SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    done = set()
    if args.out and args.resume and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if not r.get("error"):
                    done.add((r["arch"].split("(")[0], r["shape"]))
                    results.append(r)
    ok = True
    for arch, shape in pairs:
        if (arch, shape) in done:
            log.info(f"skip {arch} x {shape} (already done)")
            continue
        try:
            r = run_one(arch, shape, multi_pod=args.multi_pod,
                        print_hlo=args.print_hlo,
                        fsdp=args.fsdp_mode or ("off" if args.no_fsdp else "on"),
                        moe_shard_map=args.moe_shard_map,
                        overrides={"kv_cache_dtype": "int8"} if args.kv_int8 else None)
        except Exception:
            ok = False
            traceback.print_exc()
            r = {"arch": arch, "shape": shape, "error": True,
                 "trace": traceback.format_exc()[-2000:]}
        results.append(r)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(r) + "\n")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
