"""Serving driver: batched distributed-inference (split LM) over the
emulated lossy IoT link — the paper's DI round (Eq. 12) generalized to
autoregressive decoding.

``generate()`` rides the continuous-batching slot-pool engine
(``repro.serve.continuous``) by default: the batch is served as B
independent requests (per-request RNG chains ``fold_in(key, i)``, bucketed
prefill, one fused decode step over the slot pool), so each request's
greedy output is token-identical to ``generate_reference(prompts[i:i+1],
key=fold_in(key, i))`` and repeated calls with nearby signatures reuse one
pool with zero steady-state recompiles.  Passing ``engine=DecodeEngine()``
(or ``greedy=False``) selects the whole-generation scan engine — one AOT
program per exact signature, which draws ONE joint link mask across the
batch (the legacy batch semantics its equivalence tests pin down).
``generate_reference()`` keeps the seed per-token Python loop (one jit
dispatch per token) as the equivalence oracle and benchmark baseline; all
paths report per-round message sizes and the analytic communication
latency of the unreliable protocol (paper §III-B), and time *compute* —
the timed regions end in ``jax.block_until_ready``, not async dispatch.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHITECTURES, get_config
from repro.core import ChannelConfig, comtune
from repro.core.compression import Compressor, PCASpec, QuantSpec
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import cache as cache_lib, lm
from repro.obs import get_logger
from repro.serve import default_engine


def _override_link(cfg, loss_rate=None, channel=None):
    if loss_rate is None and channel is None:
        return cfg
    import dataclasses

    updates = {}
    if loss_rate is not None:
        updates["loss_rate"] = loss_rate
    if channel is not None:
        updates["channel"] = channel
    return cfg.with_updates(link=dataclasses.replace(cfg.link, **updates))


def _link_accounting(cfg, batch: int) -> dict:
    """Per-round message size + analytic link latency (paper §III-B)."""
    channel_cfg = ChannelConfig(loss_rate=cfg.link.loss_rate)
    spec = comtune.LinkSpec(
        loss_rate=cfg.link.loss_rate,
        compressor=_accounting_compressor(cfg),
        channel=cfg.link.channel,
        channel_params=tuple(cfg.link.channel_params),
        fec_k=cfg.link.fec_k,
        fec_m=cfg.link.fec_m,
        fec_kind=cfg.link.fec_kind,
    )
    return {
        "link_latency_s_per_round": comtune.di_latency_s(
            spec, cfg.d_model, batch, channel_cfg
        ),
        "message_kb_per_token": comtune.message_bytes(spec, cfg.d_model)
        * batch / 1e3,
    }


def generate(
    params,
    cfg,
    prompts: jax.Array,            # (B, S_prompt) int32
    num_tokens: int,
    loss_rate: float | None = None,
    key=None,
    greedy: bool = True,
    channel: str | None = None,
    temperature: float = 1.0,
    engine=None,
    num_shards: int = 0,
):
    """Returns (generated (B, num_tokens), timings dict).

    Default (``engine=None``, greedy): the continuous-batching slot-pool
    engine — per request ``i``, greedy output is token-for-token identical
    to ``generate_reference(prompts[i:i+1], key=fold_in(key, i))``, and
    the pool's AOT programs make repeated calls compile nothing new
    (``timings['compiles']``/``timings['traces']``).  ``num_shards > 1``
    rides the sharded router instead (``repro.serve.router``): one slot
    pool per device with occupancy-aware placement — same per-request
    token-identity contract, aggregate throughput scales with devices.
    With an explicit ``DecodeEngine`` (or sampling), the whole-generation
    scan engine serves the batch under its legacy joint-mask semantics,
    token-exact against ``generate_reference`` at the same batch under
    the same key.
    """
    cfg = _override_link(cfg, loss_rate=loss_rate, channel=channel)
    from repro.serve import ContinuousEngine, ShardedEngine, continuous
    from repro.serve import router as router_lib
    from repro.serve.continuous import PoolConfig, pow2_bucket

    if engine is None and greedy and not cfg.frontend and num_shards > 1:
        engine = router_lib.sharded_engine(
            cfg,
            PoolConfig(
                max_prompt=pow2_bucket(prompts.shape[1]),
                max_new=pow2_bucket(num_tokens, 16),
            ),
            num_shards=num_shards,
        )
    if engine is None and greedy and not cfg.frontend:
        # Frontend (VLM/audio) configs need an extra embed input the slot
        # pool doesn't carry yet — they stay on the whole-generation engine.
        engine = continuous.engine_for(cfg, prompts.shape[1], num_tokens)
    if isinstance(engine, (ContinuousEngine, ShardedEngine)):
        tokens, timings = engine.generate_batch(
            params, prompts, num_tokens,
            key=key if key is not None else jax.random.PRNGKey(0),
        )
    else:
        engine = engine or default_engine()
        tokens, timings = engine.generate(
            params, cfg, prompts, num_tokens,
            key=key, greedy=greedy, temperature=temperature,
        )
    timings.update(_link_accounting(cfg, prompts.shape[0]))
    return tokens, timings


def generate_reference(
    params,
    cfg,
    prompts: jax.Array,            # (B, S_prompt) int32
    num_tokens: int,
    loss_rate: float | None = None,
    key=None,
    greedy: bool = True,
    channel: str | None = None,
):
    """The seed per-token serving loop (one jit dispatch per token).

    Kept as the scan engine's equivalence oracle and the decode-bench
    baseline.  Unlike the seed, the timed regions block on the result:
    ``prefill_s`` / ``decode_s_per_token`` measure compute, not async
    dispatch.
    """
    assert greedy, "the reference loop is the greedy-equivalence oracle"
    key = key if key is not None else jax.random.PRNGKey(0)
    b, s_prompt = prompts.shape
    max_seq = s_prompt + num_tokens
    cfg = _override_link(cfg, loss_rate=loss_rate, channel=channel)
    prefill = jax.jit(make_prefill_step(cfg))
    step = jax.jit(make_serve_step(cfg))

    cache = cache_lib.init_cache(cfg, b, max_seq)
    key, sub = jax.random.split(key)
    t0 = time.perf_counter()
    logits, cache = prefill(params, {"tokens": prompts}, cache, sub)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    out = []
    token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(num_tokens):
        out.append(token)
        key, sub = jax.random.split(key)
        logits, cache = step(params, token, cache, jnp.int32(s_prompt + i), sub)
        token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    jax.block_until_ready(token)
    t_decode = time.perf_counter() - t0

    timings = {
        "prefill_s": t_prefill,
        "decode_s_per_token": t_decode / max(1, num_tokens),
        "tokens_per_s": (b * num_tokens) / max(t_decode, 1e-9),
    }
    timings.update(_link_accounting(cfg, b))
    return jnp.concatenate(out, axis=1), timings


def _accounting_compressor(cfg) -> Compressor:
    """Compressor reflecting the configured scheme's true message size.

    PCA transmits ``pca_dim`` float32 coefficients per vector (Eq. 18), NOT
    the full d_model — mapping it to "identity" (as this function once did)
    over-reported PCA's message size by d_model/pca_dim x.
    """
    link = cfg.link
    if link.compression == "quant":
        return Compressor(
            kind="quant",
            quant=QuantSpec(
                bits=link.quant_bits,
                s_min=jnp.zeros(()), s_max=jnp.ones(()),
            ),
        )
    if link.compression == "pca":
        pca_dim = link.pca_dim or cfg.d_model // 4
        return Compressor(
            kind="pca",
            pca=PCASpec(
                w=jnp.zeros((pca_dim, cfg.d_model)),
                b=jnp.zeros((cfg.d_model,)),
            ),
        )
    return Compressor(kind="identity")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES), required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--loss-rate", type=float, default=0.1)
    ap.add_argument(
        "--channel", default="iid",
        choices=["iid", "ge", "gilbert_elliott", "fading"],
        help="serve-time channel process (repro.net.channels)",
    )
    ap.add_argument(
        "--protocol", default="unreliable",
        choices=["unreliable", "arq", "fec_arq"],
        help="report link latency under this repro.net protocol policy",
    )
    ap.add_argument(
        "--deadline", type=float, default=None,
        help="report P(the protocol delivers the full uplink within this "
        "many seconds) from the analytic completion PMFs — the same "
        "deadline_feasible oracle the SLA scheduler sheds against",
    )
    ap.add_argument(
        "--attn-impl", default=None,
        choices=["naive", "blockwise", "flash_decode"],
        help="override cfg.attn_impl — blockwise/flash_decode decode via the "
        "length-masked flash-decode kernel (O(valid) cache blocks/step), "
        "naive keeps the full-cache oracle",
    )
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument(
        "--num-shards", type=int, default=0,
        help="serve through the sharded router with this many per-device "
        "slot-pool shards (0/1 = single engine); shards wrap around the "
        "visible devices — force more with "
        "XLA_FLAGS=--xla_force_host_platform_device_count=N",
    )
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    if args.attn_impl:
        cfg = cfg.with_updates(attn_impl=args.attn_impl)
    key = jax.random.PRNGKey(0)
    params = lm.init_lm(key, cfg)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32
    )
    toks, timings = generate(
        params, cfg, prompts, args.tokens, loss_rate=args.loss_rate, key=key,
        channel=args.channel, num_shards=args.num_shards,
    )
    log = get_logger("repro.launch.serve")
    log.info(f"generated: {np.asarray(toks)[:, :10]} ...")
    for k, v in timings.items():
        log.info(f"{k}: {v:.5f}")

    # Per-round latency PMF under the selected protocol policy (repro.net),
    # at the selected channel's stationary loss rate (which for "fading" is
    # set by its distance parameters, not --loss-rate).
    from repro.net import make_protocol
    from repro.net.protocol import latency_quantile

    channel_cfg = ChannelConfig(loss_rate=args.loss_rate)
    spec = comtune.LinkSpec(
        loss_rate=args.loss_rate,
        compressor=_accounting_compressor(cfg),
        channel=args.channel,
    )
    p_eff = spec.resolve_channel().stationary_loss_rate
    n_t = channel_cfg.num_packets_for_bytes(
        comtune.message_bytes(spec, cfg.d_model) * args.batch
    )
    proto = make_protocol(args.protocol)
    lat, pmf = proto.latency_pmf(n_t, channel_cfg, loss_rate=p_eff)
    mean_lat = float(np.dot(lat, pmf))
    p99 = latency_quantile(lat, pmf, 0.99)
    log.info(
        f"protocol={proto.name} E[link_latency_s]: {mean_lat:.5f} p99: {p99:.5f}"
    )
    if args.deadline is not None:
        from repro.net import deadline_feasible

        p_meet = deadline_feasible(
            proto, n_t, channel_cfg, args.deadline, loss_rate=p_eff
        )
        log.info(
            f"P(uplink complete within {args.deadline:g}s): {p_meet:.4f}"
        )


if __name__ == "__main__":
    main()
