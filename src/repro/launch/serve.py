"""Serving driver: batched distributed-inference (split LM) over the
emulated lossy IoT link — the paper's DI round (Eq. 12) generalized to
autoregressive decoding.

Each generate() call: prefill (prompt activation crosses the link once) then
per-token serve_steps (each new token's split activation crosses the link).
Reports per-round message sizes and the analytic communication latency of
the unreliable protocol (paper §III-B).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHITECTURES, get_config
from repro.core import ChannelConfig, comtune
from repro.core.compression import Compressor, QuantSpec
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import cache as cache_lib, lm


def generate(
    params,
    cfg,
    prompts: jax.Array,            # (B, S_prompt) int32
    num_tokens: int,
    loss_rate: float | None = None,
    key=None,
    greedy: bool = True,
):
    """Returns (generated (B, num_tokens), timings dict)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    b, s_prompt = prompts.shape
    max_seq = s_prompt + num_tokens
    if loss_rate is not None:
        import dataclasses

        cfg = cfg.with_updates(
            link=dataclasses.replace(cfg.link, loss_rate=loss_rate)
        )
    prefill = jax.jit(make_prefill_step(cfg))
    step = jax.jit(make_serve_step(cfg))

    cache = cache_lib.init_cache(cfg, b, max_seq)
    key, sub = jax.random.split(key)
    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts}, cache, sub)
    t_prefill = time.time() - t0

    out = []
    token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for i in range(num_tokens):
        out.append(token)
        key, sub = jax.random.split(key)
        logits, cache = step(params, token, cache, jnp.int32(s_prompt + i), sub)
        token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    t_decode = time.time() - t0

    # Communication accounting (paper §III-B).
    channel = ChannelConfig(loss_rate=cfg.link.loss_rate)
    comp = Compressor(
        kind=cfg.link.compression if cfg.link.compression != "pca" else "identity",
        quant=QuantSpec(
            bits=cfg.link.quant_bits,
            s_min=jnp.zeros(()), s_max=jnp.ones(()),
        ) if cfg.link.compression == "quant" else None,
    )
    spec = comtune.LinkSpec(loss_rate=cfg.link.loss_rate, compressor=comp)
    per_round_s = comtune.di_latency_s(spec, cfg.d_model, b, channel)
    timings = {
        "prefill_s": t_prefill,
        "decode_s_per_token": t_decode / max(1, num_tokens),
        "link_latency_s_per_round": per_round_s,
        "message_kb_per_token": comtune.message_bytes(spec, cfg.d_model) * b / 1e3,
    }
    return jnp.concatenate(out, axis=1), timings


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES), required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--loss-rate", type=float, default=0.1)
    ap.add_argument("--full-size", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_lm(key, cfg)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32
    )
    toks, timings = generate(
        params, cfg, prompts, args.tokens, loss_rate=args.loss_rate, key=key
    )
    print("generated:", np.asarray(toks)[:, :10], "...")
    for k, v in timings.items():
        print(f"{k}: {v:.5f}")


if __name__ == "__main__":
    main()
