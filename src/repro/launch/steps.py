"""Step builders: train_step / prefill_step / serve_step with their
input specs and shardings — the single source of truth used by the real
drivers (train.py, serve.py) and the multi-pod dry-run.

serve_step implements the paper's DI round (Eq. 12) for LMs: ONE token
through the device-side stack -> lossy link (quantize + packet mask +
1/(1-p) compensation) -> server-side stack, updating a seq_len cache.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import cache as cache_lib, lm
from repro.obs import device as obs_device
from repro.optim import AdamConfig, AdamState, adam_update, init_adam
from repro.sharding import rules
from repro.sharding import ctx as shard_ctx


# ---------------------------------------------------------------------------
# Step functions (pure; jit-ready)
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, adam_cfg: AdamConfig, link_mode: str = "train",
                    link_spec=None, mesh=None):
    """COMtune fine-tuning step: LM loss with the link-emulation layer
    active at the split point (paper Eq. 8); link_mode='off' is the
    'previous DI' baseline (no channel emulation).  ``link_spec`` (a full
    ``core.comtune.LinkSpec``) selects the train-time emulation — Eq. 7
    dropout or the deployment channel (bursts, shuffle=False, FEC) — and
    carries the curriculum's current rate; None derives it from cfg.link.

    A ``batch["link_rate"]`` scalar, when present, overrides the emulation
    rate *as data* — inside a scanned epoch it is one element of a (K,)
    schedule, so a loss-rate curriculum ramps per step without one compile
    per rate (dropout / plain-iid train paths only; at a constant rate the
    drawn masks are bit-identical to the static-rate program)."""

    def train_step(params, opt_state: AdamState, batch: Dict[str, Any], key):
      with shard_ctx.use_shard_map_mesh(mesh):
        def loss_fn(p):
            # Tap the emulated link: what the mask actually dropped this
            # step rides out as auxiliary metrics (constant w.r.t. p, so
            # value_and_grad's aux carries it for free).
            with obs_device.tap_link_stats() as tap:
                logits, _, aux = lm.forward(
                    p,
                    batch["tokens"],
                    cfg,
                    frontend_embed=batch.get("frontend_embed"),
                    link_key=key,
                    link_mode=link_mode,
                    link_spec=link_spec,
                    link_rate=batch.get("link_rate"),
                    mode="train",
                )
                link_stats = tap.totals()
            loss = lm.lm_loss(logits, batch["tokens"], aux, cfg.router_aux_coef)
            return loss, (aux, link_stats)

        (loss, (aux, link_stats)), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params)
        new_params, new_opt, gnorm = adam_update(grads, params, opt_state, adam_cfg)
        metrics = {
            "loss": loss, "aux": aux, "grad_norm": gnorm,
            "link_elems": link_stats["elems"],
            "link_dropped": link_stats["dropped"],
            "fec_recovered_packets": link_stats["fec_recovered"],
        }
        return new_params, new_opt, metrics

    return train_step


def make_train_epoch(
    cfg: ModelConfig,
    adam_cfg: AdamConfig,
    link_mode: str = "train",
    link_spec=None,
    mesh=None,
    jit: bool = True,
):
    """K train steps in ONE jitted ``lax.scan`` program (the PR-2 decode
    treatment applied to the trainer): params/opt-state are donated scan
    carries, and the per-step ``jax.random.split`` chain is identical to
    the per-step Python loop — ``key, sub = split(key)`` inside the scan
    body, exactly as ``launch/train.py`` did from Python — so loss
    trajectories match the loop bit-for-bit under fixed keys.

    Returns ``epoch_fn(params, opt_state, batches, key) ->
    (params, opt_state, key, metrics)`` where ``batches`` is the usual
    batch dict with a leading steps axis K (e.g. tokens (K, B, S),
    optionally a ``link_rate`` (K,) per-step curriculum schedule — traced
    data, so every rate runs in the SAME compiled epoch program) and
    ``metrics`` holds per-step ``loss``/``grad_norm`` arrays of shape (K,)
    — the device-side loss buffer the driver syncs only at log points.
    The returned ``key`` continues the chain, so consecutive epochs
    compose to the same trajectory as one long loop.
    """
    step = make_train_step(
        cfg, adam_cfg, link_mode=link_mode, link_spec=link_spec, mesh=mesh
    )

    def epoch_fn(params, opt_state, batches, key):
        def body(carry, batch):
            params, opt_state, key = carry
            key, sub = jax.random.split(key)
            params, opt_state, metrics = step(params, opt_state, batch, sub)
            out = {
                k: metrics[k]
                for k in ("loss", "grad_norm", "link_elems", "link_dropped",
                          "fec_recovered_packets")
            }
            return (params, opt_state, key), out

        (params, opt_state, key), metrics = jax.lax.scan(
            body, (params, opt_state, key), batches
        )
        return params, opt_state, key, metrics

    if not jit:
        return epoch_fn
    return jax.jit(epoch_fn, donate_argnums=(0, 1))


def make_prefill_step(cfg: ModelConfig, link_mode: str = "serve", mesh=None):
    """Builds the cache from a prompt; the prompt activation crosses the
    lossy link once (the device->server upload of the DI round)."""

    def prefill_step(params, batch: Dict[str, Any], cache, key):
      with shard_ctx.use_shard_map_mesh(mesh):
        logits, new_cache, _ = lm.forward(
            params,
            batch["tokens"],
            cfg,
            frontend_embed=batch.get("frontend_embed"),
            cache=cache,
            cache_index=0,
            link_key=key,
            link_mode=link_mode,
            mode="prefill",
        )
        return logits[:, -1], new_cache

    return prefill_step


def make_serve_step(cfg: ModelConfig, link_mode: str = "serve", mesh=None):
    """One DI decode round (paper Eq. 12)."""

    def serve_step(params, token, cache, index, key):
      with shard_ctx.use_shard_map_mesh(mesh):
        logits, new_cache, _ = lm.forward(
            params,
            token,
            cfg,
            cache=cache,
            cache_index=index,
            link_key=key,
            link_mode=link_mode,
            mode="decode",
        )
        return logits[:, 0], new_cache

    return serve_step


def make_generate_fn(
    cfg: ModelConfig,
    num_tokens: int,
    link_mode: str = "serve",
    greedy: bool = True,
    temperature: float = 1.0,
    mesh=None,
):
    """Whole-generation step: prefill + ``lax.scan`` over ``num_tokens`` DI
    decode rounds, all inside one traceable function.

    The scan body reproduces the legacy per-token Python loop exactly —
    same ``jax.random.split`` chain, same argmax, same lossy-link round per
    step — so greedy output is token-for-token identical to the seed loop
    under identical keys (tests/test_serve_engine.py).  Sampling mode draws
    one extra subkey per step for ``jax.random.categorical``.

    Returns ``generate_fn(params, prompts, cache, key) -> (tokens, cache)``
    with ``tokens`` of shape (B, num_tokens); the returned cache is the
    final decode state (aliased to the donated input cache when jitted with
    ``donate_argnums``).
    """
    prefill = make_prefill_step(cfg, link_mode=link_mode, mesh=mesh)
    step = make_serve_step(cfg, link_mode=link_mode, mesh=mesh)

    def select(logits, key):
        if greedy:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        scaled = logits.astype(jnp.float32) / jnp.float32(max(temperature, 1e-6))
        return jax.random.categorical(key, scaled, axis=-1)[:, None].astype(
            jnp.int32
        )

    def generate_fn(params, prompts, cache, key):
        s_prompt = prompts.shape[1]
        key, sub = jax.random.split(key)
        logits, cache = prefill(params, {"tokens": prompts}, cache, sub)
        if greedy:
            token = select(logits, None)
        else:
            key, ks = jax.random.split(key)
            token = select(logits, ks)

        def body(carry, i):
            key, token, cache = carry
            if greedy:
                key, sub = jax.random.split(key)
                ks = None
            else:
                key, sub, ks = jax.random.split(key, 3)
            logits, cache = step(params, token, cache, s_prompt + i, sub)
            nxt = select(logits, ks)
            # Emit the token *fed into* this round (the legacy loop appends
            # before stepping), so output[0] is the prefill-selected token.
            return (key, nxt, cache), token[:, 0]

        (_, _, cache), toks = jax.lax.scan(
            body, (key, token, cache), jnp.arange(num_tokens, dtype=jnp.int32)
        )
        return jnp.moveaxis(toks, 0, 1), cache

    return generate_fn


# ---------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig, seed: int = 0):
    return jax.eval_shape(lambda: lm.init_lm(jax.random.PRNGKey(seed), cfg))


def abstract_opt_state(cfg: ModelConfig, adam_cfg: AdamConfig):
    params = abstract_params(cfg)
    return jax.eval_shape(lambda: init_adam(params, adam_cfg))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape_cfg: ShapeConfig) -> Dict[str, Any]:
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    out = {"tokens": _sds((b, s), jnp.int32)}
    if cfg.frontend and shape_cfg.kind != "decode":
        out["frontend_embed"] = _sds(
            (b, cfg.frontend_len, cfg.d_model), jnp.bfloat16
        )
    return out


def input_specs(
    cfg: ModelConfig, shape_cfg: ShapeConfig, adam_cfg: Optional[AdamConfig] = None
) -> Tuple[Tuple, str]:
    """(abstract args, step kind) for the (arch x shape) pair."""
    key = _sds((2,), jnp.uint32)
    if shape_cfg.kind == "train":
        adam_cfg = adam_cfg or AdamConfig()
        return (
            (
                abstract_params(cfg),
                abstract_opt_state(cfg, adam_cfg),
                batch_specs(cfg, shape_cfg),
                key,
            ),
            "train",
        )
    if shape_cfg.kind == "prefill":
        cache = jax.eval_shape(
            lambda: cache_lib.init_cache(cfg, shape_cfg.global_batch, shape_cfg.seq_len)
        )
        return (
            (abstract_params(cfg), batch_specs(cfg, shape_cfg), cache, key),
            "prefill",
        )
    # decode
    cache = jax.eval_shape(
        lambda: cache_lib.init_cache(cfg, shape_cfg.global_batch, shape_cfg.seq_len)
    )
    token = _sds((shape_cfg.global_batch, 1), jnp.int32)
    index = _sds((), jnp.int32)
    return ((abstract_params(cfg), token, cache, index, key), "decode")


# ---------------------------------------------------------------------------
# Sharded jit builders
# ---------------------------------------------------------------------------

def _ns(mesh, tree):
    return rules.to_shardings(tree, mesh)


def _train_shard_specs(cfg, shape_cfg, mesh, adam_cfg, fsdp):
    """(abstract_args, p_spec, o_spec, batch_spec) for a train shape — the
    single source both the per-step and the scan-epoch sharded builders
    consume (the epoch builder prepends the K scan axis)."""
    args, kind = input_specs(cfg, shape_cfg, adam_cfg)
    assert kind == "train", f"expected a train shape, got {kind}"
    p_spec = rules.param_pspecs(args[0], mesh, fsdp=fsdp)
    o_spec = rules.opt_state_pspecs(args[1], p_spec, mesh)
    bspec = rules.token_pspec(mesh, shape_cfg.global_batch)
    batch_spec = {"tokens": bspec}
    if "frontend_embed" in args[2]:
        batch_spec["frontend_embed"] = P(bspec[0], None, None)
    return args, p_spec, o_spec, batch_spec


def build_sharded_step(
    cfg: ModelConfig,
    shape_cfg: ShapeConfig,
    mesh: Mesh,
    adam_cfg: Optional[AdamConfig] = None,
    link_mode: Optional[str] = None,
    link_spec=None,
    fsdp="on",
    moe_shard_map: bool = False,
):
    """Returns (jitted_fn, abstract_args) with full in/out shardings.
    ``link_spec`` (train kind only) overrides the cfg-derived LinkSpec."""
    adam_cfg = adam_cfg or AdamConfig(state_dtype="bfloat16")
    rep = P()

    if shape_cfg.kind == "train":
        args, p_spec, o_spec, batch_spec = _train_shard_specs(
            cfg, shape_cfg, mesh, adam_cfg, fsdp
        )
        fn = make_train_step(cfg, adam_cfg, link_mode=link_mode or "train",
                             link_spec=link_spec,
                             mesh=mesh if moe_shard_map else None)
        jitted = jax.jit(
            fn,
            in_shardings=(
                _ns(mesh, p_spec), _ns(mesh, o_spec), _ns(mesh, batch_spec),
                NamedSharding(mesh, rep),
            ),
            out_shardings=(
                _ns(mesh, p_spec), _ns(mesh, o_spec),
                _ns(mesh, {"loss": rep, "aux": rep, "grad_norm": rep,
                           "link_elems": rep, "link_dropped": rep,
                           "fec_recovered_packets": rep}),
            ),
            donate_argnums=(0, 1),
        )
        return jitted, args

    args, kind = input_specs(cfg, shape_cfg, adam_cfg)
    p_spec = rules.param_pspecs(args[0], mesh, fsdp=fsdp)
    bspec = rules.token_pspec(mesh, shape_cfg.global_batch)
    c_spec = rules.cache_pspecs(cfg, shape_cfg, mesh)
    logits_spec = P(bspec[0], "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None)

    if kind == "prefill":
        batch_spec = {"tokens": bspec}
        if "frontend_embed" in args[1]:
            batch_spec["frontend_embed"] = P(bspec[0], None, None)
        fn = make_prefill_step(cfg, link_mode=link_mode or "serve",
                               mesh=mesh if moe_shard_map else None)
        jitted = jax.jit(
            fn,
            in_shardings=(
                _ns(mesh, p_spec), _ns(mesh, batch_spec), _ns(mesh, c_spec),
                NamedSharding(mesh, rep),
            ),
            out_shardings=(
                NamedSharding(mesh, logits_spec), _ns(mesh, c_spec)
            ),
            donate_argnums=(2,),
        )
        return jitted, args

    # shard_map MoE is dispatch-bound-friendly only when tokens >> experts;
    # at decode (1 token/request) the per-layer expert-weight gathers it
    # forces cost far more than GSPMD's dispatch (measured: kimi long_500k
    # 8.6e-3 -> 5.1 s) — decode keeps the GSPMD path. §Perf H1 iteration 5.
    fn = make_serve_step(cfg, link_mode=link_mode or "serve", mesh=None)
    jitted = jax.jit(
        fn,
        in_shardings=(
            _ns(mesh, p_spec), NamedSharding(mesh, bspec), _ns(mesh, c_spec),
            NamedSharding(mesh, rep), NamedSharding(mesh, rep),
        ),
        out_shardings=(NamedSharding(mesh, logits_spec), _ns(mesh, c_spec)),
        donate_argnums=(2,),
    )
    return jitted, args


def build_sharded_epoch(
    cfg: ModelConfig,
    shape_cfg: ShapeConfig,
    mesh: Mesh,
    steps_per_epoch: int,
    adam_cfg: Optional[AdamConfig] = None,
    link_mode: str = "train",
    link_spec=None,
    fsdp="on",
    moe_shard_map: bool = False,
):
    """Data-parallel scan-compiled trainer: ``make_train_epoch`` jitted
    with full in/out shardings over ``mesh`` (``launch.mesh.make_host_mesh``
    for local runs).  Batches are batch-sharded over the 'data' axis with
    the leading K (steps) scan axis replicated; params/opt-state follow the
    FSDP rules and are donated, so one dispatch runs K sharded steps.

    Returns (jitted_epoch_fn, abstract_args) where abstract_args mirror
    ``epoch_fn(params, opt_state, batches, key)``.
    """
    adam_cfg = adam_cfg or AdamConfig(state_dtype="bfloat16")
    args, p_spec, o_spec, step_batch_spec = _train_shard_specs(
        cfg, shape_cfg, mesh, adam_cfg, fsdp
    )
    # Same sharding as the per-step path, with the K scan axis replicated.
    batch_spec = {k: P(None, *v) for k, v in step_batch_spec.items()}
    rep = P()
    fn = make_train_epoch(
        cfg, adam_cfg, link_mode=link_mode, link_spec=link_spec,
        mesh=mesh if moe_shard_map else None, jit=False,
    )
    jitted = jax.jit(
        fn,
        in_shardings=(
            _ns(mesh, p_spec), _ns(mesh, o_spec), _ns(mesh, batch_spec),
            NamedSharding(mesh, rep),
        ),
        out_shardings=(
            _ns(mesh, p_spec), _ns(mesh, o_spec), NamedSharding(mesh, rep),
            _ns(mesh, {"loss": rep, "grad_norm": rep, "link_elems": rep,
                       "link_dropped": rep, "fec_recovered_packets": rep}),
        ),
        donate_argnums=(0, 1),
    )
    k = steps_per_epoch
    ep_batches = {
        name: jax.ShapeDtypeStruct((k,) + tuple(s.shape), s.dtype)
        for name, s in args[2].items()
    }
    return jitted, (args[0], args[1], ep_batches, args[3])
