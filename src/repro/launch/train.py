"""Real training driver (CPU-scale): COMtune fine-tuning of a reduced
architecture on the synthetic LM stream, with checkpointing and eval.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --steps 200 --batch 8 --seq 128 [--full-size] [--link off|train]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import ARCHITECTURES, get_config
from repro.data import lm_batch_iterator, make_lm_dataset
from repro.launch.steps import make_train_step
from repro.models import lm
from repro.optim import AdamConfig, init_adam, schedule


def train(
    arch: str,
    steps: int = 200,
    batch: int = 8,
    seq: int = 128,
    lr: float = 3e-4,
    link_mode: str = "train",
    full_size: bool = False,
    ckpt_dir: str | None = None,
    log_every: int = 20,
    seed: int = 0,
):
    cfg = get_config(arch)
    if not full_size:
        cfg = cfg.reduced()
    adam_cfg = AdamConfig(
        lr=lr,
        grad_clip_norm=1.0,
        schedule=schedule.warmup_cosine(max(10, steps // 20), steps),
    )
    key = jax.random.PRNGKey(seed)
    params = lm.init_lm(key, cfg)
    opt_state = init_adam(params, adam_cfg)
    step_fn = jax.jit(make_train_step(cfg, adam_cfg, link_mode=link_mode))

    tokens = make_lm_dataset(cfg.vocab_size, n_tokens=max(100_000, batch * seq * 50))
    it = lm_batch_iterator(tokens, batch, seq, seed=seed)

    fe = (
        jnp.zeros((batch, cfg.frontend_len, cfg.d_model), jnp.float32)
        if cfg.frontend
        else None
    )
    losses = []
    t0 = time.time()
    for step in range(1, steps + 1):
        b = {"tokens": jnp.asarray(next(it))}
        if fe is not None:
            b["frontend_embed"] = fe
        key, sub = jax.random.split(key)
        params, opt_state, metrics = step_fn(params, opt_state, b, sub)
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == 1:
            # float(loss) above only syncs on the loss; block on the full
            # step output so s/step measures compute, not async dispatch.
            jax.block_until_ready((params, opt_state))
            print(
                f"step {step:5d} loss {losses[-1]:.4f} "
                f"grad_norm {float(metrics['grad_norm']):.3f} "
                f"({(time.time()-t0)/step:.2f}s/step)"
            )
    if ckpt_dir:
        save_checkpoint(ckpt_dir, steps, {"params": params})
        print(f"saved checkpoint to {ckpt_dir}")
    return params, losses, cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES), required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--link", default="train", choices=["train", "off"])
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    _, losses, _ = train(
        args.arch,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        lr=args.lr,
        link_mode=args.link,
        full_size=args.full_size,
        ckpt_dir=args.ckpt_dir,
    )
    print(f"final loss {np.mean(losses[-10:]):.4f} (start {np.mean(losses[:5]):.4f})")


if __name__ == "__main__":
    main()
