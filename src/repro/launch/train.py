"""Real training driver (CPU-scale): COMtune fine-tuning of a reduced
architecture on the synthetic LM stream — channel-aware, scan-compiled,
optionally data-parallel sharded, with periodic checkpointing + resume.

The trainer got the PR-2 serving treatment: by default it runs K steps per
dispatch as ONE jitted ``lax.scan`` epoch (``launch.steps.make_train_epoch``
— donated params/opt-state, per-step key-split chain identical to the
Python loop, so loss trajectories are bit-identical to ``--no-epoch-scan``)
and can shard params/opt-state/batches over the host mesh
(``--sharded``, ``launch.steps.build_sharded_epoch``).

The emulated link at the split point is a full ``core.comtune.LinkSpec``:
``--train-link channel`` fine-tunes against the *deployment* channel
(``--train-channel ge`` bursts, ``--no-shuffle`` senders, ``--train-fec
10,2`` residual-loss patterns) instead of the paper's i.i.d. dropout, and
``--curriculum p0:p1`` ramps the emulation rate across the run.  For the
dropout / plain-iid emulations the ramp is applied PER STEP as traced scan
data (one compiled epoch program per epoch shape); the stateful channels
fall back to scan-epoch granularity, each chunk compiling its static rate.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --steps 200 --batch 8 --seq 128 [--full-size] \
        [--link off|train] [--train-link dropout|channel] \
        [--train-channel ge] [--train-fec 10,2] [--no-shuffle] \
        [--curriculum 0.1:0.4] [--sharded] [--no-epoch-scan] \
        [--ckpt-dir DIR --ckpt-every 100] [--resume]
"""

from __future__ import annotations

import argparse
import contextlib
import dataclasses
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import ARCHITECTURES, get_config
from repro.configs.base import ShapeConfig
from repro.data import lm_batch_iterator, make_lm_dataset
from repro import obs
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import (
    build_sharded_epoch,
    build_sharded_step,
    make_train_epoch,
    make_train_step,
)
from repro.models import lm
from repro.optim import AdamConfig, init_adam, schedule

logger = obs.get_logger("train")

# Per-step link stats the train step/epoch metrics now carry (launch.steps).
_LINK_KEYS = ("link_elems", "link_dropped", "fec_recovered_packets")


def build_train_link_spec(
    cfg,
    train_link: Optional[str] = None,
    train_channel: Optional[str] = None,
    train_fec: Optional[Tuple[int, int]] = None,
    shuffle: Optional[bool] = None,
    loss_rate: Optional[float] = None,
):
    """The trainer's ``LinkSpec``: cfg.link plus the channel-aware CLI
    overrides.  ``train_fec`` is (k, m); ``loss_rate`` seeds the channel
    rate the "channel" emulation trains against.  Asking for a train
    channel or train FEC implies ``train_link="channel"`` — those knobs
    are dead under the dropout emulation."""
    spec = lm.link_spec_from_config(cfg)
    updates = {}
    if train_link is None and (train_channel is not None or train_fec is not None):
        train_link = "channel"
    if train_link is not None:
        updates["train_link"] = train_link
    if train_channel is not None:
        updates["channel"] = train_channel
    if train_fec is not None:
        updates["fec_k"], updates["fec_m"] = train_fec
    if shuffle is not None:
        updates["shuffle"] = shuffle
    spec = dataclasses.replace(spec, **updates)
    if loss_rate is not None:
        spec = spec.with_channel_loss_rate(loss_rate)
    return spec


def per_step_curriculum_ok(spec) -> bool:
    """True when the ramped rate can be fed as TRACED per-step scan data
    (one compiled epoch program for the whole ramp): the dropout emulation
    and the plain-iid channel draw their masks directly from the rate.
    The stateful channels (GE/fading/trace) and FEC bake the rate into
    static tables, so they keep the chunked epoch-static ramp."""
    if spec.train_link == "dropout":
        return True
    return spec.channel in ("", "iid") and spec.fec_m <= 0


def curriculum_rates(steps: int, curriculum: Tuple[float, float]) -> np.ndarray:
    """The per-step linear ramp p0 -> p1 over the whole run (float32)."""
    p0, p1 = curriculum
    if steps <= 1:
        return np.full((max(steps, 1),), p0, np.float32)
    return np.linspace(p0, p1, steps, dtype=np.float32)


def curriculum_schedule(
    steps: int, steps_per_epoch: int, curriculum: Optional[Tuple[float, float]]
):
    """Split the run into scan-epoch chunks of (start_step, n_steps, rate).

    ``rate`` is None without a curriculum (the spec's own rate applies);
    with ``curriculum=(p0, p1)`` it ramps linearly over the chunks.  The
    rate is static per chunk — each distinct rate compiles its own epoch
    program (compile-cached, so revisited rates never re-trace).  The
    iid/dropout train paths instead ramp per STEP with traced rates
    (``per_step_curriculum_ok``): the chunk rate is ignored and a
    ``link_rate`` slice of :func:`curriculum_rates` rides the batch dict,
    keeping the compile count at 1 per epoch shape.
    """
    chunks = []
    start = 0
    while start < steps:
        chunks.append((start, min(steps_per_epoch, steps - start)))
        start += steps_per_epoch
    if curriculum is None:
        return [(s, n, None) for s, n in chunks]
    p0, p1 = curriculum
    denom = max(len(chunks) - 1, 1)
    return [
        (s, n, p0 + (p1 - p0) * i / denom) for i, (s, n) in enumerate(chunks)
    ]


def train(
    arch: str,
    steps: int = 200,
    batch: int = 8,
    seq: int = 128,
    lr: float = 3e-4,
    link_mode: str = "train",
    full_size: bool = False,
    ckpt_dir: str | None = None,
    log_every: int = 20,
    seed: int = 0,
    *,
    train_link: Optional[str] = None,
    train_channel: Optional[str] = None,
    train_fec: Optional[Tuple[int, int]] = None,
    shuffle: Optional[bool] = None,
    train_loss_rate: Optional[float] = None,
    curriculum: Optional[Tuple[float, float]] = None,
    epoch_scan: bool = True,
    steps_per_epoch: int = 0,
    sharded: bool = False,
    fsdp: str = "off",
    ckpt_every: int = 0,
    resume: bool = False,
    profile_dir: Optional[str] = None,
):
    """Returns (params, losses, cfg); ``losses`` covers the steps run by
    THIS call (so a resumed run returns the tail of the trajectory)."""
    cfg = get_config(arch)
    if not full_size:
        cfg = cfg.reduced()
    adam_cfg = AdamConfig(
        lr=lr,
        grad_clip_norm=1.0,
        schedule=schedule.warmup_cosine(max(10, steps // 20), steps),
    )
    key = jax.random.PRNGKey(seed)
    params = lm.init_lm(key, cfg)
    opt_state = init_adam(params, adam_cfg)
    link_spec = build_train_link_spec(
        cfg, train_link=train_link, train_channel=train_channel,
        train_fec=train_fec, shuffle=shuffle, loss_rate=train_loss_rate,
    )
    # Per-step traced curriculum: the iid/dropout emulations take the
    # ramped rate as scan DATA (batches["link_rate"]), so the whole ramp
    # runs in one compiled epoch program per epoch shape.  The stateful
    # channels keep the chunked epoch-static ramp (their rates are baked
    # into static transition tables at trace time).
    per_step = (
        curriculum is not None
        and epoch_scan
        and not sharded
        and per_step_curriculum_ok(link_spec)
    )
    if steps_per_epoch <= 0:
        steps_per_epoch = min(steps, 50)
        if curriculum is not None and not per_step:
            # An epoch-static ramp needs multiple chunks (each chunk's rate
            # is static); default to ~5 rather than pinning at p0.
            steps_per_epoch = min(steps_per_epoch, max(1, -(-steps // 5)))
    elif curriculum is not None and not per_step and steps_per_epoch >= steps > 1:
        logger.warning(
            "warning: --curriculum with a single epoch chunk "
            f"(--steps-per-epoch {steps_per_epoch} >= --steps {steps}) "
            "trains entirely at the start rate"
        )
    if link_spec.train_link == "channel" and (
        curriculum is not None or train_loss_rate is not None
    ):
        from repro.net.channels import supports_target_rate

        if not supports_target_rate(
            link_spec.channel or "iid", link_spec.channel_params
        ):
            logger.warning(
                f"warning: --curriculum/--train-loss-rate have no effect on "
                f"the {link_spec.channel!r} channel (its loss rate comes "
                f"from its own physics/trace, not loss_rate)"
            )
            # Don't compile one epoch program per (identical) ramped rate.
            curriculum = None
    elif train_loss_rate is not None and link_spec.train_link != "channel":
        logger.warning(
            "warning: --train-loss-rate only affects --train-link channel; "
            "the dropout emulation draws at the dropout rate "
            f"({link_spec.dropout_rate})"
        )

    start_step = 0
    if resume:
        assert ckpt_dir, "--resume needs --ckpt-dir"
        template = {"params": params, "opt_state": opt_state, "key": key}
        restored, start_step = restore_checkpoint(
            ckpt_dir, template, name="train"
        )
        params, opt_state = restored["params"], restored["opt_state"]
        key = restored["key"]
        logger.info(f"resumed from {ckpt_dir} at step {start_step}")

    tokens = make_lm_dataset(cfg.vocab_size, n_tokens=max(100_000, batch * seq * 50))
    it = lm_batch_iterator(tokens, batch, seq, seed=seed)
    for _ in range(start_step):      # replay the stream up to the resume point
        next(it)

    mesh = make_host_mesh() if sharded else None
    shape_cfg = ShapeConfig("train_cli", seq, batch, "train")
    fe = (
        jnp.zeros((batch, cfg.frontend_len, cfg.d_model), jnp.float32)
        if cfg.frontend
        else None
    )

    def spec_for(rate):
        return link_spec if rate is None else link_spec.with_train_rate(rate)

    # Compile caches keyed on the (static) curriculum rate so revisited
    # rates — and the no-curriculum case — trace exactly once.
    epoch_fns: dict = {}
    step_fns: dict = {}

    def get_epoch_fn(rate, n_steps):
        k = (rate, n_steps)
        if k not in epoch_fns:
            if sharded:
                sc = dataclasses.replace(shape_cfg, name=f"train_cli_{n_steps}")
                epoch_fns[k], _ = build_sharded_epoch(
                    cfg, sc, mesh, n_steps, adam_cfg=adam_cfg,
                    link_mode=link_mode, link_spec=spec_for(rate), fsdp=fsdp,
                )
            else:
                epoch_fns[k] = make_train_epoch(
                    cfg, adam_cfg, link_mode=link_mode, link_spec=spec_for(rate)
                )
        return epoch_fns[k]

    def get_step_fn(rate):
        if rate not in step_fns:
            if sharded:
                sc = dataclasses.replace(shape_cfg, name="train_cli_step")
                step_fns[rate], _ = build_sharded_step(
                    cfg, sc, mesh, adam_cfg=adam_cfg, link_mode=link_mode,
                    link_spec=spec_for(rate), fsdp=fsdp,
                )
            else:
                step_fns[rate] = jax.jit(make_train_step(
                    cfg, adam_cfg, link_mode=link_mode, link_spec=spec_for(rate)
                ))
        return step_fns[rate]

    losses: list = []        # device scalars / arrays; synced lazily
    t0 = time.time()
    done = 0                 # steps completed by this call

    def log(step_global):
        # One host sync per log point: block on the freshest state, then
        # read the buffered device losses (satellite fix: the old driver
        # called float(loss) EVERY step, forcing a per-step host sync that
        # defeated async dispatch).
        jax.block_until_ready((params, opt_state))
        last = float(np.asarray(losses[-1]).reshape(-1)[-1])
        logger.info(
            f"step {step_global:5d} loss {last:.4f} "
            f"({(time.time()-t0)/max(done, 1):.2f}s/step)"
        )

    def maybe_ckpt(step_global, grid=1):
        # ``grid`` is the stride maybe_ckpt is called at (the chunk size in
        # the scan-epoch path): save whenever a ckpt_every point fell
        # within the last ``grid`` steps, same test as log()'s log points.
        if ckpt_dir and ckpt_every and (
            step_global % ckpt_every < grid or step_global == steps
        ):
            save_checkpoint(
                ckpt_dir, step_global,
                {"params": params, "opt_state": opt_state, "key": key},
                name="train",
            )

    rates_global = (
        curriculum_rates(steps, curriculum) if per_step else None
    )
    chunks = curriculum_schedule(steps, steps_per_epoch, curriculum)
    # Observability: the registry span / profiler wrap dispatch only (no
    # extra host syncs); link-stat device scalars are buffered like the
    # losses and summed once after the loop.
    reg = obs.registry()
    link_dev: list = []
    _obs_ctx = contextlib.ExitStack()
    _obs_ctx.enter_context(obs.exporters.jax_profile(profile_dir))
    _obs_ctx.enter_context(
        reg.span("train.run", arch=arch, steps=steps, sharded=sharded)
    )
    try:
      for chunk_start, n_steps, rate in chunks:
          if chunk_start + n_steps <= start_step:
              continue  # fully covered by the restored checkpoint
          if epoch_scan and chunk_start >= start_step:
              stack = np.stack([next(it) for _ in range(n_steps)])
              batches = {"tokens": jnp.asarray(stack)}
              if fe is not None:
                  batches["frontend_embed"] = jnp.broadcast_to(
                      fe, (n_steps,) + fe.shape
                  )
              if per_step:
                  # Traced per-step ramp: the rate is scan data, the epoch
                  # program is shared across every chunk of this shape.
                  batches["link_rate"] = jnp.asarray(
                      rates_global[chunk_start : chunk_start + n_steps]
                  )
                  rate = None
              epoch_fn = get_epoch_fn(rate, n_steps)
              with reg.span("train.epoch", start=chunk_start, steps=n_steps):
                  params, opt_state, key, metrics = epoch_fn(
                      params, opt_state, batches, key
                  )
              losses.append(metrics["loss"])
              link_dev.append({k: metrics[k] for k in _LINK_KEYS})
              done += n_steps
              step_global = chunk_start + n_steps
              if step_global % log_every < n_steps or step_global == steps:
                  log(step_global)
              maybe_ckpt(step_global, grid=n_steps)
          else:
              # Per-step path: the scan oracle/baseline, and how a resume
              # that lands mid-chunk re-aligns to the chunk grid.
              step_fn = get_step_fn(None if per_step else rate)
              for i in range(n_steps):
                  step_global = chunk_start + i + 1
                  if step_global <= start_step:
                      continue
                  b = {"tokens": jnp.asarray(next(it))}
                  if fe is not None:
                      b["frontend_embed"] = fe
                  if per_step:
                      b["link_rate"] = jnp.asarray(rates_global[step_global - 1])
                  key, sub = jax.random.split(key)
                  params, opt_state, metrics = step_fn(params, opt_state, b, sub)
                  losses.append(metrics["loss"])
                  link_dev.append({k: metrics[k] for k in _LINK_KEYS})
                  done += 1
                  if step_global % log_every == 0 or step_global == steps:
                      log(step_global)
                  maybe_ckpt(step_global)

    finally:
        _obs_ctx.close()

    if reg.enabled and link_dev:
        tot = {
            k: float(sum(float(np.asarray(d[k], np.float64).sum())
                         for d in link_dev))
            for k in _LINK_KEYS
        }
        for k, v in tot.items():
            reg.counter(f"train.{k}").inc(v)
        reg.gauge("train.realized_drop_rate").set(
            tot["link_dropped"] / max(tot["link_elems"], 1.0)
        )

    if ckpt_dir and not ckpt_every:
        save_checkpoint(
            ckpt_dir, steps,
            {"params": params, "opt_state": opt_state, "key": key},
            name="train",
        )
        logger.info(f"saved checkpoint to {ckpt_dir}")
    flat = np.concatenate([np.asarray(l).reshape(-1) for l in losses]) \
        if losses else np.zeros(0)
    return params, list(map(float, flat)), cfg


def _parse_curriculum(s: Optional[str]):
    if not s:
        return None
    p0, p1 = s.split(":")
    return float(p0), float(p1)


def _parse_fec(s: Optional[str]):
    if not s:
        return None
    k, m = s.split(",")
    return int(k), int(m)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES), required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--link", default="train", choices=["train", "off"])
    ap.add_argument(
        "--train-link", default=None, choices=["dropout", "channel"],
        help="what emulates the channel in Eq. 8 (default: cfg.link)",
    )
    ap.add_argument(
        "--train-channel", default=None,
        choices=["iid", "ge", "gilbert_elliott", "fading"],
        help="channel process for --train-link channel",
    )
    ap.add_argument(
        "--train-fec", default=None, metavar="K,M",
        help="packet FEC on the emulated train link, e.g. 10,2",
    )
    ap.add_argument(
        "--train-loss-rate", type=float, default=None,
        help="channel loss rate the 'channel' emulation trains against",
    )
    ap.add_argument(
        "--no-shuffle", action="store_true",
        help="emulate a sender without the paper's anti-burst interleaving",
    )
    ap.add_argument(
        "--curriculum", default=None, metavar="P0:P1",
        help="ramp the train-link rate from P0 to P1 across the run",
    )
    ap.add_argument(
        "--no-epoch-scan", action="store_true",
        help="per-step jit loop instead of the scan-compiled epoch",
    )
    ap.add_argument("--steps-per-epoch", type=int, default=0)
    ap.add_argument(
        "--sharded", action="store_true",
        help="data-parallel over the host mesh (batch-sharded inputs)",
    )
    ap.add_argument(
        "--fsdp", default="off", choices=["on", "off", "expert"],
        help="parameter/opt-state sharding rules for --sharded "
             "(off = replicated; see sharding.rules.param_pspecs)",
    )
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument(
        "--ckpt-every", type=int, default=0,
        help="save params/opt-state/key every N steps (with the scan-epoch "
             "executor, at the epoch boundaries that land on the N grid)",
    )
    ap.add_argument(
        "--resume", action="store_true",
        help="restore the latest checkpoint in --ckpt-dir and continue",
    )
    ap.add_argument(
        "--profile-dir", default=None,
        help="wrap the run in jax.profiler.trace writing to this directory "
             "(view with TensorBoard or ui.perfetto.dev)",
    )
    args = ap.parse_args()
    _, losses, _ = train(
        args.arch,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        lr=args.lr,
        link_mode=args.link,
        full_size=args.full_size,
        ckpt_dir=args.ckpt_dir,
        train_link=args.train_link,
        train_channel=args.train_channel,
        train_fec=_parse_fec(args.train_fec),
        train_loss_rate=args.train_loss_rate,
        shuffle=False if args.no_shuffle else None,
        curriculum=_parse_curriculum(args.curriculum),
        epoch_scan=not args.no_epoch_scan,
        steps_per_epoch=args.steps_per_epoch,
        sharded=args.sharded,
        fsdp=args.fsdp,
        ckpt_every=args.ckpt_every,
        resume=args.resume,
        profile_dir=args.profile_dir,
    )
    if losses:
        logger.info(
            f"final loss {np.mean(losses[-10:]):.4f} "
            f"(start {np.mean(losses[:5]):.4f})"
        )


if __name__ == "__main__":
    main()
