"""repro.net — the network subsystem beyond the paper's i.i.d. link.

Layered like a thin protocol stack:

    channels   stateful packet-loss processes (IID / Gilbert-Elliott /
               Markov fading / trace replay) with NumPy-stateful and
               JAX-functional execution
    fec        XOR + Cauchy-Reed-Solomon erasure coding over packets, with
               a differentiable train-time mask emulation
    protocol   unreliable / ARQ-with-deadline / hybrid FEC+ARQ policies,
               each with analytic per-round latency PMFs (generalizing
               core.link Eq. 4-5)
    simulator  event-driven multi-client serving simulation (Poisson
               arrivals, per-client channel state, server batching)
    chaos      scheduled fault injection (channel collapse, server stall,
               burst storm, block-pool squeeze) over simulator + engine
    traces     record / load / synthesize loss traces

``core.comtune.LinkSpec(channel=..., channel_params=...)`` selects a
channel model on the train/serve path; ``benchmarks/net_sweep.py`` sweeps
the channel x protocol x loss-rate grid; ``examples/multiclient_serve.py``
demonstrates the simulator.
"""

from repro.net.channels import (  # noqa: F401
    CHANNELS,
    Channel,
    FadingMarkovChannel,
    GilbertElliottChannel,
    IIDChannel,
    TraceChannel,
    gilbert_elliott_scan,
    make_channel,
)
from repro.net.fec import (  # noqa: F401
    FECSpec,
    block_recovery_mask,
    decode,
    decode_floats,
    encode,
    encode_floats,
    fec_element_keep_jnp,
    residual_loss_rate,
)
from repro.net.evalhook import (  # noqa: F401
    accuracy_per_request_masks,
    accuracy_vs_delivery_curve,
    accuracy_with_packet_masks,
    make_request_eval_fn,
    train_tiny_model,
)
from repro.net.chaos import (  # noqa: F401
    ChaosSchedule,
    EngineChaos,
    Fault,
    block_pool_squeeze,
    burst_storm,
    channel_collapse,
    server_stall,
)
from repro.net.protocol import (  # noqa: F401
    ARQProtocol,
    HybridFECARQProtocol,
    PROTOCOLS,
    RoundResult,
    UnreliableProtocol,
    deadline_feasible,
    make_protocol,
)
from repro.net.simulator import (  # noqa: F401
    SimConfig,
    SimReport,
    accuracy_curve_fn,
    run_sim,
)
from repro.net.traces import (  # noqa: F401
    load_trace,
    record_trace,
    save_trace,
    synthetic_burst_trace,
    trace_channel,
)
