"""Chaos fault-injection harness for the serving stack.

The paper's whole claim is graceful operation under *highly lossy* links —
but until now nothing in the repo could make a live run degrade on cue.
This module provides scripted faults that compose with any channel /
protocol / engine combination:

* ``channel_collapse(t0, t1, loss_rate=1.0)`` — the uplink loss rate is
  overridden inside the window (default: total outage).  The simulator
  draws the window's packet masks from an overlay i.i.d. process at the
  override rate; the client's real channel object is NOT advanced for
  those draws, so its burst state resumes exactly where it left off when
  the window ends (a radio jammed from outside, not a channel mutation).
* ``server_stall(t, dur)`` — the edge server freezes for ``dur`` seconds:
  any batch started inside the window pays the remaining stall time on
  top of its compute (GC pause / neighbor tenant / thermal throttle).
* ``burst_storm(t0, t1, rate_multiplier)`` — arrival-rate multiplier
  inside the window: every client's Poisson process runs
  ``rate_multiplier``x hotter (flash crowd).
* ``block_pool_squeeze(t0, t1, fraction)`` — ``fraction`` of the paged
  engine's allocatable KV blocks are stolen from the host allocator for
  the window (a co-tenant claiming HBM).  Live slots never lose blocks —
  the squeeze grabs free blocks as they appear, so pressure builds as
  requests retire, and everything is returned when the window closes.

``ChaosSchedule`` answers point-in-time queries; ``run_sim(chaos=...)``
injects collapse/stall/storm into the event flow (``net/simulator.py``);
``EngineChaos`` applies the block squeeze to a live ``ContinuousEngine``
between steps (host-allocator surgery only — it never touches device
state, so the engine's compile-count invariant is untouched).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

FAULT_KINDS = (
    "channel_collapse", "server_stall", "burst_storm", "block_pool_squeeze",
)


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault: ``kind`` active over ``[t0, t1)``."""

    kind: str
    t0: float
    t1: float
    loss_rate: float = 1.0        # channel_collapse
    rate_multiplier: float = 1.0  # burst_storm
    fraction: float = 0.5         # block_pool_squeeze

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}"
            )
        if not self.t1 > self.t0:
            raise ValueError(f"empty fault window [{self.t0}, {self.t1})")

    def active(self, t: float) -> bool:
        return self.t0 <= t < self.t1


def channel_collapse(t0: float, t1: float, loss_rate: float = 1.0) -> Fault:
    return Fault("channel_collapse", t0, t1,
                 loss_rate=min(max(float(loss_rate), 0.0), 1.0))


def server_stall(t: float, duration_s: float) -> Fault:
    return Fault("server_stall", t, t + duration_s)


def burst_storm(t0: float, t1: float, rate_multiplier: float = 5.0) -> Fault:
    if rate_multiplier < 1.0:
        raise ValueError("burst_storm multiplies the arrival rate (>= 1)")
    return Fault("burst_storm", t0, t1, rate_multiplier=rate_multiplier)


def block_pool_squeeze(t0: float, t1: float, fraction: float = 0.5) -> Fault:
    if not 0.0 < fraction <= 1.0:
        raise ValueError("squeeze fraction must be in (0, 1]")
    return Fault("block_pool_squeeze", t0, t1, fraction=fraction)


class ChaosSchedule:
    """Immutable set of scheduled faults with point-in-time queries."""

    def __init__(self, faults: Sequence[Fault] = ()):
        self.faults: Tuple[Fault, ...] = tuple(
            sorted(faults, key=lambda f: (f.t0, f.t1))
        )

    def __bool__(self) -> bool:
        return bool(self.faults)

    def active(self, t: float, kind: Optional[str] = None) -> List[Fault]:
        return [f for f in self.faults
                if f.active(t) and (kind is None or f.kind == kind)]

    def loss_override(self, t: float) -> Optional[float]:
        """Collapse loss rate at ``t`` (worst active window), else None."""
        rates = [f.loss_rate for f in self.active(t, "channel_collapse")]
        return max(rates) if rates else None

    def stall_until(self, t: float) -> float:
        """End of the latest server-stall window covering ``t`` (<= ``t``
        when no stall is active)."""
        ends = [f.t1 for f in self.active(t, "server_stall")]
        return max(ends) if ends else t

    def storm_multiplier(self, t: float) -> float:
        mults = [f.rate_multiplier for f in self.active(t, "burst_storm")]
        return max(mults) if mults else 1.0

    def squeeze_fraction(self, t: float) -> float:
        fracs = [f.fraction for f in self.active(t, "block_pool_squeeze")]
        return max(fracs) if fracs else 0.0

    def storms(self) -> List[Fault]:
        return [f for f in self.faults if f.kind == "burst_storm"]


class _OverrideChannel:
    """Memoryless overlay channel a collapse window substitutes for the
    client's real channel: i.i.d. drops at the override rate, state is a
    pass-through (the real channel's burst state must not advance)."""

    def __init__(self, loss_rate: float):
        self.loss_rate = float(loss_rate)

    @property
    def stationary_loss_rate(self) -> float:
        return self.loss_rate

    def init_state(self, rng: np.random.RandomState):
        return None

    def step(self, rng: np.random.RandomState, state, n_packets: int):
        keep = rng.random_sample(n_packets) >= self.loss_rate
        return keep, state


class EngineChaos:
    """Applies pool-level faults to a live ``ContinuousEngine`` — or to a
    sharded ``repro.serve.router.ShardedEngine``, where the squeeze hits
    EVERY shard's allocator at the scheduled fraction (a co-tenant claims
    HBM on each device; the router's occupancy placement then steers
    admissions toward whichever shard has free blocks left).

    Call ``apply(now)`` between engine steps (the serving-bench driver and
    ``make_sim_server`` do).  Only the host-side block allocator is
    touched: blocks move between ``engine._free_blocks`` and the chaos
    hold list, exactly like a co-tenant request that never completes.
    """

    def __init__(self, engine, schedule: ChaosSchedule):
        self.engine = engine
        self.schedule = schedule
        # A router is a fleet: one sub-harness per shard so each shard's
        # hold list tracks its own allocator.
        shards = getattr(engine, "shards", None)
        self._sub: List["EngineChaos"] = [
            EngineChaos(sh, schedule) for sh in shards
        ] if shards is not None else []
        self._held: List[int] = []

    @property
    def held_blocks(self) -> int:
        if self._sub:
            return sum(s.held_blocks for s in self._sub)
        return len(self._held)

    def apply(self, now: float) -> None:
        if self._sub:
            for s in self._sub:
                s.apply(now)
            return
        eng = self.engine
        if not eng.pool.paged:
            return
        frac = self.schedule.squeeze_fraction(now)
        allocatable = eng.pool.total_blocks - 1      # minus the trash block
        target = int(round(frac * allocatable))
        if target > len(self._held):
            # Build pressure: steal FREE blocks only (live slots keep
            # theirs), up to the target as retirements release them.
            take = min(target - len(self._held), len(eng._free_blocks))
            for _ in range(take):
                self._held.append(eng._free_blocks.pop())
        elif target < len(self._held):
            # Window over (or easing): give blocks back, LIFO like a
            # retiring request so the allocator's reuse order is preserved.
            while len(self._held) > target:
                eng._free_blocks.append(self._held.pop())

    def release_all(self) -> None:
        for s in self._sub:
            s.release_all()
        while self._held:
            self.engine._free_blocks.append(self._held.pop())
