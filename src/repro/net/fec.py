"""Packet-level forward error correction (erasure coding).

Two codes over blocks of ``k`` data packets + ``m`` parity packets:

* ``kind="xor"`` — single parity packet (m = 1): XOR of the k data packets;
  recovers any one erasure.  The degenerate cheap code used by many IoT
  stacks.
* ``kind="rs"``  — Cauchy-matrix Reed–Solomon over GF(256) (the Jerasure /
  RAID-6 construction): parity rows are a k×m Cauchy matrix; every square
  submatrix of a Cauchy matrix is nonsingular, so ANY k of the k+m packets
  reconstruct the block exactly — the MDS property the tests assert.

Payloads are byte arrays; ``encode_floats``/``decode_floats`` view float32
packet payloads as bytes so activation packets round-trip bit-exactly.

For COMtune fine-tuning the decoder is not differentiable (byte-level GF
arithmetic), so ``fec_element_keep_jnp`` provides the *channel-equivalent
mask*: a block whose erasure count is ≤ m is fully recovered (mask 1),
otherwise only the surviving data packets are kept.  Applying that mask
multiplicatively to the activation is exact for erasure channels (lost
packets are zeros, recovered packets are bit-exact), and is differentiable
w.r.t. the activation — so the training graph can emulate an FEC-protected
link the same way Eq. (7) emulates the raw one.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# GF(256) arithmetic (Reed-Solomon polynomial 0x11D, generator 2)
# ---------------------------------------------------------------------------

_GF_EXP = np.zeros(512, dtype=np.int32)
_GF_LOG = np.zeros(256, dtype=np.int32)


def _build_tables() -> None:
    x = 1
    for i in range(255):
        _GF_EXP[i] = x
        _GF_LOG[x] = i
        x <<= 1
        if x & 0x100:
            x ^= 0x11D  # x^8+x^4+x^3+x^2+1 — 2 generates the full group
    _GF_EXP[255:510] = _GF_EXP[:255]


_build_tables()


def gf_mul(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Element-wise GF(256) multiply (arrays of uint8/int)."""
    a = np.asarray(a, dtype=np.int32)
    b = np.asarray(b, dtype=np.int32)
    out = _GF_EXP[(_GF_LOG[a] + _GF_LOG[b]) % 255]
    return np.where((a == 0) | (b == 0), 0, out).astype(np.uint8)


def gf_inv(a: int) -> int:
    assert a != 0, "GF(256) inverse of zero"
    return int(_GF_EXP[255 - _GF_LOG[a]])


def gf_matmul(m: np.ndarray, v: np.ndarray) -> np.ndarray:
    """(r, k) GF matrix times (k, L) byte payloads -> (r, L)."""
    r, k = m.shape
    out = np.zeros((r, v.shape[1]), dtype=np.uint8)
    for i in range(r):
        acc = np.zeros(v.shape[1], dtype=np.uint8)
        for j in range(k):
            acc ^= gf_mul(np.full(v.shape[1], m[i, j], np.uint8), v[j])
        out[i] = acc
    return out


def gf_solve(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Solve A x = B over GF(256); A (k, k), B (k, L).  Gaussian elimination
    with XOR row-ops (addition == XOR in GF(2^8))."""
    k = a.shape[0]
    a = a.astype(np.uint8).copy()
    b = b.astype(np.uint8).copy()
    for col in range(k):
        piv = next((r for r in range(col, k) if a[r, col] != 0), None)
        assert piv is not None, "singular GF system (non-MDS selection?)"
        if piv != col:
            a[[col, piv]] = a[[piv, col]]
            b[[col, piv]] = b[[piv, col]]
        inv = gf_inv(int(a[col, col]))
        a[col] = gf_mul(a[col], np.full(k, inv, np.uint8))
        b[col] = gf_mul(b[col], np.full(b.shape[1], inv, np.uint8))
        for r in range(k):
            if r != col and a[r, col] != 0:
                f = a[r, col]
                a[r] ^= gf_mul(a[col], np.full(k, f, np.uint8))
                b[r] ^= gf_mul(b[col], np.full(b.shape[1], f, np.uint8))
    return b


def cauchy_matrix(k: int, m: int) -> np.ndarray:
    """(m, k) Cauchy matrix over GF(256): C[i, j] = 1 / (x_i ^ y_j) with
    x_i = k + i, y_j = j (disjoint index sets, k + m <= 256)."""
    assert k + m <= 256, "GF(256) supports at most 256 packets per block"
    c = np.zeros((m, k), dtype=np.uint8)
    for i in range(m):
        for j in range(k):
            c[i, j] = gf_inv((k + i) ^ j)
    return c


# ---------------------------------------------------------------------------
# Block erasure codes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FECSpec:
    """k data packets + m parity packets per block."""

    k: int = 4
    m: int = 2
    kind: str = "rs"                 # "rs" | "xor"

    def __post_init__(self):
        assert self.k >= 1 and self.m >= 0
        if self.kind == "xor":
            assert self.m <= 1, "xor parity supports m <= 1"
        assert self.k + self.m <= 256

    @property
    def block_packets(self) -> int:
        return self.k + self.m

    @property
    def overhead(self) -> float:
        """Transmission expansion factor (k+m)/k."""
        return self.block_packets / self.k

    def num_blocks(self, n_data_packets: int) -> int:
        return -(-n_data_packets // self.k)

    def transmitted_packets(self, n_data_packets: int) -> int:
        return self.num_blocks(n_data_packets) * self.block_packets


def encode(data: np.ndarray, spec: FECSpec) -> np.ndarray:
    """Encode one block: (k, L) uint8 payloads -> (k+m, L) systematic
    codeword (data rows first, parity rows after)."""
    data = np.asarray(data, dtype=np.uint8)
    k, length = data.shape
    assert k == spec.k, (k, spec.k)
    if spec.m == 0:
        return data.copy()
    if spec.kind == "xor":
        parity = np.bitwise_xor.reduce(data, axis=0)[None, :]
    elif spec.kind == "rs":
        parity = gf_matmul(cauchy_matrix(spec.k, spec.m), data)
    else:
        raise ValueError(spec.kind)
    return np.concatenate([data, parity], axis=0)


def decode(
    received: np.ndarray, received_idx: Sequence[int], spec: FECSpec
) -> np.ndarray:
    """Reconstruct the k data packets from ANY >= k received codeword rows.

    received: (r, L) uint8 rows; received_idx: their positions in the
    codeword (0..k-1 data, k..k+m-1 parity).  Raises ValueError if fewer
    than k rows survived.
    """
    received = np.asarray(received, dtype=np.uint8)
    idx = list(received_idx)
    if len(idx) < spec.k:
        raise ValueError(
            f"unrecoverable block: {len(idx)} of {spec.k} packets received"
        )
    have_data = {i for i in idx if i < spec.k}
    if len(have_data) == spec.k:   # fast path: all data rows survived
        rows = {i: received[n] for n, i in enumerate(idx) if i < spec.k}
        return np.stack([rows[i] for i in range(spec.k)], axis=0)
    if spec.kind == "xor":
        # Exactly one data row missing; parity = XOR of all data rows, so
        # the missing row = parity XOR (surviving data rows).
        (missing,) = set(range(spec.k)) - have_data
        rows = {i: received[n] for n, i in enumerate(idx)}
        assert spec.k in rows, "xor decode needs the parity row"
        acc = rows[spec.k].copy()
        for i in have_data:
            acc ^= rows[i]
        out = np.zeros((spec.k, received.shape[1]), np.uint8)
        for i in range(spec.k):
            out[i] = acc if i == missing else rows[i]
        return out
    # RS: generator rows for the received positions form a (k, k) system.
    gen = np.concatenate(
        [np.eye(spec.k, dtype=np.uint8), cauchy_matrix(spec.k, spec.m)], axis=0
    )
    sel = idx[: spec.k]
    a = gen[sel]                      # (k, k) — nonsingular by MDS property
    b = received[: spec.k]
    return gf_solve(a, b)


def encode_floats(packets: np.ndarray, spec: FECSpec) -> np.ndarray:
    """(k, n_elem) float32 packet payloads -> (k+m, n_elem*4) uint8 rows."""
    raw = np.ascontiguousarray(packets, dtype=np.float32).view(np.uint8)
    return encode(raw.reshape(packets.shape[0], -1), spec)


def decode_floats(
    received: np.ndarray, received_idx: Sequence[int], spec: FECSpec,
    n_elem: int,
) -> np.ndarray:
    """Inverse of encode_floats -> (k, n_elem) float32, bit-exact."""
    data = decode(received, received_idx, spec)
    return data.view(np.float32).reshape(spec.k, n_elem)


# ---------------------------------------------------------------------------
# Differentiable train/serve-time emulation (mask algebra)
# ---------------------------------------------------------------------------

def block_recovery_mask(pkt_keep: jax.Array, spec: FECSpec) -> jax.Array:
    """Channel-equivalent keep-mask of the k *data* packets per block after
    FEC decoding.

    pkt_keep: float32/bool 0/1 of shape (..., n_blocks * (k+m)) — the raw
    channel mask over *transmitted* (data+parity) packets, block-major.
    Returns (..., n_blocks * k): 1 where the data packet is available after
    decoding (delivered OR block-recovered), 0 otherwise.
    """
    km = spec.block_packets
    lead = pkt_keep.shape[:-1]
    n_blocks = pkt_keep.shape[-1] // km
    blk = pkt_keep.reshape(*lead, n_blocks, km).astype(jnp.float32)
    received = blk.sum(axis=-1)
    recovered = (received >= spec.k).astype(jnp.float32)[..., None]
    data_keep = blk[..., : spec.k]
    out = jnp.maximum(data_keep, recovered)
    return out.reshape(*lead, n_blocks * spec.k)


def fec_element_keep_jnp(
    key: jax.Array,
    channel,                         # repro.net.channels.Channel
    num_elements: int,
    elements_per_packet: int,
    spec: FECSpec,
    shuffle: bool = False,
) -> jax.Array:
    """Flat element keep-mask of an FEC-protected link: sample the channel
    over the *expanded* (data+parity) packet stream, decode per block, and
    expand surviving data packets to elements.  Differentiable in the sense
    required by COMtune: it is a constant 0/1 mask applied multiplicatively
    to the activation, so the train graph (``core.comtune.emulate_link``
    with ``train_link="channel"``) gets straight-through identity-on-mask
    gradients — guaranteed here by the explicit stop_gradient, whatever
    channel produced the packet draw."""
    from repro.net.channels import element_mask_from_packets

    from repro.obs import device as obs_device

    kperm, kmask = jax.random.split(key)
    n_data = -(-num_elements // elements_per_packet)
    n_tx = spec.transmitted_packets(n_data)
    raw = channel.packet_keep_jnp(kmask, n_tx)
    data_keep = block_recovery_mask(raw, spec)[:n_data]
    if obs_device.tapping():
        # Data packets the raw channel lost but decoding reconstructed.
        raw_data = raw.reshape(-1, spec.block_packets)[:, : spec.k]
        raw_data = raw_data.reshape(-1)[:n_data].astype(jnp.float32)
        obs_device.record_fec_recovered(jnp.sum(data_keep - raw_data))
    return jax.lax.stop_gradient(element_mask_from_packets(
        data_keep, num_elements, elements_per_packet, kperm, shuffle
    ))


def residual_loss_rate(spec: FECSpec, channel) -> float:
    """Analytic post-FEC data-packet loss rate under an i.i.d. approximation
    at the channel's stationary rate (exact for IIDChannel; an upper-bound
    style approximation for bursty channels, which the paper's interleaving
    assumption also makes).  Used for 1/(1-p) compensation on FEC links."""
    p = channel.stationary_loss_rate
    if spec.m == 0:
        return p
    km = spec.block_packets
    # P(block unrecoverable) summed over erasure counts e > m, times the
    # conditional data-loss fraction e_data/k ~ e * k/km / k = e/km.
    from repro.core.link import log_binom_coeff

    loss = 0.0
    for e in range(spec.m + 1, km + 1):
        pe = np.exp(
            log_binom_coeff(km, e)
            + e * np.log(max(p, 1e-12))
            + (km - e) * np.log(max(1.0 - p, 1e-12))
        )
        loss += pe * (e / km)
    return float(min(max(loss, 0.0), 1.0))
