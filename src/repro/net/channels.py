"""Stateful packet-loss channel processes (beyond the paper's Eq. 1).

The paper models the IoT link as i.i.d. Bernoulli packet loss (§III-B).
Real lossy links are bursty and time-correlated; this module provides four
channel processes behind one ``Channel`` interface so the COMtune stack,
the protocol layer, and the multi-client simulator can swap them freely:

* ``IIDChannel``            — the paper's memoryless channel (wraps
                              ``core.link`` masks).
* ``GilbertElliottChannel`` — classic two-state (Good/Bad) Markov burst-loss
                              model; packet loss probability depends on the
                              hidden state, producing loss bursts with mean
                              length ``1 / p_bg``.
* ``FadingMarkovChannel``   — distance/SNR-driven K-state birth-death Markov
                              chain: log-distance path loss sets the mean
                              SNR, each state is a quantized fading level,
                              and per-state packet loss follows the Rayleigh
                              block-fading outage approximation
                              ``p_k = 1 - exp(-gamma_th / snr_k)``.
* ``TraceChannel``          — replays a recorded 0/1 loss trace (see
                              ``repro.net.traces``), cycling when exhausted.

Every channel exposes BOTH execution styles:

* **NumPy stateful** (``init_state`` / ``step``) — the event-driven
  simulator advances per-client channel state packet by packet across
  rounds, preserving burst correlation between consecutive requests.
* **JAX functional** (``packet_keep_jnp`` / ``element_keep_jnp``) — one
  fixed-shape mask per message, jit-safe, starting from a stationary-
  sampled hidden state; this is what ``core.comtune.channel_link`` uses on
  the serving path.

``stationary_loss_rate`` gives the analytic long-run packet loss rate, used
for the receiver's ``1/(1-p)`` compensation (Eq. 11) and validated by
tests/test_net.py.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np


@runtime_checkable
class Channel(Protocol):
    """Common interface of all channel processes."""

    @property
    def stationary_loss_rate(self) -> float: ...

    def init_state(self, rng: np.random.RandomState): ...

    def step(self, rng: np.random.RandomState, state, n_packets: int
             ) -> Tuple[np.ndarray, object]:
        """Advance the process by ``n_packets`` transmissions.

        Returns (keep: bool (n_packets,), new_state)."""
        ...

    def packet_keep_jnp(self, key: jax.Array, n_packets: int) -> jax.Array:
        """Jit-safe keep-mask (float32 0/1, shape (n_packets,)) for one
        message, hidden state sampled from the stationary distribution."""
        ...


# The single Eq. 2 repeat + interleave implementation lives in core.link
# (the paper-core module); re-exported here because every channel, the FEC
# emulation, and the eval hook consume it through this package.
from repro.core.link import element_mask_from_packets  # noqa: E402,F401


class _ChannelBase:
    """Shared element-granularity plumbing on top of ``packet_keep_jnp``."""

    def element_keep_jnp(
        self, key: jax.Array, num_elements: int, elements_per_packet: int,
        shuffle: bool = False,
    ) -> jax.Array:
        kperm, kmask = jax.random.split(key)
        n_packets = -(-num_elements // elements_per_packet)
        pkt = self.packet_keep_jnp(kmask, n_packets)
        return element_mask_from_packets(
            pkt, num_elements, elements_per_packet, kperm, shuffle
        )

    def mean_loss_over(self, rng: np.random.RandomState, n_packets: int) -> float:
        """Empirical loss rate over one long stateful run (test helper)."""
        state = self.init_state(rng)
        keep, _ = self.step(rng, state, n_packets)
        return 1.0 - float(np.mean(keep))


# ---------------------------------------------------------------------------
# IID (the paper's channel)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IIDChannel(_ChannelBase):
    """Memoryless Bernoulli packet loss — exactly the paper's Eq. (1)-(3)."""

    loss_rate: float = 0.1

    @property
    def stationary_loss_rate(self) -> float:
        return float(self.loss_rate)

    def init_state(self, rng: np.random.RandomState):
        return None

    def step(self, rng, state, n_packets: int):
        keep = rng.rand(n_packets) >= self.loss_rate
        return keep, state

    def packet_keep_jnp(self, key: jax.Array, n_packets: int) -> jax.Array:
        return jax.random.bernoulli(
            key, 1.0 - self.loss_rate, (n_packets,)
        ).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Gilbert–Elliott two-state burst loss
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GilbertElliottChannel(_ChannelBase):
    """Two-state Markov chain: Good (loss ``loss_good``) / Bad (``loss_bad``).

    Transitions per packet: G->B with prob ``p_gb``, B->G with ``p_bg``.
    Stationary bad-state occupancy pi_b = p_gb / (p_gb + p_bg); stationary
    packet loss = pi_g * loss_good + pi_b * loss_bad.  Mean burst (bad
    sojourn) length = 1 / p_bg packets.
    """

    p_gb: float = 0.05
    p_bg: float = 0.4
    loss_good: float = 0.01
    loss_bad: float = 0.75

    @property
    def pi_bad(self) -> float:
        denom = self.p_gb + self.p_bg
        return float(self.p_gb / denom) if denom > 0 else 0.0

    @property
    def stationary_loss_rate(self) -> float:
        pb = self.pi_bad
        return float((1.0 - pb) * self.loss_good + pb * self.loss_bad)

    @classmethod
    def from_target(
        cls, loss_rate: float, burst_len: float = 4.0,
        loss_good: float = 0.0, loss_bad: float = 1.0,
    ) -> "GilbertElliottChannel":
        """Pick (p_gb, p_bg) hitting a target stationary loss rate with mean
        bad-sojourn ``burst_len`` packets (classic Gilbert construction:
        Bad always drops, Good never).  High targets with short bursts can
        demand p_gb > 1; in that case p_gb is pinned at 1 and p_bg lowered
        (longer bursts) so the stationary rate stays exact."""
        span = loss_bad - loss_good
        assert span > 1e-9, "loss_bad must exceed loss_good"
        pi_b = min(max((loss_rate - loss_good) / span, 0.0), 0.999)
        p_bg = 1.0 / max(burst_len, 1.0)
        p_gb = p_bg * pi_b / max(1.0 - pi_b, 1e-9)
        if p_gb > 1.0:
            p_gb = 1.0
            p_bg = (1.0 - pi_b) / pi_b   # pi_b >= 0.5 here, so p_bg <= 1
        return cls(p_gb=p_gb, p_bg=p_bg, loss_good=loss_good, loss_bad=loss_bad)

    # -- NumPy stateful --

    def init_state(self, rng: np.random.RandomState):
        return bool(rng.rand() < self.pi_bad)  # True = Bad

    def step(self, rng, state: bool, n_packets: int):
        keep = np.empty(n_packets, dtype=bool)
        bad = state
        u_loss = rng.rand(n_packets)
        u_tr = rng.rand(n_packets)
        for t in range(n_packets):
            p = self.loss_bad if bad else self.loss_good
            keep[t] = u_loss[t] >= p
            if bad:
                bad = u_tr[t] >= self.p_bg
            else:
                bad = u_tr[t] < self.p_gb
        return keep, bad

    # -- JAX functional --

    def packet_keep_jnp(self, key: jax.Array, n_packets: int) -> jax.Array:
        kinit, kloss, ktr = jax.random.split(key, 3)
        u_init = jax.random.uniform(kinit, ())
        u_loss = jax.random.uniform(kloss, (n_packets,))
        u_tr = jax.random.uniform(ktr, (n_packets,))
        return gilbert_elliott_scan(
            u_init, u_loss, u_tr,
            self.p_gb, self.p_bg, self.loss_good, self.loss_bad,
        )


def gilbert_elliott_scan(
    u_init: jax.Array,   # () uniform: stationary initial state draw
    u_loss: jax.Array,   # (..., N) uniforms: per-packet loss draw
    u_tr: jax.Array,     # (..., N) uniforms: per-packet state transition
    p_gb: float, p_bg: float, loss_good: float, loss_bad: float,
) -> jax.Array:
    """Pure-JAX Gilbert–Elliott keep-mask via ``lax.scan`` over the packet
    axis (the last axis); leading axes are independent chains.  This is also
    the bit-exact oracle for the Pallas ``burst_mask`` kernel."""
    pi_b = p_gb / max(p_gb + p_bg, 1e-12)
    bad0 = (u_init < pi_b)
    bad0 = jnp.broadcast_to(bad0, u_loss.shape[:-1])

    def body(bad, uu):
        ul, ut = uu
        p = jnp.where(bad, jnp.float32(loss_bad), jnp.float32(loss_good))
        keep = (ul >= p).astype(jnp.float32)
        nxt = jnp.where(bad, ut >= jnp.float32(p_bg), ut < jnp.float32(p_gb))
        return nxt, keep

    # scan over last axis: move it to front
    ul = jnp.moveaxis(u_loss.astype(jnp.float32), -1, 0)
    ut = jnp.moveaxis(u_tr.astype(jnp.float32), -1, 0)
    _, keep = jax.lax.scan(body, bad0, (ul, ut))
    return jnp.moveaxis(keep, 0, -1)


# ---------------------------------------------------------------------------
# Distance/SNR-driven Markov fading
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FadingMarkovChannel(_ChannelBase):
    """Finite-state Markov channel over quantized Rayleigh fading levels.

    Mean SNR from log-distance path loss:
        snr_db = tx_power_dbm - (pl0_db + 10 * pl_exp * log10(d / d0)) - noise_dbm
    The fading gain is quantized into ``n_states`` levels (state k scales the
    mean SNR by ``gain_k``); per-state packet loss uses the block-fading
    outage approximation p_k = 1 - exp(-gamma_th / snr_k).  The state chain
    is birth-death with mobility parameter ``agility`` (probability of moving
    to an adjacent level per packet), the standard FSMC construction.
    """

    distance_m: float = 50.0
    tx_power_dbm: float = 14.0      # typical IoT radio
    noise_dbm: float = -90.0
    pl0_db: float = 40.0            # path loss at d0 = 1 m
    pl_exp: float = 3.0             # indoor/urban exponent
    gamma_th_db: float = 3.0        # SNR threshold for packet success
    n_states: int = 4
    agility: float = 0.25

    @property
    def mean_snr_db(self) -> float:
        pl = self.pl0_db + 10.0 * self.pl_exp * np.log10(max(self.distance_m, 1.0))
        return float(self.tx_power_dbm - pl - self.noise_dbm)

    def _state_loss_rates(self) -> np.ndarray:
        """Per-state packet loss p_k, states ordered deep-fade -> strong."""
        snr_lin = 10.0 ** (self.mean_snr_db / 10.0)
        gamma_th = 10.0 ** (self.gamma_th_db / 10.0)
        # Quantized fading gains: log-spaced from -10 dB to +5 dB around mean.
        gains_db = np.linspace(-10.0, 5.0, self.n_states)
        snr_k = snr_lin * 10.0 ** (gains_db / 10.0)
        return 1.0 - np.exp(-gamma_th / np.maximum(snr_k, 1e-9))

    def _transition_matrix(self) -> np.ndarray:
        k, a = self.n_states, self.agility
        tm = np.zeros((k, k))
        for i in range(k):
            up = a / 2 if i + 1 < k else 0.0
            dn = a / 2 if i > 0 else 0.0
            tm[i, i] = 1.0 - up - dn
            if i + 1 < k:
                tm[i, i + 1] = up
            if i > 0:
                tm[i, i - 1] = dn
        return tm

    @property
    def stationary_loss_rate(self) -> float:
        cum_tm, losses, pi = _fading_tables(self)
        return float(np.dot(pi, losses))

    # -- NumPy stateful --

    def init_state(self, rng: np.random.RandomState):
        cum_pi = np.cumsum(_fading_tables(self)[2])
        return int(min(np.searchsorted(cum_pi, rng.rand()),
                       self.n_states - 1))

    def step(self, rng, state: int, n_packets: int):
        cum_tm, losses, _ = _fading_tables(self)
        u_loss = rng.rand(n_packets)
        u_tr = rng.rand(n_packets)
        keep = np.empty(n_packets, dtype=bool)
        s = state
        for t in range(n_packets):
            keep[t] = u_loss[t] >= losses[s]
            s = int(min(np.searchsorted(cum_tm[s], u_tr[t]),
                        self.n_states - 1))
        return keep, s

    # -- JAX functional --

    def packet_keep_jnp(self, key: jax.Array, n_packets: int) -> jax.Array:
        np_cum_tm, np_losses, np_pi = _fading_tables(self)
        cum_tm = jnp.asarray(np_cum_tm, jnp.float32)
        losses = jnp.asarray(np_losses, jnp.float32)
        pi = jnp.asarray(np_pi, jnp.float32)
        kinit, kloss, ktr = jax.random.split(key, 3)
        s0 = jnp.searchsorted(jnp.cumsum(pi), jax.random.uniform(kinit, ()))
        s0 = jnp.clip(s0, 0, self.n_states - 1)
        u_loss = jax.random.uniform(kloss, (n_packets,))
        u_tr = jax.random.uniform(ktr, (n_packets,))

        def body(s, uu):
            ul, ut = uu
            keep = (ul >= losses[s]).astype(jnp.float32)
            nxt = jnp.clip(
                jnp.searchsorted(cum_tm[s], ut), 0, self.n_states - 1
            )
            return nxt, keep

        _, keep = jax.lax.scan(body, s0, (u_loss, u_tr))
        return keep


@functools.lru_cache(maxsize=64)
def _fading_tables(ch: FadingMarkovChannel):
    """(cumulative transition matrix, per-state loss rates, stationary
    distribution) — cached per (frozen, hashable) channel config so the
    simulator's per-packet hot loop never rebuilds them."""
    tm = ch._transition_matrix()
    losses = ch._state_loss_rates()
    pi = np.full(ch.n_states, 1.0 / ch.n_states)
    for _ in range(500):
        pi = pi @ tm
    pi = pi / pi.sum()
    return np.cumsum(tm, axis=1), losses, pi


# ---------------------------------------------------------------------------
# Trace replay
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TraceChannel(_ChannelBase):
    """Replays a recorded loss trace (1 = packet delivered, 0 = lost),
    cycling when the trace is exhausted.  State = replay position."""

    keep_trace: tuple = ()           # tuple of 0/1 ints (hashable/frozen)

    @staticmethod
    def from_array(trace) -> "TraceChannel":
        arr = np.asarray(trace).astype(np.int32).reshape(-1)
        assert arr.size > 0, "empty trace"
        return TraceChannel(keep_trace=tuple(int(v) for v in arr))

    @property
    def stationary_loss_rate(self) -> float:
        arr = np.asarray(self.keep_trace)
        return float(1.0 - arr.mean()) if arr.size else 0.0

    def init_state(self, rng: np.random.RandomState):
        return int(rng.randint(len(self.keep_trace)))  # random phase

    def step(self, rng, state: int, n_packets: int):
        arr = _trace_array(self)
        idx = (state + np.arange(n_packets)) % arr.size
        return arr[idx], int((state + n_packets) % arr.size)

    def packet_keep_jnp(self, key: jax.Array, n_packets: int) -> jax.Array:
        arr = jnp.asarray(_trace_array(self), jnp.float32)
        start = jax.random.randint(key, (), 0, arr.size)
        idx = (start + jnp.arange(n_packets)) % arr.size
        return arr[idx]


@functools.lru_cache(maxsize=64)
def _trace_array(ch: TraceChannel) -> np.ndarray:
    """The trace as an ndarray, cached per frozen channel — step() runs once
    per protocol round, and re-converting a long tuple each time dominated
    simulation wall-clock."""
    return np.asarray(ch.keep_trace, dtype=bool)


# ---------------------------------------------------------------------------
# Registry / LinkSpec plumbing
# ---------------------------------------------------------------------------

CHANNELS = {
    "iid": IIDChannel,
    "gilbert_elliott": GilbertElliottChannel,
    "ge": GilbertElliottChannel,
    "fading": FadingMarkovChannel,
    "trace": TraceChannel,
}


def supports_target_rate(name: str, params=()) -> bool:
    """True when ``make_channel(name, loss_rate=p, **params)`` actually
    hits the target stationary rate ``p`` — i.e. a loss-rate curriculum
    over this channel is meaningful.  ``fading``/``trace`` derive their
    loss from their own physics/recording, and a GE channel given explicit
    ``p_gb``/``p_bg`` transition probabilities is fully pinned by them —
    all of these ignore ``loss_rate``, so the trainer warns rather than
    silently ramping a no-op knob."""
    key = name.lower()
    if key in ("ge", "gilbert_elliott"):
        pd = dict(params)
        return "p_gb" not in pd and "p_bg" not in pd
    return key == "iid"


def make_channel(name: str, loss_rate: float = 0.1, **params) -> Channel:
    """Build a channel by registry name.

    ``loss_rate`` seeds sensible defaults: for ``ge`` it picks a
    burst-4 Gilbert construction with that stationary rate (unless explicit
    p_gb/p_bg are given); for ``iid`` it is the Bernoulli rate; for
    ``fading``/``trace`` it is ignored in favour of their own params.
    """
    key = name.lower()
    if key not in CHANNELS:
        raise ValueError(
            f"unknown channel {name!r}; available: {sorted(set(CHANNELS))}"
        )
    if key in ("ge", "gilbert_elliott"):
        params.pop("loss_rate", None)
        if "p_gb" in params or "p_bg" in params:
            # Explicit transition probabilities: direct construction.
            return GilbertElliottChannel(**params)
        # Otherwise hit the target stationary rate; params may tune
        # burst_len / loss_good / loss_bad of the from_target construction.
        return GilbertElliottChannel.from_target(loss_rate, **params)
    if key == "iid":
        return IIDChannel(loss_rate=params.pop("loss_rate", loss_rate))
    if key == "fading":
        return FadingMarkovChannel(**params)
    if key == "trace":
        if "keep_trace" in params:
            return TraceChannel(keep_trace=tuple(params["keep_trace"]))
        raise ValueError("trace channel requires keep_trace=...")
    raise AssertionError(key)
