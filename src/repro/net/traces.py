"""Loss-trace recording and replay for ``TraceChannel``.

A trace is a flat 0/1 int array (1 = packet delivered).  Traces can be
recorded from any ``Channel`` (so e.g. a Gilbert–Elliott run can be frozen
and replayed deterministically across experiments), loaded from disk
(``.npy`` or whitespace-separated text), or synthesized with a prescribed
burst structure when no measurement is available.
"""

from __future__ import annotations

import os
from typing import Union

import numpy as np

from repro.net.channels import Channel, TraceChannel


def record_trace(
    channel: Channel, n_packets: int, seed: int = 0
) -> np.ndarray:
    """Run ``channel`` statefully for ``n_packets`` and return the 0/1 keep
    trace."""
    rng = np.random.RandomState(seed)
    state = channel.init_state(rng)
    keep, _ = channel.step(rng, state, n_packets)
    return np.asarray(keep, dtype=np.int32)


def save_trace(path: str, trace: np.ndarray) -> None:
    trace = np.asarray(trace, dtype=np.int32).reshape(-1)
    if path.endswith(".npy"):
        np.save(path, trace)
    else:
        np.savetxt(path, trace[None], fmt="%d")


def load_trace(path: str) -> np.ndarray:
    if not os.path.exists(path):
        raise FileNotFoundError(path)
    if path.endswith(".npy"):
        trace = np.load(path)
    else:
        trace = np.loadtxt(path)
    return np.asarray(trace, dtype=np.int32).reshape(-1)


def trace_channel(source: Union[str, np.ndarray]) -> TraceChannel:
    """Build a TraceChannel from a file path or an array."""
    trace = load_trace(source) if isinstance(source, str) else source
    return TraceChannel.from_array(trace)


def synthetic_burst_trace(
    n_packets: int,
    loss_rate: float,
    mean_burst: float = 5.0,
    seed: int = 0,
) -> np.ndarray:
    """Alternating-renewal synthetic trace: geometric loss bursts of mean
    length ``mean_burst`` separated by geometric good runs sized to hit the
    target overall loss rate."""
    assert 0.0 <= loss_rate < 1.0
    rng = np.random.RandomState(seed)
    mean_good = mean_burst * (1.0 - loss_rate) / max(loss_rate, 1e-9)
    out = np.empty(n_packets, dtype=np.int32)
    i = 0
    good = rng.rand() >= loss_rate
    while i < n_packets:
        mean_len = mean_good if good else mean_burst
        run = 1 + rng.geometric(1.0 / max(mean_len, 1.0)) - 1
        run = max(1, int(run))
        j = min(n_packets, i + run)
        out[i:j] = 1 if good else 0
        i = j
        good = not good
    return out
