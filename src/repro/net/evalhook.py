"""Bridge between the network subsystem and model accuracy.

The simulator and the channel/protocol sweep both need to turn "fraction of
the split activation delivered" into "task accuracy".  This module trains a
small COMtune split CNN once (reduced-size, CPU-friendly — smaller than
``repro.paper.experiment``'s benchmark model) and provides:

* ``accuracy_with_packet_masks`` — exact evaluation: per-sample packet
  delivery masks (e.g. produced by ``protocol.run_round`` against a bursty
  channel) are expanded to element masks with the paper's interleaving and
  pushed through the server half of the model.
* ``accuracy_vs_delivery_curve`` — the measured accuracy at a grid of
  delivered fractions, for use with ``simulator.accuracy_curve_fn`` to
  report accuracy under load without re-running the model per request.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.data as data
from repro.core import comtune
from repro.models import cnn
from repro.optim import AdamConfig, adam_update, init_adam

TINY_CFG = cnn.CNNConfig(
    blocks=((1, 8), (1, 16)),
    fc=(32,),
    num_classes=10,
    image_size=32,
    split_block=1,
)


@dataclasses.dataclass
class TinyModel:
    params: dict
    state: dict
    x_test: np.ndarray
    y_test: np.ndarray
    # Lazily cached device-half outputs on x_test (see split_activations);
    # the simulator's model-in-the-loop path evaluates per served batch and
    # must not recompute the device half every flush.
    acts: Optional[np.ndarray] = dataclasses.field(default=None, repr=False)

    @property
    def split_dim(self) -> int:
        return TINY_CFG.split_activation_dim


_CACHE: dict = {}


def train_tiny_model(
    steps: int = 150,
    dropout_rate: float = 0.3,
    seed: int = 0,
    n_train: int = 800,
    n_test: int = 400,
) -> TinyModel:
    """COMtune-train the tiny split CNN (dropout link at the split, Eq. 8)
    from scratch — one phase, enough for the orderings these sweeps report."""
    key_ = (steps, round(dropout_rate, 3), seed, n_train, n_test)
    if key_ in _CACHE:
        return _CACHE[key_]
    (xtr, ytr), (xte, yte) = data.make_image_dataset(
        n_train=n_train, n_test=n_test, num_classes=10, image_size=32,
        noise=2.0, signal_min=0.35, sub_prototypes=2, seed=seed,
    )
    adam_cfg = AdamConfig(lr=2e-3)
    key = jax.random.PRNGKey(seed)
    params, state = cnn.init_cnn(key, TINY_CFG)
    opt = init_adam(params, adam_cfg)
    it = data.batch_iterator(xtr, ytr, 64, seed=seed)

    @jax.jit
    def step(params, state, opt, xb, yb, k):
        def loss_fn(p):
            def link(a):
                return comtune.dropout_link(k, a, dropout_rate)

            logits, new_state = cnn.forward(
                p, state, xb, TINY_CFG, train=True,
                link_fn=link if dropout_rate > 0 else None,
            )
            ll = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(ll, yb[:, None], axis=-1).mean(), new_state

        (_, new_state), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt, _ = adam_update(g, params, opt, adam_cfg)
        return params, new_state, opt

    for _ in range(steps):
        xb, yb = next(it)
        key, sub = jax.random.split(key)
        params, state, opt = step(
            params, state, opt, jnp.asarray(xb), jnp.asarray(yb), sub
        )
    model = TinyModel(params=params, state=state, x_test=xte, y_test=yte)
    _CACHE[key_] = model
    return model


def split_activations(model: TinyModel) -> np.ndarray:
    """Device-half outputs on the test set, cached on the model."""
    if model.acts is None:
        a, _ = cnn.forward_device(
            model.params, model.state, jnp.asarray(model.x_test), TINY_CFG
        )
        model.acts = np.asarray(a)
    return model.acts


def _expand_packet_masks(
    pkt_masks: np.ndarray,               # (B, n_packets) bool
    num_elements: int,
    elements_per_packet: int,
    key: Optional[jax.Array] = None,
    shuffle: bool = True,
    keys: Optional[jax.Array] = None,    # (B, 2) explicit per-sample keys
) -> np.ndarray:
    """(B, num_elements) float32 element masks with per-sample interleaving
    — vmapped over the single shared Eq. 2 implementation in
    ``repro.net.channels`` so the eval path cannot drift from what
    ``channel_link`` simulates.  Pass ``keys`` for per-sample keys that are
    stable regardless of batch composition (the per-request eval path);
    otherwise the interleaving keys are split from ``key``."""
    from repro.net.channels import element_mask_from_packets

    if keys is None:
        keys = jax.random.split(key, pkt_masks.shape[0])
    fn = jax.vmap(
        lambda m, k: element_mask_from_packets(
            m, num_elements, elements_per_packet, k, shuffle
        )
    )
    return np.asarray(fn(jnp.asarray(pkt_masks, jnp.float32), keys))


def _masked_server_predictions(
    model: TinyModel, a: np.ndarray, masks: np.ndarray
) -> np.ndarray:
    """Apply element masks at the split with realized-fraction compensation
    (unbiased for partial delivery, the adaptive variant of Eq. 11) and run
    the server half; returns predicted classes (B,)."""
    frac = np.maximum(masks.mean(axis=1, keepdims=True), 1e-3)
    logits, _ = cnn.forward_server(
        model.params, model.state, jnp.asarray(a * masks / frac), TINY_CFG
    )
    return np.asarray(jnp.argmax(logits, -1))


def accuracy_with_packet_masks(
    model: TinyModel,
    pkt_masks: np.ndarray,               # (B, n_packets) bool, B = len(x_test)
    elements_per_packet: int = 25,
    seed: int = 0,
    activations: Optional[np.ndarray] = None,
) -> float:
    """DI accuracy with per-sample packet delivery masks applied at the
    split, using per-sample realized-fraction compensation (unbiased for
    partial delivery, the adaptive variant of Eq. 11)."""
    a = split_activations(model) if activations is None else activations
    masks = _expand_packet_masks(
        pkt_masks, a.shape[1], elements_per_packet, jax.random.PRNGKey(seed)
    )
    pred = _masked_server_predictions(model, a, masks)
    return float((pred == model.y_test).mean())


def accuracy_per_request_masks(
    model: TinyModel,
    pkt_masks: np.ndarray,               # (R, n_packets) bool
    rids: np.ndarray,                    # (R,) request ids
    elements_per_packet: Optional[int] = None,
    seed: int = 0,
) -> np.ndarray:
    """Per-request correctness under realized packet delivery masks.

    The DI semantics of the multi-client simulator: request ``rid`` carries
    ONE sample's split activation (test sample ``rid % n_test``), its
    uplink's realized per-packet delivery mask is expanded to an element
    mask with the paper's interleaving (keyed per-rid, so results don't
    depend on how requests were batched) and applied at the split with
    realized-fraction compensation; the server half classifies.  Returns a
    bool (R,) array — mean it for accuracy under load.
    """
    pkt_masks = np.asarray(pkt_masks, dtype=bool)
    rids = np.asarray(rids, dtype=np.int64)
    assert pkt_masks.ndim == 2 and pkt_masks.shape[0] == rids.shape[0]
    a_all = split_activations(model)
    n_test = a_all.shape[0]
    idx = rids % n_test
    a = a_all[idx]
    n_packets = pkt_masks.shape[1]
    if elements_per_packet is None:
        # The request's message is the whole split vector spread over its
        # n_packets uplink packets.
        elements_per_packet = -(-a.shape[1] // n_packets)
    # Interleaving keyed per-rid so a request's element mask doesn't depend
    # on how the server happened to batch it.
    base = jax.random.PRNGKey(seed)
    keys = jax.vmap(lambda r: jax.random.fold_in(base, r))(jnp.asarray(rids))
    masks = _expand_packet_masks(
        pkt_masks, a.shape[1], elements_per_packet, keys=keys
    )
    pred = _masked_server_predictions(model, a, masks)
    return pred == model.y_test[idx]


def make_request_eval_fn(
    model: TinyModel,
    n_packets: int,
    elements_per_packet: Optional[int] = None,
    seed: int = 0,
):
    """Bind ``accuracy_per_request_masks`` for ``run_sim``'s
    model-in-the-loop mode: ``(pkt_masks, rids) -> correct (R,) bool``."""
    if elements_per_packet is None:
        elements_per_packet = -(-TINY_CFG.split_activation_dim // n_packets)

    def fn(pkt_masks: np.ndarray, rids: np.ndarray) -> np.ndarray:
        return accuracy_per_request_masks(
            model, pkt_masks, rids,
            elements_per_packet=elements_per_packet, seed=seed,
        )

    return fn


def make_lm_request_eval_fn(
    params,
    cfg,
    n_packets: int,
    seq_len: int = 16,
    n_test: int = 256,
    seed: int = 0,
):
    """Model-in-the-loop eval for an *LM* checkpoint (e.g. one produced by
    ``launch/train.py --ckpt-dir``): request ``rid`` carries one held-out
    synthetic sequence (sample ``rid % n_test``); its realized per-packet
    uplink delivery mask is expanded to an element mask over the split
    activation (seq_len x d_model elements, per-rid interleaving) and
    forced at the split with realized-fraction compensation via the
    ``lm.forward(link_fn=...)`` override; correctness is last-position
    next-token prediction.  Returns ``(pkt_masks (R, n_packets) bool,
    rids (R,)) -> correct (R,) bool`` for ``run_sim``'s
    ``request_eval_fn`` — so channel-tuned checkpoints are scored under
    the simulator's *actual* burst patterns, not an interpolation curve.
    """
    import repro.data as data
    from repro.models import lm

    # Checkpoint-restored pytrees are numpy; the jitted forward indexes the
    # embedding with a tracer, which numpy arrays reject.
    params = jax.tree_util.tree_map(jnp.asarray, params)
    toks = data.make_lm_dataset(
        cfg.vocab_size, n_tokens=n_test * (seq_len + 1) + 2, seed=seed
    )
    seqs = toks[: n_test * (seq_len + 1)].reshape(n_test, seq_len + 1)
    x_all = seqs[:, :seq_len].astype(np.int32)
    y_all = seqs[:, seq_len].astype(np.int64)
    d = cfg.d_model
    n_elem = seq_len * d
    elements_per_packet = -(-n_elem // n_packets)

    def run(batch_toks: jax.Array, masks: jax.Array) -> jax.Array:
        m = masks.reshape(batch_toks.shape[0], seq_len, d)
        frac = jnp.maximum(m.mean(axis=(1, 2), keepdims=True), 1e-3)

        def link(a):
            return a * m.astype(a.dtype) / frac.astype(a.dtype)

        logits, _, _ = lm.forward(
            params, batch_toks, cfg, link_fn=link, mode="prefill"
        )
        return jnp.argmax(logits[:, -1], axis=-1)

    run_j = jax.jit(run)

    def fn(pkt_masks: np.ndarray, rids: np.ndarray) -> np.ndarray:
        pkt_masks = np.asarray(pkt_masks, dtype=bool)
        rids = np.asarray(rids, dtype=np.int64)
        idx = rids % n_test
        base = jax.random.PRNGKey(seed)
        keys = jax.vmap(lambda r: jax.random.fold_in(base, r))(jnp.asarray(rids))
        masks = _expand_packet_masks(
            pkt_masks, n_elem, elements_per_packet, keys=keys
        )
        pred = np.asarray(run_j(jnp.asarray(x_all[idx]), jnp.asarray(masks)))
        return pred == y_all[idx]

    return fn


def accuracy_vs_delivery_curve(
    model: TinyModel,
    fractions: Sequence[float] = (1.0, 0.9, 0.75, 0.6, 0.4, 0.2, 0.05),
    seed: int = 0,
) -> Tuple[list, list]:
    """Measured accuracy at each delivered fraction (random element masks);
    feed the result to ``simulator.accuracy_curve_fn``."""
    a = split_activations(model)
    rng = np.random.RandomState(seed)
    accs = []
    for f in fractions:
        masks = (rng.rand(*a.shape) < f).astype(np.float32)
        fr = np.maximum(masks.mean(axis=1, keepdims=True), 1e-3)
        logits, _ = cnn.forward_server(
            model.params, model.state, jnp.asarray(a * masks / fr), TINY_CFG
        )
        accs.append(
            float((jnp.argmax(logits, -1) == jnp.asarray(model.y_test)).mean())
        )
    return list(fractions), accs
