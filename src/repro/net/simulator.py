"""Event-driven multi-client serving simulator.

N device clients share one edge server over per-client lossy links.  Each
client generates split-inference requests as a Poisson process (or an
explicit hand-scheduled arrival list); a request's uplink (the split
activation, ``n_packets`` packets) runs through the client's protocol
policy over its *stateful* channel (burst state carries across requests),
then queues at the server, which serves in batches with a configurable
compute-time model.  The simulator is a classic future-event-list design
(heapq) — no wall-clock, fully deterministic given the seed.

Correctness notes (regression-tested in tests/test_net.py):

* The protocol round (and therefore the channel draw) happens at *uplink
  start* — a dedicated ``_UPLINK_START`` event fired when the client's
  half-duplex radio actually frees up — NOT at arrival.  Requests that
  queue behind a busy radio draw their packet masks in transmission order,
  so stateful (Gilbert–Elliott / fading / trace) channels evolve their
  burst state in the order packets actually hit the air.
* The reported ``duration_s`` horizon covers every *finished* request —
  served or dropped — so a simulation whose tail is all deadline drops no
  longer over-reports ``throughput_rps``.

Outputs: throughput, p50/p99 end-to-end round latency, delivered-fraction
statistics, and accuracy under load via either

* ``accuracy_fn(delivered_fraction) -> accuracy`` — the offline
  interpolation-curve bridge (``accuracy_curve_fn``), or
* ``model_in_the_loop=True`` — each served batch's realized per-request
  packet delivery masks are collected and pushed through the server half
  of the real COMtune model (``repro.net.evalhook``), so accuracy under
  load reflects burst patterns, batching, and FEC recovery instead of an
  interpolated mean.

Conservation invariant (asserted in tests): every arrived request is
eventually counted exactly once as served or dropped (a request is dropped
when its protocol round delivers < ``min_delivered_fraction`` of the
message, the deadline case of ARQ/FEC policies).
"""

from __future__ import annotations

import collections
import dataclasses
import heapq
import inspect
import itertools
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core import link as link_lib
from repro.obs.stats import latency_summary
from repro.net.channels import Channel, IIDChannel
from repro.net.chaos import ChaosSchedule, _OverrideChannel
from repro.net.protocol import UnreliableProtocol, _ProtocolBase


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_clients: int = 16
    arrival_rate_hz: float = 2.0       # Poisson rate per client
    duration_s: float = 10.0           # arrival window; sim drains afterwards
    n_packets: int = 41                # uplink packets per request (~4 kB/100 B)
    server_batch_max: int = 8          # server batches up to this many requests
    server_base_s: float = 2e-3        # per-batch fixed compute time
    server_per_item_s: float = 5e-4    # incremental compute per batched item
    min_delivered_fraction: float = 0.2  # below this the request is dropped
    seed: int = 0


@dataclasses.dataclass
class _Request:
    rid: int
    client: int
    t_arrival: float
    t_uplink_start: float = 0.0
    t_uplink_done: float = 0.0
    delivered_fraction: float = 0.0
    t_done: float = 0.0
    pkt_mask: Optional[np.ndarray] = None   # bool (n_packets,) realized delivery


@dataclasses.dataclass(frozen=True)
class SimReport:
    arrived: int
    served: int
    dropped: int
    duration_s: float
    throughput_rps: float
    latency_p50_s: float
    latency_p99_s: float
    latency_mean_s: float
    mean_delivered_fraction: float
    mean_batch_size: float
    accuracy_under_load: Optional[float] = None
    accuracy_mode: Optional[str] = None   # "curve" | "model" | None

    def row(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


# Event kinds, ordered so simultaneous events resolve deterministically:
# arrivals enqueue before radios start, radios finish before the server.
_ARRIVAL, _UPLINK_START, _UPLINK_DONE, _SERVER_DONE = 0, 1, 2, 3


def run_sim(
    cfg: SimConfig,
    channels: Optional[Sequence[Channel]] = None,
    protocol: Optional[_ProtocolBase] = None,
    channel_cfg: Optional[link_lib.ChannelConfig] = None,
    accuracy_fn: Optional[Callable[[float], float]] = None,
    arrivals: Optional[Sequence[Tuple[float, int]]] = None,
    model_in_the_loop: bool = False,
    model=None,
    request_eval_fn: Optional[Callable[[np.ndarray, np.ndarray], np.ndarray]] = None,
    engine: Optional[Callable[[Sequence["_Request"]], float]] = None,
    chaos: Optional[ChaosSchedule] = None,
) -> SimReport:
    """Run one simulation.

    ``channels`` gives one stateful channel per client (default: IID at 10%
    for all); ``protocol`` is shared (default: unreliable); ``channel_cfg``
    sets packet slot time (default: paper's 100 B @ 9 Mbit/s).

    ``arrivals`` optionally replaces the Poisson processes with an explicit
    ``[(t, client), ...]`` schedule (trace-driven workloads; also how the
    ordering tests hand-schedule contention).

    ``model_in_the_loop=True`` evaluates accuracy under load from the
    realized per-request packet masks through the real model:
    ``request_eval_fn(pkt_masks (R, n_packets) bool, rids (R,)) -> correct
    (R,) bool`` is used if given, else built from ``model`` (default: the
    lazily trained ``evalhook`` tiny COMtune CNN, request rid -> test
    sample rid mod n_test).

    ``engine`` replaces the analytic server compute-time model with the
    *live* serve engine: each served batch is handed to
    ``engine(batch_requests) -> wall_seconds`` (see
    ``repro.serve.continuous.make_sim_server``) and the measured wall time
    — real compute, plus real compile behavior the first time a batch hits
    a new prefill bucket — becomes the server busy time, so the reported
    p50/p99 include what the hardware actually did.  Composes with
    ``model_in_the_loop=True`` (mask collection is unchanged).  An engine
    callable accepting a ``now`` keyword receives the simulated batch
    start time (``make_sim_server`` uses it to drive chaos block squeezes
    and scheduler deadlines on the sim clock).

    ``chaos`` injects scheduled faults (``repro.net.chaos``) into the
    event flow: ``channel_collapse`` windows draw uplink masks from an
    i.i.d. overlay at the override loss rate (the real channel's burst
    state is NOT advanced — outage, not channel mutation), ``server_stall``
    windows extend the busy time of batches started inside them, and
    ``burst_storm`` windows multiply the Poisson arrival rate (explicit
    ``arrivals`` schedules are taken as-is).
    """
    t_wall0 = time.perf_counter()
    rng = np.random.RandomState(cfg.seed)
    channel_cfg = channel_cfg or link_lib.ChannelConfig()
    protocol = protocol or UnreliableProtocol()
    chaos = chaos if chaos else None          # empty schedule -> no-op path
    engine_takes_now = False
    if engine is not None:
        try:
            engine_takes_now = "now" in inspect.signature(engine).parameters
        except (TypeError, ValueError):
            pass
    if channels is None:
        channels = [IIDChannel(0.1) for _ in range(cfg.n_clients)]
    assert len(channels) == cfg.n_clients
    ch_state = [ch.init_state(rng) for ch in channels]
    slot_t = channel_cfg.slot_time_s()
    collect_masks = model_in_the_loop

    events: List[Tuple[float, int, int, object]] = []  # (t, kind, seq, payload)
    seq = itertools.count()

    def push(t: float, kind: int, payload) -> None:
        heapq.heappush(events, (t, kind, next(seq), payload))

    # Storm windows multiply the Poisson rate; the multiplier is evaluated
    # at scheduling time (rate-modulated, not exactly thinned — fine for a
    # fault injector).
    def arrival_rate(t: float) -> float:
        mult = chaos.storm_multiplier(t) if chaos is not None else 1.0
        return cfg.arrival_rate_hz * mult

    if arrivals is not None:
        for t, c in arrivals:
            assert 0 <= c < cfg.n_clients, (t, c)
            push(float(t), _ARRIVAL, c)
    else:
        # Seed one arrival per client; each arrival schedules the next.  The
        # window check matches the one applied to subsequent arrivals.
        for c in range(cfg.n_clients):
            t0 = rng.exponential(1.0 / arrival_rate(0.0))
            if t0 < cfg.duration_s:
                push(t0, _ARRIVAL, c)

    # Per-client uplink is half-duplex: requests on one client serialize
    # through a FIFO; the channel is drawn when transmission starts, not
    # at arrival, so burst state advances in on-air order.
    client_pending = [collections.deque() for _ in range(cfg.n_clients)]
    client_busy = [False] * cfg.n_clients
    server_queue: List[_Request] = []
    server_busy = False

    arrived = served = dropped = 0
    done: List[_Request] = []
    served_batches: List[List[_Request]] = []
    batch_sizes: List[int] = []
    t_finish = 0.0          # last served-or-dropped completion time
    rid = itertools.count()

    def start_batch(now: float) -> None:
        nonlocal server_busy
        take = server_queue[: cfg.server_batch_max]
        del server_queue[: len(take)]
        batch_sizes.append(len(take))
        if engine is not None:
            busy = float(engine(take, now=now) if engine_takes_now
                         else engine(take))
        else:
            busy = cfg.server_base_s + cfg.server_per_item_s * len(take)
        if chaos is not None:
            # A batch started inside a stall window pays the remaining
            # stall before its compute runs (frozen server, work queued).
            busy += max(0.0, chaos.stall_until(now) - now)
        server_busy = True
        push(now + busy, _SERVER_DONE, take)

    while events:
        now, kind, _, payload = heapq.heappop(events)
        if kind == _ARRIVAL:
            c = payload
            arrived += 1
            req = _Request(rid=next(rid), client=c, t_arrival=now)
            client_pending[c].append(req)
            # Kick the radio only on the empty->nonempty transition: with
            # the radio idle there is exactly one outstanding _UPLINK_START
            # per client, even for simultaneous arrivals (the busy flag
            # flips when that event is *processed*, not when scheduled).
            if not client_busy[c] and len(client_pending[c]) == 1:
                push(now, _UPLINK_START, c)
            if arrivals is None:
                # Next arrival for this client (within the arrival window).
                t_next = now + rng.exponential(1.0 / arrival_rate(now))
                if t_next < cfg.duration_s:
                    push(t_next, _ARRIVAL, c)
        elif kind == _UPLINK_START:
            c = payload
            req = client_pending[c].popleft()
            client_busy[c] = True
            req.t_uplink_start = now
            override = (chaos.loss_override(now) if chaos is not None
                        else None)
            if override is not None:
                # Collapse window: draw from the overlay process at the
                # override rate; the real channel's burst state stays put.
                result, _ = protocol.run_round(
                    rng, _OverrideChannel(override), None, cfg.n_packets
                )
            else:
                result, ch_state[c] = protocol.run_round(
                    rng, channels[c], ch_state[c], cfg.n_packets
                )
            t_up = now + result.slots * slot_t
            req.t_uplink_done = t_up
            req.delivered_fraction = result.delivered_fraction
            if collect_masks:
                req.pkt_mask = np.asarray(result.delivered, dtype=bool).copy()
            push(t_up, _UPLINK_DONE, req)
        elif kind == _UPLINK_DONE:
            req = payload
            c = req.client
            client_busy[c] = False
            if client_pending[c]:
                push(now, _UPLINK_START, c)
            if req.delivered_fraction < cfg.min_delivered_fraction:
                dropped += 1
                req.t_done = now
                t_finish = max(t_finish, now)
                continue
            server_queue.append(req)
            if not server_busy:
                start_batch(now)
        elif kind == _SERVER_DONE:
            batch = payload
            for req in batch:
                req.t_done = now
                served += 1
                done.append(req)
            t_finish = max(t_finish, now)
            if collect_masks and batch:
                served_batches.append(list(batch))
            server_busy = False
            if server_queue:
                start_batch(now)

    assert arrived == served + dropped, (arrived, served, dropped)

    # The horizon covers every finished request, served OR dropped — a
    # tail of deadline drops extends duration and dilutes throughput.
    horizon = max(t_finish, cfg.duration_s)

    acc: Optional[float] = None
    acc_mode: Optional[str] = None
    if done:
        lat = np.array([r.t_done - r.t_arrival for r in done])
        frac = np.array([r.delivered_fraction for r in done])
        summ = latency_summary(lat)              # shared obs.stats helper
        p50, p99, mean = summ["p50_s"], summ["p99_s"], summ["mean_s"]
        mfrac = float(frac.mean())
        if model_in_the_loop:
            acc = _model_in_the_loop_accuracy(
                served_batches, cfg.n_packets, model, request_eval_fn
            )
            acc_mode = "model"
        elif accuracy_fn is not None:
            acc = float(np.mean([accuracy_fn(f) for f in frac]))
            acc_mode = "curve"
    else:
        p50 = p99 = mean = mfrac = 0.0
    report = SimReport(
        arrived=arrived,
        served=served,
        dropped=dropped,
        duration_s=float(horizon),
        throughput_rps=served / max(horizon, 1e-9),
        latency_p50_s=p50,
        latency_p99_s=p99,
        latency_mean_s=mean,
        mean_delivered_fraction=mfrac,
        mean_batch_size=float(np.mean(batch_sizes)) if batch_sizes else 0.0,
        accuracy_under_load=acc,
        accuracy_mode=acc_mode,
    )
    reg = obs.registry()
    if reg.enabled:
        _publish_obs(reg, report, done, t_wall0)
    return report


# How many per-request simulated-time spans go into the event log (the
# counters/histograms always cover every request).
_OBS_SPAN_CAP = 1024


def _publish_obs(reg, report: SimReport, done: Sequence[_Request],
                 t_wall0: float) -> None:
    """Registry export of one simulation.  Per-request spans are recorded
    on the *simulated* clock, rebased onto the registry's epoch
    (``reg.perf0 + sim_time``) so a chrome trace of the event log shows the
    sim timeline starting at 0 — wall time only stamps the ``sim.run``
    span itself."""
    reg.record_span(
        "sim.run", t_wall0, time.perf_counter(),
        arrived=report.arrived, served=report.served,
        dropped=report.dropped, throughput_rps=report.throughput_rps,
    )
    reg.counter("sim.requests_arrived").inc(report.arrived)
    reg.counter("sim.requests_served").inc(report.served)
    reg.counter("sim.requests_dropped").inc(report.dropped)
    reg.gauge("sim.throughput_rps").set(report.throughput_rps)
    reg.gauge("sim.mean_batch_size").set(report.mean_batch_size)
    lat_h = reg.histogram("sim.latency_s")
    frac_h = reg.histogram("sim.delivered_fraction")
    for r in done:
        lat_h.observe(r.t_done - r.t_arrival)
        frac_h.observe(r.delivered_fraction)
    for r in done[:_OBS_SPAN_CAP]:
        parent = reg.record_span(
            "sim.request", reg.perf0 + r.t_arrival, reg.perf0 + r.t_done,
            rid=r.rid, client=r.client,
            delivered_fraction=r.delivered_fraction,
        )
        reg.record_span(
            "sim.uplink", reg.perf0 + r.t_uplink_start,
            reg.perf0 + r.t_uplink_done, parent=parent, rid=r.rid,
        )
        reg.record_span(
            "sim.server", reg.perf0 + r.t_uplink_done,
            reg.perf0 + r.t_done, parent=parent, rid=r.rid,
        )


_EVAL_CHUNK = 256   # requests per model call when flushing collected masks


def _model_in_the_loop_accuracy(
    served_batches: Sequence[Sequence[_Request]],
    n_packets: int,
    model,
    request_eval_fn,
) -> float:
    """Mean per-request correctness over the served batches' realized
    packet masks.  Masks are collected batch-by-batch as the server
    completes them and flushed through the model in bounded chunks."""
    reqs = [r for batch in served_batches for r in batch]
    if not reqs:
        return 0.0
    if request_eval_fn is None:
        # Lazy import: the simulator core stays numpy-only unless the
        # model-in-the-loop path is actually requested.
        from repro.net import evalhook

        model = model if model is not None else evalhook.train_tiny_model()
        request_eval_fn = evalhook.make_request_eval_fn(model, n_packets)
    masks = np.stack([r.pkt_mask for r in reqs])
    rids = np.array([r.rid for r in reqs], dtype=np.int64)
    correct: List[np.ndarray] = []
    for i in range(0, len(reqs), _EVAL_CHUNK):
        correct.append(
            np.asarray(
                request_eval_fn(masks[i : i + _EVAL_CHUNK],
                                rids[i : i + _EVAL_CHUNK])
            )
        )
    return float(np.concatenate(correct).mean())


def accuracy_curve_fn(
    fractions: Sequence[float], accuracies: Sequence[float]
) -> Callable[[float], float]:
    """Linear interpolation of a measured accuracy-vs-delivered-fraction
    curve (clamped at the endpoints) — the bridge from the simulator's
    per-request delivery to model accuracy under load."""
    f = np.asarray(fractions, dtype=np.float64)
    a = np.asarray(accuracies, dtype=np.float64)
    order = np.argsort(f)
    f, a = f[order], a[order]

    def fn(delivered_fraction: float) -> float:
        return float(np.interp(delivered_fraction, f, a))

    return fn
