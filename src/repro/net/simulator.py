"""Event-driven multi-client serving simulator.

N device clients share one edge server over per-client lossy links.  Each
client generates split-inference requests as a Poisson process; a request's
uplink (the split activation, ``n_packets`` packets) runs through the
client's protocol policy over its *stateful* channel (burst state carries
across requests), then queues at the server, which serves in batches with a
configurable compute-time model.  The simulator is a classic future-event-
list design (heapq) — no wall-clock, fully deterministic given the seed.

Outputs: throughput, p50/p99 end-to-end round latency, delivered-fraction
statistics, and (optionally) accuracy under load via a caller-provided
``accuracy_fn(delivered_fraction) -> accuracy`` — typically an
interpolation of the COMtune model's measured accuracy-vs-loss curve, so
the serving simulation and the learning stack stay coupled.

Conservation invariant (asserted in tests): every arrived request is
eventually counted exactly once as served or dropped (a request is dropped
when its protocol round delivers < ``min_delivered_fraction`` of the
message, the deadline case of ARQ/FEC policies).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import link as link_lib
from repro.net.channels import Channel, IIDChannel
from repro.net.protocol import UnreliableProtocol, _ProtocolBase


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_clients: int = 16
    arrival_rate_hz: float = 2.0       # Poisson rate per client
    duration_s: float = 10.0           # arrival window; sim drains afterwards
    n_packets: int = 41                # uplink packets per request (~4 kB/100 B)
    server_batch_max: int = 8          # server batches up to this many requests
    server_base_s: float = 2e-3        # per-batch fixed compute time
    server_per_item_s: float = 5e-4    # incremental compute per batched item
    min_delivered_fraction: float = 0.2  # below this the request is dropped
    seed: int = 0


@dataclasses.dataclass
class _Request:
    rid: int
    client: int
    t_arrival: float
    t_uplink_done: float = 0.0
    delivered_fraction: float = 0.0
    t_done: float = 0.0


@dataclasses.dataclass(frozen=True)
class SimReport:
    arrived: int
    served: int
    dropped: int
    duration_s: float
    throughput_rps: float
    latency_p50_s: float
    latency_p99_s: float
    latency_mean_s: float
    mean_delivered_fraction: float
    mean_batch_size: float
    accuracy_under_load: Optional[float] = None

    def row(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


# Event kinds, ordered so simultaneous events resolve deterministically.
_ARRIVAL, _UPLINK_DONE, _SERVER_DONE = 0, 1, 2


def run_sim(
    cfg: SimConfig,
    channels: Optional[Sequence[Channel]] = None,
    protocol: Optional[_ProtocolBase] = None,
    channel_cfg: Optional[link_lib.ChannelConfig] = None,
    accuracy_fn: Optional[Callable[[float], float]] = None,
) -> SimReport:
    """Run one simulation.  ``channels`` gives one stateful channel per
    client (default: IID at 10% for all); ``protocol`` is shared (default:
    unreliable); ``channel_cfg`` sets packet slot time (default: paper's
    100 B @ 9 Mbit/s)."""
    rng = np.random.RandomState(cfg.seed)
    channel_cfg = channel_cfg or link_lib.ChannelConfig()
    protocol = protocol or UnreliableProtocol()
    if channels is None:
        channels = [IIDChannel(0.1) for _ in range(cfg.n_clients)]
    assert len(channels) == cfg.n_clients
    ch_state = [ch.init_state(rng) for ch in channels]
    slot_t = channel_cfg.slot_time_s()

    events: List[Tuple[float, int, int, object]] = []  # (t, kind, seq, payload)
    seq = itertools.count()

    def push(t: float, kind: int, payload) -> None:
        heapq.heappush(events, (t, kind, next(seq), payload))

    # Seed one arrival per client; each arrival schedules the next.  The
    # window check matches the one applied to subsequent arrivals.
    for c in range(cfg.n_clients):
        t0 = rng.exponential(1.0 / cfg.arrival_rate_hz)
        if t0 < cfg.duration_s:
            push(t0, _ARRIVAL, c)

    # Per-client uplink is half-duplex: requests on one client serialize.
    client_free_at = np.zeros(cfg.n_clients)
    server_queue: List[_Request] = []
    server_busy = False

    arrived = served = dropped = 0
    done: List[_Request] = []
    batch_sizes: List[int] = []
    rid = itertools.count()

    def start_batch(now: float) -> None:
        nonlocal server_busy
        take = server_queue[: cfg.server_batch_max]
        del server_queue[: len(take)]
        batch_sizes.append(len(take))
        busy = cfg.server_base_s + cfg.server_per_item_s * len(take)
        server_busy = True
        push(now + busy, _SERVER_DONE, take)

    while events:
        now, kind, _, payload = heapq.heappop(events)
        if kind == _ARRIVAL:
            c = payload
            arrived += 1
            req = _Request(rid=next(rid), client=c, t_arrival=now)
            # Uplink starts when the client's radio is free.
            t_start = max(now, client_free_at[c])
            result, ch_state[c] = protocol.run_round(
                rng, channels[c], ch_state[c], cfg.n_packets
            )
            t_up = t_start + result.slots * slot_t
            client_free_at[c] = t_up
            req.t_uplink_done = t_up
            req.delivered_fraction = result.delivered_fraction
            push(t_up, _UPLINK_DONE, req)
            # Next arrival for this client (within the arrival window).
            t_next = now + rng.exponential(1.0 / cfg.arrival_rate_hz)
            if t_next < cfg.duration_s:
                push(t_next, _ARRIVAL, c)
        elif kind == _UPLINK_DONE:
            req = payload
            if req.delivered_fraction < cfg.min_delivered_fraction:
                dropped += 1
                req.t_done = now
                continue
            server_queue.append(req)
            if not server_busy:
                start_batch(now)
        elif kind == _SERVER_DONE:
            batch = payload
            for req in batch:
                req.t_done = now
                served += 1
                done.append(req)
            server_busy = False
            if server_queue:
                start_batch(now)

    assert arrived == served + dropped, (arrived, served, dropped)

    if done:
        lat = np.array([r.t_done - r.t_arrival for r in done])
        frac = np.array([r.delivered_fraction for r in done])
        p50 = float(np.percentile(lat, 50))
        p99 = float(np.percentile(lat, 99))
        mean = float(lat.mean())
        mfrac = float(frac.mean())
        acc = (
            float(np.mean([accuracy_fn(f) for f in frac]))
            if accuracy_fn is not None else None
        )
        horizon = max(max(r.t_done for r in done), cfg.duration_s)
    else:
        p50 = p99 = mean = mfrac = 0.0
        acc = None
        horizon = cfg.duration_s
    return SimReport(
        arrived=arrived,
        served=served,
        dropped=dropped,
        duration_s=float(horizon),
        throughput_rps=served / max(horizon, 1e-9),
        latency_p50_s=p50,
        latency_p99_s=p99,
        latency_mean_s=mean,
        mean_delivered_fraction=mfrac,
        mean_batch_size=float(np.mean(batch_sizes)) if batch_sizes else 0.0,
        accuracy_under_load=acc,
    )


def accuracy_curve_fn(
    fractions: Sequence[float], accuracies: Sequence[float]
) -> Callable[[float], float]:
    """Linear interpolation of a measured accuracy-vs-delivered-fraction
    curve (clamped at the endpoints) — the bridge from the simulator's
    per-request delivery to model accuracy under load."""
    f = np.asarray(fractions, dtype=np.float64)
    a = np.asarray(accuracies, dtype=np.float64)
    order = np.argsort(f)
    f, a = f[order], a[order]

    def fn(delivered_fraction: float) -> float:
        return float(np.interp(delivered_fraction, f, a))

    return fn
