"""Link-layer protocol policies over the packet channel.

The paper compares only two extremes (§III-B): a *reliable* protocol
(retransmit forever, Eq. 5 negative-binomial latency) and an *unreliable*
one (one shot, Eq. 4 binomial delivery).  Real IoT deployments sit between
them.  This module provides three policies behind one interface:

* ``UnreliableProtocol``   — one transmission attempt per packet;
  deterministic latency ``n_t * T``, partial delivery (exactly Eq. 4).
* ``ARQProtocol``          — round-based selective-repeat ARQ with a
  retransmission budget: undelivered packets are retransmitted for up to
  ``max_rounds`` rounds (or until a latency ``deadline_s`` would be
  exceeded).  ``max_rounds=inf`` recovers the paper's reliable protocol.
* ``HybridFECARQProtocol`` — each round transmits FEC-encoded blocks
  (``repro.net.fec``); a block is delivered when ≥ k of its k+m packets
  arrive; unrecovered blocks are retransmitted subject to the same budget.

Each policy offers:

* ``latency_pmf(n_packets, channel_cfg)`` — analytic per-round latency PMF
  (support over slot counts), generalizing ``core.link``'s Eq. 4-5
  analytics; computed by dynamic programming over the per-round binomial
  delivery process at the channel's stationary loss rate.
* ``expected_delivery_rate(n_packets, channel)`` — mean fraction of data
  packets available to the receiver at the end of the exchange.
* ``run_round(rng, channel, state, n_packets)`` — stateful Monte-Carlo
  execution against a *bursty* channel (the event-driven simulator path),
  returning per-data-packet delivery, slot count, and the advanced channel
  state.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core import link as link_lib
from repro.net import fec as fec_lib
from repro.net.channels import Channel


@dataclasses.dataclass(frozen=True)
class RoundResult:
    """Outcome of one protocol round for one request."""

    delivered: np.ndarray            # bool (n_data_packets,)
    slots: int                       # total packet-slots spent on the air
    rounds: int                      # transmission rounds used

    @property
    def delivered_fraction(self) -> float:
        return float(np.mean(self.delivered))

    @property
    def complete(self) -> bool:
        return bool(np.all(self.delivered))


def latency_quantile(lat: np.ndarray, pmf: np.ndarray, q: float) -> float:
    """Quantile of a discrete latency PMF (support assumed sorted)."""
    return float(lat[min(np.searchsorted(np.cumsum(pmf), q), lat.size - 1)])


def _binom_pmf(n: int, p_success: float) -> np.ndarray:
    """PMF over number of successes in n i.i.d. trials (support 0..n)."""
    if n == 0:
        return np.ones(1)
    ks = np.arange(n + 1)
    if p_success <= 0.0:
        out = np.zeros(n + 1)
        out[0] = 1.0
        return out
    if p_success >= 1.0:
        out = np.zeros(n + 1)
        out[-1] = 1.0
        return out
    logp = (
        link_lib.log_binom_coeff(n, ks)
        + ks * np.log(p_success)
        + (n - ks) * np.log1p(-p_success)
    )
    pmf = np.exp(logp)
    return pmf / pmf.sum()


def _clamped_loss(channel_cfg: link_lib.ChannelConfig,
                  loss_rate: Optional[float]) -> float:
    """Resolve and clamp the loss rate into [0, 1].

    The PMF tail handling at the extremes is exact by construction
    (``_binom_pmf`` branches at p<=0 / p>=1 instead of exponentiating
    ``log(0)``), but callers feeding a chaos-ramped ``loss_rate`` can
    overshoot 1.0 by float error — without the clamp that turns the DP
    weights into NaN and feasibility into NaN instead of exactly 0."""
    p = channel_cfg.loss_rate if loss_rate is None else float(loss_rate)
    return min(max(p, 0.0), 1.0)


def _retry_dp(
    n_units: int,
    slots_per_unit: int,
    p_unit_fail: float,
    max_rounds: int,
    deadline_hit,
) -> Tuple[dict, dict]:
    """DP over (missing units, slots spent) shared by ARQ and FEC+ARQ.

    One "unit" is a packet (ARQ) or an FEC block (``slots_per_unit`` = k+m
    packet slots).  Returns ``(done_all, done_complete)``: terminal
    probability mass by slot count over ALL terminal states, and over the
    full-delivery (``missing == 0``) terminals only.  ``done_complete`` is
    sub-normalized — its missing mass is the failure probability.
    """
    dist = {(n_units, 0): 1.0}
    done_all: dict = {}
    done_ok: dict = {}

    def settle(miss: int, slots: int, prob: float) -> None:
        done_all[slots] = done_all.get(slots, 0.0) + prob
        if miss == 0:
            done_ok[slots] = done_ok.get(slots, 0.0) + prob

    for _ in range(max_rounds):
        nxt: dict = {}
        for (miss, slots), prob in dist.items():
            if miss == 0 or deadline_hit(slots):
                settle(miss, slots, prob)
                continue
            new_slots = slots + miss * slots_per_unit
            pmf = _binom_pmf(miss, 1.0 - p_unit_fail)
            for rec, pr in enumerate(pmf):
                if pr < 1e-15:
                    continue
                key = (miss - rec, new_slots)
                nxt[key] = nxt.get(key, 0.0) + prob * pr
        dist = nxt
        if not dist:
            break
    for (miss, slots), prob in dist.items():
        settle(miss, slots, prob)
    return done_all, done_ok


def _dist_arrays(done: dict, slot_time_s: float
                 ) -> Tuple[np.ndarray, np.ndarray]:
    slots = np.array(sorted(done))
    mass = np.array([done[s] for s in slots])
    return slots * slot_time_s, mass


class _ProtocolBase:
    name: str = "base"

    def latency_pmf(
        self, n_packets: int, channel_cfg: link_lib.ChannelConfig,
        loss_rate: Optional[float] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def completion_latency_pmf(
        self, n_packets: int, channel_cfg: link_lib.ChannelConfig,
        loss_rate: Optional[float] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Joint (full delivery, latency) distribution.

        Returns ``(lat_s, mass)`` where ``mass[i]`` is the probability that
        the exchange delivers the COMPLETE message and finishes at latency
        ``lat_s[i]`` — sub-normalized on purpose: ``mass.sum()`` is
        P(complete delivery) and the missing probability is the failure
        mass (deadline hit / retry budget exhausted with packets missing).
        Keeping the joint form instead of conditioning on success is what
        makes ``deadline_feasible`` exactly 0 (not 0/0 = NaN) when the
        success mass vanishes at ``loss_rate=1.0``.
        """
        raise NotImplementedError

    def expected_latency_s(
        self, n_packets: int, channel_cfg: link_lib.ChannelConfig,
        loss_rate: Optional[float] = None,
    ) -> float:
        lat, pmf = self.latency_pmf(n_packets, channel_cfg, loss_rate)
        return float(np.dot(lat, pmf))

    def run_round(self, rng, channel: Channel, state, n_packets: int
                  ) -> Tuple[RoundResult, object]:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Unreliable (paper Eq. 4)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class UnreliableProtocol(_ProtocolBase):
    """One shot per packet; latency is deterministic, delivery partial."""

    name: str = "unreliable"

    def latency_pmf(self, n_packets, channel_cfg, loss_rate=None):
        lat = np.array([n_packets * channel_cfg.slot_time_s()])
        return lat, np.ones(1)

    def completion_latency_pmf(self, n_packets, channel_cfg, loss_rate=None):
        p = _clamped_loss(channel_cfg, loss_rate)
        lat = np.array([n_packets * channel_cfg.slot_time_s()])
        # All n packets must survive the single shot; (1-p)^n is exactly 0
        # at p=1 and exactly 1 at p=0.
        return lat, np.array([(1.0 - p) ** n_packets])

    def expected_delivery_rate(self, n_packets: int, channel: Channel) -> float:
        return 1.0 - channel.stationary_loss_rate

    def run_round(self, rng, channel, state, n_packets):
        keep, state = channel.step(rng, state, n_packets)
        return RoundResult(keep.copy(), n_packets, 1), state


# ---------------------------------------------------------------------------
# ARQ with a retransmission/deadline budget
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ARQProtocol(_ProtocolBase):
    """Round-based selective-repeat ARQ.

    Round 1 transmits all ``n`` packets; round j retransmits the packets
    still missing.  Stops when everything is delivered, after ``max_rounds``
    rounds, or once ``deadline_slots`` packet-slots have been spent (the
    "ARQ-with-deadline" policy: latency is bounded, delivery best-effort).
    A large integer ``max_rounds`` budget (e.g. 60) with no deadline
    approaches the paper's reliable protocol to numerical precision
    (Eq. 5 is the n=1-per-slot special case of the same process).
    """

    max_rounds: int = 4
    deadline_slots: Optional[int] = None
    name: str = "arq"

    def _deadline_hit(self, slots: int) -> bool:
        return (
            self.deadline_slots is not None and slots >= self.deadline_slots
        )

    def latency_pmf(self, n_packets, channel_cfg, loss_rate=None):
        """DP over (round, missing count) at the stationary loss rate.

        State: number of packets still missing entering round j.  Latency
        accumulated = sum over rounds of (missing_j) slots; we track the
        joint distribution of (missing, slots spent).
        """
        p = _clamped_loss(channel_cfg, loss_rate)
        done, _ = _retry_dp(
            n_packets, 1, p, self.max_rounds, self._deadline_hit
        )
        lat, pmf = _dist_arrays(done, channel_cfg.slot_time_s())
        return lat, pmf / pmf.sum()

    def completion_latency_pmf(self, n_packets, channel_cfg, loss_rate=None):
        p = _clamped_loss(channel_cfg, loss_rate)
        _, ok = _retry_dp(
            n_packets, 1, p, self.max_rounds, self._deadline_hit
        )
        return _dist_arrays(ok, channel_cfg.slot_time_s())

    def expected_delivery_rate(self, n_packets: int, channel: Channel) -> float:
        """Per-packet delivery 1 - p^rounds, where the round count honors
        the deadline budget via a mean-field slot estimate.  With no
        deadline this is exactly 1 - p^max_rounds, independent of n."""
        p = channel.stationary_loss_rate
        rounds = 0
        slots = 0.0
        missing = float(n_packets)
        for _ in range(self.max_rounds):
            if self._deadline_hit(int(slots)):
                break
            rounds += 1
            slots += missing
            missing *= p
        return 1.0 - p ** max(rounds, 1)

    def run_round(self, rng, channel, state, n_packets):
        delivered = np.zeros(n_packets, dtype=bool)
        slots = 0
        rounds = 0
        for _ in range(self.max_rounds):
            missing = np.flatnonzero(~delivered)
            if missing.size == 0 or self._deadline_hit(slots):
                break
            rounds += 1
            keep, state = channel.step(rng, state, missing.size)
            delivered[missing[keep]] = True
            slots += missing.size
        return RoundResult(delivered, slots, max(rounds, 1)), state


# ---------------------------------------------------------------------------
# Hybrid FEC + ARQ
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HybridFECARQProtocol(_ProtocolBase):
    """FEC-coded rounds with block-level retransmission.

    Each round transmits the unrecovered blocks' full codewords (k data +
    m parity packets, ``repro.net.fec``); a block is recovered when ≥ k of
    its packets arrive.  Up to ``max_rounds`` rounds.
    """

    fec: fec_lib.FECSpec = dataclasses.field(default_factory=fec_lib.FECSpec)
    max_rounds: int = 2
    name: str = "fec_arq"

    def _block_fail_prob(self, p: float) -> float:
        km = self.fec.block_packets
        pmf = _binom_pmf(km, 1.0 - p)           # over received count
        return float(pmf[: self.fec.k].sum())   # received < k -> unrecoverable

    def latency_pmf(self, n_packets, channel_cfg, loss_rate=None):
        """DP over number of unrecovered blocks per round (stationary p)."""
        p = _clamped_loss(channel_cfg, loss_rate)
        done, _ = _retry_dp(
            self.fec.num_blocks(n_packets), self.fec.block_packets,
            self._block_fail_prob(p), self.max_rounds, lambda s: False,
        )
        lat, pmf = _dist_arrays(done, channel_cfg.slot_time_s())
        return lat, pmf / pmf.sum()

    def completion_latency_pmf(self, n_packets, channel_cfg, loss_rate=None):
        """Full delivery at the block-DP granularity: every block recovered
        (>= k of its packets arrived in some round).  The rare partial
        path — all k data packets of an unrecovered block arriving across
        rounds — is ignored, consistent with ``latency_pmf``."""
        p = _clamped_loss(channel_cfg, loss_rate)
        _, ok = _retry_dp(
            self.fec.num_blocks(n_packets), self.fec.block_packets,
            self._block_fail_prob(p), self.max_rounds, lambda s: False,
        )
        return _dist_arrays(ok, channel_cfg.slot_time_s())

    def expected_delivery_rate(self, n_packets: int, channel: Channel) -> float:
        pfail = self._block_fail_prob(channel.stationary_loss_rate)
        resid = fec_lib.residual_loss_rate(self.fec, channel)
        # After max_rounds block retries the unrecovered fraction is
        # pfail^max_rounds, within which the data-loss fraction is resid/pfail
        # per round; a simple tight bound: 1 - residual^rounds behaviour.
        return float(1.0 - resid * pfail ** (self.max_rounds - 1))

    def run_round(self, rng, channel, state, n_packets):
        spec = self.fec
        n_blocks = spec.num_blocks(n_packets)
        km = spec.block_packets
        # Per-block: data-packet delivery after decode.
        block_ok = np.zeros(n_blocks, dtype=bool)
        data_keep = np.zeros((n_blocks, spec.k), dtype=bool)
        slots = 0
        rounds = 0
        for _ in range(self.max_rounds):
            todo = np.flatnonzero(~block_ok)
            if todo.size == 0:
                break
            rounds += 1
            keep, state = channel.step(rng, state, todo.size * km)
            keep = keep.reshape(todo.size, km)
            for n, b in enumerate(todo):
                if keep[n].sum() >= spec.k:
                    block_ok[b] = True
                    data_keep[b] = True      # decoder restores all k exactly
                else:
                    data_keep[b] |= keep[n, : spec.k]
            slots += todo.size * km
        delivered = data_keep.reshape(-1)[:n_packets]
        return RoundResult(delivered, slots, max(rounds, 1)), state


# ---------------------------------------------------------------------------
# Deadline feasibility
# ---------------------------------------------------------------------------

def deadline_feasible(
    protocol: _ProtocolBase,
    n_packets: int,
    channel_cfg: link_lib.ChannelConfig,
    deadline_s: float,
    loss_rate: Optional[float] = None,
) -> float:
    """P(the protocol delivers the FULL message within ``deadline_s``).

    Computed from the analytic completion PMFs, so it is the scheduler's
    early-expiry oracle: a queued request whose remaining deadline budget
    makes this (near) zero can be rejected before burning decode steps or
    air time.  Independently useful for capacity planning.

    Exactness at the extremes (regression-tested):

    * ``loss_rate=0.0`` — every packet lands in round one, so any deadline
      covering the first-shot latency gives exactly 1.0.
    * ``loss_rate=1.0`` — the success mass is zero.  The naive estimator
      P(lat <= d | complete) would divide 0/0 = NaN here; summing the
      *joint* completion mass instead returns exactly 0.0.
    """
    if deadline_s < 0.0:
        return 0.0
    lat, mass = protocol.completion_latency_pmf(
        n_packets, channel_cfg, loss_rate
    )
    if lat.size == 0:
        return 0.0
    # Tolerate float fuzz in slots * slot_time sums at the boundary.
    total = float(mass[lat <= deadline_s * (1.0 + 1e-12) + 1e-15].sum())
    return min(max(total, 0.0), 1.0)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

PROTOCOLS = {
    "unreliable": UnreliableProtocol,
    "arq": ARQProtocol,
    "fec_arq": HybridFECARQProtocol,
}


def make_protocol(name: str, **params) -> _ProtocolBase:
    key = name.lower()
    if key not in PROTOCOLS:
        raise ValueError(
            f"unknown protocol {name!r}; available: {sorted(PROTOCOLS)}"
        )
    if key == "fec_arq" and "fec" in params and isinstance(params["fec"], dict):
        params = dict(params, fec=fec_lib.FECSpec(**params["fec"]))
    return PROTOCOLS[key](**params)
