"""Analytic FLOP / HBM-byte model per (architecture x input shape).

XLA's CPU cost_analysis undercounts scanned programs (while bodies are
counted once — see hlo_parse.py), so the compute/memory roofline terms are
derived from this napkin model of the exact program we lower; the raw
cost_analysis numbers are kept in the dry-run JSON for reference.

Conventions: MACs counted as 2 FLOPs; causal attention span averaged over
positions; train = fwd + 2x bwd (+1 fwd remat of the layer stack when
cfg.remat); decode = 1 token/step with a seq_len cache.
"""

from __future__ import annotations

from typing import Dict

from repro.configs.base import LayerSpec, ModelConfig, ShapeConfig

_P_BYTES = {"bfloat16": 2, "float32": 4, "float16": 2}


def _span(seq_len: int, window: int, decode: bool) -> float:
    """Average attended KV length per query token."""
    if decode:
        return float(min(seq_len, window) if window else seq_len)
    full = (seq_len + 1) / 2.0
    return float(min(window, full) if window else full)


def _layer_flops_per_token(cfg: ModelConfig, spec: LayerSpec, seq_len: int,
                           decode: bool) -> float:
    d = cfg.d_model
    f = 0.0
    if spec.kind == "attn":
        h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
        f += 2 * d * (h * hd + 2 * kv * hd) + 2 * (h * hd) * d   # qkv + out
        f += 4 * _span(seq_len, spec.window, decode) * h * hd    # qk^T + pv
    elif spec.kind == "mamba":
        di, n, r, dc = (cfg.mamba_d_inner, cfg.mamba_d_state,
                        cfg.mamba_dt_rank, cfg.mamba_d_conv)
        f += 4 * d * di          # in_proj (x and z)
        f += 2 * dc * di         # causal conv
        f += 2 * di * (r + 2 * n) + 2 * r * di
        f += 10 * di * n         # dA, dBx, scan update, C contraction
        f += 2 * di * d + 3 * di
    elif spec.kind == "mlstm":
        h, dh = cfg.num_heads, cfg.xlstm_head_dim
        f += 2 * d * (4 * h * dh + 2 * h) + 2 * h * dh * d
        if decode:
            f += 8 * h * dh * dh          # C/n update + Cq readout
        else:
            f += 4 * _span(seq_len, 0, False) * h * dh + 6 * _span(seq_len, 0, False) * h
    elif spec.kind == "slstm":
        h, dh = cfg.num_heads, cfg.xlstm_head_dim
        f += 2 * d * 4 * h * dh + 8 * h * dh * dh + 2 * h * dh * d
    # FFN
    if spec.moe:
        e, k = cfg.num_experts, cfg.top_k
        mf = cfg.moe_dff or cfg.d_ff
        gate_mult = 6 if cfg.gated_mlp else 4
        f += 2 * d * e                                  # router
        f += k * gate_mult * d * mf                     # routed experts
        f += cfg.num_shared_experts * gate_mult * d * mf
        if cfg.dense_residual_dff:
            f += gate_mult * d * cfg.dense_residual_dff
    elif cfg.d_ff > 0:
        f += (6 if cfg.gated_mlp else 4) * d * cfg.d_ff
    return f


def _stack_flops_per_token(cfg: ModelConfig, seq_len: int, decode: bool) -> float:
    return sum(
        _layer_flops_per_token(cfg, s, seq_len, decode) for s in cfg.all_layers()
    )


def _param_bytes(cfg: ModelConfig, n_params: int) -> float:
    return n_params * _P_BYTES.get(cfg.dtype, 2)


def _kv_cache_bytes(cfg: ModelConfig, batch: int, seq_len: int) -> float:
    pb = _P_BYTES.get(cfg.dtype, 2)
    hd = cfg.resolved_head_dim
    # int8 KV: 1 byte/elem + bf16 scale per (pos, head) -> (hd + 2)/hd per elem
    kv_b = (1.0 + 2.0 / hd) if cfg.kv_cache_dtype == "int8" else pb
    total = 0.0
    for s in cfg.all_layers():
        if s.kind == "attn":
            c = min(seq_len, s.window) if s.window else seq_len
            total += 2 * batch * c * cfg.num_kv_heads * hd * kv_b
        elif s.kind == "mamba":
            total += batch * cfg.mamba_d_inner * (cfg.mamba_d_state * 4 +
                                                  (cfg.mamba_d_conv - 1) * pb)
        elif s.kind == "mlstm":
            dh = cfg.xlstm_head_dim
            total += batch * cfg.num_heads * (dh * dh + dh + 1) * 4
        elif s.kind == "slstm":
            total += batch * cfg.num_heads * cfg.xlstm_head_dim * 4 * 4
    return total


def analytic_cost(
    cfg: ModelConfig,
    shape_cfg: ShapeConfig,
    n_params: int,
    n_active_params: int,
) -> Dict[str, float]:
    """Global (all-chips) FLOPs and HBM bytes for ONE step of the lowered
    program."""
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    decode = shape_cfg.is_decode
    tokens = b * (1 if decode else s)
    pb = _P_BYTES.get(cfg.dtype, 2)
    d, v = cfg.d_model, cfg.vocab_size

    stack_ft = _stack_flops_per_token(cfg, s, decode)
    logits_ft = 2 * d * v
    fwd = (stack_ft + logits_ft) * tokens

    if shape_cfg.kind == "train":
        mult_stack = 4.0 if cfg.remat else 3.0       # fwd + 2 bwd (+1 remat fwd)
        flops = (mult_stack * stack_ft + 3.0 * logits_ft) * tokens
        flops += 10.0 * n_params                      # Adam update
        # params: read fwd+bwd (+remat) + write; grads write+read; Adam m/v r+w
        p_traffic = (mult_stack + 1) * _param_bytes(cfg, n_params)
        p_traffic += 2 * n_params * pb + 4 * n_params * pb
        # activations: residual stream in/out per layer (blockwise attention
        # keeps the quadratic scores in registers/VMEM)
        act = cfg.num_layers * tokens * d * pb * 4
        logits_bytes = tokens * v * 4 * 2
        bytes_ = p_traffic + act + logits_bytes
    elif shape_cfg.kind == "prefill":
        flops = fwd
        bytes_ = (
            _param_bytes(cfg, n_params)
            + _kv_cache_bytes(cfg, b, s)               # cache write
            + cfg.num_layers * tokens * d * pb * 4     # activations
            + tokens * v * 4
        )
    else:  # decode: one token, memory-bound by params + cache read
        flops = (stack_ft + logits_ft) * tokens
        # MoE decode reads only the experts hit this step.
        expert_frac = 1.0
        if cfg.num_experts:
            hit = min(cfg.num_experts, tokens * cfg.top_k)
            expert_frac = hit / cfg.num_experts
        p_read = n_active_params + (n_params - n_active_params) * 0.0
        # read: non-expert params fully + expert params by hit fraction
        p_read = (
            n_params
            - _expert_params(cfg, n_params, n_active_params)
            + _expert_params(cfg, n_params, n_active_params) * expert_frac
        )
        bytes_ = p_read * pb + _kv_cache_bytes(cfg, b, s) + tokens * v * 4
    return {"flops": float(flops), "hbm_bytes": float(bytes_)}


def _expert_params(cfg: ModelConfig, n_params: int, n_active: int) -> float:
    """Total expert-tensor params, recovered from the active-param ratio:
    n_active = n_params - E_p + E_p * top_k / E  =>  E_p solvable."""
    if not cfg.num_experts or cfg.num_experts == cfg.top_k:
        return 0.0
    return (n_params - n_active) / (1.0 - cfg.top_k / cfg.num_experts)
