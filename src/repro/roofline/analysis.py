"""Three-term roofline analysis from a compiled dry-run artifact.

    compute term    = FLOPs            / (chips * peak_FLOP/s)
    memory term     = HBM bytes        / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

Collective bytes come from the optimized HLO text with while-loop bodies
multiplied by their trip counts (hlo_parse.py) — XLA's cost_analysis counts
a scanned layer stack's body once, which would undercount by the unit count.
For the same reason the compute/memory terms use the analytic program model
(analytic.py); the raw cost_analysis numbers are retained in the report for
reference.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.roofline import analytic as analytic_lib
from repro.roofline import hlo_parse

PEAK_FLOPS = 197e12         # bf16 per chip
HBM_BW = 819e9              # bytes/s per chip
ICI_BW = 50e9               # bytes/s per link

collective_bytes = hlo_parse.collective_bytes_with_trip_counts


# ---------------------------------------------------------------------------
# Model-FLOPs accounting (6*N*D with N = active params)
# ---------------------------------------------------------------------------

def count_params(shapes_tree: Any) -> int:
    return sum(
        int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(shapes_tree)
    )


def active_params(cfg, params_shapes: Any) -> int:
    """Total params with MoE expert tensors scaled by top_k/E — the
    per-token active parameter count used for MODEL_FLOPS of MoE archs."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shapes)[0]:
        names = [
            str(k.key) if isinstance(k, jax.tree_util.DictKey) else ""
            for k in path
        ]
        n = int(np.prod(leaf.shape))
        is_expert = (
            cfg.num_experts > 0
            and "ffn" in names
            and names[-1] in ("w_up", "w_gate", "w_down")
            and len(leaf.shape) >= 3
            and cfg.num_experts in leaf.shape
        )
        if is_expert:
            n = int(n * cfg.top_k / cfg.num_experts)
        total += n
    return total


def model_flops(cfg, params_shapes: Any, tokens: int, decode: bool,
                kind: str = "") -> float:
    """6*N_active*D (training) or 2*N_active*D (single forward: prefill or
    decode)."""
    n = active_params(cfg, params_shapes)
    mult = 6.0 if (kind or ("decode" if decode else "train")) == "train" else 2.0
    return mult * n * tokens


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float                      # analytic, global
    hbm_bytes: float                  # analytic, global
    coll_bytes: float                 # HLO, trip-count-aware, per device
    coll_breakdown: Dict[str, float]
    model_flops_: float
    raw_cost_flops: float = 0.0       # cost_analysis (body-once; reference)
    raw_cost_bytes: float = 0.0
    bytes_per_device: Optional[float] = None

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        # coll_bytes is per-device traffic (SPMD module is per-device).
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_frac(self) -> float:
        return self.model_flops_ / max(self.flops, 1.0)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.coll_bytes,
            "collective_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops_,
            "raw_cost_flops": self.raw_cost_flops,
            "raw_cost_bytes": self.raw_cost_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flops_frac": self.useful_flops_frac,
            "bytes_per_device": self.bytes_per_device,
        }


def analyze(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost: Dict[str, float],
    hlo_text: str,
    cfg,
    shape_cfg,
    params_shapes,
    tokens: int,
    decode: bool,
    bytes_per_device: Optional[float] = None,
) -> RooflineReport:
    coll_total, coll_breakdown = hlo_parse.collective_bytes_with_trip_counts(
        hlo_text
    )
    n_params = count_params(params_shapes)
    n_active = active_params(cfg, params_shapes)
    ana = analytic_lib.analytic_cost(cfg, shape_cfg, n_params, n_active)
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        flops=ana["flops"],
        hbm_bytes=ana["hbm_bytes"],
        coll_bytes=coll_total,
        coll_breakdown=coll_breakdown,
        model_flops_=model_flops(cfg, params_shapes, tokens, decode,
                                 kind=shape_cfg.kind),
        raw_cost_flops=float(cost.get("flops", 0.0)),
        raw_cost_bytes=float(cost.get("bytes accessed", 0.0)),
        bytes_per_device=bytes_per_device,
    )
