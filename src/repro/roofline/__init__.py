from repro.roofline.analysis import (  # noqa: F401
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    RooflineReport,
    analyze,
    collective_bytes,
    model_flops,
)
