"""Trip-count-aware collective accounting over optimized HLO text.

XLA's ``compiled.cost_analysis()`` (and a naive line scan of the HLO) counts
a ``while`` body ONCE, but a scanned layer stack executes its body U times —
so collectives inside the unit scan would be undercounted by U.  This module
walks the computation graph: per-computation collective bytes, then a
recursive evaluation from ENTRY where each ``while`` multiplies its body cost
by the loop trip count (read from the largest integer constant in the
condition computation — exact for counting loops produced by lax.scan /
fori_loop).
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_WHILE_RE = re.compile(r"=\s*.*?\bwhile\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"\b[su](?:8|16|32|64)\[\]\s+constant\((\d+)\)")
_COLL_RE = re.compile(
    r"=\s*(.*?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\("
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    entry_alias = None
    for line in hlo.splitlines():
        m = _COMP_START_RE.match(line.strip()) if "{" in line else None
        if m and "->" in line:
            cur = m.group(1)
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                entry_alias = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    if entry_alias is not None:
        comps["__ENTRY__"] = comps[entry_alias]
    return comps


def collective_bytes_with_trip_counts(hlo: str) -> Tuple[float, Dict[str, float]]:
    """Returns (total_bytes, per-kind breakdown) with while bodies multiplied
    by their trip counts."""
    comps = _split_computations(hlo)

    own: Dict[str, Dict[str, int]] = {}
    whiles: Dict[str, List[Tuple[str, str]]] = {}
    for name, lines in comps.items():
        per_kind = {k: 0 for k in _COLLECTIVES}
        wl = []
        for line in lines:
            if "-done(" in line:
                continue
            cm = _COLL_RE.search(line)
            if cm:
                per_kind[cm.group(2)] += _shape_bytes(cm.group(1))
            wm = _WHILE_RE.search(line)
            if wm:
                wl.append((wm.group(1), wm.group(2)))
        own[name] = per_kind
        whiles[name] = wl

    def trip_count(cond_name: str) -> int:
        consts = []
        for line in comps.get(cond_name, []):
            consts += [int(c) for c in _CONST_RE.findall(line)]
        return max(consts) if consts else 1

    memo: Dict[str, Dict[str, float]] = {}

    def total(name: str, stack=()) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if name in stack or name not in own:
            return {k: 0.0 for k in _COLLECTIVES}
        acc = {k: float(v) for k, v in own[name].items()}
        for cond, body in whiles[name]:
            tc = trip_count(cond)
            sub = total(body, stack + (name,))
            for k in acc:
                acc[k] += tc * sub[k]
        memo[name] = acc
        return acc

    entry = "__ENTRY__" if "__ENTRY__" in comps else next(iter(comps), None)
    if entry is None:
        return 0.0, {k: 0.0 for k in _COLLECTIVES}
    breakdown = total(entry)
    return sum(breakdown.values()), breakdown
