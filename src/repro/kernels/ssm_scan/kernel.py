"""Chunked linear-recurrence scan kernel (Mamba / mLSTM state update).

Computes all prefix states of   h[t] = a[t] * h[t-1] + b[t]   (elementwise
over a flattened state dim D = d_inner * d_state), the recurrence at the
heart of selective SSMs.

TPU mapping: grid = (D_tiles, T_chunks) with T the *sequential* (arbitrary)
grid dimension — the running state h lives in a VMEM scratch tile (block_d,)
that persists across T-chunk grid steps (TPU grids execute sequentially, so
the scratch carries the recurrence between chunks; ``pl.when`` zeroes or
seeds it from h0 on the first chunk).  Inside a chunk the recurrence is an
unrolled VPU loop over ``block_t`` rows of the (block_t, block_d) tile.

block_d is a multiple of 128 (VPU lanes); block_t trades VMEM footprint
(2 tiles of block_t x block_d f32) against grid overhead.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import pallas_interpret


def _scan_kernel(a_ref, b_ref, h0_ref, o_ref, h_scratch, *, block_t: int):
    tj = pl.program_id(1)

    @pl.when(tj == 0)
    def _seed():
        h_scratch[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)      # (bt, bd)
    b = b_ref[...].astype(jnp.float32)
    h = h_scratch[...]                      # (bd,)

    def body(i, carry):
        h = carry
        h = a[i] * h + b[i]
        o_ref[i, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, body, h, unroll=8)
    h_scratch[...] = h


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_d", "interpret")
)
def ssm_scan_kernel(
    a: jax.Array,     # (T, D) decay
    b: jax.Array,     # (T, D) increment
    h0: jax.Array,    # (D,) initial state
    *,
    block_t: int = 128,
    block_d: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Returns all prefix states h: (T, D)."""
    t, d = a.shape
    bt = min(block_t, t)
    bd = min(block_d, d)
    pad_t = (-t) % bt
    pad_d = (-d) % bd
    if pad_t or pad_d:
        a = jnp.pad(a, ((0, pad_t), (0, pad_d)))
        b = jnp.pad(b, ((0, pad_t), (0, pad_d)))
        h0 = jnp.pad(h0, (0, pad_d))
    grid = (a.shape[1] // bd, a.shape[0] // bt)  # D outer, T inner/sequential
    out = pl.pallas_call(
        functools.partial(_scan_kernel, block_t=bt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bd), lambda dj, tj: (tj, dj)),
            pl.BlockSpec((bt, bd), lambda dj, tj: (tj, dj)),
            pl.BlockSpec((bd,), lambda dj, tj: (dj,)),
        ],
        out_specs=pl.BlockSpec((bt, bd), lambda dj, tj: (tj, dj)),
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd,), jnp.float32)],
        # TPU-only: the scratch carry needs Mosaic VMEM AND sequential
        # grid execution — GPU (parallel grid, Triton) must interpret.
        interpret=pallas_interpret(interpret, compiled_on=("tpu",)),
    )(a, b, h0)
    return out[:t, :d]
