"""Pure-jnp oracle: lax.scan linear recurrence h[t] = a[t]*h[t-1] + b[t]."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """a, b: (T, D); h0: (D,) -> all prefix states (T, D) in f32."""

    def step(h, ab):
        at, bt = ab
        h = at.astype(jnp.float32) * h + bt.astype(jnp.float32)
        return h, h

    _, hs = jax.lax.scan(step, h0.astype(jnp.float32), (a, b))
    return hs
