"""Jitted wrapper: batched (vmapped) chunked SSM scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.kernel import ssm_scan_kernel


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def ssm_scan(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """a, b: (B, T, D); h0: (B, D) -> prefix states (B, T, D) f32."""
    fn = lambda aa, bb, hh: ssm_scan_kernel(aa, bb, hh, interpret=_use_interpret())
    return jax.vmap(fn)(a, b, h0)
