"""Jitted wrapper: batched (vmapped) chunked SSM scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.kernel import ssm_scan_kernel


def ssm_scan(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """a, b: (B, T, D); h0: (B, D) -> prefix states (B, T, D) f32."""
    return jax.vmap(ssm_scan_kernel)(a, b, h0)
