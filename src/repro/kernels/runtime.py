"""Shared execution-mode policy for the Pallas kernel packages.

Every kernel entry point takes ``interpret: Optional[bool] = None`` and
resolves ``None`` through :func:`pallas_interpret`:

* ``REPRO_PALLAS_INTERPRET=1/0`` (or true/false/yes/no/on/off) forces the
  mode process-wide — the escape hatch CI and kernel-equivalence tests use;
* otherwise compile exactly when ``jax.default_backend()`` is in the
  kernel's ``compiled_on`` set and interpret everywhere else.  CPU has no
  Pallas lowering, so it always interprets.  The default set is
  ``("tpu", "gpu")``; kernels that are TPU-only — e.g. ``ssm_scan``, whose
  correctness relies on TPU's *sequential* grid execution and whose
  ``pltpu.VMEM`` scratch has no Triton lowering — pass
  ``compiled_on=("tpu",)`` so GPU falls back to interpret instead of
  failing to lower (the previous per-package ``!= "tpu"`` checks
  interpreted on GPU unconditionally, silently de-optimizing the portable
  kernels too).

The resolution happens at trace time, so the decision is baked into the
jit cache entry: changing the env var mid-process does not retrace
already-compiled signatures.
"""

from __future__ import annotations

import os
from typing import Optional, Sequence

import jax

ENV_VAR = "REPRO_PALLAS_INTERPRET"

_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off")


def pallas_interpret(
    override: Optional[bool] = None,
    compiled_on: Sequence[str] = ("tpu", "gpu"),
) -> bool:
    """Resolve the interpret flag for a ``pallas_call``.

    ``override`` (a kernel call's explicit ``interpret=`` argument) wins;
    then the ``REPRO_PALLAS_INTERPRET`` env var; then backend detection —
    compile iff the backend is in ``compiled_on``.
    """
    if override is not None:
        return bool(override)
    env = os.environ.get(ENV_VAR, "").strip().lower()
    if env in _TRUE:
        return True
    if env in _FALSE:
        return False
    return jax.default_backend() not in compiled_on
