"""Pallas TPU kernels for the perf-critical hot spots, each validated in
interpret mode against a pure-jnp oracle (ref.py):

* ``lossy_link``       — fused split-point egress (quantize+mask+dequantize+
                         compensate), the paper's per-DI-round hot path;
* ``flash_attention``  — blocked online-softmax attention w/ sliding window
                         (train/prefill, Sq > 1);
* ``decode_attention`` — length-masked flash decode for the s == 1 step:
                         only cache blocks below the request's valid length
                         are read, int8 KV dequantized inline per block;
* ``ssm_scan``         — chunked linear recurrence for Mamba/mLSTM states.

Interpret-vs-compile policy is shared (``kernels.runtime.pallas_interpret``):
interpret exactly on CPU, compile on GPU/TPU, overridable via
``REPRO_PALLAS_INTERPRET``.  See ``kernels/README.md``.
"""

from repro.kernels.runtime import pallas_interpret  # noqa: F401
