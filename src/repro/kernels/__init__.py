"""Pallas TPU kernels for the perf-critical hot spots, each validated in
interpret mode against a pure-jnp oracle (ref.py):

* ``lossy_link``      — fused split-point egress (quantize+mask+dequantize+
                        compensate), the paper's per-DI-round hot path;
* ``flash_attention`` — blocked online-softmax attention w/ sliding window;
* ``ssm_scan``        — chunked linear recurrence for Mamba/mLSTM states.
"""
