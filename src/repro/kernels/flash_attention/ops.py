"""Jitted GQA-aware wrapper around the flash-attention Pallas kernel.

Interpret-vs-compile is resolved by the kernel itself via
``kernels.runtime.pallas_interpret`` (CPU interprets, GPU/TPU compile,
``REPRO_PALLAS_INTERPRET`` overrides)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def flash_attention(
    q: jax.Array,    # (B, Sq, H, hd)
    k: jax.Array,    # (B, Skv, KV, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    softcap: float = 0.0,
) -> jax.Array:
    """GQA front-end: broadcasts KV heads to query heads, folds (B, H) into
    the kernel's batch axis."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, -1, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, -1, hd)
    out = flash_attention_kernel(
        qf,
        kf,
        vf,
        causal=causal,
        window=window,
        q_offset=q_offset,
        block_q=block_q,
        block_kv=block_kv,
        softcap=softcap,
    )
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
