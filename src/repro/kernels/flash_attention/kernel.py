"""FlashAttention forward kernel for TPU (Pallas): blocked online-softmax
causal attention with sliding-window support.

Grid: (batch*heads, num_q_blocks).  Per grid step the kernel holds one
(block_q, head_dim) query tile in VMEM plus the full (kv_len, head_dim)
K/V panels for that head (BlockSpec-delivered), and walks KV blocks with a
``fori_loop`` whose bounds are *clipped to the causal/window-reachable
range* — out-of-window KV blocks are never touched, which is what makes
gemma3-style local attention cheap.

MXU alignment: block_q / block_kv are multiples of 128 (padded as needed);
head_dim is the matmul contraction dim.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import pallas_interpret

NEG_INF = -1.0e30


def _flash_kernel(
    q_ref,    # (1, block_q, hd)
    k_ref,    # (1, kv_len, hd)
    v_ref,    # (1, kv_len, hd)
    o_ref,    # (1, block_q, hd)
    *,
    block_q: int,
    block_kv: int,
    kv_len: int,
    kv_valid: int,
    q_offset: int,
    causal: bool,
    window: int,
    softcap: float,
):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                       # (bq, hd)
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    q_pos = q_offset + qi * block_q + jax.lax.iota(jnp.int32, block_q)

    n_kv_blocks = kv_len // block_kv
    # Causal upper bound: last kv block that any of this tile's queries can
    # see.  Window lower bound: first block still inside the window.
    if causal:
        hi = jnp.minimum(
            (q_offset + (qi + 1) * block_q + block_kv - 1) // block_kv, n_kv_blocks
        )
    else:
        hi = n_kv_blocks
    if window > 0:
        lo = jnp.maximum((q_offset + qi * block_q - window + 1) // block_kv, 0)
    else:
        lo = 0

    def body(kj, carry):
        acc, m, l = carry
        k = k_ref[0, pl.dslice(kj * block_kv, block_kv), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(kj * block_kv, block_kv), :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                           # (bq, bkv)
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        k_pos = kj * block_kv + jax.lax.iota(jnp.int32, block_kv)
        msk = (k_pos < kv_valid)[None, :] & jnp.ones((block_q, 1), jnp.bool_)
        if causal:
            msk &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            msk &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(msk, s, NEG_INF)
        s_max = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, s_max)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(msk, p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_new = acc * corr[:, None] + pv
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((block_q, hd), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(lo, hi, body, (acc0, m0, l0))
    out = acc / jnp.maximum(l, 1e-20)[:, None]
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "q_offset", "block_q", "block_kv", "softcap", "interpret"
    ),
)
def flash_attention_kernel(
    q: jax.Array,    # (BH, Sq, hd)
    k: jax.Array,    # (BH, Skv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    softcap: float = 0.0,
    interpret: Optional[bool] = None,
) -> jax.Array:
    bh, sq, hd = q.shape
    skv = k.shape[1]
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    pad_q = (-sq) % bq
    pad_kv = (-skv) % bkv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        # Padded KV positions get k_pos > any causal q_pos -> masked out by
        # the causal test only if queries exist; also guard explicitly.
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0)))
    kv_len = k.shape[1]
    grid = (bh, q.shape[1] // bq)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            block_q=bq,
            block_kv=bkv,
            kv_len=kv_len,
            kv_valid=skv,
            q_offset=q_offset,
            causal=causal,
            window=window,
            softcap=softcap,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, kv_len, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, kv_len, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=pallas_interpret(interpret),
    )(q, k, v)
    return out[:, :sq]
