"""Pure-jnp oracle: naive masked softmax attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def flash_attention_ref(
    q: jax.Array,    # (BH, Sq, hd)
    k: jax.Array,    # (BH, Skv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    sq, skv = q.shape[1], k.shape[1]
    hd = q.shape[-1]
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd))
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(skv)
    msk = jnp.ones((sq, skv), bool)
    if causal:
        msk &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        msk &= q_pos[:, None] - k_pos[None, :] < window
    s = jnp.where(msk[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p, v.astype(jnp.float32)).astype(q.dtype)
