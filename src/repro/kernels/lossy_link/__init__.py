from repro.kernels.lossy_link.ops import lossy_link_egress  # noqa: F401
