from repro.kernels.lossy_link.ops import (  # noqa: F401
    burst_mask,
    lossy_link_egress,
)
