"""Pure-jnp oracle for the fused lossy-link egress (bit-exact reference)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lossy_link_egress_ref(
    x: jax.Array,
    u: jax.Array,
    s_min: jax.Array,
    s_max: jax.Array,
    *,
    bits: int,
    loss_rate: float,
) -> jax.Array:
    x32 = x.astype(jnp.float32)
    levels = jnp.float32(2**bits - 1)
    rng = jnp.maximum(s_max.astype(jnp.float32) - s_min.astype(jnp.float32), 1e-8)
    clipped = jnp.clip(x32, s_min, s_max)
    code = jnp.round((clipped - s_min) / rng * levels)
    deq = code / levels * rng + s_min
    keep = u.astype(jnp.float32) >= jnp.float32(loss_rate)
    comp = 1.0 / (1.0 - jnp.float32(loss_rate)) if loss_rate > 0.0 else 1.0
    return jnp.where(keep, deq * comp, 0.0).astype(x.dtype)
