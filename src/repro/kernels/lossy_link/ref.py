"""Pure-jnp oracle for the fused lossy-link egress (bit-exact reference)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lossy_link_egress_ref(
    x: jax.Array,
    u: jax.Array,
    s_min: jax.Array,
    s_max: jax.Array,
    *,
    bits: int,
    loss_rate: float,
) -> jax.Array:
    x32 = x.astype(jnp.float32)
    levels = jnp.float32(2**bits - 1)
    rng = jnp.maximum(s_max.astype(jnp.float32) - s_min.astype(jnp.float32), 1e-8)
    clipped = jnp.clip(x32, s_min, s_max)
    code = jnp.round((clipped - s_min) / rng * levels)
    deq = code / levels * rng + s_min
    keep = u.astype(jnp.float32) >= jnp.float32(loss_rate)
    comp = 1.0 / max(1.0 - float(loss_rate), 1e-6) if loss_rate > 0.0 else 1.0
    comp = jnp.float32(comp)
    return jnp.where(keep, deq * comp, 0.0).astype(x.dtype)


def burst_mask_ref(
    u_init: jax.Array,   # (R,)
    u_loss: jax.Array,   # (R, N)
    u_tr: jax.Array,     # (R, N)
    *,
    p_gb: float,
    p_bg: float,
    loss_good: float,
    loss_bad: float,
) -> jax.Array:
    """Pure-jnp Gilbert–Elliott oracle (lax.scan over the packet axis);
    identical comparisons to the Pallas kernel, so masks match exactly."""
    from repro.net.channels import gilbert_elliott_scan  # noqa: RPA004 — oracle defers to the channel model so masks stay bit-exact; lazy import, no cycle

    return gilbert_elliott_scan(
        u_init, u_loss, u_tr, p_gb, p_bg, loss_good, loss_bad
    )
