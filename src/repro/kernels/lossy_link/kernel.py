"""Fused lossy-link egress kernel (the paper's split-point hot path).

One pass over the split activation performs, per element:

    quantize (clip -> n-bit code)  ->  packet-loss mask  ->  dequantize
    ->  1/(1-p) compensation                                   (Eq. 13-15 + 10-11)

On the serving path this is executed once per DI round on the device side;
fusing it avoids three HBM round-trips of the (tokens, d_model) activation.
Uniform random draws are precomputed outside (jax.random) and streamed in —
on a real TPU deployment these could come from pltpu.prng_random_bits, but
keeping RNG outside makes interpret-mode validation bit-exact against the
jnp oracle.

Tiling: (block_t, block_d) VMEM tiles over the (tokens, d_model) activation;
the per-feature scale factors are (block_d,) tiles broadcast down the token
axis.  block_d is a multiple of 128 (VPU lane width).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _egress_kernel(
    x_ref, u_ref, smin_ref, smax_ref, o_ref, *, bits: int, loss_rate: float
):
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    s_min = smin_ref[...].astype(jnp.float32)[None, :]
    s_max = smax_ref[...].astype(jnp.float32)[None, :]

    levels = jnp.float32(2**bits - 1)
    rng = jnp.maximum(s_max - s_min, 1e-8)
    clipped = jnp.clip(x, s_min, s_max)
    code = jnp.round((clipped - s_min) / rng * levels)
    deq = code / levels * rng + s_min

    keep = u >= jnp.float32(loss_rate)
    comp = 1.0 / (1.0 - jnp.float32(loss_rate)) if loss_rate > 0.0 else 1.0
    y = jnp.where(keep, deq * comp, 0.0)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bits", "loss_rate", "block_t", "block_d", "interpret")
)
def lossy_link_egress_kernel(
    x: jax.Array,        # (T, D)
    u: jax.Array,        # (T, D) uniform [0, 1)
    s_min: jax.Array,    # (D,)
    s_max: jax.Array,    # (D,)
    *,
    bits: int,
    loss_rate: float,
    block_t: int = 256,
    block_d: int = 512,
    interpret: bool = True,
) -> jax.Array:
    t, d = x.shape
    bt = min(block_t, t)
    bd = min(block_d, d)
    pad_t = (-t) % bt
    pad_d = (-d) % bd
    if pad_t or pad_d:
        x = jnp.pad(x, ((0, pad_t), (0, pad_d)))
        u = jnp.pad(u, ((0, pad_t), (0, pad_d)), constant_values=1.0)
        s_min = jnp.pad(s_min, (0, pad_d))
        s_max = jnp.pad(s_max, (0, pad_d), constant_values=1.0)
    grid = (x.shape[0] // bt, x.shape[1] // bd)
    out = pl.pallas_call(
        functools.partial(_egress_kernel, bits=bits, loss_rate=loss_rate),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bt, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bt, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, u, s_min, s_max)
    return out[:t, :d]
