"""Fused lossy-link egress kernel (the paper's split-point hot path).

One pass over the split activation performs, per element:

    quantize (clip -> n-bit code)  ->  packet-loss mask  ->  dequantize
    ->  1/(1-p) compensation                                   (Eq. 13-15 + 10-11)

On the serving path this is executed once per DI round on the device side;
fusing it avoids three HBM round-trips of the (tokens, d_model) activation.
Uniform random draws are precomputed outside (jax.random) and streamed in —
on a real TPU deployment these could come from pltpu.prng_random_bits, but
keeping RNG outside makes interpret-mode validation bit-exact against the
jnp oracle.

Tiling: (block_t, block_d) VMEM tiles over the (tokens, d_model) activation;
the per-feature scale factors are (block_d,) tiles broadcast down the token
axis.  block_d is a multiple of 128 (VPU lane width).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import pallas_interpret


def _egress_kernel(
    x_ref, u_ref, smin_ref, smax_ref, o_ref, *, bits: int, loss_rate: float
):
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    s_min = smin_ref[...].astype(jnp.float32)[None, :]
    s_max = smax_ref[...].astype(jnp.float32)[None, :]

    levels = jnp.float32(2**bits - 1)
    rng = jnp.maximum(s_max - s_min, 1e-8)
    clipped = jnp.clip(x, s_min, s_max)
    code = jnp.round((clipped - s_min) / rng * levels)
    deq = code / levels * rng + s_min

    keep = u >= jnp.float32(loss_rate)
    comp = 1.0 / max(1.0 - float(loss_rate), 1e-6) if loss_rate > 0.0 else 1.0
    comp = jnp.float32(comp)
    y = jnp.where(keep, deq * comp, 0.0)
    o_ref[...] = y.astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# Gilbert–Elliott burst-mask kernel (repro.net serving hot path)
# ---------------------------------------------------------------------------

def _burst_mask_kernel(
    uinit_ref, uloss_ref, utr_ref, o_ref,
    *, p_gb: float, p_bg: float, loss_good: float, loss_bad: float,
    n_valid: int,
):
    """One block of independent Gilbert–Elliott chains.

    Rows are independent channel realizations (one per message in the
    serving batch); columns are packets in sequence.  The hidden Good/Bad
    state is carried down the packet axis by a ``fori_loop`` writing one
    lane-column per step — the chain is inherently sequential in time, but
    the whole batch of rows advances in lockstep on the VPU, so the Markov
    process never leaves the device on the jit-compiled serving path.
    """
    pi_b = p_gb / max(p_gb + p_bg, 1e-12)
    bad = (uinit_ref[...] < jnp.float32(pi_b)).reshape(-1, 1)  # (block_r, 1)
    # Loop only the true packet count: the chain is inherently sequential,
    # so stepping the lane-padding columns (discarded by the wrapper's
    # out[:r, :n] slice) would cost real wall-clock.
    n = n_valid

    def body(t, bad):
        ul = uloss_ref[:, pl.ds(t, 1)]                         # (block_r, 1)
        ut = utr_ref[:, pl.ds(t, 1)]
        p = jnp.where(bad, jnp.float32(loss_bad), jnp.float32(loss_good))
        o_ref[:, pl.ds(t, 1)] = (ul >= p).astype(o_ref.dtype)
        return jnp.where(bad, ut >= jnp.float32(p_bg), ut < jnp.float32(p_gb))

    jax.lax.fori_loop(0, n, body, bad)


@functools.partial(
    jax.jit,
    static_argnames=(
        "p_gb", "p_bg", "loss_good", "loss_bad", "block_r", "interpret"
    ),
)
def burst_mask_kernel(
    u_init: jax.Array,   # (R,) uniform [0, 1): stationary initial state
    u_loss: jax.Array,   # (R, N) uniforms: per-packet loss draw
    u_tr: jax.Array,     # (R, N) uniforms: per-packet state transition
    *,
    p_gb: float,
    p_bg: float,
    loss_good: float,
    loss_bad: float,
    block_r: int = 8,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """(R, N) float32 Gilbert–Elliott packet keep-masks, bit-exact against
    ``ref.burst_mask_ref`` for identical uniforms."""
    r, n = u_loss.shape
    br = min(block_r, r)
    pad_r = (-r) % br
    pad_n = (-n) % 128          # lane-align the packet axis
    if pad_r or pad_n:
        u_init = jnp.pad(u_init, (0, pad_r), constant_values=1.0)
        u_loss = jnp.pad(u_loss, ((0, pad_r), (0, pad_n)), constant_values=1.0)
        u_tr = jnp.pad(u_tr, ((0, pad_r), (0, pad_n)), constant_values=1.0)
    rp, np_ = u_loss.shape
    out = pl.pallas_call(
        functools.partial(
            _burst_mask_kernel,
            p_gb=p_gb, p_bg=p_bg, loss_good=loss_good, loss_bad=loss_bad,
            n_valid=n,
        ),
        grid=(rp // br,),
        in_specs=[
            pl.BlockSpec((br,), lambda i: (i,)),
            pl.BlockSpec((br, np_), lambda i: (i, 0)),
            pl.BlockSpec((br, np_), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, np_), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, np_), jnp.float32),
        interpret=pallas_interpret(interpret),
    )(u_init.astype(jnp.float32), u_loss.astype(jnp.float32),
      u_tr.astype(jnp.float32))
    return out[:r, :n]


@functools.partial(
    jax.jit, static_argnames=("bits", "loss_rate", "block_t", "block_d", "interpret")
)
def lossy_link_egress_kernel(
    x: jax.Array,        # (T, D)
    u: jax.Array,        # (T, D) uniform [0, 1)
    s_min: jax.Array,    # (D,)
    s_max: jax.Array,    # (D,)
    *,
    bits: int,
    loss_rate: float,
    block_t: int = 256,
    block_d: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    t, d = x.shape
    bt = min(block_t, t)
    bd = min(block_d, d)
    pad_t = (-t) % bt
    pad_d = (-d) % bd
    if pad_t or pad_d:
        x = jnp.pad(x, ((0, pad_t), (0, pad_d)))
        u = jnp.pad(u, ((0, pad_t), (0, pad_d)), constant_values=1.0)
        s_min = jnp.pad(s_min, (0, pad_d))
        s_max = jnp.pad(s_max, (0, pad_d), constant_values=1.0)
    grid = (x.shape[0] // bt, x.shape[1] // bd)
    out = pl.pallas_call(
        functools.partial(_egress_kernel, bits=bits, loss_rate=loss_rate),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bt, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bt, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=pallas_interpret(interpret),
    )(x, u, s_min, s_max)
    return out[:t, :d]
