"""Jitted public wrapper: ties the kernel into core.comtune's serve path."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compression import QuantSpec
from repro.kernels.lossy_link.kernel import lossy_link_egress_kernel


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def lossy_link_egress(
    key: jax.Array,
    x: jax.Array,           # (..., D) split-point activation
    quant: QuantSpec,
    loss_rate: float,
) -> jax.Array:
    """Quantize -> mask(p) -> dequantize -> 1/(1-p), fused."""
    shape = x.shape
    d = shape[-1]
    flat = x.reshape(-1, d)
    u = jax.random.uniform(key, flat.shape, jnp.float32)
    out = lossy_link_egress_kernel(
        flat,
        u,
        quant.s_min.astype(jnp.float32),
        quant.s_max.astype(jnp.float32),
        bits=quant.bits,
        loss_rate=float(loss_rate),
        interpret=_use_interpret(),
    )
    return out.reshape(shape)
