"""Jitted public wrapper: ties the kernel into core.comtune's serve path."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compression import QuantSpec
from repro.kernels.lossy_link.kernel import (
    burst_mask_kernel,
    lossy_link_egress_kernel,
)


def lossy_link_egress(
    key: jax.Array,
    x: jax.Array,           # (..., D) split-point activation
    quant: QuantSpec,
    loss_rate: float,
) -> jax.Array:
    """Quantize -> mask(p) -> dequantize -> 1/(1-p), fused."""
    shape = x.shape
    d = shape[-1]
    flat = x.reshape(-1, d)
    u = jax.random.uniform(key, flat.shape, jnp.float32)
    out = lossy_link_egress_kernel(
        flat,
        u,
        quant.s_min.astype(jnp.float32),
        quant.s_max.astype(jnp.float32),
        bits=quant.bits,
        loss_rate=float(loss_rate),
    )
    return out.reshape(shape)


def burst_mask(
    key: jax.Array,
    n_rows: int,
    n_packets: int,
    *,
    p_gb: float,
    p_bg: float,
    loss_good: float = 0.0,
    loss_bad: float = 1.0,
) -> jax.Array:
    """(n_rows, n_packets) float32 Gilbert–Elliott packet keep-masks,
    generated on-device so the serving hot path stays jit-compiled.  RNG is
    drawn with jax.random outside the kernel (see module note in
    kernel.py) and streamed in, keeping interpret-mode validation bit-exact
    against the lax.scan oracle."""
    kinit, kloss, ktr = jax.random.split(key, 3)
    u_init = jax.random.uniform(kinit, (n_rows,), jnp.float32)
    u_loss = jax.random.uniform(kloss, (n_rows, n_packets), jnp.float32)
    u_tr = jax.random.uniform(ktr, (n_rows, n_packets), jnp.float32)
    return burst_mask_kernel(
        u_init, u_loss, u_tr,
        p_gb=float(p_gb), p_bg=float(p_bg),
        loss_good=float(loss_good), loss_bad=float(loss_bad),
    )
