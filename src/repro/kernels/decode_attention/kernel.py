"""Flash-decode forward kernel (Pallas): length-masked online-softmax
attention for the s == 1 decode step, with inline int8 dequantization.

Shapes follow the decode cache's native layout so no transpose/copy of the
cache is ever materialized:

* q        — (B, KV, G, hd)   one query token, GQA-grouped
* k / v    — (B, C, KV, hd)   rotating cache buffer (int8 codes or bf16)
* k/v scale— (B, C, KV)       per-(pos, head) bf16 absmax scales (int8 only)
* n_valid  — (B, 1) int32     count of live cache slots for this request

Grid: (B, KV) — one grid step per (request, kv-head).  The kernel holds
the (G, hd) query tile plus the (C, hd) K/V panels for that head
(BlockSpec-delivered, strided view of the native (B, C, KV, hd) buffer)
and walks KV blocks with a ``fori_loop`` whose upper bound is
``ceil(n_valid / block_kv)`` — blocks past the valid prefix are never
*computed on or dequantized*, which turns the decode step's FLOPs and
dequant work from O(max_seq) into O(valid).  Caveat on *memory* traffic:
with this portable BlockSpec a compiled TPU run still DMAs the full
(C, hd) panel into VMEM before the body runs, so the O(valid) HBM-bytes
claim currently holds for the jnp fallback (``ref.py`` — XLA dynamic
slices read only the walked blocks), while TPU gets the compute/dequant
saving.  ``paged_flash_decode_kernel`` below closes that gap for the
block-pool layout: ``n_valid`` and the block table ride as
scalar-prefetch (SMEM) operands of a ``PrefetchScalarGridSpec``, so the
index map resolves physical blocks *before* each DMA fires and only
walked blocks ever move — O(valid) bytes on TPU too.
Rotating sliding-window caches need no extra handling: writes
land at ``index % C`` (``models.attention._write_decode``), so the live
slots are always the contiguous prefix ``[0, min(index + 1, C))`` — once
the window wraps, ``n_valid == C`` and the masked walk degenerates to the
full (bounded) window.  Cached keys carry RoPE from write time and softmax
is permutation-invariant over slots, so slot order never matters.

Inline dequantization: int8 codes are loaded per block and scaled in
VMEM/registers (``codes_f32 * scale_f32``), so the quantized cache is
never expanded to bf16 in HBM — the full-cache ``_read_cache`` dequant
this kernel replaces was the dominant decode-step HBM traffic.

The kernel is vmap-able (the slot-pool engine vmaps it over the slot axis
with a per-slot ``n_valid``); ``ref.py`` mirrors this file's f32
arithmetic op for op, so the pure-jnp fallback agrees with the
interpret-mode kernel to float-ulp level (tests pin ~2e-6; XLA fusion
reassociation is the only difference).

``n_valid`` rides as a (1, 1) int32 VMEM block per grid step; the
portable spec keeps one code path for interpret/Triton/Mosaic (see the
memory-traffic caveat above for what a TPU SMEM prefetch would add).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import pallas_interpret

NEG_INF = -1.0e30


def _make_kernel(*, block_kv: int, softcap: float, quantized: bool):
    def kernel(*refs):
        if quantized:
            q_ref, k_ref, v_ref, ks_ref, vs_ref, n_ref, o_ref = refs
        else:
            q_ref, k_ref, v_ref, n_ref, o_ref = refs
            ks_ref = vs_ref = None
        q = q_ref[0, 0].astype(jnp.float32)                  # (G, hd)
        g, hd = q.shape
        scale = 1.0 / jnp.sqrt(jnp.float32(hd))
        n_valid = n_ref[0, 0]
        n_blocks = (n_valid + block_kv - 1) // block_kv

        def body(kj, carry):
            acc, m, l = carry
            sl = pl.dslice(kj * block_kv, block_kv)
            k = k_ref[0, sl, 0, :].astype(jnp.float32)       # (bkv, hd)
            v = v_ref[0, sl, 0, :].astype(jnp.float32)
            if quantized:
                k = k * ks_ref[0, sl, 0].astype(jnp.float32)[:, None]
                v = v * vs_ref[0, sl, 0].astype(jnp.float32)[:, None]
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            ) * scale                                        # (G, bkv)
            if softcap > 0.0:
                s = jnp.tanh(s / softcap) * softcap
            k_pos = kj * block_kv + jax.lax.iota(jnp.int32, block_kv)
            msk = (k_pos < n_valid)[None, :]
            s = jnp.where(msk, s, NEG_INF)
            s_max = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m, s_max)
            p = jnp.exp(s - m_new[:, None])
            p = jnp.where(msk, p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jax.lax.dot_general(
                p, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            return acc * corr[:, None] + pv, m_new, l_new

        acc0 = jnp.zeros((g, hd), jnp.float32)
        m0 = jnp.full((g,), NEG_INF, jnp.float32)
        l0 = jnp.zeros((g,), jnp.float32)
        acc, m, l = jax.lax.fori_loop(0, n_blocks, body, (acc0, m0, l0))
        o_ref[0, 0] = (acc / jnp.maximum(l, 1e-20)[:, None]).astype(o_ref.dtype)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("block_kv", "softcap", "interpret")
)
def flash_decode_kernel(
    q: jax.Array,                        # (B, KV, G, hd)
    k: jax.Array,                        # (B, C, KV, hd) int8 or bf16/f32
    v: jax.Array,
    k_scale: Optional[jax.Array],        # (B, C, KV) or None
    v_scale: Optional[jax.Array],
    n_valid: jax.Array,                  # (B, 1) int32
    *,
    block_kv: int = 64,
    softcap: float = 0.0,
    interpret: Optional[bool] = None,
) -> jax.Array:
    b, kvh, g, hd = q.shape
    c = k.shape[1]
    assert c % block_kv == 0, (c, block_kv)
    quantized = k_scale is not None
    in_specs = [
        pl.BlockSpec((1, 1, g, hd), lambda i, h: (i, h, 0, 0)),
        pl.BlockSpec((1, c, 1, hd), lambda i, h: (i, 0, h, 0)),
        pl.BlockSpec((1, c, 1, hd), lambda i, h: (i, 0, h, 0)),
    ]
    args = [q, k, v]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, c, 1), lambda i, h: (i, 0, h)),
            pl.BlockSpec((1, c, 1), lambda i, h: (i, 0, h)),
        ]
        args += [k_scale, v_scale]
    in_specs.append(pl.BlockSpec((1, 1), lambda i, h: (i, 0)))
    args.append(n_valid)
    return pl.pallas_call(
        _make_kernel(block_kv=block_kv, softcap=softcap, quantized=quantized),
        grid=(b, kvh),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda i, h: (i, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=pallas_interpret(interpret),
    )(*args)


# ---------------------------------------------------------------------------
# Paged variant: block-table walk with scalar-prefetch (SMEM) metadata
# ---------------------------------------------------------------------------
#
# Same online-softmax arithmetic, different iteration structure: the KV
# walk moves from a fori_loop inside one grid step to the (sequential,
# minor) third grid dimension, because with a PrefetchScalarGridSpec it is
# the *index map* — evaluated from SMEM-resident scalars before the DMA —
# that picks which physical (block_size, hd) block to deliver.  Softmax
# state (acc, m, l) persists across the j steps in VMEM scratch;
# ``pl.when`` guards init (j == 0), the masked walk (j * block_size <
# n_valid — blocks past the valid prefix are neither computed on nor, on
# TPU, fetched), and the final normalize/write (last j).


def _make_paged_kernel(*, block_size: int, softcap: float, quantized: bool):
    def kernel(*refs):
        if quantized:
            (nv_ref, bt_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
             acc_ref, m_ref, l_ref) = refs
        else:
            (nv_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
             acc_ref, m_ref, l_ref) = refs
            ks_ref = vs_ref = None
        del bt_ref  # consumed by the index maps, not the body
        i = pl.program_id(0)
        j = pl.program_id(2)
        n_valid = nv_ref[i]

        @pl.when(j == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)

        @pl.when(j * block_size < n_valid)
        def _block():
            q = q_ref[0, 0].astype(jnp.float32)              # (G, hd)
            scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
            k = k_ref[0, :, 0, :].astype(jnp.float32)        # (bs, hd)
            v = v_ref[0, :, 0, :].astype(jnp.float32)
            if quantized:
                k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
                v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            ) * scale                                        # (G, bs)
            if softcap > 0.0:
                s = jnp.tanh(s / softcap) * softcap
            k_pos = j * block_size + jax.lax.iota(jnp.int32, block_size)
            msk = (k_pos < n_valid)[None, :]
            s = jnp.where(msk, s, NEG_INF)
            m = m_ref[:, 0]
            l = l_ref[:, 0]
            s_max = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m, s_max)
            p = jnp.exp(s - m_new[:, None])
            p = jnp.where(msk, p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jax.lax.dot_general(
                p, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            acc_ref[...] = acc_ref[...] * corr[:, None] + pv
            m_ref[...] = m_new[:, None]
            l_ref[...] = l_new[:, None]

        @pl.when(j == pl.num_programs(2) - 1)
        def _finish():
            o_ref[0, 0] = (
                acc_ref[...] / jnp.maximum(l_ref[:, 0], 1e-20)[:, None]
            ).astype(o_ref.dtype)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("block_size", "softcap", "interpret")
)
def paged_flash_decode_kernel(
    q: jax.Array,                        # (B, KV, G, hd)
    k: jax.Array,                        # (N, bs, KV, hd) block pool
    v: jax.Array,
    k_scale: Optional[jax.Array],        # (N, bs, KV) or None
    v_scale: Optional[jax.Array],
    block_table: jax.Array,              # (B, J) int32 physical block ids
    n_valid: jax.Array,                  # (B,) int32
    *,
    block_size: int,
    softcap: float = 0.0,
    interpret: Optional[bool] = None,
) -> jax.Array:
    b, kvh, g, hd = q.shape
    bs = k.shape[1]
    assert bs == block_size, (bs, block_size)
    j_l = block_table.shape[1]
    quantized = k_scale is not None
    kv_map = lambda i, h, j, nv, bt: (bt[i, j], 0, h, 0)
    sc_map = lambda i, h, j, nv, bt: (bt[i, j], 0, h)
    in_specs = [
        pl.BlockSpec((1, 1, g, hd), lambda i, h, j, nv, bt: (i, h, 0, 0)),
        pl.BlockSpec((1, bs, 1, hd), kv_map),
        pl.BlockSpec((1, bs, 1, hd), kv_map),
    ]
    args = [q, k, v]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, bs, 1), sc_map),
            pl.BlockSpec((1, bs, 1), sc_map),
        ]
        args += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, j_l),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, g, hd), lambda i, h, j, nv, bt: (i, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),                # acc
            pltpu.VMEM((g, 1), jnp.float32),                 # m
            pltpu.VMEM((g, 1), jnp.float32),                 # l
        ],
    )
    return pl.pallas_call(
        _make_paged_kernel(
            block_size=block_size, softcap=softcap, quantized=quantized,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=pallas_interpret(interpret),
    )(jnp.asarray(n_valid, jnp.int32), jnp.asarray(block_table, jnp.int32),
      *args)
