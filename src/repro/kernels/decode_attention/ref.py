"""Pure-jnp length-masked flash-decode fallback — the CPU production path.

This is NOT a naive oracle: it mirrors ``kernel.py`` operation for
operation (same f32 dequant, same ``lax.dot_general`` dimension numbers
and ``preferred_element_type``, same mask/where order, same online-softmax
update expressions, same ``fori_loop`` bound ``ceil(n_valid / block_kv)``)
so CPU CI exercises the same arithmetic recipe the accelerator kernel
runs, at the kernel's O(valid) cost: the traced loop bound lowers to a
``while_loop``, so blocks past the valid prefix are never read or
dequantized.  Against the interpret-mode kernel the outputs agree to
float-ulp level (~2e-6 in f32, pinned by tests) — the only residual
difference is XLA CPU fusion/FMA reassociation, which varies between any
two lowered programs and is not controllable from jnp.  The naive
full-cache oracle lives in ``models.attention._naive_attn``; tests
triangulate kernel ~= ref ~= naive.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def _decode_one(q, k, v, k_scale, v_scale, n_valid, *, block_kv, softcap):
    """One (request, kv-head): q (G, hd) vs k/v (C, hd) [+ scales (C,)]."""
    g, hd = q.shape
    q = q.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    quantized = k_scale is not None
    n_blocks = (n_valid + block_kv - 1) // block_kv

    def body(kj, carry):
        acc, m, l = carry
        start = kj * block_kv
        kb = jax.lax.dynamic_slice_in_dim(k, start, block_kv).astype(jnp.float32)
        vb = jax.lax.dynamic_slice_in_dim(v, start, block_kv).astype(jnp.float32)
        if quantized:
            kb = kb * jax.lax.dynamic_slice_in_dim(
                k_scale, start, block_kv
            ).astype(jnp.float32)[:, None]
            vb = vb * jax.lax.dynamic_slice_in_dim(
                v_scale, start, block_kv
            ).astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(
            q, kb, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                            # (G, bkv)
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        k_pos = start + jax.lax.iota(jnp.int32, block_kv)
        msk = (k_pos < n_valid)[None, :]
        s = jnp.where(msk, s, NEG_INF)
        s_max = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, s_max)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(msk, p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p, vb, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return acc * corr[:, None] + pv, m_new, l_new

    acc0 = jnp.zeros((g, hd), jnp.float32)
    m0 = jnp.full((g,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((g,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_blocks, body, (acc0, m0, l0))
    return acc / jnp.maximum(l, 1e-20)[:, None]


def flash_decode_ref(
    q: jax.Array,                        # (B, KV, G, hd)
    k: jax.Array,                        # (B, C, KV, hd)
    v: jax.Array,
    k_scale: Optional[jax.Array],        # (B, C, KV) or None
    v_scale: Optional[jax.Array],
    n_valid: jax.Array,                  # (B, 1) int32
    *,
    block_kv: int = 64,
    softcap: float = 0.0,
) -> jax.Array:
    c = k.shape[1]
    assert c % block_kv == 0, (c, block_kv)
    one = functools.partial(_decode_one, block_kv=block_kv, softcap=softcap)
    # inner: map the kv-head axis (q axis 0; cache axis 1; scale axis 1)
    per_head = jax.vmap(one, in_axes=(0, 1, 1, 1 if k_scale is not None else None,
                                      1 if v_scale is not None else None, None))
    # outer: map the request/batch axis (n_valid (1,) -> scalar)
    out = jax.vmap(
        lambda qq, kk, vv, ks, vs, nn: per_head(qq, kk, vv, ks, vs, nn[0])
    )(q, k, v, k_scale, v_scale, n_valid)
    return out.astype(q.dtype)                               # (B, KV, G, hd)


def _paged_one(q, k_pool, v_pool, k_scale, v_scale, bt, n_valid, *,
               block_size, softcap):
    """One (request, kv-head): q (G, hd) vs pools (N, bs, hd) [+ scales
    (N, bs)] through the block-table row ``bt`` (J,) int32.  Identical
    arithmetic to :func:`_decode_one` — only the block fetch changes from
    a contiguous ``dynamic_slice`` to a table-indexed ``dynamic_index``,
    mirroring the paged kernel's SMEM-resolved index map."""
    g, hd = q.shape
    q = q.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    quantized = k_scale is not None
    n_blocks = (n_valid + block_size - 1) // block_size

    def body(kj, carry):
        acc, m, l = carry
        pid = jax.lax.dynamic_index_in_dim(bt, kj, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(
            k_pool, pid, keepdims=False
        ).astype(jnp.float32)                                # (bs, hd)
        vb = jax.lax.dynamic_index_in_dim(
            v_pool, pid, keepdims=False
        ).astype(jnp.float32)
        if quantized:
            kb = kb * jax.lax.dynamic_index_in_dim(
                k_scale, pid, keepdims=False
            ).astype(jnp.float32)[:, None]
            vb = vb * jax.lax.dynamic_index_in_dim(
                v_scale, pid, keepdims=False
            ).astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(
            q, kb, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                            # (G, bs)
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        k_pos = kj * block_size + jax.lax.iota(jnp.int32, block_size)
        msk = (k_pos < n_valid)[None, :]
        s = jnp.where(msk, s, NEG_INF)
        s_max = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, s_max)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(msk, p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p, vb, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return acc * corr[:, None] + pv, m_new, l_new

    acc0 = jnp.zeros((g, hd), jnp.float32)
    m0 = jnp.full((g,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((g,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_blocks, body, (acc0, m0, l0))
    return acc / jnp.maximum(l, 1e-20)[:, None]


def paged_flash_decode_ref(
    q: jax.Array,                        # (B, KV, G, hd)
    k: jax.Array,                        # (N, bs, KV, hd) block pool
    v: jax.Array,
    k_scale: Optional[jax.Array],        # (N, bs, KV) or None
    v_scale: Optional[jax.Array],
    block_table: jax.Array,              # (B, J) int32
    n_valid: jax.Array,                  # (B,) int32
    *,
    block_size: int,
    softcap: float = 0.0,
) -> jax.Array:
    assert k.shape[1] == block_size, (k.shape, block_size)
    one = functools.partial(_paged_one, block_size=block_size, softcap=softcap)
    # inner: map the kv-head axis (q axis 0; pool axis 2; scale axis 2);
    # the block table and n_valid are shared across heads
    per_head = jax.vmap(one, in_axes=(0, 2, 2, 2 if k_scale is not None else None,
                                      2 if v_scale is not None else None,
                                      None, None))
    # outer: map the request axis; the pool itself is shared (closed over)
    out = jax.vmap(
        lambda qq, bt, nn: per_head(qq, k, v, k_scale, v_scale, bt, nn),
        in_axes=(0, 0, 0),
    )(q, block_table, n_valid)
    return out.astype(q.dtype)                               # (B, KV, G, hd)
