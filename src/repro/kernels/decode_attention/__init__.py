from repro.kernels.decode_attention.kernel import (
    flash_decode_kernel,
    paged_flash_decode_kernel,
)
from repro.kernels.decode_attention.ops import (
    decode_attention,
    decode_block_kv,
    paged_decode_attention,
)
from repro.kernels.decode_attention.ref import (
    flash_decode_ref,
    paged_flash_decode_ref,
)

__all__ = [
    "decode_attention",
    "decode_block_kv",
    "flash_decode_kernel",
    "flash_decode_ref",
    "paged_decode_attention",
    "paged_flash_decode_kernel",
    "paged_flash_decode_ref",
]
