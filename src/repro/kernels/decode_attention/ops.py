"""Public decode-attention entry point: cache-layout front-end + backend
dispatch (Pallas kernel on accelerators, bit-identical jnp fallback on CPU).

``decode_attention`` consumes the model's decode state directly — the
grouped query ``(B, 1, KV, G, hd)`` and the rotating cache dict in its
native ``(B, C, KV, hd)`` layout (int8 codes + scales or bf16) — so no
transposed/dequantized copy of the cache is ever materialized.  Dispatch:

* ``REPRO_FLASH_DECODE_IMPL=kernel|ref`` forces a path (tests/benchmarks);
* otherwise the jnp fallback on CPU (a compiled interpret-mode Pallas call
  would be orders of magnitude slower than the identical-math jnp program)
  and the real kernel elsewhere (interpret resolution per
  ``kernels.runtime.pallas_interpret``).

Both paths are vmap-able over a leading slot axis with per-slot
``n_valid`` — this is how the continuous-batching engine's fused decode
step runs one length-masked attention per in-flight request.
"""

from __future__ import annotations

import math
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import (
    flash_decode_kernel,
    paged_flash_decode_kernel,
)
from repro.kernels.decode_attention.ref import (
    flash_decode_ref,
    paged_flash_decode_ref,
)

IMPL_ENV_VAR = "REPRO_FLASH_DECODE_IMPL"


def _impl(override: Optional[str] = None) -> str:
    choice = (override or os.environ.get(IMPL_ENV_VAR, "") or "").strip().lower()
    if choice in ("kernel", "ref"):
        return choice
    if choice:
        raise ValueError(
            f"unknown decode-attention impl {choice!r} (from "
            f"{'impl=' if override else IMPL_ENV_VAR}) — want 'kernel' or "
            "'ref'; unset for backend auto-detection"
        )
    return "ref" if jax.default_backend() == "cpu" else "kernel"


def decode_block_kv(cache_len: int, block_kv: int) -> int:
    """Effective KV block of the masked walk.

    Prefers the largest common divisor of ``cache_len`` and ``block_kv``
    so the walk needs no copies (engine cache lengths are multiples of
    the bucket floor, making this ``min(block_kv, cache_len)`` or a near
    power of two).  When the divisor degenerates below 16 (coprime-ish
    lengths like 65 or 100, where a gcd-sized walk would be slower than
    the matvec it replaces), keeps ``block_kv`` — ``decode_attention``
    then zero-pads the cache to a block multiple once per call instead.
    """
    bkv = min(block_kv, cache_len)
    g = math.gcd(bkv, cache_len)
    return g if g >= min(16, bkv) else bkv


def decode_attention(
    q: jax.Array,                        # (B, 1, KV, G, hd) grouped query
    cache: Dict[str, Any],               # k/v (B, C, KV, hd) [+ k/v_scale]
    n_valid: jax.Array,                  # scalar or (B,) live-slot count
    *,
    softcap: float = 0.0,
    block_kv: int = 64,
    impl: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Length-masked decode attention over the rotating cache.

    Returns ``(B, 1, KV, G, hd)`` in ``q.dtype`` — a drop-in for the
    decode branch of ``models.attention.attention_forward``.  Only cache
    blocks below ``ceil(n_valid / block_kv)`` are read (and, for int8
    caches, dequantized — inline, per block, in f32).
    """
    b, s, kvh, g, hd = q.shape
    assert s == 1, f"decode attention is the s == 1 path, got S={s}"
    k, v = cache["k"], cache["v"]
    k_scale = cache.get("k_scale")
    v_scale = cache.get("v_scale")
    c = k.shape[1]
    bkv = decode_block_kv(c, block_kv)
    pad = (-c) % bkv
    if pad:
        # Degenerate cache length (no usable divisor): pad the position
        # axis to a block multiple.  Padded rows sit at k_pos >= C >=
        # n_valid, so the validity mask never reads them; the one-copy
        # cost only triggers for lengths the engines never produce.
        grow = lambda a: jnp.pad(
            a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2)
        )
        k, v = grow(k), grow(v)
        if k_scale is not None:
            k_scale, v_scale = grow(k_scale), grow(v_scale)
    n = jnp.broadcast_to(
        jnp.asarray(n_valid, jnp.int32).reshape(-1), (b,)
    ).reshape(b, 1)
    qh = q[:, 0]                                             # (B, KV, G, hd)
    if _impl(impl) == "kernel":
        out = flash_decode_kernel(
            qh, k, v, k_scale, v_scale, n,
            block_kv=bkv, softcap=softcap, interpret=interpret,
        )
    else:
        out = flash_decode_ref(
            qh, k, v, k_scale, v_scale, n, block_kv=bkv, softcap=softcap
        )
    return out[:, None]


def paged_decode_attention(
    q: jax.Array,                        # (B, 1, KV, G, hd) grouped query
    pool: Dict[str, Any],                # k/v (N, bs, KV, hd) [+ k/v_scale]
    block_table: jax.Array,              # (B, J_max) int32 physical blocks
    n_valid: jax.Array,                  # (B,) live-row count per request
    *,
    seq_len: int,                        # this layer's rotating cache length
    block_size: int,
    softcap: float = 0.0,
    impl: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Length-masked decode attention over a shared block pool.

    The paged twin of :func:`decode_attention`: same return contract
    ``(B, 1, KV, G, hd)`` in ``q.dtype``, but K/V rows live in
    ``(num_blocks, block_size, KV, hd)`` pool buffers addressed through
    each request's block-table row.  ``seq_len`` is static (the layer's
    ``cache_len``), so the table is sliced to this layer's
    ``ceil(seq_len / block_size)`` walkable blocks at trace time —
    windowed layers never index past their own rotation, and the padded
    tail rows of a short last block stay behind the ``k_pos < n_valid``
    mask (``n_valid <= seq_len``).  No pad/copy path is needed here: pool
    blocks are whole by construction.
    """
    b, s, kvh, g, hd = q.shape
    assert s == 1, f"decode attention is the s == 1 path, got S={s}"
    k, v = pool["k"], pool["v"]
    k_scale = pool.get("k_scale")
    v_scale = pool.get("v_scale")
    assert k.shape[1] == block_size, (k.shape, block_size)
    j_l = -(-seq_len // block_size)
    assert block_table.shape[1] >= j_l, (block_table.shape, j_l)
    bt = jnp.asarray(block_table, jnp.int32)[:, :j_l]
    n = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32).reshape(-1), (b,))
    qh = q[:, 0]                                             # (B, KV, G, hd)
    if _impl(impl) == "kernel":
        out = paged_flash_decode_kernel(
            qh, k, v, k_scale, v_scale, bt, n,
            block_size=block_size, softcap=softcap, interpret=interpret,
        )
    else:
        out = paged_flash_decode_ref(
            qh, k, v, k_scale, v_scale, bt, n,
            block_size=block_size, softcap=softcap,
        )
    return out[:, None]
