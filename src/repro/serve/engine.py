"""Scan-compiled decode engine with a compile cache.

The seed serving loop (``launch/serve.py``) dispatched one ``jax.jit`` call
per generated token from Python and rebuilt its jitted step closures on
every ``generate()`` call, so every call paid a full re-trace and the
Python loop overhead dominated decode latency.  The engine replaces it
with:

* one jit-compiled program for the *entire* generation — prefill plus a
  ``lax.scan`` over the decode rounds (key-split, token selection, and the
  lossy-link DI round all inside the scan body; see
  ``launch.steps.make_generate_fn``);
* a process-wide compile cache keyed on the full generation signature
  ``(cfg, batch, prompt_len, num_tokens, greedy, temperature)`` — ``cfg``
  is a frozen dataclass whose ``link`` field carries the channel / FEC /
  compression spec, so distinct link configurations compile separately and
  repeated calls with the same signature never re-trace;
* a donated decode cache (the scan carry reuses the input buffers instead
  of copying the KV/SSM state);
* ahead-of-time compilation: a cache miss runs ``jit(...).lower(abstract
  args).compile()`` and stores the resulting ``jax.stages.Compiled``
  executable.  Calling a ``Compiled`` can never silently re-trace or
  re-compile (a signature mismatch raises instead), so the first
  ``generate()`` call's timed region is pure execution with no warm-up
  run, and "zero steady-state recompiles" holds by construction;
* per-entry trace / compile / call counters, so callers (benchmarks, CI)
  can assert "exactly one trace and one XLA build across N calls".

The scan body's per-token DI round inherits the decode-attention dispatch
from ``cfg.attn_impl``: "blockwise"/"flash_decode" configs (the production
default) compile the length-masked flash-decode path
(``repro.kernels.decode_attention`` — O(valid) cache blocks per step,
inline int8 dequant), "naive" keeps the full-cache masked matvec oracle.

The continuous-batching slot-pool engine built on the same AOT machinery
lives in ``repro.serve.continuous``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs.base import ModelConfig
from repro.launch.steps import make_generate_fn
from repro.models import cache as cache_lib


def generate_key(
    cfg: ModelConfig,
    batch: int,
    prompt_len: int,
    num_tokens: int,
    greedy: bool = True,
    temperature: float = 1.0,
) -> Tuple:
    """Compile-cache key for one generation signature.  ``cfg`` (frozen,
    hashable) subsumes the architecture *and* the link spec — loss rate,
    channel process, channel params, FEC, compression.  Greedy decoding
    ignores temperature, so it is normalized out of the key (identical
    programs must not compile twice)."""
    temp = 1.0 if greedy else round(temperature, 6)
    return (cfg, batch, prompt_len, num_tokens, greedy, temp)


def abstract_like(tree):
    """ShapeDtypeStruct skeleton of a concrete pytree (AOT lowering input)."""
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(jnp.shape(a), jnp.result_type(a)), tree
    )


@dataclasses.dataclass
class CompiledGenerate:
    """One cached AOT executable + its usage counters."""

    fn: Callable                     # jax.stages.Compiled
    key: Tuple
    traces: int = 0
    compiles: int = 0
    calls: int = 0
    compile_s: float = 0.0   # wall time of this entry's AOT lower+compile


class DecodeEngine:
    """Compile-once-serve-many wrapper around ``make_generate_fn``."""

    def __init__(self) -> None:
        self._compiled: Dict[Tuple, CompiledGenerate] = {}

    # -- compile cache ----------------------------------------------------

    def get_compiled(
        self,
        cfg: ModelConfig,
        batch: int,
        prompt_len: int,
        num_tokens: int,
        greedy: bool = True,
        temperature: float = 1.0,
        *,
        params=None,
    ) -> CompiledGenerate:
        key = generate_key(cfg, batch, prompt_len, num_tokens, greedy, temperature)
        entry = self._compiled.get(key)
        if entry is not None:
            return entry
        assert params is not None, "a compile-cache miss needs params (shapes)"
        gen_fn = make_generate_fn(
            cfg, num_tokens, greedy=greedy, temperature=temperature
        )
        entry = CompiledGenerate(fn=None, key=key)  # type: ignore[arg-type]

        def traced(params, prompts, cache, rng):
            # Python side effect fires at trace time only (during lower());
            # this is the trace counter the CI smoke test asserts on.
            entry.traces += 1
            return gen_fn(params, prompts, cache, rng)

        # AOT: lower + compile against abstract inputs, store the Compiled
        # executable.  A Compiled cannot silently re-trace — the first real
        # call runs the prebuilt program, so first-call timings are pure
        # execution (the old warm-up-by-execution run is gone).
        t0 = time.perf_counter()
        jitted = jax.jit(traced, donate_argnums=(2,))
        cache_s = jax.eval_shape(
            lambda: cache_lib.init_cache(cfg, batch, prompt_len + num_tokens)
        )
        entry.fn = jitted.lower(
            abstract_like(params),
            jax.ShapeDtypeStruct((batch, prompt_len), jnp.int32),
            cache_s,
            jax.ShapeDtypeStruct((2,), jnp.uint32),
        ).compile()
        entry.compiles += 1
        entry.compile_s = time.perf_counter() - t0
        self._compiled[key] = entry
        return entry

    def clear(self) -> None:
        self._compiled.clear()

    @property
    def num_compiled(self) -> int:
        return len(self._compiled)

    def total_traces(self) -> int:
        return sum(e.traces for e in self._compiled.values())

    def total_compiles(self) -> int:
        return sum(e.compiles for e in self._compiled.values())

    def stats(self) -> Dict[str, Any]:
        return {
            "entries": self.num_compiled,
            "traces": self.total_traces(),
            "compiles": self.total_compiles(),
            "calls": sum(e.calls for e in self._compiled.values()),
        }

    # -- serving ----------------------------------------------------------

    def generate(
        self,
        params,
        cfg: ModelConfig,
        prompts: jax.Array,
        num_tokens: int,
        *,
        key: Optional[jax.Array] = None,
        greedy: bool = True,
        temperature: float = 1.0,
    ) -> Tuple[jax.Array, Dict[str, float]]:
        """One generation: returns ((B, num_tokens) int32, timings).

        A new signature is AOT-compiled (``jit(...).lower(...).compile()``)
        on the cache miss, so ``timings['generate_s']`` is the blocked wall
        time of pure execution — compute, never dispatch or compile — on
        every call including the first: the stored ``Compiled`` executable
        cannot silently re-trace, there is no second hidden compile on the
        first real call and no throwaway warm-up run.
        ``timings['compile_s']`` is the signature's one-off AOT cost (0.0
        on cache hits); ``timings['decode_s_per_token']`` is the whole call
        (prefill + all rounds) divided by ``num_tokens``.  The fresh decode
        caches built here are donated to the compiled program.
        """
        key = key if key is not None else jax.random.PRNGKey(0)
        key = jnp.asarray(key, jnp.uint32)
        prompts = jnp.asarray(prompts, jnp.int32)
        b, s_prompt = prompts.shape
        compiled_this_call = (
            generate_key(cfg, b, s_prompt, num_tokens, greedy, temperature)
            not in self._compiled
        )
        entry = self.get_compiled(
            cfg, b, s_prompt, num_tokens, greedy=greedy,
            temperature=temperature, params=params,
        )
        cache = cache_lib.init_cache(cfg, b, s_prompt + num_tokens)
        t0 = time.perf_counter()
        tokens, final_cache = entry.fn(params, prompts, cache, key)
        jax.block_until_ready(tokens)
        t_total = time.perf_counter() - t0
        del final_cache  # aliased to the donated input; engine owns neither
        entry.calls += 1
        reg = obs.registry()
        if reg.enabled:
            reg.record_span(
                "decode_engine.generate", t0, t0 + t_total,
                batch=b, prompt_len=s_prompt, tokens=num_tokens,
                compiled=compiled_this_call,
            )
            reg.histogram("decode_engine.generate_s").observe(t_total)
            reg.counter("decode_engine.tokens_generated").inc(b * num_tokens)
            reg.counter("decode_engine.calls").inc()
        timings = {
            "generate_s": t_total,
            "decode_s_per_token": t_total / max(1, num_tokens),
            "tokens_per_s": (b * num_tokens) / max(t_total, 1e-9),
            "traces": float(entry.traces),
            "compile_s": entry.compile_s if compiled_this_call else 0.0,
            "compiled_this_call": float(compiled_this_call),
        }
        return tokens, timings


_DEFAULT_ENGINE: Optional[DecodeEngine] = None


def default_engine() -> DecodeEngine:
    """Process-wide engine (the compile cache survives across callers)."""
    global _DEFAULT_ENGINE
    if _DEFAULT_ENGINE is None:
        _DEFAULT_ENGINE = DecodeEngine()
    return _DEFAULT_ENGINE


def engine_generate(params, cfg, prompts, num_tokens, **kw):
    """Module-level convenience over :func:`default_engine`."""
    return default_engine().generate(params, cfg, prompts, num_tokens, **kw)
