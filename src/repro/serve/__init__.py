"""repro.serve — the serving engine layer.

Two engines over the step builders in ``repro.launch.steps``:

* ``DecodeEngine`` — the whole-generation scan engine: one AOT-compiled
  ``lax.scan`` program per (arch, batch, prompt_len, num_tokens, link-spec)
  signature, cached so repeated ``generate()`` calls never re-trace, with
  donated decode caches and compute-accurate (``block_until_ready``)
  timing.  Kept as the batch oracle and benchmark baseline.
* ``ContinuousEngine`` — the continuous-batching slot-pool engine
  (``repro.serve.continuous``): a persistent ``max_slots`` pool driven by
  exactly two kinds of AOT programs (bucketed prefill + one fused decode
  step), so heterogeneous live traffic runs with zero steady-state
  recompiles.  This is what ``launch.serve.generate`` rides by default.

Plus the sharded front (``repro.serve.router``): ``ShardedEngine`` runs
one ``ContinuousEngine`` per mesh device behind an occupancy-aware
router that exposes the same engine surface — ``SLAScheduler`` and the
chaos harness sit in front of the routed fleet unchanged.
"""

from repro.serve.engine import (  # noqa: F401
    CompiledGenerate,
    DecodeEngine,
    abstract_like,
    default_engine,
    engine_generate,
    generate_key,
)
from repro.serve.continuous import (  # noqa: F401
    ContinuousEngine,
    PoolConfig,
    PoolExhausted,
    Request,
    clear_engines,
    engine_for,
    make_sim_server,
    padding_safe,
    pool_engine,
    pow2_bucket,
)
from repro.serve.router import (  # noqa: F401
    ShardedEngine,
    clear_routers,
    sharded_engine,
)
from repro.serve.scheduler import (  # noqa: F401
    SLA,
    SLAScheduler,
    VirtualClock,
    protocol_feasibility,
)
