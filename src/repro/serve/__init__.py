"""repro.serve — the serving engine layer.

``DecodeEngine`` turns the step builders in ``repro.launch.steps`` into a
production-shaped serving path: one jit-compiled ``lax.scan`` program per
(arch, batch, prompt_len, num_tokens, link-spec) signature, cached so
repeated ``generate()`` calls never re-trace, with donated decode caches
and compute-accurate (``block_until_ready``) timing.
"""

from repro.serve.engine import (  # noqa: F401
    CompiledGenerate,
    DecodeEngine,
    default_engine,
    engine_generate,
    generate_key,
)
