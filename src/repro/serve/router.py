"""Sharded serving: one logical slot pool spanning the host mesh.

``ContinuousEngine`` is single-device by construction — its slot pool,
block allocator, and AOT ``Compiled`` executables all live on one chip,
so aggregate tokens/s is capped by that chip no matter how many devices
the mesh has.  ``ShardedEngine`` lifts that cap with the standard
router-over-replicas topology:

* one ``ContinuousEngine(device=dev)`` per mesh device ("shard"), each
  holding its own slot/block pool and its own per-device ``Compiled``
  prefill/decode executables (per-shard compiles stay exactly
  ``num_buckets + 1``; the executables are device-pinned, so the
  steady-state zero-recompile contract holds per shard);
* a host-side **occupancy-aware router** that places each admission on
  the shard with the most free capacity — among shards that can admit
  the request at all (a free slot, and — paged — enough free blocks),
  pick the one maximizing ``(free_slots, free_blocks, -shard_idx)``.
  The ``-shard_idx`` tiebreak makes placement fully deterministic;
* the **same engine surface** the single-device pool exposes
  (``submit`` / ``try_admit`` / ``preempt_slot`` / ``running_slots`` /
  ``free_slot_count`` / ``free_block_count`` / ``blocks_held`` /
  ``blocks_needed`` / ``step`` / ``run``), with slots numbered globally
  (``gslot = shard_idx * max_slots + local_slot``), so
  ``SLAScheduler.tick()`` probes the router exactly as it probes one
  engine — preemption picks a global slot, the router forwards to the
  owning shard, and the freed request may resume on a DIFFERENT shard
  (the keyed computation is deterministic in the request key, so
  cross-shard resume stays greedy token-identical; regression-tested
  under iid + GE + int8).

Exactness is placement-invariant by construction: every request runs
the identical batch-1 keyed math whichever shard admits it, because the
shards are full replicas (same params, same pool config, same
programs) and requests never share RNG or link state.

Aggregation semantics where one pool's scalar answer has no exact
multi-pool equivalent:

* ``free_slot_count`` — SUM over shards (a request needs one slot on
  ANY shard, and the scheduler only tests ``> 0``);
* ``free_block_count()`` — MAX over shards: one admission lands on one
  shard, so the best single shard is what decides admissibility.  The
  scheduler's all-or-nothing preemption estimate adds victims' blocks
  across shards to this, which can overestimate what any single shard
  can reach; the result is a wasted preemption round followed by
  backoff (retry), never corruption — ``try_admit`` re-checks the real
  per-shard allocator before committing anything;
* ``PoolExhausted`` typed fields — ``free_slots``/``free_blocks``
  aggregate as sums across shards (the backpressure report describes
  the whole logical pool).

This module is a pure HOST layer over the engines: it reads host
mirrors and drives admission through the public engine API only —
RPA007 (``repro.analysis``) enforces the boundary statically, exactly
as it does for the SLA scheduler and the chaos harness.
"""

from __future__ import annotations

import collections
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs.base import ModelConfig
from repro.serve.continuous import (
    ContinuousEngine,
    PoolConfig,
    PoolExhausted,
    Request,
    build_request,
)
from repro.serve.scheduler import SLA
from repro.sharding.rules import pool_shard_devices


class ShardedEngine:
    """Occupancy-routed fleet of per-device ``ContinuousEngine`` shards.

    ``mesh=`` (a ``launch.mesh.make_host_mesh`` mesh; its ``model`` axis
    must be size 1 — the slot axis is what shards) or an explicit
    ``devices=`` sequence picks the shard devices; with neither, every
    visible device gets a shard.  ``devices`` may repeat a device —
    tests use several shards on the single CPU device to exercise all
    routing logic in-process without a forced multi-device backend.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        pool: Optional[PoolConfig] = None,
        attn_impl: Optional[str] = None,
        *,
        mesh=None,
        devices: Optional[Sequence] = None,
    ):
        if devices is None:
            devices = (
                pool_shard_devices(mesh) if mesh is not None
                else list(jax.devices())
            )
        devices = list(devices)
        if not devices:
            raise ValueError("ShardedEngine: empty device list")
        self.pool = pool or PoolConfig()
        self.shards: List[ContinuousEngine] = [
            ContinuousEngine(cfg, self.pool, attn_impl, device=dev)
            for dev in devices
        ]
        self.cfg = self.shards[0].cfg        # after any attn_impl override
        self.devices = devices
        self.num_shards = len(devices)
        # Router-level FIFO queue + rid namespace (shard queues stay
        # empty: the router admits through try_admit directly, so the
        # placement decision is always the router's).
        self._queue: collections.deque = collections.deque()
        self._rid = 0
        self.scheduler = None
        self._stalled_steps = 0
        # Placement ledger: admissions per shard, and per-rid placement
        # history (a resumed request appends again — the cross-shard
        # resume tests read this).
        self.placement_counts: List[int] = [0] * self.num_shards
        self.placements: Dict[int, List[int]] = {}
        for sh in self.shards:
            # Completion sink: per-shard completions reach the router's
            # scheduler accounting (and the router gauges) at the
            # shard's sanctioned completion sync point, WITHOUT the
            # shard ticking the scheduler itself.
            sh.completion_sink = self

    # -- aggregate occupancy (the scheduler's probes) ----------------------

    @property
    def active(self) -> int:
        return sum(sh.active for sh in self.shards)

    @property
    def free_slot_count(self) -> int:
        """Free slots across ALL shards (sum — one is enough to admit)."""
        return sum(sh.free_slot_count for sh in self.shards)

    def free_block_count(self) -> int:
        """Free blocks on the BEST single shard (max, not sum): one
        admission lands on one shard, so the most any request can use is
        what one shard can offer.  See the module docstring for how this
        interacts with the scheduler's preemption estimate."""
        return max(sh.free_block_count() for sh in self.shards)

    @property
    def queue_depth(self) -> int:
        if self.scheduler is not None:
            return self.scheduler.queue_depth
        return len(self._queue)

    @property
    def compiles(self) -> int:
        """Total XLA builds across shards (each shard individually holds
        ``compiles == num_buckets + 1`` once its buckets are warm)."""
        return sum(sh.compiles for sh in self.shards)

    @property
    def num_buckets(self) -> int:
        return max(sh.num_buckets for sh in self.shards)

    # -- global slot numbering ---------------------------------------------

    def _locate(self, gslot: int) -> Tuple[int, int]:
        shard_idx, local = divmod(int(gslot), self.pool.max_slots)
        if not 0 <= shard_idx < self.num_shards:
            raise IndexError(
                f"global slot {gslot} out of range for {self.num_shards} "
                f"shard(s) x {self.pool.max_slots} slots"
            )
        return shard_idx, local

    def running_slots(self) -> List[Tuple[int, Request]]:
        """(global_slot, request) over every shard — the preemption-victim
        candidates, exactly the single-engine contract with
        ``gslot = shard_idx * max_slots + local_slot``."""
        out: List[Tuple[int, Request]] = []
        for i, sh in enumerate(self.shards):
            base = i * self.pool.max_slots
            out.extend((base + slot, req) for slot, req in sh.running_slots())
        return out

    def blocks_held(self, gslot: int) -> int:
        shard_idx, local = self._locate(gslot)
        return self.shards[shard_idx].blocks_held(local)

    def blocks_needed(self, prompt_len: int, max_tokens: int) -> int:
        # Identical pool config on every shard — any shard answers.
        return self.shards[0].blocks_needed(prompt_len, max_tokens)

    def preempt_slot(self, gslot: int) -> Request:
        """Evict the request on a global slot (scheduler preemption).
        Re-admission routes through placement again, so the request may
        resume on a different shard — token-identical either way."""
        shard_idx, local = self._locate(gslot)
        req = self.shards[shard_idx].preempt_slot(local)
        self._publish_router_gauges()
        return req

    # -- intake + placement -------------------------------------------------

    def attach_scheduler(self, sched) -> None:
        """Install an SLA scheduler in front of the ROUTER (it probes the
        router, never a shard directly); must happen before traffic."""
        assert not self._queue and self.active == 0, (
            "attach the scheduler before submitting traffic"
        )
        self.scheduler = sched

    def submit(
        self, prompt, max_tokens: int, key: Optional[jax.Array] = None,
        sla: Optional[SLA] = None,
    ) -> Request:
        """Queue one request; returns its handle (filled in by run())."""
        req = build_request(self, self._rid, prompt, max_tokens, key, sla)
        self._rid += 1
        if self.scheduler is not None:
            self.scheduler.enqueue(req)
        else:
            self._queue.append(req)
        obs.registry().counter("serve.requests_submitted").inc()
        return req

    def _place(self, req: Request) -> Optional[int]:
        """Deterministic occupancy-aware placement: among shards that can
        admit ``req`` right now, the one maximizing
        ``(free_slots, free_blocks, -idx)``; None when no shard can."""
        need = (
            self.blocks_needed(req.prompt.size, req.max_tokens)
            if self.pool.paged else 0
        )
        best = None
        best_key = None
        for i, sh in enumerate(self.shards):
            if sh.free_slot_count <= 0:
                continue
            blocks = sh.free_block_count() if self.pool.paged else 0
            if self.pool.paged and blocks < need:
                continue
            k = (sh.free_slot_count, blocks, -i)
            if best_key is None or k > best_key:
                best, best_key = i, k
        return best

    def try_admit(self, params, req: Request) -> bool:
        """Place + admit ONE request; False (no side effects) when no
        shard has the capacity.  The scheduler's tick() probes candidates
        in ITS order through this, exactly as with one engine."""
        idx = self._place(req)
        if idx is None:
            return False
        ok = self.shards[idx].try_admit(params, req)
        if not ok:
            # _place checked the same public occupancy try_admit checks,
            # on the same host mirrors, with no admission in between.
            raise AssertionError(
                f"shard {idx} refused an admission its occupancy allowed"
            )
        self.placement_counts[idx] += 1
        self.placements.setdefault(req.rid, []).append(idx)
        reg = obs.registry()
        reg.counter("router.placements").inc()
        reg.counter(f"router.placements.shard{idx}").inc()
        self._publish_router_gauges()
        return True

    def shard_of(self, req: Request) -> Optional[int]:
        """The shard currently (or last) hosting ``req``, by placement
        history; None before first admission."""
        hist = self.placements.get(req.rid)
        return hist[-1] if hist else None

    # -- driving ------------------------------------------------------------

    def _admit(self, params) -> None:
        # FIFO admission (no scheduler): strict arrival order — the same
        # head-of-line contract as the single engine, with the head
        # probing every shard through _place.
        while self._queue and self.try_admit(params, self._queue[0]):
            self._queue.popleft()

    def step(self, params) -> None:
        """One router tick: admit (scheduler tick when attached, FIFO
        otherwise), then step every shard that has live slots.  Idle
        shards are skipped — an empty pool has nothing to decode."""
        if self.scheduler is not None:
            self.scheduler.tick(self, params)
        else:
            self._admit(params)
        if self.active:
            self._stalled_steps = 0
            for sh in self.shards:
                if sh.active:
                    sh.step(params)
        elif self.scheduler is None and self._queue:
            self._stalled_steps += 1
            if self._stalled_steps > self.pool.exhaust_wait_steps:
                waited, self._stalled_steps = self._stalled_steps, 0
                head = self._queue[0]
                raise PoolExhausted(
                    waited_steps=waited,
                    queued=len(self._queue),
                    # Backpressure report spans the whole logical pool:
                    # sums across shards (free_block_count() is the
                    # admission probe and stays a max).
                    free_slots=self.free_slot_count,
                    free_blocks=sum(
                        sh.free_block_count() for sh in self.shards
                    ),
                    need_blocks=self.blocks_needed(
                        head.prompt.size, head.max_tokens
                    ) if self.pool.paged else 0,
                )
        else:
            self._stalled_steps = 0

    def run(self, params) -> List[Request]:
        """Drive until the queue and every shard are empty; returns every
        request finished since the last run, merged across shards in
        completion order (ties broken by rid).  Same VirtualClock caveat
        as the single engine's run()."""
        reg = obs.registry()
        with reg.span(
            "router.run", queued=len(self._queue), shards=self.num_shards
        ):
            while self._queue or self.active or (
                self.scheduler is not None and self.scheduler.pending
            ):
                self.step(params)
            done: List[Request] = []
            for sh in self.shards:
                done.extend(sh.take_finished())
            done.sort(key=lambda r: (r.t_done, r.rid))
        if reg.enabled:
            self._publish_router_gauges()
            self.publish_device_counters(reg)
        return done

    def harvest(self) -> None:
        """Sync every shard's finished work into host mirrors (the same
        boundary ``ContinuousEngine.harvest`` exposes — external drivers
        call this instead of reaching into shard internals)."""
        for sh in self.shards:
            sh.harvest()

    def warm(self, params, prompt_lens: Sequence[int] = ()) -> None:
        """Compile every needed program on EVERY shard: for each prompt
        length's bucket, admit-and-preempt one throwaway request per
        shard (through the public API, so this also warms the decode
        step and the deaden-slot scatter via the engine's own init).
        After warm(), a steady-state mixed-shard workload over these
        buckets runs under ``analysis.guards.no_recompile`` with zero
        builds, whichever shards the router picks."""
        lens = sorted({int(n) for n in (prompt_lens or (1,))})
        for sh in self.shards:
            for n in lens:
                req = build_request(
                    sh, -1, [1] * n, 1, key=jax.random.PRNGKey(0)
                )
                admitted = sh.try_admit(params, req)
                assert admitted, "warm() needs an idle pool"
                (slot,) = [s for s, r in sh.running_slots() if r is req]
                sh.preempt_slot(slot)

    def on_complete(self, engine, req: Request) -> None:
        """Per-shard completion sink (see ContinuousEngine.completion_sink):
        forward to the scheduler's accounting, then refresh the occupancy
        gauges — the completing shard just freed capacity."""
        if self.scheduler is not None:
            self.scheduler.on_complete(engine, req)
        self._publish_router_gauges()

    # -- observability ------------------------------------------------------

    def _publish_router_gauges(self) -> None:
        """Per-shard occupancy + router queue depth, stamped at the
        existing host sync points (admission / preemption / completion —
        pure host-mirror reads, no device sync)."""
        reg = obs.registry()
        if not reg.enabled:
            return
        reg.gauge("router.queue_depth").set(float(self.queue_depth))
        for i, sh in enumerate(self.shards):
            reg.gauge(f"serve.shard_free_slots.{i}").set(
                float(sh.free_slot_count)
            )
            reg.gauge(f"serve.shard_free_blocks.{i}").set(
                float(sh.free_block_count())
            )

    def device_counters(self) -> Dict[str, float]:
        """Shard device counters summed into one logical-pool view, with
        the realized drop rate re-derived from the summed link totals
        (rates do not sum).  One sync per shard — run-boundary use."""
        total: Dict[str, float] = {}
        for sh in self.shards:
            for k, v in sh.device_counters().items():
                total[k] = total.get(k, 0.0) + v
        total["realized_drop_rate"] = total.get("link_dropped", 0.0) / max(
            total.get("link_elems", 0.0), 1.0
        )
        return total

    def publish_device_counters(self, reg=None) -> Dict[str, float]:
        reg = reg or obs.registry()
        host = self.device_counters()
        for k, v in host.items():
            reg.gauge(f"serve.device.{k}").set(v)
        return host

    def stats(self) -> Dict[str, float]:
        """Aggregate + per-shard counters.  Flat keys (``shard{i}.*``)
        so the bench JSON stays a one-level dict like the engine's."""
        out: Dict[str, float] = {
            "num_shards": float(self.num_shards),
            "compiles": float(self.compiles),
            "num_buckets": float(self.num_buckets),
            "tokens_generated": float(
                sum(sh.tokens_generated for sh in self.shards)
            ),
            "steps": float(sum(sh.steps for sh in self.shards)),
        }
        for i, sh in enumerate(self.shards):
            out[f"shard{i}.compiles"] = float(sh.compiles)
            out[f"shard{i}.num_buckets"] = float(sh.num_buckets)
            out[f"shard{i}.tokens_generated"] = float(sh.tokens_generated)
            out[f"shard{i}.placements"] = float(self.placement_counts[i])
        return out

    # -- one-shot batch API (mirrors ContinuousEngine.generate_batch) -------

    def generate_batch(
        self,
        params,
        prompts,                  # (B, S) int32
        num_tokens: int,
        *,
        key: Optional[jax.Array] = None,
    ):
        """Serve a same-length batch as B independent requests with keys
        ``fold_in(key, i)`` — the single-engine contract, so per request
        the greedy output is token-identical to
        ``generate_reference(prompts[i:i+1], key=fold_in(key, i))``
        regardless of which shard each request lands on."""
        key = key if key is not None else jax.random.PRNGKey(0)
        prompts = jnp.asarray(prompts, jnp.int32)
        b = prompts.shape[0]
        compiles_before = self.compiles
        compile_s_before = sum(sh.compile_s for sh in self.shards)
        reqs = [
            self.submit(prompts[i], num_tokens, key=jax.random.fold_in(key, i))
            for i in range(b)
        ]
        t0 = time.perf_counter()
        self.run(params)
        t_total = time.perf_counter() - t0
        compile_s = sum(sh.compile_s for sh in self.shards) - compile_s_before
        exec_s = max(t_total - compile_s, 1e-9)
        tokens = jnp.stack([jnp.asarray(r.tokens) for r in reqs])
        timings = {
            "generate_s": exec_s,
            "decode_s_per_token": exec_s / max(1, num_tokens),
            "tokens_per_s": (b * num_tokens) / exec_s,
            "compiles": float(self.compiles),
            "compile_s": compile_s,
            "compiled_this_call": float(self.compiles > compiles_before),
            "num_shards": float(self.num_shards),
        }
        return tokens, timings


# ---------------------------------------------------------------------------
# Process-wide router registry (mirrors continuous.pool_engine)
# ---------------------------------------------------------------------------

_ROUTERS: Dict[Tuple, ShardedEngine] = {}
_MAX_ROUTERS = 2      # each router holds num_shards device pools


def sharded_engine(
    cfg: ModelConfig,
    pool: Optional[PoolConfig] = None,
    *,
    num_shards: int = 0,
) -> ShardedEngine:
    """Router per (cfg, pool, num_shards) — pools and compiled programs
    survive across callers.  ``num_shards=0`` spans every visible device;
    ``num_shards > len(jax.devices())`` wraps shards around the available
    devices (several pools per device — the in-process test/dev mode)."""
    pool = pool or PoolConfig()
    k = (cfg, pool, num_shards)
    if k in _ROUTERS:
        _ROUTERS[k] = _ROUTERS.pop(k)          # refresh LRU position
        return _ROUTERS[k]
    while len(_ROUTERS) >= _MAX_ROUTERS:
        _ROUTERS.pop(next(iter(_ROUTERS)))
    devs = list(jax.devices())
    if num_shards:
        devs = [devs[i % len(devs)] for i in range(num_shards)]
    _ROUTERS[k] = ShardedEngine(cfg, pool, devices=devs)
    return _ROUTERS[k]


def clear_routers() -> None:
    _ROUTERS.clear()
