"""Continuous-batching serve engine: slot pool + bucketed prefill.

The whole-generation scan engine (``repro.serve.engine``) compiles one
program per ``(batch, prompt_len, num_tokens)`` signature.  Under live
multi-client traffic — heterogeneous prompt lengths, Poisson arrivals —
that is either a recompile storm (one XLA build per new signature) or
worst-case padding (everyone pays the longest request).  This engine
replaces the execution model with the standard continuous-batching design:

* a persistent **slot pool** — ``max_slots`` independent batch-1 decode
  states (``models.cache.init_slot_pool``) plus per-slot scalars (current
  token, cache length, RNG key chain, generated-token count, budget) and a
  per-slot output buffer, all living on device across requests;
* a **bucketed prefill** program per prompt-length bucket (power-of-two
  padding): runs the padded prompt through the device->link->server stack,
  selects the first token at the request's *true* last position, and
  writes the freshly built batch-1 cache + scalars into a free slot
  (``dynamic_update_slice``; the slot index is data, not shape);
* ONE fused **decode-step** program: ``vmap`` of the per-token DI serve
  step over the slot axis — per-slot cache index, per-slot RNG key chain,
  per-slot lossy-link round, per-slot stop bookkeeping — stepping every
  in-flight request at once.  Requests join and retire mid-flight without
  retracing: admission/retirement only changes slot *data*.

Exactness.  Each slot runs the identical math a batch-1
``generate_reference`` run performs: the prefill's link is the streamed
per-position round (``core.comtune.streamed_channel_link`` — invariant to
right padding), causal attention makes padded positions invisible to real
ones, and the per-slot key chain reproduces the reference's
``key, sub = split(key)`` sequence.  Greedy outputs are token-for-token
identical to ``generate_reference(prompt[None], key=request_key)``
(tests/test_continuous_serve.py, iid + Gilbert-Elliott).

Zero steady-state recompiles.  Every program is AOT-compiled
(``jit(...).lower(...).compile()``) and stored as a ``jax.stages.Compiled``
executable, which *cannot* silently re-trace — a signature mismatch raises.
``engine.compiles`` therefore counts every XLA build exactly: after the
buckets seen by the workload are warm, it equals ``num_buckets + 1`` and
never grows again.

Retired slots keep stepping (their updates are select-masked on the scalar
state, and their cache writes land in positions the attention mask never
reads before the next admission fully overwrites the slot) — masking the
cache too would double the HBM traffic of the hot step for nothing.

Models with recurrent layers (mamba/xLSTM) or sliding windows shorter than
the largest bucket fall back to exact-length buckets: right padding would
leak into their recurrent/rotating state, so each distinct prompt length
compiles its own prefill (still compile-cached and AOT).

Paged mode (``PoolConfig(paged=True)``) swaps the per-slot contiguous
caches for a shared block pool (``models.cache.init_block_pool``) with
per-slot block tables: admission reserves only the blocks a request can
touch instead of a full ``max_seq`` cache, the bucketed prefill copies just
the prompt's blocks into the pool, and the fused decode step follows each
slot's table through the paged flash-decode attention.  Same exactness and
compile contracts as above; see ``_make_paged_decode_step``.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import math
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.launch.steps import make_serve_step
from repro.models import attention as attention_lib, cache as cache_lib, lm
from repro.obs import device as obs_device
from repro.serve.engine import abstract_like
from repro.serve.scheduler import SLA


def pow2_bucket(n: int, floor: int = 8) -> int:
    b = max(floor, 1)
    while b < n:
        b *= 2
    return b


def padding_safe(cfg: ModelConfig, max_bucket: int) -> bool:
    """True when right-padding a prompt to ``max_bucket`` cannot change the
    real positions' outputs or decode state: attention-only stacks (causal
    masking ignores right padding) whose sliding windows, if any, are at
    least as long as the largest bucket (so the rotating prefill write
    never evicts real positions because of padding)."""
    for s in cfg.all_layers():
        if s.kind != "attn":
            return False
        if s.window and s.window < max_bucket:
            return False
    return True


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """Static shape/behavior of one slot pool (one compile signature).

    ``paged=True`` switches the decode state from ``max_slots`` contiguous
    ``max_seq``-row caches to a shared block pool of ``num_blocks`` x
    ``block_size`` KV rows with per-slot block tables — admission then
    reserves only the blocks a request can actually touch
    (``ceil(min(max(bucket, prompt + max_tokens), max_seq) / block_size)``),
    so ``max_slots`` can exceed what worst-case-contiguous HBM would allow.
    ``num_blocks=0`` derives the worst-case-equivalent pool
    (``max_slots * blocks_per_slot`` + the reserved trash block); set it
    explicitly to oversubscribe.
    """

    max_slots: int = 8
    max_new: int = 64            # per-request generation budget ceiling
    max_prompt: int = 128        # longest admissible prompt
    min_bucket: int = 8          # smallest prefill bucket (power-of-two grid)
    greedy: bool = True
    temperature: float = 1.0
    paged: bool = False
    block_size: int = 16         # KV rows per pool block (paged only)
    num_blocks: int = 0          # physical blocks incl. trash; 0 = derive
    # Backpressure budget when NO scheduler is installed: consecutive
    # no-progress steps (queue non-empty, nothing live, nothing
    # admissible) the engine tolerates before raising PoolExhausted
    # instead of head-of-line blocking forever.
    exhaust_wait_steps: int = 1000

    @property
    def max_bucket(self) -> int:
        return pow2_bucket(self.max_prompt, self.min_bucket)

    @property
    def max_seq(self) -> int:
        return self.max_bucket + self.max_new

    @property
    def blocks_per_slot(self) -> int:
        """Block-table row width: blocks a worst-case request reserves."""
        return -(-self.max_seq // self.block_size)

    @property
    def total_blocks(self) -> int:
        if self.num_blocks:
            return self.num_blocks
        return self.max_slots * self.blocks_per_slot + 1


@dataclasses.dataclass
class Request:
    """One in-flight generation request."""

    rid: int
    prompt: np.ndarray            # (S,) int32
    max_tokens: int
    key: jax.Array                # (2,) uint32 — the per-request RNG chain
    tokens: Optional[np.ndarray] = None   # (max_tokens,) int32 when done
    bucket: int = 0               # prefill bucket this request was padded to
    t_submit: float = 0.0         # queued
    t_admit: float = 0.0          # scheduler picked a slot (before prefill)
    t_first_token: float = 0.0    # prefill produced the first token
    t_done: float = 0.0           # last decode round completed
    t_retire: float = 0.0         # output harvested to host
    # SLA scheduling (repro.serve.scheduler) — defaults are best-effort.
    sla: Optional[SLA] = None
    state: str = "queued"         # queued|running|completed|expired|rejected
    n_preempts: int = 0           # times evicted mid-flight (recompute resume)
    retries: int = 0              # admission attempts that hit backoff
    t_deadline: float = math.inf  # absolute, on the scheduler's clock

    @property
    def done(self) -> bool:
        return self.tokens is not None

    @property
    def terminal(self) -> bool:
        """Terminally resolved: the scheduler will never touch it again."""
        return self.state in ("completed", "expired", "rejected")

    @property
    def ttft_s(self) -> float:
        """Time to first token, from submission (includes queue wait).
        Honest — blocked on device — only with the obs registry enabled;
        otherwise it is a dispatch-time stamp (a lower bound)."""
        return self.t_first_token - self.t_submit

    @property
    def tpot_s(self) -> float:
        """Mean time per output token over the decode tail (first token
        excluded: it comes from the prefill program)."""
        return (self.t_done - self.t_first_token) / max(1, self.max_tokens - 1)

    @property
    def e2e_s(self) -> float:
        return self.t_done - self.t_submit


class PoolExhausted(RuntimeError):
    """Typed backpressure signal: with no scheduler installed, the engine
    waited ``PoolConfig.exhaust_wait_steps`` consecutive steps with queued
    work, zero live slots, and nothing admissible (e.g. a chaos block
    squeeze holding the pool) — the caller must shed load or free
    capacity instead of the old behavior (head-of-line blocking forever).
    The wait budget re-arms after the raise, so drivers that catch and
    retry get the full budget again."""

    def __init__(self, *, waited_steps: int, queued: int, free_slots: int,
                 free_blocks: int, need_blocks: int):
        self.waited_steps = waited_steps
        self.queued = queued
        self.free_slots = free_slots
        self.free_blocks = free_blocks
        self.need_blocks = need_blocks
        super().__init__(
            f"admission stalled for {waited_steps} steps: {queued} queued, "
            f"{free_slots} free slots, {free_blocks} free blocks "
            f"(head needs {need_blocks}); install an SLAScheduler for "
            "preemption/shedding or free pool capacity"
        )


def build_request(
    eng, rid: int, prompt, max_tokens: int,
    key: Optional[jax.Array] = None, sla: Optional[SLA] = None,
) -> Request:
    """Validate + construct one :class:`Request` against ``eng``'s pool
    limits.  Shared by ``ContinuousEngine.submit`` and the sharded
    router's submit (``repro.serve.router``): the router keeps its own
    rid namespace and queue but admits against identical per-shard
    pools, so the limits — and the impossible-request rejection — are
    the same.  ``eng`` only needs ``.pool`` and ``.blocks_needed``."""
    prompt = np.asarray(prompt, np.int32).reshape(-1)
    pool = eng.pool
    assert 1 <= prompt.size <= pool.max_prompt, (
        prompt.size, pool.max_prompt
    )
    assert 1 <= max_tokens <= pool.max_new, (max_tokens, pool.max_new)
    if pool.paged:
        # Reject impossible requests at submission: admission blocks
        # head-of-line on a full pool (progress is guaranteed because
        # live requests retire), but a request needing more blocks than
        # the pool HAS would deadlock the queue forever.
        need = eng.blocks_needed(prompt.size, int(max_tokens))
        cap = pool.total_blocks - 1
        if need > cap:
            raise ValueError(
                f"request needs {need} pool blocks (prompt {prompt.size}, "
                f"max_tokens {max_tokens}, block_size "
                f"{pool.block_size}) but the pool only has {cap} "
                "allocatable blocks — it could never be admitted"
            )
    if key is None:
        key = jax.random.PRNGKey(rid)
    return Request(
        rid=rid, prompt=prompt, max_tokens=int(max_tokens),
        key=jnp.asarray(key, jnp.uint32), t_submit=time.perf_counter(),
        sla=sla,
    )


class ContinuousEngine:
    """Slot-pooled continuous-batching engine for one model config.

    The fused decode step vmaps the per-token DI round over the slot axis,
    so with ``cfg.attn_impl`` in {"blockwise", "flash_decode"} (the
    production default) every slot runs the length-masked flash-decode
    attention (``repro.kernels.decode_attention``) with its OWN
    ``cache_index`` — a slot 10 tokens into a 1024-slot cache reads ~1
    KV block instead of all 1024, and int8 caches dequantize inline.
    ``attn_impl`` overrides the config's choice (benchmarks use it to flip
    between the masked path and the ``"naive"`` full-cache oracle without
    re-deriving configs).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        pool: Optional[PoolConfig] = None,
        attn_impl: Optional[str] = None,
        device=None,
    ):
        assert not cfg.frontend, (
            "frontend (VLM/audio) configs are not supported by the slot-pool "
            "engine yet — use the whole-generation DecodeEngine"
        )
        if attn_impl is not None:
            cfg = cfg.with_updates(attn_impl=attn_impl)
        self.cfg = cfg
        self.pool = pool or PoolConfig()
        # ``device`` pins THIS engine's slot pool and all of its AOT
        # executables to one device — the sharded router
        # (``repro.serve.router``) builds one engine per mesh device so a
        # logical pool spans the host mesh.  None keeps the default-device
        # behavior (single-device engines are unchanged).
        self.device = device
        self._placed_params = None
        self._placed_params_id: Optional[int] = None
        if self.pool.paged:
            bad = sorted(
                {s.kind for s in cfg.all_layers() if s.kind != "attn"}
            )
            if bad:
                raise ValueError(
                    f"paged slot pools support attention-only stacks; {cfg.name!r} "
                    f"has {bad} layers (O(1) recurrent state — nothing to page)"
                )
            if self.pool.total_blocks < 2:
                raise ValueError(
                    "paged pool needs >= 2 blocks (block 0 is the trash block)"
                )
        self._padded = padding_safe(cfg, self.pool.max_bucket)
        # Device state + AOT executables (built lazily on first use, since
        # they need the parameter shapes).
        self._state: Optional[Dict[str, Any]] = None
        self._decode_fn = None
        self._prefill_fns: Dict[int, Any] = {}
        # Host-side mirrors (scheduling never reads device memory).
        self._queue: collections.deque = collections.deque()
        self._slot_req: List[Optional[Request]] = [None] * self.pool.max_slots
        self._remaining: List[int] = [0] * self.pool.max_slots
        self._free: List[int] = list(range(self.pool.max_slots))
        self._pending_harvest: List[Tuple[int, Request]] = []
        self._finished: List[Request] = []
        self._req_metrics: collections.deque = collections.deque(maxlen=4096)
        self._rid = 0
        # Optional SLA scheduler (repro.serve.scheduler.SLAScheduler):
        # when attached, submit() routes into its ready queue and step()
        # calls its tick() in place of FIFO admission.
        self.scheduler = None
        # Completion sink: an object whose on_complete(engine, req) fires
        # at the completion sync point WITHOUT this engine ticking it.
        # The sharded router installs its scheduler here on every shard —
        # admission routes through the router (placement), but deadline-hit
        # accounting still needs the per-shard completion stamp.
        self.completion_sink = None
        self._stalled_steps = 0
        # Paged-pool host allocator: block 0 is the reserved trash block
        # and is never handed out; free list is LIFO so a freed request's
        # blocks are reused first (stale-row safety is the n_valid mask's
        # job, not the allocator's).
        self._free_blocks: List[int] = (
            list(range(self.pool.total_blocks - 1, 0, -1))
            if self.pool.paged else []
        )
        self._slot_blocks: List[List[int]] = [
            [] for _ in range(self.pool.max_slots)
        ]
        # Counters / stats.
        self.compiles = 0
        self.traces = 0
        self.compile_s = 0.0
        self.steps = 0
        self.busy_slot_steps = 0
        self.tokens_generated = 0
        self.blocks_written = 0
        self.peak_blocks_used = 0
        self.active_per_step: collections.deque = collections.deque(
            maxlen=65536
        )

    # -- static program construction --------------------------------------

    def _dev_ctx(self):
        """Context placing array creation AND AOT lowering on this
        engine's device (no-op for the default single-device engine)."""
        if self.device is None:
            return contextlib.nullcontext()
        return jax.default_device(self.device)

    def _params_for(self, params):
        """Per-device parameter copy, cached by identity: a device-pinned
        engine must not re-upload the (large) params every dispatch, and
        its ``Compiled`` executables expect inputs resident on its own
        device.  The default engine passes params through untouched."""
        if self.device is None:
            return params
        if self._placed_params_id != id(params):
            self._placed_params = jax.device_put(params, self.device)
            self._placed_params_id = id(params)
        return self._placed_params

    def _aot(self, fn, donate: Tuple[int, ...], avals: Tuple) -> Any:
        """jit -> lower -> compile; returns the Compiled executable and
        bumps the engine-wide compile/trace accounting.  Lowering runs
        under ``_dev_ctx`` so a device-pinned engine's executables target
        its own device (AOT avals carry no placement of their own)."""

        def traced(*args):
            self.traces += 1     # Python side effect: fires at trace time
            return fn(*args)

        t0 = time.perf_counter()
        with self._dev_ctx():
            compiled = jax.jit(
                traced, donate_argnums=donate
            ).lower(*avals).compile()
        self.compile_s += time.perf_counter() - t0
        self.compiles += 1
        return compiled

    def _init_state(self) -> Dict[str, Any]:
      with self._dev_ctx():
        p = self.pool
        if p.paged:
            cache = cache_lib.init_block_pool(
                self.cfg, p.total_blocks, p.block_size, device=self.device
            )
        else:
            cache = cache_lib.init_slot_pool(
                self.cfg, p.max_slots, p.max_seq, device=self.device
            )
        state = {
            "cache": cache,
            "token": jnp.zeros((p.max_slots, 1, 1), jnp.int32),
            "length": jnp.zeros((p.max_slots,), jnp.int32),
            "key": jnp.zeros((p.max_slots, 2), jnp.uint32),
            "n_gen": jnp.zeros((p.max_slots,), jnp.int32),
            "budget": jnp.zeros((p.max_slots,), jnp.int32),
            "out": jnp.zeros((p.max_slots, p.max_new), jnp.int32),
            # On-device telemetry (obs.DeviceCounters): carried and
            # accumulated UNCONDITIONALLY — whether the host registry is
            # enabled only decides whether anyone reads it, so obs on/off
            # traces byte-identical programs and the compile-count
            # invariant is independent of observability.
            "obs": obs_device.counter_zeros(),
        }
        if p.paged:
            # Per-slot block-table rows (zero-padded: unreserved entries
            # point at the trash block).  Data, not shape — admission and
            # retirement rewrite rows without retracing anything.
            state["block_table"] = jnp.zeros(
                (p.max_slots, p.blocks_per_slot), jnp.int32
            )
        if self.device is not None:
            # Commit the whole tree (``default_device`` only places,
            # commitment keeps follow-the-data dispatches — e.g. the
            # deaden-slot scatter — on THIS shard's device).
            state = jax.device_put(state, self.device)
        return state

    def _make_decode_step(self):
        if self.pool.paged:
            return self._make_paged_decode_step()
        cfg, pool = self.cfg, self.pool
        step = make_serve_step(cfg)
        masked_attn = cfg.attn_impl != "naive"

        def pool_step(params, state):
            def one(token, cache, length, key, n_gen, budget, out_row):
                # Mirrors one iteration of the reference per-token loop at
                # batch 1: emit the carried token, split the slot's key,
                # run the DI round, select the next token.  The link tap
                # is installed INSIDE the vmapped body (an outer collector
                # would leak batch tracers); the per-slot totals ride out
                # as vmap outputs.
                live = n_gen < budget
                with obs_device.tap_link_stats() as tap:
                    if pool.greedy:
                        key2, sub = jax.random.split(key)
                        logits, new_cache = step(
                            params, token, cache, length, sub
                        )
                        nxt = jnp.argmax(logits, axis=-1)[:, None].astype(
                            jnp.int32
                        )
                    else:
                        key2, sub, ks = jax.random.split(key, 3)
                        logits, new_cache = step(
                            params, token, cache, length, sub
                        )
                        scaled = logits.astype(jnp.float32) / jnp.float32(
                            max(pool.temperature, 1e-6)
                        )
                        nxt = jax.random.categorical(ks, scaled, axis=-1)[
                            :, None
                        ].astype(jnp.int32)
                    link = tap.totals()
                out2 = jax.lax.dynamic_update_slice(out_row, token[0], (n_gen,))
                sel = lambda a, b: jnp.where(live, a, b)
                # NOTE: new_cache is NOT select-masked — a retired slot's
                # dirty write lands at its frozen length (never read past
                # the attention validity mask) and the next admission
                # overwrites the whole slot.  Masking would double the HBM
                # traffic of the hot step.
                return (
                    sel(nxt, token),
                    new_cache,
                    sel(length + 1, length),
                    sel(key2, key),
                    sel(n_gen + 1, n_gen),
                    sel(out2, out_row),
                    link,
                )

            token, cache, length, key, n_gen, out, link = jax.vmap(one)(
                state["token"], state["cache"], state["length"],
                state["key"], state["n_gen"], state["budget"], state["out"],
            )
            # Device counters: only LIVE slots count (retired slots keep
            # stepping, but their rounds belong to no request — exactly
            # the rounds a per-request reference run never performs).
            livef = (state["n_gen"] < state["budget"]).astype(jnp.float32)
            valid = (state["length"] + 1).astype(jnp.float32)
            read_b = cache_lib.decode_read_bytes_jnp(
                cfg, pool.max_seq, valid, masked=masked_attn
            )
            c = state["obs"]
            new_obs = {
                "decode_steps": c["decode_steps"] + jnp.int32(1),
                "valid_tokens": c["valid_tokens"] + jnp.sum(livef * valid),
                "decode_read_bytes": c["decode_read_bytes"]
                + jnp.sum(livef * read_b),
                "link_elems": c["link_elems"] + jnp.sum(livef * link["elems"]),
                "link_dropped": c["link_dropped"]
                + jnp.sum(livef * link["dropped"]),
                "fec_recovered_packets": c["fec_recovered_packets"]
                + jnp.sum(livef * link["fec_recovered"]),
            }
            return {
                "cache": cache, "token": token, "length": length,
                "key": key, "n_gen": n_gen, "budget": state["budget"],
                "out": out, "obs": new_obs,
            }

        return pool_step

    def _make_paged_decode_step(self):
        """The fused decode step over the SHARED block pool.

        The contiguous step vmaps a batch-1 serve step over the slot axis;
        a shared pool cannot be vmapped (every slot scatters into the same
        buffers), so this runs ONE batched forward over all slots instead:
        per-slot lengths become the ``(B, 1)`` position batch, the
        per-slot link rounds come from ``lm.make_slotwise_link_fn`` (an
        inner vmap with per-slot keys — bitwise the draws the vmapped
        engine makes), and the paged attention branch
        (``models.attention`` + ``kernels.decode_attention``) consumes the
        block table through a ``PagedIndex``.  Every op is batch-row
        independent, so per-slot results equal the vmapped form's — the
        token-identity contract vs ``generate_reference`` is unchanged
        (regression-tested under iid + GE + int8).  Scalar-state updates
        are live-masked exactly like the contiguous step; dirty cache
        writes by retired slots are routed to the trash block *inside*
        ``_write_decode_paged`` (with a shared pool they could otherwise
        land in blocks already reallocated to live requests).
        """
        cfg, pool = self.cfg, self.pool

        def pool_step(params, state):
            live = state["n_gen"] < state["budget"]
            if pool.greedy:
                ks = jax.vmap(jax.random.split)(state["key"])    # (B, 2, 2)
                key2, sub, kcat = ks[:, 0], ks[:, 1], None
            else:
                ks = jax.vmap(lambda k: jax.random.split(k, 3))(state["key"])
                key2, sub, kcat = ks[:, 0], ks[:, 1], ks[:, 2]
            pidx = attention_lib.PagedIndex(
                lengths=state["length"],
                block_table=state["block_table"],
                live=live,
                max_seq=pool.max_seq,
                block_size=pool.block_size,
            )
            if cfg.mrope_sections:
                positions = jnp.broadcast_to(
                    state["length"][:, None, None],
                    (pool.max_slots, 3, 1),
                )
            else:
                positions = state["length"][:, None]
            tokens = state["token"][:, 0]                        # (B, 1)
            with obs_device.tap_link_stats() as tap:
                link_fn = lm.make_slotwise_link_fn(
                    cfg, params["link"], sub, "serve", live=live
                )
                logits, new_cache, _ = lm.forward(
                    params, tokens, cfg,
                    positions=positions,
                    cache=state["cache"], cache_index=pidx,
                    link_fn=link_fn, mode="decode",
                )
                link = tap.totals()
            last = logits[:, 0]                                  # (B, V)
            if pool.greedy:
                nxt = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
            else:
                scaled = last.astype(jnp.float32) / jnp.float32(
                    max(pool.temperature, 1e-6)
                )
                nxt = jax.vmap(jax.random.categorical)(kcat, scaled)[
                    :, None
                ].astype(jnp.int32)
            # Emit the token fed INTO the round (reference-loop order).
            out2 = jax.vmap(
                lambda row, t, n: jax.lax.dynamic_update_slice(row, t, (n,))
            )(state["out"], tokens[:, 0:1], state["n_gen"])
            livec = live[:, None]
            livef = live.astype(jnp.float32)
            valid = (state["length"] + 1).astype(jnp.float32)
            read_b = cache_lib.decode_read_bytes_jnp(
                cfg, pool.max_seq, valid,
                paged=True, block_size=pool.block_size,
            )
            c = state["obs"]
            new_obs = {
                "decode_steps": c["decode_steps"] + jnp.int32(1),
                "valid_tokens": c["valid_tokens"] + jnp.sum(livef * valid),
                "decode_read_bytes": c["decode_read_bytes"]
                + jnp.sum(livef * read_b),
                # Link totals arrive pre-masked: the slot-wise link fn
                # weights each slot's draws by ``live`` before emitting.
                "link_elems": c["link_elems"] + link["elems"],
                "link_dropped": c["link_dropped"] + link["dropped"],
                "fec_recovered_packets": c["fec_recovered_packets"]
                + link["fec_recovered"],
            }
            return {
                "cache": new_cache,
                "block_table": state["block_table"],
                "token": jnp.where(livec[..., None], nxt[:, :, None],
                                   state["token"]),
                "length": jnp.where(live, state["length"] + 1,
                                    state["length"]),
                "key": jnp.where(livec, key2, state["key"]),
                "n_gen": jnp.where(live, state["n_gen"] + 1, state["n_gen"]),
                "budget": state["budget"],
                "out": jnp.where(livec, out2, state["out"]),
                "obs": new_obs,
            }

        return pool_step

    def _make_prefill(self, bucket: int):
        cfg, pool = self.cfg, self.pool
        # Paged admission writes a STATIC number of blocks per bucket
        # program: the padded prompt occupies ceil(bucket / block_size)
        # blocks (padded rows ride along exactly as in the contiguous slot
        # copy — invisible behind causal masking and n_valid).  True_len
        # stays data; the copy count must be shape-static.
        nb_prompt = min(
            -(-bucket // pool.block_size), pool.blocks_per_slot
        ) if pool.paged else 0

        def prefill(params, state, prompt, true_len, slot, budget, rkey,
                    *rest):
            # Reference chain: key, sub = split(request_key); prefill(sub).
            key, sub = jax.random.split(rkey)
            fresh = cache_lib.init_cache(cfg, 1, pool.max_seq)
            # Link counters for the streamed prompt upload.  NOTE: the
            # streamed link runs over the PADDED bucket, so these totals
            # include the padded positions' draws (they are real rounds of
            # the compiled program; the oracle test replicates the
            # padding).
            with obs_device.tap_link_stats() as tap:
                logits, filled, _ = lm.forward(
                    params, prompt, cfg,
                    cache=fresh, cache_index=0,
                    link_key=sub, link_mode="serve", mode="prefill",
                )
                link = tap.totals()
            last = jax.lax.dynamic_slice(
                logits, (0, true_len - 1, 0), (1, 1, logits.shape[-1])
            )[:, 0]                                   # (1, V): true last pos
            if pool.greedy:
                tok0 = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
            else:
                key, ks = jax.random.split(key)
                scaled = last.astype(jnp.float32) / jnp.float32(
                    max(pool.temperature, 1e-6)
                )
                tok0 = jax.random.categorical(ks, scaled, axis=-1)[
                    :, None
                ].astype(jnp.int32)
            set1 = lambda arr, v: arr.at[slot].set(v)
            c = state["obs"]
            new_obs = {
                **c,
                "link_elems": c["link_elems"] + link["elems"],
                "link_dropped": c["link_dropped"] + link["dropped"],
                "fec_recovered_packets": c["fec_recovered_packets"]
                + link["fec_recovered"],
            }
            if pool.paged:
                (bt_row,) = rest
                new_cache = cache_lib.write_prompt_blocks(
                    state["cache"], filled, bt_row, nb_prompt,
                    pool.block_size,
                )
                extra = {
                    "block_table": jax.lax.dynamic_update_slice(
                        state["block_table"], bt_row[None],
                        (slot, jnp.int32(0)),
                    ),
                }
            else:
                new_cache = cache_lib.write_slot(state["cache"], filled, slot)
                extra = {}
            return {
                **extra,
                "obs": new_obs,
                "cache": new_cache,
                "token": jax.lax.dynamic_update_slice(
                    state["token"], tok0[None], (slot, 0, 0)
                ),
                "length": set1(state["length"], true_len),
                "key": set1(state["key"], key),
                "n_gen": set1(state["n_gen"], jnp.int32(0)),
                "budget": set1(state["budget"], budget),
                "out": jax.lax.dynamic_update_slice(
                    state["out"],
                    jnp.zeros((1, pool.max_new), jnp.int32),
                    (slot, 0),
                ),
            }

        return prefill

    def _ensure(self, params) -> None:
        if self._state is None:
            self._state = self._init_state()
            # Warm the deaden-slot scatter (a no-op on the all-zero budget)
            # so a mid-run preemption never compiles anything: the slot
            # index is a device scalar, so ONE cached program serves every
            # slot and the steady state stays build-free.
            self._deaden_slot(0)
        if self._decode_fn is None:
            avals = (abstract_like(params), abstract_like(self._state))
            self._decode_fn = self._aot(self._make_decode_step(), (1,), avals)

    def _prefill_for(self, params, bucket: int):
        fn = self._prefill_fns.get(bucket)
        if fn is None:
            scalar = jax.ShapeDtypeStruct((), jnp.int32)
            avals = (
                abstract_like(params),
                abstract_like(self._state),
                jax.ShapeDtypeStruct((1, bucket), jnp.int32),
                scalar, scalar, scalar,
                jax.ShapeDtypeStruct((2,), jnp.uint32),
            )
            if self.pool.paged:
                avals += (
                    jax.ShapeDtypeStruct(
                        (self.pool.blocks_per_slot,), jnp.int32
                    ),
                )
            fn = self._aot(self._make_prefill(bucket), (1,), avals)
            self._prefill_fns[bucket] = fn
        return fn

    # -- scheduling --------------------------------------------------------

    def bucket_for(self, length: int) -> int:
        if self._padded:
            return pow2_bucket(length, self.pool.min_bucket)
        return length

    @property
    def num_buckets(self) -> int:
        return len(self._prefill_fns)

    @property
    def active(self) -> int:
        return sum(r is not None for r in self._slot_req)

    @property
    def free_slot_count(self) -> int:
        return len(self._free)

    def free_block_count(self) -> int:
        """Blocks the host allocator could hand out right now (paged)."""
        return len(self._free_blocks)

    def running_slots(self) -> List[Tuple[int, Request]]:
        """(slot, request) for every in-flight slot — the scheduler's
        preemption-victim candidates (host mirrors only, no device read)."""
        return [
            (slot, req) for slot, req in enumerate(self._slot_req)
            if req is not None
        ]

    def blocks_held(self, slot: int) -> int:
        return len(self._slot_blocks[slot])

    def blocks_needed(self, prompt_len: int, max_tokens: int) -> int:
        """Blocks one request reserves for its whole lifetime: the padded
        prefill rows plus every decode write, capped by the rotation at
        ``max_seq`` (and hence by the block-table row width)."""
        p = self.pool
        rows = min(
            max(self.bucket_for(prompt_len), prompt_len + max_tokens),
            p.max_seq,
        )
        return min(cache_lib.blocks_for(rows, p.block_size), p.blocks_per_slot)

    def attach_scheduler(self, sched) -> None:
        """Install an SLA scheduler; must happen before any traffic (a
        half-FIFO, half-scheduled queue would have no coherent order)."""
        assert not self._queue and self.active == 0, (
            "attach the scheduler before submitting traffic"
        )
        self.scheduler = sched

    def submit(
        self, prompt, max_tokens: int, key: Optional[jax.Array] = None,
        sla: Optional[SLA] = None,
    ) -> Request:
        """Queue one request; returns its handle (filled in by run())."""
        req = build_request(self, self._rid, prompt, max_tokens, key, sla)
        self._rid += 1
        if self.scheduler is not None:
            self.scheduler.enqueue(req)
        else:
            self._queue.append(req)
        obs.registry().counter("serve.requests_submitted").inc()
        return req

    def harvest(self) -> None:
        """Read every finished-but-unread output row to the host (one
        device sync for the whole batch).  Public for router/driver use;
        run() calls it at drain."""
        self._harvest()

    def take_finished(self) -> List[Request]:
        """Harvest, then hand over (and clear) the finished-request list.
        The sharded router merges these across shards; run() is the
        single-engine wrapper around the same drain."""
        self._harvest()
        done, self._finished = self._finished, []
        return done

    def _harvest(self) -> None:
        if not self._pending_harvest:
            return
        out = np.asarray(self._state["out"])    # one sync for the batch
        now = time.perf_counter()
        reg = obs.registry()
        for slot, req in self._pending_harvest:
            req.tokens = out[slot, : req.max_tokens].copy()
            req.t_retire = now
            self._req_metrics.append(
                {"ttft_s": req.ttft_s, "tpot_s": req.tpot_s,
                 "e2e_s": req.e2e_s}
            )
            if reg.enabled:
                self._emit_request_spans(reg, req, slot)
        self._pending_harvest.clear()

    def _emit_request_spans(self, reg, req: Request, slot: int) -> None:
        """The submit→retire span chain, reconstructed from the stamps
        taken at sync points (one parent span + the four lifecycle
        phases), plus the TTFT/TPOT/e2e histograms."""
        parent = reg.record_span(
            "request", req.t_submit, req.t_retire, rid=req.rid, slot=slot,
            bucket=req.bucket, prompt_len=int(req.prompt.size),
            max_tokens=req.max_tokens, ttft_s=req.ttft_s, tpot_s=req.tpot_s,
        )
        reg.record_span(
            "request/queue", req.t_submit, req.t_admit,
            parent=parent, rid=req.rid,
        )
        reg.record_span(
            "request/prefill", req.t_admit, req.t_first_token,
            parent=parent, rid=req.rid, bucket=req.bucket,
        )
        reg.record_span(
            "request/decode", req.t_first_token, req.t_done,
            parent=parent, rid=req.rid, tokens=req.max_tokens,
        )
        reg.record_span(
            "request/retire", req.t_done, req.t_retire,
            parent=parent, rid=req.rid,
        )
        reg.histogram("serve.ttft_s").observe(req.ttft_s)
        reg.histogram("serve.tpot_s").observe(req.tpot_s)
        reg.histogram("serve.e2e_s").observe(req.e2e_s)
        reg.counter("serve.requests_retired").inc()
        reg.counter("serve.tokens_generated").inc(req.max_tokens)

    def _admit(self, params) -> None:
        # FIFO admission (no scheduler): strict arrival order, so a head
        # that does not fit blocks everyone behind it — progress is
        # guaranteed by retirements, and step() converts a permanent stall
        # into PoolExhausted after the wait budget.
        while self._queue and self.try_admit(params, self._queue[0]):
            self._queue.popleft()

    def try_admit(self, params, req: Request) -> bool:
        """Admit ONE request into a free slot if resources allow; returns
        False (no side effects) when there is no free slot or — paged —
        not enough free blocks.  The scheduler's tick() probes candidates
        in ITS order through this; FIFO _admit() probes only the head."""
        p = self.pool
        # A router-fronted shard sees try_admit before any step(): make
        # sure the pool exists, and dispatch against this shard's own
        # parameter copy (no-ops for the default single-device engine).
        self._ensure(params)
        params = self._params_for(params)
        if not self._free:
            return False
        need = 0
        if p.paged:
            # Pool-exhaustion gate BEFORE committing to the admission: a
            # full pool refuses (live slots never lose blocks here;
            # retirements — or the scheduler's preemptions — free some)
            # instead of partially admitting or stealing from a live slot.
            need = self.blocks_needed(req.prompt.size, req.max_tokens)
            if need > len(self._free_blocks):
                return False
        if self._pending_harvest:
            # A freed slot's output row is about to be zeroed: read the
            # finished requests first (one host sync for all of them).
            self._harvest()
        slot = self._free.pop()
        bucket = self.bucket_for(req.prompt.size)
        fn = self._prefill_for(params, bucket)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, : req.prompt.size] = req.prompt
        req.bucket = bucket
        extra = ()
        if p.paged:
            blocks = [self._free_blocks.pop() for _ in range(need)]
            self._slot_blocks[slot] = blocks
            bt_row = np.zeros((p.blocks_per_slot,), np.int32)
            bt_row[: len(blocks)] = blocks
            extra = (jnp.asarray(bt_row),)
        # Admission is the scheduling decision, so stamp it BEFORE the
        # prefill dispatch — the old after-dispatch stamp folded the
        # prefill into the "queue wait" phase and made TTFT's prefill
        # component unmeasurable.
        req.t_admit = time.perf_counter()
        self._state = fn(
            params, self._state, jnp.asarray(padded),
            jnp.asarray(req.prompt.size, jnp.int32),
            jnp.asarray(slot, jnp.int32),
            jnp.asarray(req.max_tokens, jnp.int32),
            req.key,
            *extra,
        )
        self._slot_req[slot] = req
        self._remaining[slot] = req.max_tokens
        req.state = "running"
        if p.paged:
            nb = min(
                cache_lib.blocks_for(bucket, p.block_size),
                p.blocks_per_slot,
            )
            self.blocks_written += nb
            used = sum(len(b) for b in self._slot_blocks)
            self.peak_blocks_used = max(self.peak_blocks_used, used)
            obs.registry().counter("serve.blocks_written").inc(nb)
            self._publish_pool_gauges()
        if obs.registry().enabled:
            # Honest TTFT: the first token is computed by the prefill
            # program, so block on it before stamping.  Only with obs
            # on — the disabled path keeps the async pipeline and the
            # stamp is a dispatch-time lower bound.
            jax.block_until_ready(self._state["token"])  # noqa: RPA005 — sanctioned sync point (honest TTFT, obs-on only)
        req.t_first_token = time.perf_counter()
        return True

    def _deaden_slot(self, slot: int) -> None:
        """Zero a slot's generation budget on device: the decode step's
        live mask (``n_gen < budget``) stops its scalar updates, and — in
        paged mode — routes its cache writes to the trash block.  The slot
        index is a device scalar so ONE cached scatter serves every slot
        (warmed at state init; preemption never builds a program)."""
        self._state["budget"] = (
            self._state["budget"].at[jnp.asarray(slot, jnp.int32)].set(0)
        )

    def preempt_slot(self, slot: int) -> Request:
        """Evict the slot's in-flight request (scheduler preemption):
        recompute-on-resume, vLLM-style.  Deaden the slot on device FIRST
        — once its blocks return to the allocator they can be handed to
        the very next admission, and a still-live slot would keep writing
        through its stale block table into them.  Then release the host
        mirrors; re-admission replays the request from scratch under the
        same key, so the resumed run is greedy token-identical to an
        uninterrupted one."""
        req = self._slot_req[slot]
        assert req is not None, f"slot {slot} has no in-flight request"
        self._deaden_slot(slot)
        self._slot_req[slot] = None
        self._remaining[slot] = 0
        self._free.append(slot)
        if self.pool.paged:
            self._free_blocks.extend(reversed(self._slot_blocks[slot]))
            self._slot_blocks[slot] = []
            self._publish_pool_gauges()
        req.state = "queued"
        req.n_preempts += 1
        obs.registry().counter("serve.preemptions").inc()
        return req

    def _pool_fragmentation(self) -> float:
        """Internal fragmentation of the live reservations: 1 − (rows
        holding real tokens) / (rows reserved), over live slots.  0.0 with
        nothing live."""
        bs = self.pool.block_size
        reserved = valid = 0
        for slot, req in enumerate(self._slot_req):
            if req is None:
                continue
            nres = len(self._slot_blocks[slot]) * bs
            done_toks = req.max_tokens - self._remaining[slot]
            valid += min(int(req.prompt.size) + done_toks, nres)
            reserved += nres
        if reserved == 0:
            return 0.0
        return 1.0 - valid / reserved

    def _publish_pool_gauges(self) -> None:
        """Paged-pool occupancy gauges, set at the existing host sync
        points (admission / retirement — pure host-mirror reads, no extra
        device sync)."""
        reg = obs.registry()
        reg.gauge("serve.pool_blocks_total").set(
            float(self.pool.total_blocks - 1)
        )
        reg.gauge("serve.pool_blocks_used").set(
            float(sum(len(b) for b in self._slot_blocks))
        )
        reg.gauge("serve.pool_fragmentation").set(self._pool_fragmentation())

    def _decode_once(self, params) -> None:
        self.active_per_step.append(self.active)
        params = self._params_for(params)
        self._state = self._decode_fn(params, self._state)
        self.steps += 1
        completed = []
        for slot, req in enumerate(self._slot_req):
            if req is None:
                continue
            self.busy_slot_steps += 1
            self.tokens_generated += 1
            self._remaining[slot] -= 1
            if self._remaining[slot] == 0:
                completed.append((slot, req))
                self._slot_req[slot] = None
                self._free.append(slot)
                if self.pool.paged:
                    # LIFO free: the retired request's blocks go back in
                    # reverse so the next admission reuses them first.
                    self._free_blocks.extend(
                        reversed(self._slot_blocks[slot])
                    )
                    self._slot_blocks[slot] = []
        if completed and self.pool.paged:
            self._publish_pool_gauges()
        if completed:
            # Block before stamping t_done: dispatch is async, so a
            # dispatch-time stamp would under-report completion latency
            # whenever execution lags the host (the sync only happens on
            # completion steps, so steady-state steps still pipeline).
            jax.block_until_ready(self._state["out"])  # noqa: RPA005 — sanctioned sync point (completion steps only; steady steps pipeline)
            now = time.perf_counter()
            for slot, req in completed:
                req.t_done = now
                req.state = "completed"
                self._pending_harvest.append((slot, req))
                self._finished.append(req)
                sched = self.scheduler or self.completion_sink
                if sched is not None:
                    # Deadline-hit accounting rides the sanctioned
                    # completion sync above — no extra device read.
                    sched.on_complete(self, req)

    def step(self, params) -> None:
        """One engine tick: admit (scheduler tick when one is attached,
        FIFO otherwise), then run one fused decode step over the pool (if
        anything is live).  Unscheduled no-progress stalls are bounded by
        ``PoolConfig.exhaust_wait_steps`` → ``PoolExhausted``."""
        self._ensure(params)
        if self.scheduler is not None:
            self.scheduler.tick(self, params)
        else:
            self._admit(params)
        if self.active:
            self._stalled_steps = 0
            self._decode_once(params)
        elif self.scheduler is None and self._queue:
            self._stalled_steps += 1
            if self._stalled_steps > self.pool.exhaust_wait_steps:
                waited, self._stalled_steps = self._stalled_steps, 0
                head = self._queue[0]
                raise PoolExhausted(
                    waited_steps=waited,
                    queued=len(self._queue),
                    free_slots=len(self._free),
                    free_blocks=len(self._free_blocks),
                    need_blocks=self.blocks_needed(
                        head.prompt.size, head.max_tokens
                    ) if self.pool.paged else 0,
                )
        else:
            self._stalled_steps = 0

    def run(self, params) -> List[Request]:
        """Drive until the queue and the pool are empty; returns every
        request finished since the last run (harvested, ``tokens`` filled).
        With a scheduler attached, also drains its ready/retry queues —
        requests it expires or rejects resolve terminally without tokens
        (check ``req.state``).  NOTE: a scheduler on a ``VirtualClock``
        must be driven by step()+advance() instead; run() never advances
        virtual time, so future retry deadlines would spin forever."""
        reg = obs.registry()
        with reg.span("engine.run", queued=len(self._queue)):
            self._ensure(params)
            while self._queue or self.active or (
                self.scheduler is not None and self.scheduler.pending
            ):
                self.step(params)
            done = self.take_finished()
        if reg.enabled:
            self.publish_device_counters(reg)
        return done

    def device_counters(self) -> Dict[str, float]:
        """The on-device ``obs.DeviceCounters`` pytree as host floats plus
        the derived realized drop rate.  One sync — call at run/epoch
        boundaries, not per step."""
        if self._state is None:
            host = {k: 0.0 for k in obs_device.COUNTER_KEYS}
            host["realized_drop_rate"] = 0.0
            return host
        return obs_device.counters_to_host(self._state["obs"])

    def publish_device_counters(self, reg=None) -> Dict[str, float]:
        """Harvest the device counters into registry gauges."""
        reg = reg or obs.registry()
        host = self.device_counters()
        for k, v in host.items():
            reg.gauge(f"serve.device.{k}").set(v)
        return host

    def request_stats(self) -> Dict[str, float]:
        """Per-request latency summaries (TTFT / TPOT / e2e, exact
        percentiles) over the retained request window."""
        from repro.obs import stats as obs_stats

        out: Dict[str, float] = {"requests": float(len(self._req_metrics))}
        for field in ("ttft_s", "tpot_s", "e2e_s"):
            s = obs_stats.latency_summary(
                [m[field] for m in self._req_metrics]
            )
            for k, v in s.items():
                out[f"{field[:-2]}_{k}"] = v
        return out

    def stats(self) -> Dict[str, float]:
        active = sorted(self.active_per_step)
        out = {
            "compiles": self.compiles,
            "traces": self.traces,
            "compile_s": self.compile_s,
            "num_buckets": self.num_buckets,
            "steps": self.steps,
            "tokens_generated": self.tokens_generated,
            "slot_occupancy": self.busy_slot_steps
            / max(1, self.steps * self.pool.max_slots),
            # Sustained concurrency: the in-flight request count per decode
            # step — median is the bench's density metric (robust to the
            # ramp-up/drain tails of a saturated run).
            "active_median": float(active[len(active) // 2]) if active else 0.0,
            "active_peak": float(active[-1]) if active else 0.0,
            "active_mean": float(sum(active)) / len(active) if active else 0.0,
            **self.request_stats(),
        }
        if self.pool.paged:
            out.update(
                pool_blocks_total=float(self.pool.total_blocks - 1),
                peak_blocks_used=float(self.peak_blocks_used),
                blocks_written=float(self.blocks_written),
            )
        return out

    # -- one-shot batch API (launch.serve.generate rides this) -------------

    def generate_batch(
        self,
        params,
        prompts,                  # (B, S) int32
        num_tokens: int,
        *,
        key: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, Dict[str, float]]:
        """Serve a same-length batch as B independent requests with keys
        ``fold_in(key, i)``.  Per request, greedy output is token-identical
        to ``generate_reference(prompts[i:i+1], key=fold_in(key, i))`` —
        each request is its own DI stream, which is the multi-client
        semantics (the whole-generation engine instead draws one joint
        link mask across the batch)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        prompts = np.asarray(prompts, np.int32)
        b = prompts.shape[0]
        compiles_before, compile_s_before = self.compiles, self.compile_s
        reqs = [
            self.submit(prompts[i], num_tokens, key=jax.random.fold_in(key, i))
            for i in range(b)
        ]
        t0 = time.perf_counter()
        self.run(params)
        t_total = time.perf_counter() - t0
        compile_s = self.compile_s - compile_s_before
        exec_s = max(t_total - compile_s, 1e-9)
        tokens = jnp.asarray(np.stack([r.tokens for r in reqs]))
        timings = {
            "generate_s": exec_s,
            "decode_s_per_token": exec_s / max(1, num_tokens),
            "tokens_per_s": (b * num_tokens) / exec_s,
            "traces": float(self.traces),
            "compiles": float(self.compiles),
            "compile_s": compile_s,
            "compiled_this_call": float(self.compiles > compiles_before),
            "slot_occupancy": self.stats()["slot_occupancy"],
        }
        return tokens, timings


# ---------------------------------------------------------------------------
# Process-wide engine registry (mirrors serve.default_engine)
# ---------------------------------------------------------------------------

_ENGINES: Dict[Tuple, ContinuousEngine] = {}
_MAX_ENGINES = 4      # each engine retains a device slot pool; bound the set


def pool_engine(cfg: ModelConfig, pool: Optional[PoolConfig] = None) -> ContinuousEngine:
    """Engine per (cfg, pool) — the slot pool and its compiled programs
    survive across callers, which is the whole point.  The registry is a
    small LRU: every distinct cfg (each loss-rate/channel override bakes a
    new one) holds a full device slot pool, so e.g. a loss-rate sweep must
    not accumulate pools without bound.  An evicted engine keeps working
    for anyone still holding it; it just stops being shared."""
    pool = pool or PoolConfig()
    k = (cfg, pool)
    if k in _ENGINES:
        _ENGINES[k] = _ENGINES.pop(k)          # refresh LRU position
        return _ENGINES[k]
    while len(_ENGINES) >= _MAX_ENGINES:
        _ENGINES.pop(next(iter(_ENGINES)))
    _ENGINES[k] = ContinuousEngine(cfg, pool)
    return _ENGINES[k]


def engine_for(
    cfg: ModelConfig, prompt_len: int, num_tokens: int
) -> ContinuousEngine:
    """Engine whose pool covers (prompt_len, num_tokens), with both
    dimensions rounded to powers of two so repeated one-shot ``generate()``
    calls with nearby signatures coalesce onto one pool."""
    pool = PoolConfig(
        max_prompt=pow2_bucket(prompt_len),
        max_new=pow2_bucket(num_tokens, 16),
    )
    return pool_engine(cfg, pool)


def clear_engines() -> None:
    _ENGINES.clear()


# ---------------------------------------------------------------------------
# Simulator bridge: serve a sim batch through the live engine
# ---------------------------------------------------------------------------

def make_sim_server(
    engine: ContinuousEngine,
    params,
    *,
    prompt_lens: Sequence[int] = (8, 16, 32),
    num_tokens: int = 8,
    seed: int = 0,
    chaos=None,
    sla_for=None,
):
    """Adapter for ``net.simulator.run_sim(engine=...)``: maps each sim
    request (by rid, deterministically) to a synthetic prompt whose length
    cycles through ``prompt_lens`` (>= 3 buckets by default), serves the
    batch through the live engine, and returns the measured wall seconds —
    so the simulator's reported p50/p99 include real compute *and* real
    compile behavior (the first batch hitting a new bucket pays its AOT
    build, steady state pays none).

    ``chaos`` (a ``net.chaos.ChaosSchedule``) applies pool-level faults —
    the block squeeze — to the live engine at each batch's simulated start
    time (the simulator passes ``now=`` because ``serve_batch`` declares
    it).  ``sla_for`` maps a sim rid to an ``SLA`` when the engine has a
    scheduler attached (None = best-effort)."""
    vocab = engine.cfg.vocab_size
    base = jax.random.PRNGKey(seed)
    echaos = None
    if chaos:
        from repro.net.chaos import EngineChaos

        echaos = EngineChaos(engine, chaos)

    def serve_batch(reqs, now: float = 0.0) -> float:
        if echaos is not None:
            echaos.apply(now)
        t0 = time.perf_counter()
        for r in reqs:
            rid = int(r.rid)
            length = int(prompt_lens[rid % len(prompt_lens)])
            prompt = np.random.RandomState(seed + rid).randint(
                0, vocab, size=(length,)
            ).astype(np.int32)
            engine.submit(
                prompt, num_tokens, key=jax.random.fold_in(base, rid),
                sla=sla_for(rid) if sla_for is not None else None,
            )
        engine.run(params)
        return time.perf_counter() - t0

    return serve_batch
