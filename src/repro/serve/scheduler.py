"""SLA-aware scheduling over the ContinuousEngine slot/block pool.

The paper's setting is deadline-bounded inference over unreliable links;
until now the engine served every request best-effort FIFO, and a full
block pool head-of-line blocked admission indefinitely.  This module puts
a scheduler in front of the pool:

* **SLA classes** — each request may carry ``SLA(deadline_s, priority,
  class_name)``.  Higher ``priority`` wins; within a priority the earliest
  absolute deadline goes first (EDF-within-priority).
* **Preemption by recompute** — when a high-priority request cannot be
  admitted (no slot / not enough KV blocks), the scheduler evicts
  lower-priority in-flight slots: the victim's host-side record (rid,
  prompt, key, budget) is frozen, its slot is deadened on device and its
  blocks returned to the allocator, and it re-enters the ready queue to be
  re-admitted later through the normal bucketed-prefill path.  The whole
  keyed computation is deterministic in the request key, so a resumed run
  is greedy token-identical to an uninterrupted one (regression-tested
  under iid + GE + int8 + windowed wrap).  No KV snapshotting, no new
  compiled programs — the engine's ``compiles == num_buckets + 1``
  invariant is untouched.
* **Early expiry** — a queued request that can no longer meet its deadline
  (deadline already passed, the per-token service-time EMA says the decode
  cannot fit, or a pluggable ``feasibility`` oracle — e.g.
  ``protocol_feasibility`` over the analytic latency PMFs — returns a
  probability at or below ``feasibility_floor``) is terminally ``expired``
  instead of burning decode steps.
* **Bounded retry with backoff** — a request that cannot be admitted and
  cannot preempt re-queues with exponential backoff; after ``max_retries``
  attempts it is terminally ``rejected`` (admission control: shed load
  instead of letting the queue grow without bound).

The scheduler is a pure **host** layer: it reads the engine's host
mirrors through the public API (``try_admit`` / ``preempt_slot`` /
``running_slots`` / ``free_block_count``) and never touches device state
or forces a sync — RPA007 (``repro.analysis``) enforces this statically.
Because that surface is all it probes, the sharded router
(``repro.serve.router.ShardedEngine``) fronts it unchanged: ``tick()``
sees one logical pool with globally-numbered slots, preemption forwards
to the owning shard, and a preempted request may resume on a different
shard (token-identical — the keyed math is placement-invariant).
All obs counters/gauges (``sched.preemptions``, ``sched.expired``,
``sched.resumes``, per-class ``sched.deadline_hit_rate.*``) are stamped
at the engine's existing sync points, so the zero-steady-state-recompile
and compile-count contracts hold with scheduler + chaos + obs all enabled.

Time is pluggable: ``clock`` is any zero-arg callable.  The default is
``time.perf_counter``; benchmarks and CI use a ``VirtualClock`` advanced
deterministically by the workload driver (one fixed ``dt`` per engine
step), which makes deadline-hit-rate gating reproducible.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro import obs


@dataclasses.dataclass(frozen=True)
class SLA:
    """Per-request service-level agreement.

    ``deadline_s`` is relative to submission (``math.inf`` = best effort);
    ``priority`` is an integer, larger wins; ``class_name`` buckets the
    per-class deadline-hit accounting ("interactive" / "batch" / ...).
    """

    deadline_s: float = math.inf
    priority: int = 0
    class_name: str = "default"


class VirtualClock:
    """Deterministic clock for virtual-time scheduling runs: the driver
    advances it explicitly (e.g. a fixed dt per engine step)."""

    def __init__(self, t0: float = 0.0):
        self.now = float(t0)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        self.now += float(dt)
        return self.now


def protocol_feasibility(
    protocol, n_packets: int, channel_cfg, loss_rate=None,
) -> Callable[[object, float], float]:
    """Uplink-aware feasibility oracle for ``SLAScheduler(feasibility=...)``.

    Maps ``(request, remaining_s) -> P(the uplink could deliver the full
    message within the remaining deadline budget)`` via
    ``net.protocol.deadline_feasible``.  ``loss_rate`` may be a float or a
    zero-arg callable (e.g. chaos-schedule-driven, so a channel collapse
    makes queued requests exactly infeasible and the scheduler sheds them
    early instead of burning pool space on doomed work).
    """
    from repro.net.protocol import deadline_feasible

    def fn(req, remaining_s: float) -> float:
        p = loss_rate() if callable(loss_rate) else loss_rate
        return deadline_feasible(
            protocol, n_packets, channel_cfg, remaining_s, loss_rate=p
        )

    return fn


_TERMINAL = ("completed", "expired", "rejected")


class SLAScheduler:
    """EDF-within-priority admission with preemption, expiry, and bounded
    retry over one ``ContinuousEngine``.  Attach with
    ``engine.attach_scheduler(sched)``; the engine then routes
    ``submit()`` into the scheduler's ready queue and calls ``tick()``
    once per step in place of FIFO admission.
    """

    def __init__(
        self,
        *,
        clock: Optional[Callable[[], float]] = None,
        preemption: bool = True,
        max_retries: int = 32,
        backoff_s: float = 0.05,
        backoff_mult: float = 2.0,
        backoff_cap_s: float = 2.0,
        feasibility: Optional[Callable[[object, float], float]] = None,
        feasibility_floor: float = 0.0,
        ema_alpha: float = 0.3,
    ):
        self.clock = clock or time.perf_counter
        self.preemption = preemption
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.backoff_mult = backoff_mult
        self.backoff_cap_s = backoff_cap_s
        self.feasibility = feasibility
        self.feasibility_floor = feasibility_floor
        self.ema_alpha = ema_alpha
        self._ready: List = []
        self._retry: List[Tuple[float, int, object]] = []   # heap
        self._seq = itertools.count()
        self._admit_t: Dict[int, float] = {}
        self._tpot_ema = 0.0          # clock-units per generated token
        self.stats: Dict[str, int] = {
            "submitted": 0, "admitted": 0, "completed": 0,
            "preemptions": 0, "resumes": 0, "expired": 0,
            "rejected": 0, "retries": 0,
        }
        self._classes: Dict[str, Dict[str, int]] = {}

    # -- request intake ----------------------------------------------------

    def enqueue(self, req) -> None:
        """Called by ``engine.submit``: stamp the absolute deadline on the
        scheduler clock, shed immediately-hopeless requests, queue the
        rest for the next tick."""
        now = self.clock()
        sla = req.sla or SLA()
        req.t_deadline = now + sla.deadline_s
        self.stats["submitted"] += 1
        self._cls(req)["submitted"] += 1
        if self._hopeless(req, now):
            self._expire(req, now)
            return
        self._ready.append(req)

    @property
    def pending(self) -> bool:
        return bool(self._ready or self._retry)

    @property
    def queue_depth(self) -> int:
        return len(self._ready) + len(self._retry)

    # -- the admission tick ------------------------------------------------

    def tick(self, engine, params) -> None:
        """One admission pass: requeue due retries, expire the hopeless,
        admit EDF-within-priority, preempting lower-priority slots when
        that makes an admission possible, backing off the rest."""
        now = self.clock()
        while self._retry and self._retry[0][0] <= now:
            _, _, req = heapq.heappop(self._retry)
            self._ready.append(req)
        if not self._ready:
            return
        ready = sorted(self._ready, key=self._order)
        # Preemption victims land back in self._ready during the loop and
        # wait for the next tick (their resources just went to the
        # preemptor — re-admitting them now would thrash).
        self._ready = []
        for req in ready:
            if self._hopeless(req, now):
                self._expire(req, now)
                continue
            if engine.try_admit(params, req):
                self._note_admit(req, now)
                continue
            if (
                self.preemption
                and self._preempt_for(engine, req)
                and engine.try_admit(params, req)
            ):
                self._note_admit(req, now)
                continue
            # Resource-blocked and not worth a preemption: retry later.
            # The loop continues — a smaller or lower-priority request
            # behind this one may still fit (no head-of-line blocking).
            self._backoff(req, now)

    def on_complete(self, engine, req) -> None:
        """Called by the engine at its completion sync point (after the
        sanctioned ``block_until_ready``): deadline-hit accounting and the
        service-time EMA the early-expiry estimate uses."""
        now = self.clock()
        t_admit = self._admit_t.pop(req.rid, None)
        if t_admit is not None:
            per_tok = max(now - t_admit, 0.0) / max(1, req.max_tokens)
            self._tpot_ema = (
                per_tok if self._tpot_ema == 0.0
                else (1.0 - self.ema_alpha) * self._tpot_ema
                + self.ema_alpha * per_tok
            )
        self.stats["completed"] += 1
        cls = self._cls(req)
        cls["completed"] += 1
        hit = now <= req.t_deadline
        if hit:
            cls["hits"] += 1
        reg = obs.registry()
        if reg.enabled:
            name = self._class_name(req)
            reg.counter("sched.completed").inc()
            if req.t_deadline != math.inf:
                reg.histogram(f"sched.deadline_slack_s.{name}").observe(
                    req.t_deadline - now
                )
            reg.gauge(f"sched.deadline_hit_rate.{name}").set(
                self._hit_rate(cls)
            )

    # -- reports -----------------------------------------------------------

    def class_report(self) -> Dict[str, Dict[str, float]]:
        """Per-class terminal accounting: ``deadline_hit_rate`` counts a
        hit only for on-time completions, over ALL terminally-resolved
        requests of the class (expired/rejected count as misses)."""
        out: Dict[str, Dict[str, float]] = {}
        for name, c in self._classes.items():
            row = dict(c)
            row["terminal"] = (
                c["completed"] + c["expired"] + c["rejected"]
            )
            row["deadline_hit_rate"] = self._hit_rate(c)
            out[name] = row
        return out

    # -- internals ---------------------------------------------------------

    @staticmethod
    def _sla(req) -> SLA:
        return req.sla or SLA()

    def _class_name(self, req) -> str:
        return self._sla(req).class_name

    def _cls(self, req) -> Dict[str, int]:
        name = self._class_name(req)
        if name not in self._classes:
            self._classes[name] = {
                "submitted": 0, "completed": 0, "hits": 0,
                "expired": 0, "rejected": 0, "preempted": 0,
            }
        return self._classes[name]

    @staticmethod
    def _hit_rate(c: Dict[str, int]) -> float:
        term = c["completed"] + c["expired"] + c["rejected"]
        return c["hits"] / term if term else 0.0

    def _order(self, req):
        return (-self._sla(req).priority, req.t_deadline, req.rid)

    def _hopeless(self, req, now: float) -> bool:
        if req.t_deadline == math.inf:
            return False
        remaining = req.t_deadline - now
        if remaining <= 0.0:
            return True
        if self._tpot_ema > 0.0 and \
                self._tpot_ema * req.max_tokens > remaining:
            return True
        if self.feasibility is not None and \
                self.feasibility(req, remaining) <= self.feasibility_floor:
            return True
        return False

    def _note_admit(self, req, now: float) -> None:
        self._admit_t[req.rid] = now
        self.stats["admitted"] += 1
        if req.n_preempts > 0:
            self.stats["resumes"] += 1
            obs.registry().counter("sched.resumes").inc()

    def _preempt_for(self, engine, req) -> bool:
        """Evict enough strictly-lower-priority running slots to make
        ``req`` admissible.  All-or-nothing: if even preempting every
        eligible victim could not free enough, nothing is evicted."""
        pool = engine.pool
        pri = self._sla(req).priority
        victims = [
            (slot, vr) for slot, vr in engine.running_slots()
            if self._sla(vr).priority < pri
        ]
        if not victims:
            return False
        need_blocks = (
            engine.blocks_needed(req.prompt.size, req.max_tokens)
            if pool.paged else 0
        )
        have_slot = engine.free_slot_count > 0
        have_blocks = engine.free_block_count() if pool.paged else 0

        def satisfied() -> bool:
            return have_slot and (
                not pool.paged or have_blocks >= need_blocks
            )

        if pool.paged:
            attainable = have_blocks + sum(
                engine.blocks_held(s) for s, _ in victims
            )
            if attainable < need_blocks:
                return False
        # Cheapest victims first: lowest priority, and within a priority
        # the latest deadline (best-effort requests before tight ones).
        victims.sort(
            key=lambda sv: (self._sla(sv[1]).priority, -sv[1].t_deadline)
        )
        took = False
        for slot, vr in victims:
            if satisfied():
                break
            if pool.paged:
                have_blocks += engine.blocks_held(slot)
            engine.preempt_slot(slot)
            have_slot = True
            took = True
            self._ready.append(vr)
            self.stats["preemptions"] += 1
            self._cls(vr)["preempted"] += 1
            obs.registry().counter("sched.preemptions").inc()
        return took

    def _backoff(self, req, now: float) -> None:
        req.retries += 1
        self.stats["retries"] += 1
        if req.retries > self.max_retries:
            self._reject(req, now)
            return
        delay = min(
            self.backoff_s * self.backoff_mult ** (req.retries - 1),
            self.backoff_cap_s,
        )
        heapq.heappush(self._retry, (now + delay, next(self._seq), req))

    def _expire(self, req, now: float) -> None:
        req.state = "expired"
        self.stats["expired"] += 1
        self._cls(req)["expired"] += 1
        reg = obs.registry()
        reg.counter("sched.expired").inc()
        if reg.enabled and req.t_deadline != math.inf:
            name = self._class_name(req)
            reg.histogram(f"sched.deadline_slack_s.{name}").observe(
                req.t_deadline - now
            )
            reg.gauge(f"sched.deadline_hit_rate.{name}").set(
                self._hit_rate(self._cls(req))
            )

    def _reject(self, req, now: float) -> None:
        req.state = "rejected"
        self.stats["rejected"] += 1
        self._cls(req)["rejected"] += 1
        reg = obs.registry()
        reg.counter("sched.rejected").inc()
        if reg.enabled:
            reg.gauge(
                f"sched.deadline_hit_rate.{self._class_name(req)}"
            ).set(self._hit_rate(self._cls(req)))
