"""Learning-rate schedules (multipliers on the base lr)."""

from __future__ import annotations

import jax.numpy as jnp


def constant():
    return lambda step: jnp.ones((), jnp.float32)


def warmup_cosine(warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(1.0, warmup_steps)
        prog = jnp.clip(
            (s - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps), 0.0, 1.0
        )
        cos = final_frac + (1.0 - final_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup_steps, warm, cos)

    return fn


def warmup_linear(warmup_steps: int, total_steps: int):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(1.0, warmup_steps)
        decay = jnp.clip(
            1.0 - (s - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps),
            0.0,
            1.0,
        )
        return jnp.where(s < warmup_steps, warm, decay)

    return fn
