"""Adam / AdamW in pure JAX (pytree-native, shard-transparent).

Optimizer state has the same pytree structure (and, under pjit, the same
sharding) as the parameters — ZeRO-1 falls out of GSPMD for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0          # AdamW when > 0
    grad_clip_norm: float = 0.0        # 0 = off
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None
    state_dtype: str = "float32"       # "bfloat16" halves optimizer memory


def init_adam(params, cfg: AdamConfig) -> AdamState:
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


def adam_update(
    grads, params, state: AdamState, cfg: AdamConfig
) -> Tuple[Any, AdamState, jax.Array]:
    """Returns (new_params, new_state, grad_norm)."""
    if cfg.grad_clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = cfg.lr if cfg.schedule is None else cfg.lr * cfg.schedule(step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, p, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_p = treedef.flatten_up_to(params)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, p, m, v) for g, p, m, v in zip(flat_g, flat_p, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamState(step=step, mu=new_m, nu=new_v), gnorm
