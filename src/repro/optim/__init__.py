from repro.optim.adam import (  # noqa: F401
    AdamConfig,
    AdamState,
    adam_update,
    clip_by_global_norm,
    global_norm,
    init_adam,
)
from repro.optim import schedule  # noqa: F401
