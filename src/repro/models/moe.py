"""Top-k Mixture-of-Experts FFN with sort-based capacity dispatch.

Production formulation (not the dense all-experts trick):

1. router logits -> top-k experts per token, renormalized softmax gates;
2. the (tokens × k) assignments are sorted by expert id and each expert
   takes its first ``capacity`` tokens (position-in-expert via a stable
   sort + per-expert cumulative count) — overflow tokens are dropped,
   exactly like capacity-factor routing in Switch/GShard/Mesh;
3. tokens are gathered into an (E, C, d) buffer, experts run as a single
   batched einsum (E-sharded over the "model" mesh axis = expert
   parallelism; GSPMD inserts the all-to-alls), results scatter-add back
   with gate weights.

Variants required by the assigned archs:
* shared experts (Kimi-K2): dense FFN(s) of the expert width applied to all
  tokens, added to the routed output;
* dense residual (Arctic): a full dense FFN in parallel with the MoE.

Load-balance auxiliary loss (Switch-style): E · Σ_e f_e · P_e.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Params, activation, dense_init, split_keys
from repro.models.mlp import init_mlp, mlp_forward

# Version shim: jax.shard_map(check_vma=) is the current API; older
# releases spell it jax.shard_map(check_rep=) or live under
# jax.experimental.shard_map.  Gate on the actual signature, not presence.
if hasattr(jax, "shard_map"):
    import inspect

    _SM_KW = (
        "check_vma"
        if "check_vma" in inspect.signature(jax.shard_map).parameters
        else "check_rep"
    )

    def _shard_map(fn, mesh, in_specs, out_specs):
        return jax.shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            **{_SM_KW: False},
        )
else:                                             # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def _shard_map(fn, mesh, in_specs, out_specs):
        return _exp_shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_dff or cfg.d_ff
    ks = split_keys(key, 6)
    p = {
        "router": dense_init(ks[0], (d, e), dtype, scale=0.1),
        "w_up": dense_init(ks[1], (e, d, f), dtype),
        "w_gate": dense_init(ks[2], (e, d, f), dtype),
        "w_down": dense_init(ks[3], (e, f, d), dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(
            ks[4], d, f * cfg.num_shared_experts, cfg.gated_mlp, dtype
        )
    if cfg.dense_residual_dff:
        p["dense_residual"] = init_mlp(
            ks[5], d, cfg.dense_residual_dff, cfg.gated_mlp, dtype
        )
    return p


def _capacity(num_tokens: int, cfg: ModelConfig) -> int:
    cap = int(num_tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(cfg.top_k, cap)


def moe_forward(
    p: Params, x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    Dispatches to the shard_map expert-parallel formulation when a mesh
    context is active (launch/steps.py) and the expert count divides the
    'model' axis; otherwise runs the single-device/GSPMD formulation below.
    """
    from repro.sharding import ctx as shard_ctx

    mesh = shard_ctx.shard_map_mesh()
    if (
        mesh is not None
        and "model" in mesh.axis_names
        and cfg.num_experts % mesh.shape["model"] == 0
    ):
        return moe_forward_shard_map(p, x, cfg, mesh)
    return moe_forward_dense(p, x, cfg)


def moe_forward_dense(
    p: Params, x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """Single-program formulation (scatter/gather dispatch).  Under GSPMD
    the computed-index scatter partitions catastrophically (measured: ~60 GB
    full-payload all-reduces per MoE layer on arctic x train_4k — see
    EXPERIMENTS.md §Perf hillclimb 1); production meshes use
    moe_forward_shard_map instead."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)

    # --- routing ---
    logits = (xt @ p["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9
    )

    # --- load-balance aux (Switch): E * sum_e f_e * P_e ---
    one_hot_top1 = jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32)
    f_e = one_hot_top1.mean(axis=0)
    p_e = probs.mean(axis=0)
    aux = jnp.float32(e) * jnp.sum(f_e * p_e)

    # --- capacity dispatch via stable sort ---
    cap = _capacity(t, cfg)
    flat_expert = expert_ids.reshape(-1)                     # (T*k,)
    flat_gate = gate_vals.reshape(-1).astype(x.dtype)
    flat_token = jnp.repeat(jnp.arange(t), k)                # source token ids

    order = jnp.argsort(flat_expert, stable=True)            # group by expert
    sorted_expert = flat_expert[order]
    # position within the expert's group
    pos_in_expert = jnp.arange(t * k) - jnp.searchsorted(
        sorted_expert, sorted_expert, side="left"
    )
    keep = pos_in_expert < cap
    slot = sorted_expert * cap + jnp.where(keep, pos_in_expert, 0)
    slot = jnp.where(keep, slot, e * cap)                    # dropped -> scratch

    # gather tokens into (E*C+1, d) buffer (last row = scratch for drops)
    src_tok = flat_token[order]
    buffer = jnp.zeros((e * cap + 1, d), x.dtype)
    buffer = buffer.at[slot].set(
        jnp.where(keep[:, None], xt[src_tok], 0.0), mode="drop"
    )
    expert_in = buffer[: e * cap].reshape(e, cap, d)

    # --- expert compute (E-sharded einsums) ---
    act = activation(cfg.act)
    h = act(jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", expert_in, p["w_up"]
    )
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])   # (E, C, d)

    # --- combine back with gates ---
    out_flat = expert_out.reshape(e * cap, d)
    gathered = jnp.where(
        keep[:, None], out_flat[jnp.minimum(slot, e * cap - 1)], 0.0
    )
    weighted = gathered * flat_gate[order][:, None]
    out = jnp.zeros((t, d), x.dtype).at[src_tok].add(weighted)

    # --- dense side paths ---
    if "shared" in p:
        out = out + mlp_forward(p["shared"], xt, cfg.act, cfg.gated_mlp)
    if "dense_residual" in p:
        out = out + mlp_forward(p["dense_residual"], xt, cfg.act, cfg.gated_mlp)
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Expert-parallel shard_map formulation (production path)
# ---------------------------------------------------------------------------

def moe_forward_shard_map(
    p: Params, x: jax.Array, cfg: ModelConfig, mesh
) -> Tuple[jax.Array, jax.Array]:
    """Explicit expert parallelism: tokens are batch-sharded over the data
    axes and replicated over 'model'; each model rank routes its local
    tokens to the E/m experts it OWNS (dispatch is a purely local
    sort+scatter), runs them, and the per-rank partial outputs are combined
    with ONE psum over 'model' per layer (~|tokens|*d bytes) instead of
    GSPMD's full-payload dispatch all-reduces.  Expert weights arrive via
    shard_map's resharding = the FSDP-style weight gather."""
    from jax.sharding import PartitionSpec as P

    from repro.sharding import ctx as shard_ctx

    data_axes, model_ax = shard_ctx.mesh_axes(mesh)
    b = x.shape[0]
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    batch_axes = data_axes if (data_axes and b % n_data == 0) else ()
    e = cfg.num_experts
    m = mesh.shape[model_ax]
    e_loc = e // m

    # Routed-expert tensors enter the shard_map; shared-expert / dense
    # residual paths stay outside as ordinary GSPMD matmuls (they were never
    # the problem and keeping them out avoids gathering their weights).
    p_routed = {k: p[k] for k in ("router", "w_up", "w_gate", "w_down")}
    p_specs = {
        "router": P(),
        "w_up": P(model_ax, None, None),
        "w_gate": P(model_ax, None, None),
        "w_down": P(model_ax, None, None),
    }
    x_spec = P(batch_axes if batch_axes else None, None, None)

    def local_fn(p_loc, x_loc):
        bl, sl, d = x_loc.shape
        t = bl * sl
        xt = x_loc.reshape(t, d)
        k = cfg.top_k
        rank = jax.lax.axis_index(model_ax)
        first = rank * e_loc

        logits = (xt @ p_loc["router"]).astype(jnp.float32)      # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(axis=-1, keepdims=True), 1e-9
        )

        # aux loss from GLOBAL statistics (pmean over the data axes).
        one_hot_top1 = jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32)
        f_e = one_hot_top1.mean(axis=0)
        p_e = probs.mean(axis=0)
        for a in data_axes:
            f_e = jax.lax.pmean(f_e, a)
            p_e = jax.lax.pmean(p_e, a)
        aux = jnp.float32(e) * jnp.sum(f_e * p_e)

        # ---- local dispatch to OWNED experts only ----
        cap = _capacity(t, cfg)
        flat_expert = expert_ids.reshape(-1)
        flat_gate = gate_vals.reshape(-1).astype(x_loc.dtype)
        flat_token = jnp.repeat(jnp.arange(t), k)
        owned = (flat_expert >= first) & (flat_expert < first + e_loc)
        local_eid = jnp.where(owned, flat_expert - first, e_loc)   # e_loc = trash

        order = jnp.argsort(local_eid, stable=True)
        sorted_eid = local_eid[order]
        pos_in_expert = jnp.arange(t * k) - jnp.searchsorted(
            sorted_eid, sorted_eid, side="left"
        )
        keep = (sorted_eid < e_loc) & (pos_in_expert < cap)
        slot = jnp.where(keep, sorted_eid * cap + pos_in_expert, e_loc * cap)

        src_tok = flat_token[order]
        buffer = jnp.zeros((e_loc * cap + 1, d), x_loc.dtype)
        buffer = buffer.at[slot].set(
            jnp.where(keep[:, None], xt[src_tok], 0.0), mode="drop"
        )
        expert_in = buffer[: e_loc * cap].reshape(e_loc, cap, d)

        act = activation(cfg.act)
        h = act(
            jnp.einsum("ecd,edf->ecf", expert_in, p_loc["w_gate"])
        ) * jnp.einsum("ecd,edf->ecf", expert_in, p_loc["w_up"])
        expert_out = jnp.einsum("ecf,efd->ecd", h, p_loc["w_down"])

        out_flat = expert_out.reshape(e_loc * cap, d)
        gathered = jnp.where(
            keep[:, None], out_flat[jnp.minimum(slot, e_loc * cap - 1)], 0.0
        )
        weighted = gathered * flat_gate[order][:, None]
        out = jnp.zeros((t, d), x_loc.dtype).at[src_tok].add(weighted)

        out = jax.lax.psum(out, model_ax)
        return out.reshape(bl, sl, d), aux

    out, aux = _shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(p_specs, x_spec),
        out_specs=(x_spec, P()),
    )(p_routed, x)

    # dense side paths (plain GSPMD tensor parallelism)
    bsz, sl, d = x.shape
    xt = x.reshape(bsz * sl, d)
    if "shared" in p:
        out = out + mlp_forward(p["shared"], xt, cfg.act, cfg.gated_mlp).reshape(
            bsz, sl, d
        )
    if "dense_residual" in p:
        out = out + mlp_forward(
            p["dense_residual"], xt, cfg.act, cfg.gated_mlp
        ).reshape(bsz, sl, d)
    return out, aux
