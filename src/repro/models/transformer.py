"""Unified decoder stack over heterogeneous layer kinds.

The stack is a repeating ``unit_pattern`` of layers scanned with ``lax.scan``
across ``U`` units (stacked params, leading axis U) plus an unrolled
``prologue``.  The COMtune link layer splits the unit scan in two — the
device-side scan and the server-side scan — so the split point is a
first-class part of the lowered program.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention, mamba, mlp, moe, xlstm
from repro.models.common import Params, apply_norm, init_norm, split_keys


# ---------------------------------------------------------------------------
# Per-layer init / forward
# ---------------------------------------------------------------------------

def _has_ffn(cfg: ModelConfig, spec: LayerSpec) -> bool:
    return spec.moe or cfg.d_ff > 0


def init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype) -> Params:
    ks = split_keys(key, 4)
    p: Params = {"norm1": init_norm(ks[0], cfg.d_model, cfg.norm, dtype)}
    if spec.kind == "attn":
        p["mix"] = attention.init_attention(ks[1], cfg, dtype)
    elif spec.kind == "mamba":
        p["mix"] = mamba.init_mamba(ks[1], cfg, dtype)
    elif spec.kind == "mlstm":
        p["mix"] = xlstm.init_mlstm(ks[1], cfg, dtype)
    elif spec.kind == "slstm":
        p["mix"] = xlstm.init_slstm(ks[1], cfg, dtype)
    else:
        raise ValueError(spec.kind)
    if _has_ffn(cfg, spec):
        p["norm2"] = init_norm(ks[2], cfg.d_model, cfg.norm, dtype)
        if spec.moe:
            p["ffn"] = moe.init_moe(ks[3], cfg, dtype)
        else:
            p["ffn"] = mlp.init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype)
    return p


def layer_forward(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    spec: LayerSpec,
    positions: jax.Array,
    cache: Optional[Params],
    cache_index,
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Pre-norm residual layer. Returns (x, new_cache, aux_loss)."""
    h_in = apply_norm(p["norm1"], x, cfg.norm)
    if spec.kind == "attn":
        h, new_cache = attention.attention_forward(
            p["mix"], h_in, cfg, spec, positions, cache, cache_index
        )
    elif spec.kind == "mamba":
        h, new_cache = mamba.mamba_forward(p["mix"], h_in, cfg, cache)
    elif spec.kind == "mlstm":
        if cache is not None and x.shape[1] == 1:
            h, new_cache = xlstm.mlstm_step(p["mix"], h_in, cfg, cache)
        else:
            # chunkwise-parallel form: O(S*chunk) memory instead of O(S^2)
            # (§Perf hillclimb 2); returns the exact recurrent state.
            h, st = xlstm.mlstm_chunked(p["mix"], h_in, cfg, cache)
            new_cache = st if cache is not None else None
    elif spec.kind == "slstm":
        h, new_cache = xlstm.slstm_forward(p["mix"], h_in, cfg, cache)
    else:
        raise ValueError(spec.kind)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if _has_ffn(cfg, spec):
        y_in = apply_norm(p["norm2"], x, cfg.norm)
        if spec.moe:
            y, aux = moe.moe_forward(p["ffn"], y_in, cfg)
        else:
            y = mlp.mlp_forward(p["ffn"], y_in, cfg.act, cfg.gated_mlp)
        x = x + y
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Stack init
# ---------------------------------------------------------------------------

def init_stack(key, cfg: ModelConfig, dtype) -> Params:
    u = cfg.resolved_num_units
    k_pro, k_units = jax.random.split(key)
    prologue = [
        init_layer(k, cfg, spec, dtype)
        for k, spec in zip(split_keys(k_pro, max(1, len(cfg.prologue))), cfg.prologue)
    ]
    unit_keys = jax.random.split(k_units, u)

    def init_unit(k):
        ks = split_keys(k, len(cfg.unit_pattern))
        return [init_layer(kk, cfg, spec, dtype) for kk, spec in zip(ks, cfg.unit_pattern)]

    units = jax.vmap(init_unit)(unit_keys)  # leaves: (U, ...)
    return {"prologue": prologue, "units": units}


# ---------------------------------------------------------------------------
# Stack forward (two scan segments around the link split)
# ---------------------------------------------------------------------------

def _unit_body(cfg: ModelConfig, positions, cache_index, with_cache: bool):
    """Returns a scan body over one unit of layers."""

    def body_fixed(carry, xs):
        x, aux = carry
        if with_cache:
            unit_params, unit_cache = xs
        else:
            unit_params, unit_cache = xs, [None] * len(cfg.unit_pattern)
        new_caches = []
        for j, spec in enumerate(cfg.unit_pattern):
            x, nc, a = layer_forward(
                unit_params[j], x, cfg, spec, positions, unit_cache[j], cache_index
            )
            aux = aux + a
            new_caches.append(nc)
        return (x, aux), (new_caches if with_cache else None)

    return body_fixed


def _slice_units(tree, lo: int, hi: int):
    return jax.tree_util.tree_map(lambda a: a[lo:hi], tree)


def run_stack(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    cache: Optional[Dict[str, Any]] = None,
    cache_index=None,
    link_fn=None,
    mode: str = "train",
) -> Tuple[jax.Array, Optional[Dict[str, Any]], jax.Array]:
    """Run prologue + unit scans, applying ``link_fn`` (the COMtune link
    layer) at the configured split point.  Returns (x, new_cache, aux)."""
    u = cfg.resolved_num_units
    split = min(max(cfg.link.split_after_units, 0), u) if link_fn is not None else 0
    aux = jnp.zeros((), jnp.float32)
    with_cache = cache is not None

    # --- prologue (unrolled) ---
    new_pro = []
    for i, spec in enumerate(cfg.prologue):
        c_i = cache["prologue"][i] if with_cache else None
        x, nc, a = layer_forward(
            params["prologue"][i], x, cfg, spec, positions, c_i, cache_index
        )
        aux = aux + a
        new_pro.append(nc)

    body = _unit_body(cfg, positions, cache_index, with_cache)
    if mode == "train" and cfg.remat:
        body = jax.checkpoint(body)

    def scan_segment(x, aux, lo, hi):
        if hi <= lo:
            return x, aux, None
        p_seg = _slice_units(params["units"], lo, hi)
        if with_cache:
            c_seg = [_slice_units(c, lo, hi) for c in cache["units"]]
            (x, aux), ys = jax.lax.scan(body, (x, aux), (p_seg, c_seg))
        else:
            (x, aux), ys = jax.lax.scan(body, (x, aux), p_seg)
        return x, aux, ys

    x, aux, ys1 = scan_segment(x, aux, 0, split if link_fn is not None else 0)
    if link_fn is not None:
        x = link_fn(x)
    x, aux, ys2 = scan_segment(x, aux, split, u)

    new_cache = None
    if with_cache:
        segs = [s for s in (ys1, ys2) if s is not None]
        if len(segs) == 2:
            new_units = jax.tree_util.tree_map(
                lambda a, b: jnp.concatenate([a, b], axis=0), segs[0], segs[1]
            )
        else:
            new_units = segs[0]
        new_cache = {"prologue": new_pro, "units": new_units}
    return x, new_cache, aux
