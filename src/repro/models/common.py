"""Shared building blocks: norms, initializers, activations, embeddings."""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Initializers (all take an rng key; params created in cfg dtype)
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float = 1.0):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * 0.02).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def init_norm(key, d: int, kind: str, dtype) -> Params:
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}  # (1 + scale) convention
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(p: Params, x: jax.Array, kind: str) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu":
        return jax.nn.relu
    raise ValueError(name)


# ---------------------------------------------------------------------------
# Utility
# ---------------------------------------------------------------------------

def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def count_params(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))
