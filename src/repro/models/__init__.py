"""Model zoo: unified transformer stack (attn/mamba/xLSTM/MoE), the paper's
VGG-style CNN, caches, and modality-frontend stubs."""

from repro.models import (  # noqa: F401
    attention,
    cache,
    cnn,
    common,
    frontends,
    lm,
    mamba,
    mlp,
    moe,
    rope,
    transformer,
    xlstm,
)
