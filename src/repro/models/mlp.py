"""Dense FFN: SwiGLU (silu) / GeGLU (gelu) gated, or plain 2-layer MLP."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Params, activation, dense_init, split_keys


def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype) -> Params:
    ks = split_keys(key, 3)
    p = {
        "w_up": dense_init(ks[0], (d_model, d_ff), dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), dtype),
    }
    if gated:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype)
    return p


def mlp_forward(p: Params, x: jax.Array, act_name: str, gated: bool) -> jax.Array:
    act = activation(act_name)
    up = x @ p["w_up"]
    if gated:
        up = act(x @ p["w_gate"]) * up
    else:
        up = act(up)
    return up @ p["w_down"]
