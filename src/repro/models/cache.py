"""Decode-state containers (KV caches, conv/SSM states, xLSTM states).

Layout mirrors the transformer stack: an (optional) list of per-prologue-layer
states plus, for each position ``j`` in the repeating unit pattern, a state
pytree stacked over the ``U`` scan units (leading axis U).  Windowed attention
layers allocate ``min(max_seq, window)`` rotating slots — this is what makes
gemma3-style 5:1 local:global long-context decode cheap.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention, mamba, xlstm
from repro.models.common import dtype_of


def _layer_cache(spec: LayerSpec, cfg: ModelConfig, batch: int, max_seq: int, dtype):
    if spec.kind == "attn":
        length = attention.cache_len(spec, max_seq)
        return attention.init_kv_cache(
            batch, length, cfg.num_kv_heads, cfg.resolved_head_dim, dtype,
            kv_cache_dtype=cfg.kv_cache_dtype,
        )
    if spec.kind == "mamba":
        return mamba.init_mamba_cache(batch, cfg, dtype)
    if spec.kind == "mlstm":
        return xlstm.init_mlstm_cache(batch, cfg)
    if spec.kind == "slstm":
        return xlstm.init_slstm_cache(batch, cfg)
    raise ValueError(spec.kind)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    """Build the full decode state for a model."""
    dtype = dtype_of(cfg.dtype)
    u = cfg.resolved_num_units
    prologue = [
        _layer_cache(spec, cfg, batch, max_seq, dtype) for spec in cfg.prologue
    ]
    units: List[Any] = []
    for spec in cfg.unit_pattern:
        one = _layer_cache(spec, cfg, batch, max_seq, dtype)
        units.append(
            jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (u,) + a.shape), one
            )
        )
    return {"prologue": prologue, "units": units}


def cache_spec(cfg: ModelConfig, batch: int, max_seq: int):
    """ShapeDtypeStruct skeleton of the cache (for dry-run input_specs)."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_seq))


# ---------------------------------------------------------------------------
# Slot pools (continuous-batching serve engine)
# ---------------------------------------------------------------------------
#
# A slot pool is ``n_slots`` independent batch-1 decode states stacked on a
# new leading slot axis: leaf shapes are ``(n_slots,) + leaf(batch=1)``.
# The engine's fused decode step vmaps the per-token serve step over that
# axis (per-slot cache index / RNG key / link round), and admission writes
# a freshly prefilled batch-1 cache into one slot with
# ``jax.lax.dynamic_update_slice`` — both are fixed-shape programs, so
# requests join and retire without retracing.


def init_slot_pool(
    cfg: ModelConfig, n_slots: int, max_seq: int, device=None
) -> Dict[str, Any]:
    """Zeros-initialized pool of ``n_slots`` batch-1 decode states.

    ``device`` places the fresh pool on one specific device as COMMITTED
    arrays — the sharded serving router builds one pool per mesh device,
    and committed state is what keeps every later donated dispatch pinned
    to that shard instead of following the default device."""
    one = cache_spec(cfg, 1, max_seq)
    pool = jax.tree_util.tree_map(
        lambda s: jnp.zeros((n_slots,) + tuple(s.shape), s.dtype), one
    )
    return pool if device is None else jax.device_put(pool, device)


def write_slot(pool: Dict[str, Any], slot_cache: Dict[str, Any], slot) -> Dict[str, Any]:
    """Overwrite slot ``slot`` (a traced int32 scalar is fine) of the pool
    with a batch-1 cache of the same ``max_seq`` — the full-slot reset the
    bucketed prefill performs at admission.  Every leaf of the slot's old
    state is replaced, which is what makes the decode step's dirty writes
    by retired slots harmless."""

    def upd(p, c):
        if c.dtype != p.dtype:
            raise ValueError(
                f"write_slot: cache leaf dtype {c.dtype} does not match pool "
                f"leaf dtype {p.dtype} — a silent cast here would corrupt "
                "quantized caches (e.g. bf16 values written as int8 codes); "
                "build the slot cache from the same config as the pool"
            )
        return jax.lax.dynamic_update_slice(
            p, c[None], (slot,) + (0,) * c.ndim
        )

    return jax.tree_util.tree_map(upd, pool, slot_cache)


def read_slot(pool: Dict[str, Any], slot) -> Dict[str, Any]:
    """One slot's batch-1 cache (dynamic_slice; ``slot`` may be traced)."""

    def rd(p):
        sizes = (1,) + tuple(p.shape[1:])
        out = jax.lax.dynamic_slice(p, (slot,) + (0,) * (p.ndim - 1), sizes)
        return out[0]

    return jax.tree_util.tree_map(rd, pool)


# ---------------------------------------------------------------------------
# Block pools (paged KV storage, vLLM-style)
# ---------------------------------------------------------------------------
#
# A block pool replaces the contiguous per-slot cache with ``num_blocks``
# physical blocks of ``block_size`` KV rows each, shared by every slot.
# Per attention layer the leaves are ``(num_blocks, block_size, KV, hd)``
# codes (+ ``(num_blocks, block_size, KV)`` scales for int8), mirroring the
# cache tree layout ({"prologue": [...], "units": [(U, ...) stacked]}).
#
# Block 0 is RESERVED as the trash block: it is never handed out by the
# host allocator, and the paged decode write routes dead slots' rows there
# (``phys = where(live, table_entry, 0)``) so a retired slot can never
# scribble on a block that has been reallocated to a live request.
#
# Slots own blocks through a per-slot block-table row (engine state,
# ``(max_slots, ceil(max_seq / block_size))`` int32, zero-padded); the
# allocator itself is plain host-side Python in the serve engine — only
# the table crosses into the compiled programs.


def blocks_for(rows: int, block_size: int) -> int:
    """Blocks needed to hold ``rows`` KV rows (ceil division)."""
    return -(-rows // block_size)


def init_block_pool(
    cfg: ModelConfig, num_blocks: int, block_size: int, device=None
) -> Dict[str, Any]:
    """Zeros-initialized global block pool for an attention-only stack.

    Every layer shares the same physical blocks (one pool tree, per-layer
    leaves), so a slot's block-table row addresses all layers at once.
    Windowed layers simply stop using rows past their ``cache_len`` — the
    rotating write index wraps at the layer's own length, and the padded
    tail rows of the last block are inert (never written, and the
    ``k_pos < n_valid`` mask keeps them out of every softmax).

    ``device=`` commits the pool to one device (sharded serving builds one
    pool per shard; committed arrays keep every donated dispatch on that
    shard).
    """
    for spec in cfg.all_layers():
        if spec.kind != "attn":
            raise ValueError(
                "init_block_pool: paged pools support attention-only stacks; "
                f"layer kind {spec.kind!r} carries O(1) recurrent state per "
                "slot and has nothing to page — keep it on the contiguous "
                "slot pool"
            )
    if num_blocks < 2:
        raise ValueError(
            f"init_block_pool: num_blocks={num_blocks} < 2 — block 0 is the "
            "reserved trash block, so a usable pool needs at least one more"
        )
    dtype = dtype_of(cfg.dtype)
    u = cfg.resolved_num_units

    def one():
        return attention.init_kv_cache(
            num_blocks, block_size, cfg.num_kv_heads, cfg.resolved_head_dim,
            dtype, kv_cache_dtype=cfg.kv_cache_dtype,
        )

    prologue = [one() for _ in cfg.prologue]
    # Units get real zero buffers (not broadcast views): the pool is
    # long-lived, donated engine state.
    one_spec = jax.eval_shape(one)
    units: List[Any] = [
        jax.tree_util.tree_map(
            lambda s: jnp.zeros((u,) + tuple(s.shape), s.dtype), one_spec
        )
        for _ in cfg.unit_pattern
    ]
    pool = {"prologue": prologue, "units": units}
    return pool if device is None else jax.device_put(pool, device)


def block_pool_spec(cfg: ModelConfig, num_blocks: int, block_size: int):
    """ShapeDtypeStruct skeleton of the block pool."""
    return jax.eval_shape(lambda: init_block_pool(cfg, num_blocks, block_size))


def write_prompt_blocks(
    pool: Dict[str, Any],
    slot_cache: Dict[str, Any],
    bt_row,
    n_prompt_blocks: int,
    block_size: int,
) -> Dict[str, Any]:
    """Admission copy for the paged pool: scatter only the prompt's blocks.

    ``slot_cache`` is the freshly prefilled batch-1 contiguous cache and
    ``bt_row`` the slot's (zero-padded) block-table row.  Unlike
    :func:`write_slot`, which copies all ``max_seq`` rows of every layer,
    this writes exactly ``n_prompt_blocks`` blocks (``ceil(bucket /
    block_size)``, a static per-bucket constant — padded prompt rows ride
    along exactly as they do in the contiguous slot copy, and stay
    invisible behind the causal mask / ``n_valid``).  If the bucket needs
    more blocks than a short layer (windowed ``cache_len``) or the
    reservation provides, the surplus scatter lands in trash block 0 via
    the table row's zero padding — harmless by construction.
    """

    def upd(p, c):
        if c.dtype != p.dtype:
            raise ValueError(
                f"write_prompt_blocks: cache leaf dtype {c.dtype} does not "
                f"match pool leaf dtype {p.dtype} — build the slot cache "
                "from the same config as the pool"
            )
        rows = c.shape[1]
        j_l = blocks_for(rows, block_size)
        nb = min(n_prompt_blocks, j_l)
        flat = c[0]
        pad = j_l * block_size - rows
        if pad:
            flat = jnp.pad(flat, ((0, pad),) + ((0, 0),) * (flat.ndim - 1))
        blocks = flat.reshape((j_l, block_size) + flat.shape[1:])[:nb]
        return p.at[bt_row[:nb]].set(blocks)

    prologue = [
        jax.tree_util.tree_map(upd, p, c)
        for p, c in zip(pool["prologue"], slot_cache["prologue"])
    ]
    units = [
        jax.tree_util.tree_map(lambda pp, cc: jax.vmap(upd)(pp, cc), pu, cu)
        for pu, cu in zip(pool["units"], slot_cache["units"])
    ]
    return {"prologue": prologue, "units": units}


def _attn_row_bytes(cfg: ModelConfig) -> int:
    """Bytes per KV row of one attention layer (k + v codes, plus bf16
    scales when the cache is int8-quantized)."""
    dtype = dtype_of(cfg.dtype)
    kv_itemsize = 1 if cfg.kv_cache_dtype == "int8" else jnp.dtype(dtype).itemsize
    row_bytes = 2 * cfg.num_kv_heads * cfg.resolved_head_dim * kv_itemsize
    if cfg.kv_cache_dtype == "int8":
        row_bytes += 2 * cfg.num_kv_heads * 2
    return row_bytes


def decode_read_bytes(
    cfg: ModelConfig,
    max_seq: int,
    valid: int,
    masked: bool = True,
    paged: bool = False,
    block_size: int = 16,
) -> int:
    """Attention-cache bytes ONE decode step reads for one request.

    ``masked=False`` is the legacy full-cache path: every attention layer
    reads (and for int8, dequantizes) all ``cache_len`` K/V rows + scales.
    ``masked=True`` is the length-masked flash-decode path: only
    ``ceil(valid / attn_decode_block_kv)`` blocks are touched.
    ``paged=True`` is the block-table path: ``ceil(valid / block_size)``
    pool blocks of KV rows per layer, plus the scalar-prefetch metadata
    the kernel stages through SMEM (the int32 block-table row and the
    int32 ``n_valid`` scalar) — with scalar prefetch the index map picks
    physical blocks before the DMA fires, so unlike the contiguous TPU
    kernel there is no full-panel delivery to discount (see
    kernels/README.md).  Analytic — no allocation;
    ``benchmarks/decode_attn_bench.py`` reports it next to the measured
    step latency.
    """
    import math

    from repro.kernels.decode_attention import decode_block_kv

    row_bytes = _attn_row_bytes(cfg)
    total = 0
    for spec in cfg.all_layers():
        if spec.kind != "attn":
            continue
        length = attention.cache_len(spec, max_seq)
        if paged:
            j_l = blocks_for(length, block_size)
            nblk = min(math.ceil(min(valid, length) / block_size), j_l)
            rows = nblk * block_size
            total += rows * row_bytes + 4 * j_l + 4     # + bt row + n_valid
            continue
        if masked:
            bkv = decode_block_kv(length, cfg.attn_decode_block_kv)
            rows = min(math.ceil(min(valid, length) / bkv) * bkv, length)
        else:
            rows = length
        total += rows * row_bytes
    return total


def decode_read_bytes_jnp(
    cfg: ModelConfig,
    max_seq: int,
    valid,
    masked: bool = True,
    paged: bool = False,
    block_size: int = 16,
):
    """Traced twin of :func:`decode_read_bytes`: ``valid`` may be a traced
    scalar or vector (the slot pool's per-slot lengths), so the slot-pool
    engine can accumulate the per-step read-bytes device counter inside
    the fused decode program.  Agrees exactly with the int analytic for
    every concrete ``valid`` (tested) — the per-layer cache lengths and
    effective block sizes are static, only the ceil-to-block arithmetic
    runs on device."""
    from repro.kernels.decode_attention import decode_block_kv

    row_bytes = _attn_row_bytes(cfg)
    valid = jnp.asarray(valid, jnp.float32)
    total = jnp.zeros_like(valid)
    for spec in cfg.all_layers():
        if spec.kind != "attn":
            continue
        length = attention.cache_len(spec, max_seq)
        if paged:
            j_l = blocks_for(length, block_size)
            v = jnp.minimum(valid, float(length))
            nblk = jnp.minimum(jnp.ceil(v / block_size), float(j_l))
            total = total + (
                nblk * float(block_size * row_bytes) + float(4 * j_l + 4)
            )
            continue
        if masked:
            bkv = decode_block_kv(length, cfg.attn_decode_block_kv)
            v = jnp.minimum(valid, float(length))
            rows = jnp.minimum(jnp.ceil(v / bkv) * bkv, float(length))
        else:
            rows = jnp.full_like(valid, float(length))
        total = total + rows * float(row_bytes)
    return total


def admission_write_bytes(
    cfg: ModelConfig,
    max_seq: int,
    bucket: int,
    paged: bool = False,
    block_size: int = 16,
) -> int:
    """Cache bytes ONE admission writes into the pool for one request.

    Contiguous slot pool: :func:`write_slot` replaces every leaf of the
    slot — the full batch-1 ``max_seq`` cache, independent of the prompt.
    Paged pool: :func:`write_prompt_blocks` scatters only
    ``ceil(bucket / block_size)`` blocks per layer (capped at the layer's
    own block count), so the copy scales with the padded prompt length,
    not ``max_seq``.
    """
    if not paged:
        return cache_bytes(cfg, 1, max_seq)
    row_bytes = _attn_row_bytes(cfg)
    total = 0
    for spec in cfg.all_layers():
        if spec.kind != "attn":
            continue
        length = attention.cache_len(spec, max_seq)
        nb = min(blocks_for(bucket, block_size), blocks_for(length, block_size))
        total += nb * block_size * row_bytes
    return total


def block_pool_bytes(cfg: ModelConfig, num_blocks: int, block_size: int) -> int:
    """Total block-pool footprint in bytes (no allocation) — the paged
    counterpart of :func:`cache_bytes`, used to size equal-HBM
    comparisons in ``benchmarks/serving_bench.py``."""
    import math

    return sum(
        math.prod(leaf.shape) * leaf.dtype.itemsize
        for leaf in jax.tree_util.tree_leaves(
            block_pool_spec(cfg, num_blocks, block_size)
        )
    )


def cache_bytes(cfg: ModelConfig, batch: int, max_seq: int) -> int:
    """Total decode-state footprint in bytes (no allocation) — what the
    serve engine's donated-cache scan carries, reported by decode_bench."""
    import math

    return sum(
        math.prod(leaf.shape) * leaf.dtype.itemsize
        for leaf in jax.tree_util.tree_leaves(cache_spec(cfg, batch, max_seq))
    )
