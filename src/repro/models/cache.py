"""Decode-state containers (KV caches, conv/SSM states, xLSTM states).

Layout mirrors the transformer stack: an (optional) list of per-prologue-layer
states plus, for each position ``j`` in the repeating unit pattern, a state
pytree stacked over the ``U`` scan units (leading axis U).  Windowed attention
layers allocate ``min(max_seq, window)`` rotating slots — this is what makes
gemma3-style 5:1 local:global long-context decode cheap.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention, mamba, xlstm
from repro.models.common import dtype_of


def _layer_cache(spec: LayerSpec, cfg: ModelConfig, batch: int, max_seq: int, dtype):
    if spec.kind == "attn":
        length = attention.cache_len(spec, max_seq)
        return attention.init_kv_cache(
            batch, length, cfg.num_kv_heads, cfg.resolved_head_dim, dtype,
            kv_cache_dtype=cfg.kv_cache_dtype,
        )
    if spec.kind == "mamba":
        return mamba.init_mamba_cache(batch, cfg, dtype)
    if spec.kind == "mlstm":
        return xlstm.init_mlstm_cache(batch, cfg)
    if spec.kind == "slstm":
        return xlstm.init_slstm_cache(batch, cfg)
    raise ValueError(spec.kind)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    """Build the full decode state for a model."""
    dtype = dtype_of(cfg.dtype)
    u = cfg.resolved_num_units
    prologue = [
        _layer_cache(spec, cfg, batch, max_seq, dtype) for spec in cfg.prologue
    ]
    units: List[Any] = []
    for spec in cfg.unit_pattern:
        one = _layer_cache(spec, cfg, batch, max_seq, dtype)
        units.append(
            jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (u,) + a.shape), one
            )
        )
    return {"prologue": prologue, "units": units}


def cache_spec(cfg: ModelConfig, batch: int, max_seq: int):
    """ShapeDtypeStruct skeleton of the cache (for dry-run input_specs)."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_seq))


# ---------------------------------------------------------------------------
# Slot pools (continuous-batching serve engine)
# ---------------------------------------------------------------------------
#
# A slot pool is ``n_slots`` independent batch-1 decode states stacked on a
# new leading slot axis: leaf shapes are ``(n_slots,) + leaf(batch=1)``.
# The engine's fused decode step vmaps the per-token serve step over that
# axis (per-slot cache index / RNG key / link round), and admission writes
# a freshly prefilled batch-1 cache into one slot with
# ``jax.lax.dynamic_update_slice`` — both are fixed-shape programs, so
# requests join and retire without retracing.


def init_slot_pool(cfg: ModelConfig, n_slots: int, max_seq: int) -> Dict[str, Any]:
    """Zeros-initialized pool of ``n_slots`` batch-1 decode states."""
    one = cache_spec(cfg, 1, max_seq)
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros((n_slots,) + tuple(s.shape), s.dtype), one
    )


def write_slot(pool: Dict[str, Any], slot_cache: Dict[str, Any], slot) -> Dict[str, Any]:
    """Overwrite slot ``slot`` (a traced int32 scalar is fine) of the pool
    with a batch-1 cache of the same ``max_seq`` — the full-slot reset the
    bucketed prefill performs at admission.  Every leaf of the slot's old
    state is replaced, which is what makes the decode step's dirty writes
    by retired slots harmless."""

    def upd(p, c):
        return jax.lax.dynamic_update_slice(
            p, c[None].astype(p.dtype), (slot,) + (0,) * c.ndim
        )

    return jax.tree_util.tree_map(upd, pool, slot_cache)


def read_slot(pool: Dict[str, Any], slot) -> Dict[str, Any]:
    """One slot's batch-1 cache (dynamic_slice; ``slot`` may be traced)."""

    def rd(p):
        sizes = (1,) + tuple(p.shape[1:])
        out = jax.lax.dynamic_slice(p, (slot,) + (0,) * (p.ndim - 1), sizes)
        return out[0]

    return jax.tree_util.tree_map(rd, pool)


def decode_read_bytes(
    cfg: ModelConfig, max_seq: int, valid: int, masked: bool = True
) -> int:
    """Attention-cache bytes ONE decode step reads for one request.

    ``masked=False`` is the legacy full-cache path: every attention layer
    reads (and for int8, dequantizes) all ``cache_len`` K/V rows + scales.
    ``masked=True`` is the length-masked flash-decode path: only
    ``ceil(valid / attn_decode_block_kv)`` blocks are touched — the bytes
    the jnp fallback actually reads (the compiled TPU kernel's portable
    BlockSpec still delivers the full panel; see kernels/README.md).
    Analytic — no allocation; ``benchmarks/decode_attn_bench.py`` reports
    it next to the measured step latency.
    """
    import math

    from repro.kernels.decode_attention import decode_block_kv

    dtype = dtype_of(cfg.dtype)
    kv_itemsize = 1 if cfg.kv_cache_dtype == "int8" else jnp.dtype(dtype).itemsize
    hd, kvh = cfg.resolved_head_dim, cfg.num_kv_heads
    total = 0
    for spec in cfg.all_layers():
        if spec.kind != "attn":
            continue
        length = attention.cache_len(spec, max_seq)
        if masked:
            bkv = decode_block_kv(length, cfg.attn_decode_block_kv)
            rows = min(math.ceil(min(valid, length) / bkv) * bkv, length)
        else:
            rows = length
        row_bytes = 2 * kvh * hd * kv_itemsize          # k + v codes
        if cfg.kv_cache_dtype == "int8":
            row_bytes += 2 * kvh * 2                    # bf16 scales
        total += rows * row_bytes
    return total


def decode_read_bytes_jnp(
    cfg: ModelConfig, max_seq: int, valid, masked: bool = True
):
    """Traced twin of :func:`decode_read_bytes`: ``valid`` may be a traced
    scalar or vector (the slot pool's per-slot lengths), so the slot-pool
    engine can accumulate the per-step read-bytes device counter inside
    the fused decode program.  Agrees exactly with the int analytic for
    every concrete ``valid`` (tested) — the per-layer cache lengths and
    effective block sizes are static, only the ceil-to-block arithmetic
    runs on device."""
    from repro.kernels.decode_attention import decode_block_kv

    dtype = dtype_of(cfg.dtype)
    kv_itemsize = 1 if cfg.kv_cache_dtype == "int8" else jnp.dtype(dtype).itemsize
    hd, kvh = cfg.resolved_head_dim, cfg.num_kv_heads
    valid = jnp.asarray(valid, jnp.float32)
    total = jnp.zeros_like(valid)
    for spec in cfg.all_layers():
        if spec.kind != "attn":
            continue
        length = attention.cache_len(spec, max_seq)
        row_bytes = 2 * kvh * hd * kv_itemsize
        if cfg.kv_cache_dtype == "int8":
            row_bytes += 2 * kvh * 2
        if masked:
            bkv = decode_block_kv(length, cfg.attn_decode_block_kv)
            v = jnp.minimum(valid, float(length))
            rows = jnp.minimum(jnp.ceil(v / bkv) * bkv, float(length))
        else:
            rows = jnp.full_like(valid, float(length))
        total = total + rows * float(row_bytes)
    return total


def cache_bytes(cfg: ModelConfig, batch: int, max_seq: int) -> int:
    """Total decode-state footprint in bytes (no allocation) — what the
    serve engine's donated-cache scan carries, reported by decode_bench."""
    import math

    return sum(
        math.prod(leaf.shape) * leaf.dtype.itemsize
        for leaf in jax.tree_util.tree_leaves(cache_spec(cfg, batch, max_seq))
    )
