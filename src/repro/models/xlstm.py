"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory with recurrent gate connections).

mLSTM train/prefill uses the stabilized parallel (quadratic) form — the
decay matrix D_ts built from cumulative log-forget-gates plays the role of
the attention matrix; decode uses the exact recurrent update on carried
(C, n, m).  sLSTM is inherently sequential (h_{t-1} feeds the gates) and
always runs as a `lax.scan` over time.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Params, dense_init, rmsnorm, split_keys

NEG_INF = -1.0e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    h = cfg.num_heads
    dh = cfg.xlstm_head_dim
    ks = split_keys(key, 6)
    return {
        "wq": dense_init(ks[0], (d, h * dh), dtype),
        "wk": dense_init(ks[1], (d, h * dh), dtype),
        "wv": dense_init(ks[2], (d, h * dh), dtype),
        "wi": dense_init(ks[3], (d, h), dtype, scale=0.1),
        "wf": dense_init(ks[4], (d, h), dtype, scale=0.1),
        "f_bias": jnp.full((h,), 3.0, dtype),   # forget-gate open at init
        "wo": dense_init(ks[5], (d, h * dh), dtype),
        "w_out": dense_init(jax.random.fold_in(key, 99), (h * dh, d), dtype),
        "norm_scale": jnp.zeros((h * dh,), dtype),
    }


def _mlstm_qkv(p: Params, x: jax.Array, cfg: ModelConfig):
    b, s, _ = x.shape
    h, dh = cfg.num_heads, cfg.xlstm_head_dim
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    k = (x @ p["wk"]).reshape(b, s, h, dh) / jnp.sqrt(jnp.asarray(dh, x.dtype))
    v = (x @ p["wv"]).reshape(b, s, h, dh)
    i_pre = (x @ p["wi"]).astype(jnp.float32)                      # (B, S, H)
    f_pre = (x @ p["wf"]).astype(jnp.float32) + p["f_bias"].astype(jnp.float32)
    o_gate = jax.nn.sigmoid(x @ p["wo"]).reshape(b, s, h, dh)
    return q, k, v, i_pre, f_pre, o_gate


def mlstm_parallel(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Stabilized parallel form (training / prefill)."""
    b, s, _ = x.shape
    h, dh = cfg.num_heads, cfg.xlstm_head_dim
    q, k, v, i_pre, f_pre, o_gate = _mlstm_qkv(p, x, cfg)

    log_f = jax.nn.log_sigmoid(f_pre)                              # (B, S, H)
    f_cum = jnp.cumsum(log_f, axis=1)                              # F_t
    # D_ts = F_t - F_s + i_s   (s <= t)
    d_mat = (
        f_cum[:, :, None, :] - f_cum[:, None, :, :] + i_pre[:, None, :, :]
    )  # (B, T, S, H)
    t_idx = jnp.arange(s)
    causal = t_idx[:, None] >= t_idx[None, :]
    d_mat = jnp.where(causal[None, :, :, None], d_mat, NEG_INF)
    m = jnp.max(d_mat, axis=2)                                     # (B, T, H)
    decay = jnp.exp(d_mat - m[:, :, None, :])                      # (B, T, S, H)
    decay = jnp.moveaxis(decay, 3, 1)                              # (B, H, T, S)

    scores = jnp.einsum("bthd,bshd->bhts", q, k).astype(jnp.float32)
    weights = scores * decay                                       # (B, H, T, S)
    m_bht = jnp.moveaxis(m, 2, 1)                                  # (B, H, T)
    norm = jnp.maximum(jnp.abs(weights.sum(axis=-1)), jnp.exp(-m_bht))
    weights = weights / jnp.maximum(norm, 1e-6)[..., None]
    h_out = jnp.einsum("bhts,bshd->bthd", weights, v.astype(jnp.float32))
    h_out = h_out.astype(x.dtype) * o_gate
    h_flat = h_out.reshape(b, s, h * dh)
    h_flat = rmsnorm(h_flat, p["norm_scale"])
    return h_flat @ p["w_out"]


def mlstm_chunked(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    state: Optional[Params] = None,
) -> Tuple[jax.Array, Params]:
    """Chunkwise-parallel mLSTM (GLA/mamba2-style): sequential scan over
    chunks of ``cfg.scan_chunk`` positions carrying the recurrent (C, n, m)
    state; quadratic work only within a chunk.

    Replaces the fully-parallel form for long sequences: the (B, S, S, H)
    decay matrix becomes (B, L, L, H) per chunk — for xlstm-350m x
    prefill_32k this removes the TB-scale f32 intermediates (and their
    collectives) that made the parallel form collective/memory-bound
    (EXPERIMENTS.md §Perf hillclimb 2).
    """
    b, s, d = x.shape
    h, dh = cfg.num_heads, cfg.xlstm_head_dim
    chunk = max(1, min(cfg.scan_chunk, s))
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    xc = x.reshape(b, nc, chunk, d)
    # padded positions must not touch the state: f -> 1 (no decay), i -> -inf
    valid = (jnp.arange(nc * chunk) < s).reshape(nc, chunk)

    if state is None:
        state = init_mlstm_cache(b, cfg)

    t_idx = jnp.arange(chunk)
    causal = t_idx[:, None] >= t_idx[None, :]

    def chunk_step(carry, inputs):
        x_chunk, valid_c = inputs
        c_in, n_in, m_in = carry["c"], carry["n"], carry["m"]
        q, k, v, i_pre, f_pre, o_gate = _mlstm_qkv(p, x_chunk, cfg)
        log_f = jax.nn.log_sigmoid(f_pre)                       # (B, L, H)
        vmask = valid_c[None, :, None]                          # (1, L, 1)
        log_f = jnp.where(vmask, log_f, 0.0)
        i_pre = jnp.where(vmask, i_pre, NEG_INF)
        f_cum = jnp.cumsum(log_f, axis=1)                       # F_t

        # --- intra-chunk decay ---
        d_intra = (
            f_cum[:, :, None, :] - f_cum[:, None, :, :] + i_pre[:, None, :, :]
        )
        d_intra = jnp.where(causal[None, :, :, None], d_intra, NEG_INF)
        m_intra = jnp.max(d_intra, axis=2)                      # (B, L, H)
        m_cross = f_cum + m_in[:, None, :]                      # (B, L, H)
        m_t = jnp.maximum(m_intra, m_cross)

        w_intra = jnp.exp(d_intra - m_t[:, :, None, :])         # (B, T, S, H)
        w_intra = jnp.moveaxis(w_intra, 3, 1)                   # (B, H, T, S)
        scores = jnp.einsum("bthd,bshd->bhts", q, k).astype(jnp.float32)
        intra = scores * w_intra

        cross_scale = jnp.exp(m_cross - m_t)                    # (B, L, H)
        qf = q.astype(jnp.float32)
        num_cross = (
            jnp.einsum("bhvk,bthk->bthv", c_in, qf) * cross_scale[..., None]
        )
        qn_cross = jnp.einsum("bhk,bthk->bth", n_in, qf) * cross_scale

        row_sum = jnp.moveaxis(intra.sum(axis=-1), 1, 2)        # (B, T, H)
        denom = jnp.maximum(jnp.abs(row_sum + qn_cross), jnp.exp(-m_t))
        denom = jnp.maximum(denom, 1e-6)
        h_intra = jnp.einsum("bhts,bshd->bthd", intra, v.astype(jnp.float32))
        h_out = (h_intra + num_cross) / denom[..., None]
        h_out = h_out.astype(x_chunk.dtype) * o_gate

        # --- state update (closed form over the chunk) ---
        f_total = f_cum[:, -1, :]                               # (B, H)
        d_s = f_total[:, None, :] - f_cum + i_pre               # (B, L, H)
        m_seq = jnp.max(d_s, axis=1)
        m_old = f_total + m_in
        m_new = jnp.maximum(m_seq, m_old)
        w_s = jnp.exp(d_s - m_new[:, None, :])
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
        c_seq = jnp.einsum("bsh,bshv,bshk->bhvk", w_s, vf, kf)
        n_seq = jnp.einsum("bsh,bshk->bhk", w_s, kf)
        old_scale = jnp.exp(m_old - m_new)
        new_state = {
            "c": old_scale[..., None, None] * c_in + c_seq,
            "n": old_scale[..., None] * n_in + n_seq,
            "m": m_new,
        }
        return new_state, h_out

    final_state, hs = jax.lax.scan(
        chunk_step, state, (jnp.swapaxes(xc, 0, 1), valid)
    )
    out = jnp.swapaxes(hs, 0, 1).reshape(b, nc * chunk, h * dh)[:, :s]
    out = rmsnorm(out, p["norm_scale"])
    return out @ p["w_out"], final_state


def mlstm_final_state(
    p: Params, x: jax.Array, cfg: ModelConfig, cache: Params
) -> Params:
    """Closed-form recurrent state after consuming x (prefill -> decode
    handoff): C_S = Σ_s exp(F_S - F_s + i_s - m) v_s k_s^T, etc.  Starting
    state (cache) is folded in with decay exp(F_S + m_old - m)."""
    q, k, v, i_pre, f_pre, _ = _mlstm_qkv(p, x, cfg)
    log_f = jax.nn.log_sigmoid(f_pre)                              # (B, S, H)
    f_cum = jnp.cumsum(log_f, axis=1)
    f_total = f_cum[:, -1, :]                                      # (B, H) = F_S
    d_s = f_total[:, None, :] - f_cum + i_pre                      # (B, S, H)
    m_seq = jnp.max(d_s, axis=1)                                   # (B, H)
    m_old = f_total + cache["m"]
    m_new = jnp.maximum(m_seq, m_old)
    w = jnp.exp(d_s - m_new[:, None, :])                           # (B, S, H)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    c_seq = jnp.einsum("bsh,bshv,bshk->bhvk", w, vf, kf)
    n_seq = jnp.einsum("bsh,bshk->bhk", w, kf)
    old_scale = jnp.exp(m_old - m_new)
    c_new = old_scale[..., None, None] * cache["c"] + c_seq
    n_new = old_scale[..., None] * cache["n"] + n_seq
    return {"c": c_new, "n": n_new, "m": m_new}


def init_mlstm_cache(batch: int, cfg: ModelConfig) -> Params:
    h, dh = cfg.num_heads, cfg.xlstm_head_dim
    return {
        "c": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), NEG_INF, jnp.float32),
    }


def mlstm_step(
    p: Params, x: jax.Array, cfg: ModelConfig, cache: Params
) -> Tuple[jax.Array, Params]:
    """Recurrent decode update. x: (B, 1, d)."""
    b = x.shape[0]
    h, dh = cfg.num_heads, cfg.xlstm_head_dim
    q, k, v, i_pre, f_pre, o_gate = _mlstm_qkv(p, x, cfg)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                            # (B, H, dh)
    i_pre, f_pre, o_gate = i_pre[:, 0], f_pre[:, 0], o_gate[:, 0]

    log_f = jax.nn.log_sigmoid(f_pre)                              # (B, H)
    m_new = jnp.maximum(log_f + cache["m"], i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + cache["m"] - m_new)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    c_new = f_g[..., None, None] * cache["c"] + i_g[..., None, None] * (
        vf[..., :, None] * kf[..., None, :]
    )  # (B, H, dh_v, dh_k)
    n_new = f_g[..., None] * cache["n"] + i_g[..., None] * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhvk,bhk->bhv", c_new, qf)
    qn = jnp.einsum("bhk,bhk->bh", n_new, qf)
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new)) + 1e-6
    h_out = (num / denom[..., None]).astype(x.dtype) * o_gate
    h_flat = rmsnorm(h_out.reshape(b, 1, h * dh), p["norm_scale"])
    return h_flat @ p["w_out"], {"c": c_new, "n": n_new, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    h = cfg.num_heads
    dh = cfg.xlstm_head_dim
    ks = split_keys(key, 8)
    return {
        "wz": dense_init(ks[0], (d, h * dh), dtype),
        "wi": dense_init(ks[1], (d, h * dh), dtype, scale=0.1),
        "wf": dense_init(ks[2], (d, h * dh), dtype, scale=0.1),
        "wo": dense_init(ks[3], (d, h * dh), dtype),
        "rz": dense_init(ks[4], (h, dh, dh), dtype, scale=0.5),
        "ri": dense_init(ks[5], (h, dh, dh), dtype, scale=0.5),
        "rf": dense_init(ks[6], (h, dh, dh), dtype, scale=0.5),
        "ro": dense_init(ks[7], (h, dh, dh), dtype, scale=0.5),
        "f_bias": jnp.full((h * dh,), 3.0, dtype),
        "w_out": dense_init(jax.random.fold_in(key, 99), (h * dh, d), dtype),
        "norm_scale": jnp.zeros((h * dh,), dtype),
    }


def init_slstm_cache(batch: int, cfg: ModelConfig) -> Params:
    h, dh = cfg.num_heads, cfg.xlstm_head_dim
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, h, dh), NEG_INF, jnp.float32), "h": z}


def _slstm_cell(p: Params, cfg: ModelConfig, x_t: jax.Array, state: Params):
    """One sLSTM step. x_t: (B, d) pre-projected gate inputs supplied here."""
    b = x_t.shape[0]
    h, dh = cfg.num_heads, cfg.xlstm_head_dim
    h_prev = state["h"]                                            # (B, H, dh) f32

    def rec(w, hp):  # block-diagonal recurrent matmul
        return jnp.einsum("bhk,hkv->bhv", hp, w.astype(jnp.float32))

    xz = (x_t @ p["wz"]).reshape(b, h, dh).astype(jnp.float32)
    xi = (x_t @ p["wi"]).reshape(b, h, dh).astype(jnp.float32)
    xf = ((x_t @ p["wf"]) + p["f_bias"]).reshape(b, h, dh).astype(jnp.float32)
    xo = (x_t @ p["wo"]).reshape(b, h, dh).astype(jnp.float32)

    z = jnp.tanh(xz + rec(p["rz"], h_prev))
    i_pre = xi + rec(p["ri"], h_prev)
    f_pre = xf + rec(p["rf"], h_prev)
    o = jax.nn.sigmoid(xo + rec(p["ro"], h_prev))

    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + state["m"], i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + state["m"] - m_new)
    c_new = f_g * state["c"] + i_g * z
    n_new = f_g * state["n"] + i_g
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "m": m_new, "h": h_new}


def slstm_forward(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    cache: Optional[Params] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    """Sequential over time for any S; decode is just S == 1."""
    b, s, _ = x.shape
    h, dh = cfg.num_heads, cfg.xlstm_head_dim
    state = cache if cache is not None else init_slstm_cache(b, cfg)

    def step(st, x_t):
        st2 = _slstm_cell(p, cfg, x_t, st)
        return st2, st2["h"]

    state_f, hs = jax.lax.scan(step, state, jnp.swapaxes(x, 0, 1))
    out = jnp.swapaxes(hs, 0, 1).astype(x.dtype).reshape(b, s, h * dh)
    out = rmsnorm(out, p["norm_scale"])
    return out @ p["w_out"], (state_f if cache is not None else None)
