"""Modality frontend STUBS (the one sanctioned carve-out).

For the VLM (qwen2-vl) and audio (musicgen) architectures we implement the
*language/decoder transformer* only; the vision encoder (ViT/SigLIP +
projector) and the audio codec (EnCodec) are stubbed: ``input_specs()``
provides precomputed patch/frame embeddings of the right shape, and the
model consumes them by overwriting the first ``frontend_len`` token
embeddings (after a small trainable adapter projection, so the fusion
boundary is still learnable).

musicgen note: its decoder consumes EnCodec *tokens* (vocab 2048) directly,
so the codec stub is simply "tokens are precomputed"; we additionally accept
optional conditioning frame embeddings through the same adapter.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Params, dense_init


def init_frontend_adapter(key, cfg: ModelConfig, dtype) -> Params:
    return {"proj": dense_init(key, (cfg.d_model, cfg.d_model), dtype)}


def fuse_frontend(
    p: Params,
    x: jax.Array,                  # (B, S, d) token embeddings
    frontend_embed: Optional[jax.Array],  # (B, F, d) stub embeddings
) -> jax.Array:
    if frontend_embed is None:
        return x
    fused = frontend_embed.astype(x.dtype) @ p["proj"]
    f = fused.shape[1]
    return jnp.concatenate([fused, x[:, f:]], axis=1)
