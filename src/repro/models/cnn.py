"""The paper's DNN (Fig. 3): a VGG16-style CNN for 32x32 image
classification — five conv blocks (a conv layers, b channels) with
BatchNorm + 2x2 max-pool, then an FC block (256, 128, classes).

The model is split after block 1 (paper §IV-A): the IoT device runs block 1
(activation dims 16*16*64 = 16,384 -> 65.5 kB in fp32, matching the paper),
the edge server runs blocks 2-5 + FC.  ``width_scale`` < 1 gives a reduced
variant for CPU-budget experiments (documented wherever used).
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init, split_keys


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    blocks: Tuple[Tuple[int, int], ...] = ((2, 64), (2, 128), (3, 256), (3, 512), (3, 512))
    fc: Tuple[int, ...] = (256, 128)
    num_classes: int = 10
    image_size: int = 32
    in_channels: int = 3
    split_block: int = 1          # device runs blocks[:split_block]
    width_scale: float = 1.0

    def scaled_blocks(self):
        return tuple((a, max(8, int(b * self.width_scale))) for a, b in self.blocks)

    @property
    def split_activation_dim(self) -> int:
        size = self.image_size // (2**self.split_block)
        return size * size * self.scaled_blocks()[self.split_block - 1][1]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    std = jnp.sqrt(2.0 / fan_in)
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * std


def init_cnn(key, cfg: CNNConfig) -> Tuple[Params, Params]:
    """Returns (params, bn_state)."""
    params: Params = {"blocks": [], "fc": []}
    state: Params = {"blocks": []}
    cin = cfg.in_channels
    for a, b in cfg.scaled_blocks():
        key, *ks = jax.random.split(key, a + 1)
        convs = []
        for i in range(a):
            convs.append(
                {"w": _conv_init(ks[i], 3, 3, cin if i == 0 else b, b),
                 "b": jnp.zeros((b,), jnp.float32)}
            )
        params["blocks"].append(
            {"convs": convs,
             "bn": {"scale": jnp.ones((b,), jnp.float32),
                    "bias": jnp.zeros((b,), jnp.float32)}}
        )
        state["blocks"].append(
            {"mean": jnp.zeros((b,), jnp.float32), "var": jnp.ones((b,), jnp.float32)}
        )
        cin = b
    feat = cfg.image_size // (2 ** len(cfg.blocks))
    dim = feat * feat * cfg.scaled_blocks()[-1][1]
    dims = (dim,) + cfg.fc + (cfg.num_classes,)
    key, *ks = jax.random.split(key, len(dims))
    for i in range(len(dims) - 1):
        params["fc"].append(
            {"w": dense_init(ks[i], (dims[i], dims[i + 1]), jnp.float32, scale=1.4),
             "b": jnp.zeros((dims[i + 1],), jnp.float32)}
        )
    return params, state


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def _maxpool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _batchnorm(x, p, s, train: bool, momentum: float = 0.9):
    if train:
        mean = x.mean(axis=(0, 1, 2))
        var = x.var(axis=(0, 1, 2))
        new_s = {
            "mean": momentum * s["mean"] + (1 - momentum) * mean,
            "var": momentum * s["var"] + (1 - momentum) * var,
        }
    else:
        mean, var = s["mean"], s["var"]
        new_s = s
    y = (x - mean) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    return y, new_s


def _block(x, bp, bs, train: bool):
    n = len(bp["convs"])
    new_bs = bs
    for i, cp in enumerate(bp["convs"]):
        x = _conv(x, cp["w"], cp["b"])
        if i == n - 1:  # BN after the last conv of the block (paper Fig. 3)
            x, new_bs = _batchnorm(x, bp["bn"], bs, train)
        x = jax.nn.relu(x)
    return _maxpool(x), new_bs


def forward_device(params, state, x, cfg: CNNConfig, train: bool = False):
    """Blocks [0, split): runs on the IoT device.  Returns flat activation
    (B, split_activation_dim) and updated BN state slices."""
    new_states = []
    for i in range(cfg.split_block):
        x, ns = _block(x, params["blocks"][i], state["blocks"][i], train)
        new_states.append(ns)
    b = x.shape[0]
    return x.reshape(b, -1), new_states


def forward_server(params, state, a_flat, cfg: CNNConfig, train: bool = False):
    """Blocks [split, end) + FC: runs on the edge server."""
    nblocks = len(cfg.scaled_blocks())
    size = cfg.image_size // (2**cfg.split_block)
    ch = cfg.scaled_blocks()[cfg.split_block - 1][1]
    x = a_flat.reshape(a_flat.shape[0], size, size, ch)
    new_states = []
    for i in range(cfg.split_block, nblocks):
        x, ns = _block(x, params["blocks"][i], state["blocks"][i], train)
        new_states.append(ns)
    x = x.reshape(x.shape[0], -1)
    for j, fp in enumerate(params["fc"]):
        x = x @ fp["w"] + fp["b"]
        if j < len(params["fc"]) - 1:
            x = jax.nn.relu(x)
    return x, new_states


def forward(params, state, x, cfg: CNNConfig, train: bool = False, link_fn=None):
    """Full model with optional link layer at the split (COMtune Eq. 8)."""
    a, dev_states = forward_device(params, state, x, cfg, train)
    if link_fn is not None:
        a = link_fn(a)
    logits, srv_states = forward_server(params, state, a, cfg, train)
    new_state = {"blocks": dev_states + srv_states}
    return logits, new_state
