"""Mamba selective-SSM block (Jamba's recurrent layer).

Training/prefill uses a **chunked parallel scan**: `lax.scan` over chunks of
``cfg.scan_chunk`` positions, `lax.associative_scan` inside each chunk —
activation memory is O(B · chunk · d_inner · d_state) rather than O(B · S ·
d_inner · d_state).  Decode is a single recurrent update on carried
(conv_state, ssm_state).  The Pallas kernel in ``repro/kernels/ssm_scan``
implements the same chunked recurrence with explicit VMEM tiling.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Params, dense_init, split_keys


def init_mamba(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    di = cfg.mamba_d_inner
    n = cfg.mamba_d_state
    r = cfg.mamba_dt_rank
    dc = cfg.mamba_d_conv
    ks = split_keys(key, 6)
    # S4D-real initialization for A.
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": dense_init(ks[1], (dc, di), dtype, scale=1.0),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, r + 2 * n), dtype),
        "dt_proj": dense_init(ks[3], (r, di), dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),  # softplus^-1(~0.01)
        "A_log": jnp.log(a_init).astype(jnp.float32),
        "D": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[4], (di, d), dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. x: (B, S, di), w: (dc, di)."""
    dc = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    s = x.shape[1]
    for j in range(dc):
        out = out + pad[:, j : j + s, :] * w[j][None, None, :]
    return out + b


def _conv_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array, b: jax.Array):
    """x_t: (B, di); conv_state: (B, dc-1, di) holding previous inputs."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B, dc, di)
    out = jnp.einsum("bcd,cd->bd", window, w) + b
    new_state = window[:, 1:, :]
    return out, new_state


def _ssm_params(p: Params, x_conv: jax.Array, cfg: ModelConfig):
    """x_conv (..., di) -> (dA or (dt, A)), dBx pieces."""
    r, n = cfg.mamba_dt_rank, cfg.mamba_d_state
    proj = x_conv @ p["x_proj"]
    dt_low, b_ssm, c_ssm = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        (dt_low @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # (..., di)
    a = -jnp.exp(p["A_log"])  # (di, N)
    return dt, a, b_ssm.astype(jnp.float32), c_ssm.astype(jnp.float32)


def _chunked_selective_scan(
    dt: jax.Array,      # (B, S, di) f32
    a: jax.Array,       # (di, N) f32
    b_ssm: jax.Array,   # (B, S, N)
    c_ssm: jax.Array,   # (B, S, N)
    x: jax.Array,       # (B, S, di) f32
    chunk: int,
    h0: Optional[jax.Array] = None,   # (B, di, N)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B, S, di), h_final (B, di, N))."""
    bsz, s, di = x.shape
    n = a.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_ssm = jnp.pad(b_ssm, ((0, 0), (0, pad), (0, 0)))
        c_ssm = jnp.pad(c_ssm, ((0, 0), (0, pad), (0, 0)))
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk

    da = jnp.exp(dt[..., None] * a[None, None])                  # (B, S', di, N)
    dbx = dt[..., None] * b_ssm[:, :, None, :] * x[..., None]    # (B, S', di, N)
    da = da.reshape(bsz, nc, chunk, di, n)
    dbx = dbx.reshape(bsz, nc, chunk, di, n)
    c_ssm = c_ssm.reshape(bsz, nc, chunk, n)

    def combine(left, right):
        al, bl = left
        ar, br = right
        return ar * al, ar * bl + br

    def chunk_step(h, inputs):
        da_c, dbx_c, c_c = inputs  # (B, chunk, di, N), ..., (B, chunk, N)
        acum, bcum = jax.lax.associative_scan(combine, (da_c, dbx_c), axis=1)
        h_all = acum * h[:, None] + bcum                          # (B, chunk, di, N)
        y = jnp.einsum("bsdn,bsn->bsd", h_all, c_c)
        return h_all[:, -1], y

    h_init = h0 if h0 is not None else jnp.zeros((bsz, di, n), jnp.float32)
    h_final, ys = jax.lax.scan(
        chunk_step,
        h_init,
        (
            jnp.swapaxes(da, 0, 1),
            jnp.swapaxes(dbx, 0, 1),
            jnp.swapaxes(c_ssm, 0, 1),
        ),
    )
    y = jnp.swapaxes(ys, 0, 1).reshape(bsz, nc * chunk, di)[:, :s]
    return y, h_final


def init_mamba_cache(batch: int, cfg: ModelConfig, dtype) -> Params:
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, cfg.mamba_d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.mamba_d_inner, cfg.mamba_d_state), jnp.float32),
    }


def mamba_forward(
    p: Params,
    x: jax.Array,                  # (B, S, d)
    cfg: ModelConfig,
    cache: Optional[Params] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    bsz, s, _ = x.shape
    xz = x @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)

    if cache is not None and s == 1:
        # ---- decode ----
        x_conv, conv_state = _conv_step(
            x_in[:, 0], cache["conv"].astype(x_in.dtype), p["conv_w"], p["conv_b"]
        )
        x_conv = jax.nn.silu(x_conv)
        dt, a, b_ssm, c_ssm = _ssm_params(p, x_conv, cfg)
        # x_conv is (B, di) here, so dt: (B, di); b_ssm/c_ssm: (B, N)
        da = jnp.exp(dt[..., None] * a[None])                  # (B, di, N)
        dbx = dt[..., None] * b_ssm[:, None, :] * x_conv.astype(jnp.float32)[..., None]
        h = da * cache["ssm"] + dbx
        y = jnp.einsum("bdn,bn->bd", h, c_ssm)
        y = y + p["D"].astype(jnp.float32) * x_conv.astype(jnp.float32)
        out = (y.astype(x.dtype) * jax.nn.silu(z[:, 0]))[:, None, :]
        new_cache = {"conv": conv_state.astype(cache["conv"].dtype), "ssm": h}
        return out @ p["out_proj"], new_cache

    # ---- train / prefill ----
    x_conv = jax.nn.silu(_causal_conv(x_in, p["conv_w"], p["conv_b"]))
    dt, a, b_ssm, c_ssm = _ssm_params(p, x_conv, cfg)
    # b_ssm/c_ssm per-position: (B, S, N)
    y, h_final = _chunked_selective_scan(
        dt,
        a,
        b_ssm,
        c_ssm,
        x_conv.astype(jnp.float32),
        cfg.scan_chunk,
        h0=cache["ssm"] if cache is not None else None,
    )
    y = y + p["D"].astype(jnp.float32) * x_conv.astype(jnp.float32)
    out = y.astype(x.dtype) * jax.nn.silu(z)
    new_cache = None
    if cache is not None:
        dc = cfg.mamba_d_conv
        tail = x_in[:, -(dc - 1) :, :]
        if s < dc - 1:
            tail = jnp.concatenate([cache["conv"].astype(x_in.dtype)[:, s:], x_in], axis=1)
        new_cache = {"conv": tail.astype(cache["conv"].dtype), "ssm": h_final}
    return out @ p["out_proj"], new_cache
