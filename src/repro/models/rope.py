"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE (arXiv:2409.12191): the head_dim/2 rotary frequency channels are
partitioned into ``sections`` (temporal, height, width); each section rotates
with its own position stream.  Positions therefore have shape (B, 3, S) for
M-RoPE and (B, S) for standard RoPE.  For pure-text spans all three streams
carry the same value, which makes M-RoPE degenerate to RoPE exactly.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    half = head_dim // 2
    exponents = jnp.arange(0, half, dtype=jnp.float32) / half
    return 1.0 / (theta**exponents)


def _angles_standard(positions: jax.Array, head_dim: int, theta: float) -> jax.Array:
    """positions (B, S) -> angles (B, S, head_dim/2)."""
    inv = rope_frequencies(head_dim, theta)
    return positions.astype(jnp.float32)[..., None] * inv[None, None, :]


def _angles_mrope(
    positions: jax.Array, head_dim: int, theta: float, sections: Tuple[int, ...]
) -> jax.Array:
    """positions (B, 3, S) -> angles (B, S, head_dim/2) with per-section
    position streams."""
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    inv = rope_frequencies(head_dim, theta)  # (half,)
    # section id per frequency channel
    sec_ids = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)]
    )  # (half,)
    # gather the right position stream per channel: (B, S, half)
    pos = positions.astype(jnp.float32)  # (B, 3, S)
    pos_per_channel = jnp.take(pos, sec_ids, axis=1)  # (B, half, S)
    pos_per_channel = jnp.swapaxes(pos_per_channel, 1, 2)  # (B, S, half)
    return pos_per_channel * inv[None, None, :]


def apply_rope(
    x: jax.Array,
    positions: jax.Array,
    theta: float,
    sections: Tuple[int, ...] = (),
) -> jax.Array:
    """Rotate x (B, S, N, head_dim) by position-dependent angles.

    positions: (B, S) for RoPE, (B, 3, S) for M-RoPE (sections non-empty).
    """
    head_dim = x.shape[-1]
    if sections:
        ang = _angles_mrope(positions, head_dim, theta, sections)
    else:
        ang = _angles_standard(positions, head_dim, theta)
    sin = jnp.sin(ang)[:, :, None, :]  # (B, S, 1, half)
    cos = jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def default_positions(batch: int, seq: int, offset=0, mrope: bool = False) -> jax.Array:
    """Sequential text positions; offset may be a traced scalar (decode)."""
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset  # (1, S)
    pos = jnp.broadcast_to(pos, (batch, seq))
    if mrope:
        pos = jnp.broadcast_to(pos[:, None, :], (batch, 3, seq))
    return pos
