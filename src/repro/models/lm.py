"""Causal LM assembled from the unified stack, with the COMtune link layer
as a first-class feature (paper Eq. 8 for training, Eq. 12 for serving).

The link sits between the device-side and server-side unit scans; its
compression parameters (quantization scale factors / PCA basis) live inside
the parameter pytree so calibration results are part of checkpoints and the
lowered multi-pod program.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import comtune
from repro.core.compression import Compressor, PCASpec, QuantSpec
from repro.models import frontends, rope as rope_lib, transformer
from repro.models.common import (
    Params,
    apply_norm,
    dense_init,
    dtype_of,
    embed_init,
    init_norm,
    split_keys,
)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_link_params(key, cfg: ModelConfig, dtype) -> Params:
    """Compression parameters at the split point (calibrated later)."""
    d = cfg.d_model
    link = cfg.link
    p: Params = {}
    if link.compression == "quant":
        p["s_min"] = jnp.full((d,), -6.0, jnp.float32)
        p["s_max"] = jnp.full((d,), 6.0, jnp.float32)
    elif link.compression == "pca":
        dim = link.pca_dim or d // 4
        w = dense_init(key, (dim, d), jnp.float32, scale=1.0)
        p["w"] = w
        p["b"] = jnp.zeros((d,), jnp.float32)
    return p


def init_lm(key, cfg: ModelConfig) -> Params:
    dtype = dtype_of(cfg.dtype)
    ks = split_keys(key, 6)
    p: Params = {
        "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype),
        "stack": transformer.init_stack(ks[1], cfg, dtype),
        "final_norm": init_norm(ks[2], cfg.d_model, cfg.norm, dtype),
        "link": init_link_params(ks[3], cfg, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[4], (cfg.d_model, cfg.vocab_size), dtype)
    if cfg.frontend:
        p["frontend"] = frontends.init_frontend_adapter(ks[5], cfg, dtype)
    return p


# ---------------------------------------------------------------------------
# Link layer constructors
# ---------------------------------------------------------------------------

def _compressor_from_params(cfg: ModelConfig, link_params: Params) -> Compressor:
    link = cfg.link
    if link.compression == "quant":
        return Compressor(
            kind="quant",
            quant=QuantSpec(
                bits=link.quant_bits,
                s_min=link_params["s_min"],
                s_max=link_params["s_max"],
            ),
        )
    if link.compression == "pca":
        return Compressor(
            kind="pca", pca=PCASpec(w=link_params["w"], b=link_params["b"])
        )
    return Compressor(kind="identity")


def link_spec_from_config(
    cfg: ModelConfig,
    loss_rate: Optional[float] = None,
    **overrides,
) -> comtune.LinkSpec:
    """The ``LinkSpec`` a model config implies (compressor left at its
    default — the calibrated one lives in the param pytree and is grafted
    on inside :func:`make_link_fn`)."""
    link = cfg.link
    spec_kwargs = dict(
        dropout_rate=link.dropout_rate,
        loss_rate=link.loss_rate if loss_rate is None else loss_rate,
        train_link=link.train_link,
        channel=link.channel,
        channel_params=tuple(link.channel_params),
        shuffle=link.shuffle,
        fec_k=link.fec_k,
        fec_m=link.fec_m,
        fec_kind=link.fec_kind,
    )
    spec_kwargs.update(overrides)
    return comtune.LinkSpec(**spec_kwargs)


def make_link_fn(
    cfg: ModelConfig,
    link_params: Params,
    key: Optional[jax.Array],
    mode: str,
    loss_rate: Optional[float] = None,
    link_spec: Optional[comtune.LinkSpec] = None,
    link_rate=None,
):
    """Build the function applied at the split point — a closure over
    ``comtune.emulate_link``, the one differentiable link path shared by
    training and serving.

    mode:
      "train"   -> Eq. 8:  STE-compressed roundtrip + the emulation picked
                   by ``spec.train_link`` (Eq. 7 dropout / full channel)
      "serve"   -> Eq. 12: compress -> channel(p) -> 1/(1-p) -> decompress
      "clean"   -> compression only, no loss (reliable-protocol reference)
      "off"     -> None (link disabled; plain model)

    ``link_spec`` (a full ``LinkSpec``, e.g. from the trainer's curriculum)
    takes precedence over the cfg-derived spec; its compressor field is
    replaced by the calibrated one carried in ``link_params`` either way.

    ``link_rate`` overrides the *emulation rate of the current mode* and
    may be a TRACED scalar — this is how the per-step curriculum feeds the
    ramped rate as scan data instead of a compile-time constant.  In train
    mode it sets whatever ``spec.train_link`` draws at (dropout rate or
    channel loss rate); in serve mode it sets the channel loss rate.
    Traced rates are only supported on the dropout / plain-iid paths (the
    stateful channels bake their rate into static transition tables).
    """
    if mode == "off":
        return None
    compressor = _compressor_from_params(cfg, link_params)
    if link_spec is None:
        link_spec = link_spec_from_config(cfg, loss_rate=loss_rate)
    elif loss_rate is not None:
        # Authoritative: also strips a channel_params ("loss_rate", x)
        # entry that would otherwise shadow the caller's rate.
        link_spec = link_spec.with_channel_loss_rate(loss_rate)
    if link_rate is not None:
        if mode == "train":
            link_spec = link_spec.with_train_rate(link_rate)
        else:
            link_spec = link_spec.with_channel_loss_rate(link_rate)
    spec = dataclasses.replace(link_spec, compressor=compressor)

    def fn(x):
        return comtune.emulate_link(key, x, spec, mode)

    return fn


def make_slotwise_link_fn(
    cfg: ModelConfig,
    link_params: Params,
    keys: jax.Array,                   # (B, 2) uint32 — one key per slot
    mode: str,
    loss_rate: Optional[float] = None,
    link_spec: Optional[comtune.LinkSpec] = None,
    live: Optional[jax.Array] = None,  # (B,) bool — weights for obs totals
):
    """Per-slot link for a *batched* decode step over shared state.

    The contiguous slot-pool engine vmaps the whole serve step, so each
    lane's :func:`make_link_fn` closure naturally draws from that lane's
    key.  The paged engine cannot vmap (the block pool is shared across
    slots), so this builds the equivalent batched link: the split-point
    activation ``(B, S, d)`` is vmapped row-by-row through
    ``comtune.emulate_link`` with per-slot keys — bitwise the same draws
    as the vmapped-engine form.  Each row's tap totals come out of the
    vmap as batched outputs and are re-published to the ambient collector
    weighted by ``live`` (matching the contiguous engine's live-masked
    counter accumulation; dead slots still compute, but never count).
    """
    if mode == "off":
        return None
    compressor = _compressor_from_params(cfg, link_params)
    if link_spec is None:
        link_spec = link_spec_from_config(cfg, loss_rate=loss_rate)
    elif loss_rate is not None:
        link_spec = link_spec.with_channel_loss_rate(loss_rate)
    spec = dataclasses.replace(link_spec, compressor=compressor)

    from repro.obs import device as obs_device

    def fn(x):                                       # (B, S, d)
        def one(k, xr):
            with obs_device.tap_link_stats() as tap:
                y = comtune.emulate_link(k, xr[None], spec, mode)
                totals = tap.totals()
            return y[0], totals

        y, totals = jax.vmap(one)(keys, x)
        w = (
            jnp.ones((x.shape[0],), jnp.float32)
            if live is None
            else live.astype(jnp.float32)
        )
        obs_device.emit(
            {name: jnp.sum(w * v) for name, v in totals.items()}
        )
        return y

    return fn


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def forward(
    params: Params,
    tokens: jax.Array,                 # (B, S) int32
    cfg: ModelConfig,
    *,
    positions: Optional[jax.Array] = None,
    frontend_embed: Optional[jax.Array] = None,
    cache: Optional[Dict[str, Any]] = None,
    cache_index=None,
    link_key: Optional[jax.Array] = None,
    link_mode: str = "off",
    loss_rate: Optional[float] = None,
    link_spec: Optional[comtune.LinkSpec] = None,
    link_rate=None,
    link_fn=None,
    mode: str = "train",
) -> Tuple[jax.Array, Optional[Dict[str, Any]], jax.Array]:
    """Returns (logits (B, S, V) float32, new_cache, moe_aux).

    ``link_spec`` carries the full emulated-link configuration (channel
    process, FEC, train-time emulation kind, curriculum rate); when omitted
    it is derived from ``cfg.link``.  ``link_rate`` (possibly traced)
    overrides the emulation rate — see :func:`make_link_fn`.  ``link_fn``
    replaces the link layer entirely with a caller-supplied callable
    (e.g. the eval hook forcing a *realized* delivery mask at the split)."""
    b, s = tokens.shape
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(jnp.sqrt(jnp.float32(cfg.d_model)), x.dtype)
    if cfg.frontend and frontend_embed is not None:
        x = frontends.fuse_frontend(params["frontend"], x, frontend_embed)

    if positions is None:
        offset = cache_index if cache_index is not None else 0
        positions = rope_lib.default_positions(
            b, s, offset=offset, mrope=bool(cfg.mrope_sections)
        )

    if link_fn is None:
        link_fn = make_link_fn(
            cfg, params["link"], link_key, link_mode, loss_rate=loss_rate,
            link_spec=link_spec, link_rate=link_rate,
        )
    x, new_cache, aux = transformer.run_stack(
        params["stack"],
        x,
        cfg,
        positions,
        cache=cache,
        cache_index=cache_index,
        link_fn=link_fn,
        mode=mode,
    )
    x = apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = x @ params["lm_head"]
    return logits.astype(jnp.float32), new_cache, aux


def lm_loss(
    logits: jax.Array, tokens: jax.Array, aux: jax.Array, aux_coef: float
) -> jax.Array:
    """Next-token cross entropy (shift-by-one) + MoE load-balance aux.

    Sharded-vocab-safe formulation: the target logit is extracted with a
    one-hot contraction over the (model-sharded) vocab dim and the logsumexp
    is a reduction — both lower to tiny (B, S) all-reduces.  The naive
    ``take_along_axis(log_softmax(...))`` gathers the full f32 logits across
    the mesh (measured: 2x40 GB/device/step on qwen1.5-0.5b x train_4k;
    see EXPERIMENTS.md §Perf iteration 1)."""
    targets = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lg, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(targets, lg.shape[-1], dtype=lg.dtype)
    target_logit = jnp.sum(lg * onehot, axis=-1)
    nll = lse - target_logit
    return nll.mean() + aux_coef * aux
