"""Grouped-query attention with RoPE/M-RoPE, sliding windows and KV caches.

Softmax implementations, selected by ``cfg.attn_impl``:

* ``naive``        — materializes (Sq, Skv) scores; smoke tests.  At decode
                     (Sq == 1) it masks dead cache slots before softmax and,
                     when ``cache_index`` is a concrete int, slices the
                     valid prefix so only live positions are dequantized.
* ``blockwise``    — online-softmax over KV blocks inside a scan over Q
                     blocks (FlashAttention recurrence in pure jnp) for
                     train/prefill: activation memory is O(S · block)
                     instead of O(S²).  The Pallas kernel in
                     ``repro/kernels/flash_attention`` implements the same
                     recurrence with explicit VMEM tiling for TPU.
* ``flash_decode`` — train/prefill as ``blockwise``; the s == 1 decode step
                     runs ``repro/kernels/decode_attention`` — length-masked
                     online softmax that reads only ``ceil(valid/block)``
                     cache blocks and dequantizes int8 KV inline, making the
                     decode step O(valid tokens) instead of O(max_seq).
                     ``blockwise`` configs also take this decode path (it is
                     the production default the serve engines compile);
                     ``naive`` keeps the full-cache matvec as the oracle.

Sliding-window layers keep a **rotating KV cache** of ``window`` slots;
RoPE is applied at write time so cached keys need no absolute positions at
read time.  Rotating writes land at ``index % C``, so the live slots are
always the contiguous prefix ``[0, min(index + 1, C))`` — the one fact the
length-masked decode paths rely on.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import rope as rope_lib
from repro.models.common import Params, dense_init, split_keys, zeros_init

NEG_INF = -1.0e30


class PagedIndex(NamedTuple):
    """Paged-decode coordinates, passed as ``cache_index`` when the decode
    state is a block pool instead of a contiguous cache.

    The stack closes over ``cache_index`` (it is not a scan operand), so the
    static ``max_seq`` / ``block_size`` ints ride through ``run_stack``
    untouched and each layer derives its own rotating length from them.
    ``live`` routes dead slots' decode writes to the reserved trash block 0
    — with a shared pool, a retired slot's blocks may already belong to a
    new request, so dirty writes must land somewhere unowned."""

    lengths: jax.Array        # (B,) int32 — tokens already cached per slot
    block_table: jax.Array    # (B, J) int32 — physical block ids (0 = trash)
    live: jax.Array           # (B,) bool — slot currently owns its blocks
    max_seq: int
    block_size: int


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    d, h, kv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dtype),
        "wk": dense_init(ks[1], (d, kv * hd), dtype),
        "wv": dense_init(ks[2], (d, kv * hd), dtype),
        "w_out": dense_init(ks[3], (h * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def _constrain_attention(qg, k, v, cfg: ModelConfig):
    """Pin q/k/v shardings for train/prefill attention when a production
    mesh context is active.  Preference order:
      1. shard KV heads over 'model' (contraction dims stay local);
      2. shard the batch over (data..., 'model') jointly — attention becomes
         fully per-example-local at the cost of one reshard per layer.
    Measured effect on arctic x train_4k: removes the 235 MB x 992 partial
    all-reduces inside the blockwise-attention loop."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.sharding import ctx as shard_ctx

    mesh = shard_ctx.shard_map_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return qg, k, v
    m = mesh.shape["model"]
    data_axes, _ = shard_ctx.mesh_axes(mesh)
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    b, _, kvh, g, _ = qg.shape
    h = kvh * g
    bs = data_axes if (data_axes and b % n_data == 0) else None
    cons = lambda x, s: jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, s)
    )

    if kvh % m == 0:
        q_spec = P(bs, None, "model", None, None)
        kv_spec = P(bs, None, "model", None)
        return cons(qg, q_spec), cons(k, kv_spec), cons(v, kv_spec)
    if h % m == 0 and g > 1:
        # Iteration 6: replicate KV heads up to H (2x KV memory for gemma3,
        # 8x for kimi) so the full query-head count shards over 'model'.
        bsz, s = qg.shape[0], qg.shape[1]
        hd = qg.shape[-1]
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        qg = qg.reshape(bsz, s, h, 1, hd)
        q_spec = P(bs, None, "model", None, None)
        kv_spec = P(bs, None, "model", None)
        return cons(qg, q_spec), cons(k, kv_spec), cons(v, kv_spec)
    # Batch sharding over (data x model) was tried here and REFUTED:
    # the per-layer q/k/v+out reshard cost ~3x more than the partial
    # all-reduces it removed (arctic x train_4k: 39.3s -> 132s; see
    # EXPERIMENTS.md §Perf hillclimb 1 iteration 3).
    return qg, k, v


# ---------------------------------------------------------------------------
# Softmax attention cores
# ---------------------------------------------------------------------------

def _grouped(q: jax.Array, num_kv: int) -> jax.Array:
    """(B, S, H, hd) -> (B, S, KV, G, hd)."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, hd)


def _naive_attn(
    q: jax.Array,          # (B, Sq, KV, G, hd)
    k: jax.Array,          # (B, Skv, KV, hd)
    v: jax.Array,
    mask: jax.Array,       # broadcastable to (B, KV, G, Sq, Skv)
    softcap: float,
) -> jax.Array:
    hd = q.shape[-1]
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if softcap > 0.0:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out


def _blockwise_attn(
    q: jax.Array,          # (B, Sq, KV, G, hd)
    k: jax.Array,          # (B, Skv, KV, hd)
    v: jax.Array,
    *,
    causal: bool,
    window: int,
    q_offset,
    block_q: int,
    block_kv: int,
    softcap: float,
) -> jax.Array:
    """FlashAttention-style online softmax in pure jnp."""
    b, sq, kvh, g, hd = q.shape
    skv = k.shape[1]
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    # Pad to block multiples.
    pad_q = (-sq) % bq
    pad_kv = (-skv) % bkv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    nq = q.shape[1] // bq
    nkv = k.shape[1] // bkv
    qb = q.reshape(b, nq, bq, kvh, g, hd)
    kb = k.reshape(b, nkv, bkv, kvh, hd)
    vb = v.reshape(b, nkv, bkv, kvh, hd)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    def q_block(qi, qblk):
        q_pos = q_offset + qi * bq + jnp.arange(bq)  # (bq,)

        def kv_step(carry, inputs):
            acc, m, l = carry
            kj, kblk, vblk = inputs
            k_pos = kj * bkv + jnp.arange(bkv)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qblk, kblk).astype(jnp.float32) * scale
            if softcap > 0.0:
                s = jnp.tanh(s / softcap) * softcap
            msk = jnp.ones((bq, bkv), bool)
            if causal:
                msk &= k_pos[None, :] <= q_pos[:, None]
            if window > 0:
                msk &= q_pos[:, None] - k_pos[None, :] < window
            msk &= (k_pos[None, :] < skv)  # kv padding
            s = jnp.where(msk[None, None, None, :, :], s, NEG_INF)
            s_max = jnp.max(s, axis=-1)                        # (b,kv,g,bq)
            m_new = jnp.maximum(m, s_max)
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(msk[None, None, None, :, :], p, 0.0)
            correction = jnp.exp(m - m_new)
            l_new = l * correction + jnp.sum(p, axis=-1)
            acc_new = acc * correction[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(qblk.dtype), vblk
            ).astype(jnp.float32)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, kvh, g, bq, hd), jnp.float32)
        m0 = jnp.full((b, kvh, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, bq), jnp.float32)
        kjs = jnp.arange(nkv)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), (kjs, jnp.swapaxes(kb, 0, 1), jnp.swapaxes(vb, 0, 1))
        )
        out = acc / jnp.maximum(l[..., None], 1e-20)
        return jnp.einsum("bkgqh->bqkgh", out).astype(q.dtype)  # (b,bq,kv,g,hd)

    outs = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), jnp.swapaxes(qb, 0, 1)))
    out = jnp.swapaxes(outs, 0, 1).reshape(b, nq * bq, kvh, g, hd)
    return out[:, :sq]


# ---------------------------------------------------------------------------
# Cache helpers (rotating buffer for windowed layers)
# ---------------------------------------------------------------------------

def cache_len(spec: LayerSpec, max_seq: int) -> int:
    return min(max_seq, spec.window) if spec.window > 0 else max_seq


def init_kv_cache(
    batch: int, length: int, num_kv: int, head_dim: int, dtype,
    kv_cache_dtype: str = "",
) -> Params:
    """bf16 cache, or int8 + per-(pos, head) bf16 scales (§Perf hillclimb 3:
    decode is HBM-bound on the cache read; int8 halves cache bytes)."""
    if kv_cache_dtype == "int8":
        return {
            "k": jnp.zeros((batch, length, num_kv, head_dim), jnp.int8),
            "v": jnp.zeros((batch, length, num_kv, head_dim), jnp.int8),
            "k_scale": jnp.zeros((batch, length, num_kv), jnp.bfloat16),
            "v_scale": jnp.zeros((batch, length, num_kv), jnp.bfloat16),
        }
    return {
        "k": jnp.zeros((batch, length, num_kv, head_dim), dtype),
        "v": jnp.zeros((batch, length, num_kv, head_dim), dtype),
    }


def _quantize_kv(x: jax.Array):
    """(..., hd) -> int8 codes + per-(...,) bf16 scale (absmax)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    codes = jnp.round(x.astype(jnp.float32) / scale[..., None])
    return codes.astype(jnp.int8), scale.astype(jnp.bfloat16)


def _dequantize_kv(codes: jax.Array, scale: jax.Array, dtype):
    return (
        codes.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]
    ).astype(dtype)


def _is_quantized(cache: Params) -> bool:
    return "k_scale" in cache


def _read_cache(cache: Params, dtype):
    if _is_quantized(cache):
        return (
            _dequantize_kv(cache["k"], cache["k_scale"], dtype),
            _dequantize_kv(cache["v"], cache["v_scale"], dtype),
        )
    return cache["k"], cache["v"]


def _write_decode(cache: Params, k: jax.Array, v: jax.Array, index) -> Params:
    """Write one position (S==1) at rotating slot index % C."""
    c = cache["k"].shape[1]
    slot = index % c
    upd = lambda buf, val: jax.lax.dynamic_update_slice_in_dim(buf, val, slot, axis=1)
    if _is_quantized(cache):
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        return {
            "k": upd(cache["k"], kq), "v": upd(cache["v"], vq),
            "k_scale": upd(cache["k_scale"], ks),
            "v_scale": upd(cache["v_scale"], vs),
        }
    return {"k": upd(cache["k"], k), "v": upd(cache["v"], v)}


def _write_decode_paged(
    cache: Params, k: jax.Array, v: jax.Array, idx: PagedIndex, c_len: int
) -> Params:
    """Paged twin of :func:`_write_decode`: scatter each slot's one new
    position into its block-table row.  Logical row ``lengths % c_len``
    (same rotation as contiguous) maps to block ``row // block_size``,
    offset ``row % block_size``; dead slots write trash block 0."""
    bs = cache["k"].shape[1]
    row = idx.lengths % c_len                                    # (B,)
    ent = jnp.take_along_axis(
        idx.block_table, (row // bs)[:, None], axis=1
    )[:, 0]
    phys = jnp.where(idx.live, ent, 0)
    rin = row % bs

    def upd(buf, val):
        return buf.at[phys, rin].set(val)

    if _is_quantized(cache):
        kq, ks = _quantize_kv(k[:, 0])
        vq, vs = _quantize_kv(v[:, 0])
        return {
            "k": upd(cache["k"], kq), "v": upd(cache["v"], vq),
            "k_scale": upd(cache["k_scale"], ks),
            "v_scale": upd(cache["v_scale"], vs),
        }
    return {"k": upd(cache["k"], k[:, 0]), "v": upd(cache["v"], v[:, 0])}


def _concrete_index(cache_index) -> Optional[int]:
    """``cache_index`` as a Python int when it is statically known (plain
    int or concrete jax scalar outside jit); None for tracers."""
    if isinstance(cache_index, (int, np.integer)):
        return int(cache_index)
    try:
        return int(cache_index)
    except (jax.errors.TracerIntegerConversionError,
            jax.errors.ConcretizationTypeError, TypeError):
        return None


def _masked_decode_attn(
    qg: jax.Array, cache: Params, cache_index, softcap: float, dtype
) -> jax.Array:
    """Decode (s == 1) fallback: validity-masked ``_naive_attn`` over the
    rotating buffer.  When ``cache_index`` is concrete (e.g. the un-jitted
    reference loop) the valid prefix is sliced out FIRST, so only live
    positions are dequantized/read — the full-cache dequant the int8 cache
    otherwise pays every step.  Traced indices (every jitted engine) keep
    the fixed-shape masked form; they escape O(max_seq) via the
    ``flash_decode`` path instead."""
    c = cache["k"].shape[1]
    idx = _concrete_index(cache_index)
    if idx is not None:
        n_valid = min(idx + 1, c)
        cache = {name: buf[:, :n_valid] for name, buf in cache.items()}
        valid = jnp.ones((1, n_valid), bool)
    else:
        n_valid = jnp.minimum(cache_index + 1, c)  # scalar
        valid = jnp.arange(c)[None, :] < n_valid   # (1, C)
    mask = valid[:, None, None, None, :]           # (1,1,1,1,C) -> bcast
    k_read, v_read = _read_cache(cache, dtype)
    return _naive_attn(qg, k_read, v_read, mask, softcap)


def _write_prefill(cache: Params, k: jax.Array, v: jax.Array) -> Params:
    """Write a full prefill (positions 0..S-1) consistent with rotating
    decode writes: position p lands in slot p % C, keeping only the last C."""
    c = cache["k"].shape[1]
    s = k.shape[1]
    quant = _is_quantized(cache)
    if quant:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        parts = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    else:
        parts = {"k": k, "v": v}
    out = {}
    if s <= c:
        for name, val in parts.items():
            out[name] = jax.lax.dynamic_update_slice_in_dim(
                cache[name], val, 0, axis=1
            )
        return out
    slots = (jnp.arange(c) + (s - c)) % c
    for name, val in parts.items():
        out[name] = cache[name].at[:, slots].set(val[:, s - c :])
    return out


# ---------------------------------------------------------------------------
# Layer forward
# ---------------------------------------------------------------------------

def attention_forward(
    p: Params,
    x: jax.Array,                      # (B, S, d)
    cfg: ModelConfig,
    spec: LayerSpec,
    positions: jax.Array,              # (B, S) or (B, 3, S)
    cache: Optional[Params] = None,
    cache_index=None,                  # scalar count of tokens already cached
) -> Tuple[jax.Array, Optional[Params]]:
    b, s, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim

    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kvh, hd)
    v = v.reshape(b, s, kvh, hd)
    q = rope_lib.apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = rope_lib.apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    qg = _grouped(q, kvh)
    if cache is None:
        # Production-mesh activation sharding for the blockwise loop
        # (EXPERIMENTS.md §Perf hillclimb 1, iterations 2+6): KV-head
        # sharding when divisible; else replicate KV heads up to the query
        # head count when THAT divides (gemma3/kimi/qwen2-vl GQA pattern) —
        # either way the online-softmax loop becomes communication-free.
        qg, k, v = _constrain_attention(qg, k, v, cfg)

    new_cache = None
    if cache is not None and s == 1 and isinstance(cache_index, PagedIndex):
        # ---- paged decode: scatter into the block pool, attend via the
        # block table.  Always the flash-decode kernel/ref — the block
        # pool has no contiguous layout for the naive oracle to read.
        idx = cache_index
        c = cache_len(spec, idx.max_seq)
        new_cache = _write_decode_paged(cache, k, v, idx, c)
        from repro.kernels.decode_attention import paged_decode_attention

        n_valid = jnp.minimum(idx.lengths.astype(jnp.int32) + 1, c)
        out = paged_decode_attention(
            qg, new_cache, idx.block_table, n_valid,
            seq_len=c,
            block_size=idx.block_size,
            softcap=cfg.logit_softcap,
        )
    elif cache is not None and s == 1:
        # ---- decode: write one slot, attend over the rotating buffer ----
        new_cache = _write_decode(cache, k, v, cache_index)
        if cfg.attn_impl in ("flash_decode", "blockwise"):
            # Length-masked flash decode: O(valid) cache blocks read,
            # int8 KV dequantized inline — the serve engines' default.
            from repro.kernels.decode_attention import decode_attention

            c = new_cache["k"].shape[1]
            n_valid = jnp.minimum(
                jnp.asarray(cache_index, jnp.int32) + 1, c
            )
            out = decode_attention(
                qg, new_cache, n_valid,
                softcap=cfg.logit_softcap,
                block_kv=cfg.attn_decode_block_kv,
            )
        else:
            out = _masked_decode_attn(
                qg, new_cache, cache_index, cfg.logit_softcap, k.dtype
            )
    else:
        # ---- train / prefill: self-attention over the fresh sequence ----
        if cfg.attn_impl in ("blockwise", "flash_decode") and s > cfg.attn_block_q:
            out = _blockwise_attn(
                qg,
                k,
                v,
                causal=True,
                window=spec.window,
                q_offset=0,
                block_q=cfg.attn_block_q,
                block_kv=cfg.attn_block_kv,
                softcap=cfg.logit_softcap,
            )
        else:
            q_pos = jnp.arange(s)
            msk = q_pos[:, None] >= q_pos[None, :]
            if spec.window > 0:
                msk &= q_pos[:, None] - q_pos[None, :] < spec.window
            out = _naive_attn(
                qg, k, v, msk[None, None, None, :, :], cfg.logit_softcap
            )
        if cache is not None:
            new_cache = _write_prefill(cache, k, v)

    out = out.reshape(b, s, h * hd)
    return out @ p["w_out"], new_cache
