"""COMtune core: lossy-link model, compression, and the split-model
fine-tuning/serving compositions (the paper's contribution)."""

from repro.core.comtune import (  # noqa: F401
    LinkSpec,
    channel_link,
    comtune_forward,
    di_latency_s,
    distributed_inference,
    dropout_link,
    emulate_link,
    message_bytes,
)
from repro.core.compression import Compressor, PCASpec, QuantSpec  # noqa: F401
from repro.core.link import ChannelConfig, apply_channel  # noqa: F401
