"""Calibration of compression parameters (paper Appendix A).

* Quantization scale factors ``s_min``/``s_max`` are determined *per element*
  "based on the range of the distribution of the element using the
  pre-obtained dataset" — we use per-feature min/max (optionally percentile
  clipped) over a calibration batch of split-point activations.
* PCA basis ``w`` (top-D' eigenvectors of the activation covariance, Eq. 20-22)
  and bias ``b`` (Eq. 23).  Eigenvectors are computed with NumPy's symmetric
  eigendecomposition on the (D, D) covariance — D is a feature dim (e.g.
  16384 for the paper's CNN, d_model for LMs), fine on host.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import Compressor, PCASpec, QuantSpec


def collect_activations(apply_fn, params, batches) -> np.ndarray:
    """Run the device-side sub-model over calibration batches and stack the
    flattened split-point activations into (N, D)."""
    outs = []
    for batch in batches:
        a = apply_fn(params, batch)
        a = np.asarray(a)
        outs.append(a.reshape(-1, a.shape[-1]))
    return np.concatenate(outs, axis=0)


def calibrate_quant(
    activations: np.ndarray,
    bits: int,
    percentile: float = 0.0,
) -> QuantSpec:
    """Per-feature scale factors. ``percentile`` > 0 trims outliers
    symmetrically (e.g. 0.1 -> use the 0.1/99.9 percentiles)."""
    if percentile > 0.0:
        s_min = np.percentile(activations, percentile, axis=0)
        s_max = np.percentile(activations, 100.0 - percentile, axis=0)
    else:
        s_min = activations.min(axis=0)
        s_max = activations.max(axis=0)
    # Guard degenerate features.
    flat = s_max - s_min < 1e-6
    s_max = np.where(flat, s_min + 1e-6, s_max)
    return QuantSpec(
        bits=bits,
        s_min=jnp.asarray(s_min, jnp.float32),
        s_max=jnp.asarray(s_max, jnp.float32),
    )


def calibrate_pca(activations: np.ndarray, reduced_dim: int) -> PCASpec:
    """Eq. (20)-(23). activations: (N, D)."""
    a = np.asarray(activations, dtype=np.float64)
    mean = a.mean(axis=0)
    centered = a - mean
    # Covariance S (Eq. 20); use the N x N trick when N < D.
    n, d = centered.shape
    if n >= d:
        cov = centered.T @ centered / n
        eigval, eigvec = np.linalg.eigh(cov)  # ascending
        order = np.argsort(eigval)[::-1]
        basis = eigvec[:, order].T  # rows = eigenvectors, descending eigval
    else:
        gram = centered @ centered.T / n
        eigval, eigvec = np.linalg.eigh(gram)
        order = np.argsort(eigval)[::-1]
        eigval = np.maximum(eigval[order], 1e-12)
        # v_i = X^T u_i / sqrt(n * lambda_i)
        basis = (centered.T @ eigvec[:, order] / np.sqrt(n * eigval)).T
    w = basis[:reduced_dim]  # (D', D)
    # Bias b: projection of the mean onto the DISCARDED eigenvectors (Eq. 23).
    # Equivalent: b = mean - w^T w mean.
    b = mean - w.T @ (w @ mean)
    return PCASpec(w=jnp.asarray(w, jnp.float32), b=jnp.asarray(b, jnp.float32))


def make_compressor(
    activations: np.ndarray,
    *,
    kind: str,
    message_bytes: float | None = None,
    bits: int | None = None,
    reduced_dim: int | None = None,
    percentile: float = 0.0,
) -> Compressor:
    """Build a Compressor sized for a target message size M (paper's knob)
    or from explicit bits / reduced_dim."""
    d = activations.shape[-1]
    float_bytes = 4.0
    if kind == "identity":
        return Compressor(kind="identity")
    if kind == "quant":
        if bits is None:
            assert message_bytes is not None
            bits = QuantSpec.bits_for_message_size(message_bytes, d * float_bytes)
        return Compressor(kind="quant", quant=calibrate_quant(activations, bits, percentile))
    if kind == "pca":
        if reduced_dim is None:
            assert message_bytes is not None
            reduced_dim = PCASpec.reduced_dim_for_message_size(
                message_bytes, float_bytes, d
            )
        return Compressor(kind="pca", pca=calibrate_pca(activations, reduced_dim))
    raise ValueError(kind)
