"""COMtune — the paper's contribution (§III-C/D) as a composable JAX module.

Two compositions over a split model ``f = f_out ∘ f_in``:

* Fine-tuning graph (Eq. 8):
      f_trn = f_out ∘ f_dec ∘ f_d(r) ∘ f_cmp ∘ f_in
  where ``f_d`` is inverted dropout with rate ``r`` (Eq. 7) emulating the
  channel + receiver compensation.

* Distributed-inference graph (Eq. 12):
      y = f_out ∘ f_dec ∘ (1/(1-p) · f_c(p)) ∘ f_cmp ∘ f_in
  where ``f_c`` is the real (simulated) packet-loss channel (Eq. 1/10) and
  the receiver compensates by 1/(1-p) (Eq. 11).

``LinkSpec`` carries everything about the emulated link: dropout rate for
training, loss rate + granularity for serving, the compressor, and whether
the fused Pallas egress kernel should be used on the serving path.

These functions are architecture-agnostic: ``f_in``/``f_out`` are arbitrary
callables (CNN halves in the paper reproduction, transformer layer-stacks in
the LM framework).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import link as link_lib
from repro.core.compression import Compressor


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """Configuration of the emulated IoT link at the split point."""

    dropout_rate: float = 0.0          # r used during COMtune fine-tuning
    loss_rate: float = 0.0             # p used during DI serving
    compressor: Compressor = dataclasses.field(default_factory=Compressor)
    granularity: str = "element"       # "element" (Eq. 1) or "packet" (Eq. 2-3)
    elements_per_packet: int = 25      # 100 B packets / 4 B floats
    shuffle: bool = True               # paper's anti-burst interleaving
    use_kernel: bool = False           # fused Pallas egress on serve path
    adaptive_compensation: bool = False  # beyond-paper: use observed 1/(1-p̂)

    def with_loss_rate(self, p: float) -> "LinkSpec":
        return dataclasses.replace(self, loss_rate=p)

    def with_dropout_rate(self, r: float) -> "LinkSpec":
        return dataclasses.replace(self, dropout_rate=r)


# ---------------------------------------------------------------------------
# Link layers
# ---------------------------------------------------------------------------

def dropout_link(key: jax.Array, x: jax.Array, rate: float) -> jax.Array:
    """Eq. (7): inverted dropout — the paper's channel emulation layer."""
    if rate <= 0.0:
        return x
    keep = jax.random.bernoulli(key, 1.0 - rate, x.shape)
    return jnp.where(keep, x / jnp.asarray(1.0 - rate, x.dtype), 0.0)


def channel_link(key: jax.Array, x: jax.Array, spec: LinkSpec) -> jax.Array:
    """Eq. (10)-(11): the serving-time channel + compensation, acting on the
    *compressed* message representation."""
    if spec.loss_rate <= 0.0:
        return x
    if spec.adaptive_compensation:
        # Beyond-paper: compensate by the realized keep fraction p̂ rather
        # than the nominal p — unbiased per-message instead of in expectation.
        if spec.granularity == "element":
            mask = link_lib.element_loss_mask(key, x.shape, spec.loss_rate)
        else:
            flat = link_lib.packet_loss_mask(
                key, x.size, spec.loss_rate, spec.elements_per_packet, spec.shuffle
            )
            mask = flat.reshape(x.shape)
        kept = jnp.maximum(mask.mean(), 1e-3)
        return x * mask.astype(x.dtype) / kept.astype(x.dtype)
    return link_lib.apply_channel(
        key,
        x,
        spec.loss_rate,
        granularity=spec.granularity,
        elements_per_packet=spec.elements_per_packet,
        shuffle=spec.shuffle,
        compensate=True,
    )


# ---------------------------------------------------------------------------
# Split-model compositions
# ---------------------------------------------------------------------------

SubModel = Callable[..., jax.Array]  # (params, x, ...) -> activation / logits


def comtune_forward(
    f_in: SubModel,
    f_out: SubModel,
    params_in: Any,
    params_out: Any,
    x: jax.Array,
    key: jax.Array,
    spec: LinkSpec,
    train: bool = True,
) -> jax.Array:
    """Eq. (8): the fine-tuning graph.  Dropout emulates the channel; the
    compressor is applied as a differentiable roundtrip (STE for quant)."""
    a = f_in(params_in, x)
    a = spec.compressor.roundtrip_train(a)
    if train:
        a = dropout_link(key, a, spec.dropout_rate)
    return f_out(params_out, a)


def distributed_inference(
    f_in: SubModel,
    f_out: SubModel,
    params_in: Any,
    params_out: Any,
    x: jax.Array,
    key: jax.Array,
    spec: LinkSpec,
) -> jax.Array:
    """Eq. (12): the DI serving graph.

    device side:  a  = f_cmp(f_in(x))          -> transmitted message
    channel:      a' = f_c(a | p)              -> drops
    server side:  y  = f_out(f_dec(a' / (1-p)))
    """
    a_raw = f_in(params_in, x)
    msg = spec.compressor.compress(a_raw)
    if spec.use_kernel and spec.compressor.kind == "quant":
        from repro.kernels.lossy_link import ops as ll_ops

        a_rec = ll_ops.lossy_link_egress(
            key,
            a_raw,
            spec.compressor.quant,
            spec.loss_rate,
        )
    else:
        msg = channel_link(key, msg, spec)
        a_rec = spec.compressor.decompress(msg)
    return f_out(params_out, a_rec)


def message_bytes(spec: LinkSpec, feature_dim: int) -> float:
    """Size of one transmitted message (per activation vector)."""
    n = spec.compressor.message_elements(feature_dim)
    return n * spec.compressor.bytes_per_element()


def di_latency_s(
    spec: LinkSpec,
    feature_dim: int,
    batch: int,
    channel: link_lib.ChannelConfig,
) -> float:
    """Communication latency of one DI round (unreliable protocol,
    §III-B): n_t * l / b."""
    total_bytes = message_bytes(spec, feature_dim) * batch
    n_t = -(-int(total_bytes) // channel.packet_bytes)
    return n_t * channel.slot_time_s()
