"""COMtune — the paper's contribution (§III-C/D) as a composable JAX module.

Two compositions over a split model ``f = f_out ∘ f_in``:

* Fine-tuning graph (Eq. 8):
      f_trn = f_out ∘ f_dec ∘ f_d(r) ∘ f_cmp ∘ f_in
  where ``f_d`` emulates the channel + receiver compensation.  The paper
  uses inverted dropout with rate ``r`` (Eq. 7); ``spec.train_link =
  "channel"`` replaces it with the *deployment* channel — stateful burst
  masks (Gilbert–Elliott / fading / trace), ``shuffle=False`` senders, and
  differentiable FEC emulation — so fine-tuning targets the link the model
  will actually serve on.

* Distributed-inference graph (Eq. 12):
      y = f_out ∘ f_dec ∘ (1/(1-p) · f_c(p)) ∘ f_cmp ∘ f_in
  where ``f_c`` is the real (simulated) packet-loss channel (Eq. 1/10) and
  the receiver compensates by 1/(1-p) (Eq. 11).

Both graphs route through ONE entry point, :func:`emulate_link` — the
single differentiable link path shared by training and serving, so any
channel/FEC configuration the serving stack supports can also be trained
against.

``LinkSpec`` carries everything about the emulated link: the train-time
emulation kind + dropout rate, loss rate + granularity for serving, the
compressor, channel process, FEC code, and whether the fused Pallas egress
kernel should be used on the serving path.

These functions are architecture-agnostic: ``f_in``/``f_out`` are arbitrary
callables (CNN halves in the paper reproduction, transformer layer-stacks in
the LM framework).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import link as link_lib
from repro.core.link import MIN_KEEP_FRACTION
from repro.core.compression import Compressor
from repro.obs import device as obs_device


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """Configuration of the emulated IoT link at the split point."""

    dropout_rate: float = 0.0          # r used during COMtune fine-tuning
    loss_rate: float = 0.0             # p used during DI serving
    # What emulates the channel in the fine-tuning graph (Eq. 8):
    #   "dropout" — the paper's Eq. 7 inverted dropout at dropout_rate.
    #   "channel" — the full serving channel (spec.channel/fec_*) at
    #               loss_rate, with straight-through mask gradients.
    train_link: str = "dropout"
    compressor: Compressor = dataclasses.field(default_factory=Compressor)
    granularity: str = "element"       # "element" (Eq. 1) or "packet" (Eq. 2-3)
    elements_per_packet: int = 25      # 100 B packets / 4 B floats
    shuffle: bool = True               # paper's anti-burst interleaving
    use_kernel: bool = False           # fused Pallas egress on serve path
    adaptive_compensation: bool = False  # beyond-paper: use observed 1/(1-p̂)

    # Channel process (repro.net.channels registry).  "iid" is the paper's
    # memoryless channel; "ge"/"gilbert_elliott", "fading", "trace" select
    # the stateful models.  channel_params is a hashable tuple of (name,
    # value) pairs forwarded to net.channels.make_channel.
    channel: str = "iid"
    channel_params: tuple = ()

    # Packet-level FEC on the serve/train path (repro.net.fec): k data +
    # m parity packets per block; m = 0 disables coding.
    fec_k: int = 0
    fec_m: int = 0
    fec_kind: str = "rs"

    def with_loss_rate(self, p: float) -> "LinkSpec":
        return self.with_channel_loss_rate(p)

    def with_dropout_rate(self, r: float) -> "LinkSpec":
        return dataclasses.replace(self, dropout_rate=r)

    def with_train_link(self, kind: str) -> "LinkSpec":
        return dataclasses.replace(self, train_link=kind)

    def with_channel_loss_rate(self, rate: float) -> "LinkSpec":
        """Set ``loss_rate`` authoritatively: any ``("loss_rate", x)``
        entry in channel_params is dropped, since it would shadow the new
        rate in ``resolve_channel``/``channel_link`` and silently pin the
        channel at the old value."""
        params = tuple(
            (k, v) for k, v in self.channel_params if k != "loss_rate"
        )
        return dataclasses.replace(self, loss_rate=rate, channel_params=params)

    def with_train_rate(self, rate: float) -> "LinkSpec":
        """Set the rate the *training* emulation draws losses at: the
        dropout rate for ``train_link="dropout"``, the (authoritative)
        channel loss rate for ``train_link="channel"`` (curriculum
        schedules use this)."""
        if self.train_link == "channel":
            return self.with_channel_loss_rate(rate)
        return dataclasses.replace(self, dropout_rate=rate)

    def with_channel(self, channel: str, **params) -> "LinkSpec":
        return dataclasses.replace(
            self, channel=channel, channel_params=tuple(sorted(params.items()))
        )

    @property
    def uses_net_path(self) -> bool:
        """True when the link cannot take the plain-iid fast paths (e.g.
        the fused egress kernel, which bakes in spec.loss_rate): a stateful
        channel, FEC protection, or a channel_params loss_rate override."""
        return (
            self.channel not in ("", "iid")
            or self.fec_m > 0
            or "loss_rate" in dict(self.channel_params)
        )

    @property
    def fec_spec(self):
        if self.fec_m <= 0:
            return None
        from repro.net.fec import FECSpec

        return FECSpec(k=max(self.fec_k, 1), m=self.fec_m, kind=self.fec_kind)

    def resolve_channel(self):
        """Instantiate the net.channels model this spec names.  An explicit
        ("loss_rate", x) entry in channel_params overrides spec.loss_rate."""
        from repro.net import channels as net_channels

        params = dict(self.channel_params)
        loss_rate = params.pop("loss_rate", self.loss_rate)
        return net_channels.make_channel(
            self.channel or "iid", loss_rate=loss_rate, **params
        )


# ---------------------------------------------------------------------------
# Link layers
# ---------------------------------------------------------------------------

def dropout_link(key: jax.Array, x: jax.Array, rate) -> jax.Array:
    """Eq. (7): inverted dropout — the paper's channel emulation layer.

    ``rate`` may be a traced scalar (the per-step curriculum passes the
    ramped rate as scan data); the zero-rate shortcut only applies to
    static Python rates, and a traced rate draws the same bernoulli bits
    as the equal static rate (uniform < p), so constant traced schedules
    stay bit-identical to the static path."""
    if isinstance(rate, (int, float)) and rate <= 0.0:
        obs_device.record_full_keep(x.size)
        return x
    keep = jax.random.bernoulli(key, 1.0 - rate, x.shape)
    obs_device.record_mask(keep)
    return jnp.where(keep, x / jnp.asarray(1.0 - rate, x.dtype), 0.0)


def _stateful_channel_mask(key: jax.Array, x: jax.Array, spec: LinkSpec):
    """Keep-mask + effective stationary loss rate for the non-iid channels
    (repro.net), honoring FEC protection when enabled."""
    from repro.net import fec as fec_lib
    from repro.net.channels import element_mask_from_packets

    ch = spec.resolve_channel()
    fspec = spec.fec_spec
    if fspec is not None:
        flat = fec_lib.fec_element_keep_jnp(
            key, ch, x.size, spec.elements_per_packet, fspec,
            shuffle=spec.shuffle,
        )
        p_eff = fec_lib.residual_loss_rate(fspec, ch)
        return flat.reshape(x.shape), p_eff
    if spec.use_kernel and spec.channel in ("ge", "gilbert_elliott"):
        # Fused Pallas path: Gilbert–Elliott packet masks generated
        # on-device so the jit-compiled serving step never leaves XLA.
        from repro.kernels.lossy_link import ops as ll_ops

        kperm, kmask = jax.random.split(key)
        n_packets = -(-x.size // spec.elements_per_packet)
        pkt = ll_ops.burst_mask(
            kmask, 1, n_packets,
            p_gb=ch.p_gb, p_bg=ch.p_bg,
            loss_good=ch.loss_good, loss_bad=ch.loss_bad,
        )[0]
        flat = element_mask_from_packets(
            pkt, x.size, spec.elements_per_packet, kperm, spec.shuffle
        )
    else:
        flat = ch.element_keep_jnp(
            key, x.size, spec.elements_per_packet, shuffle=spec.shuffle
        )
    return flat.reshape(x.shape), ch.stationary_loss_rate


def channel_link(key: jax.Array, x: jax.Array, spec: LinkSpec) -> jax.Array:
    """Eq. (10)-(11): the channel + compensation, acting on the
    *compressed* message representation (serve path), or on the STE
    roundtrip activation when the train graph emulates the deployment
    channel (``emulate_link`` with ``train_link="channel"``; masks and
    compensation are stop-gradient, so grads are identity-on-mask).
    ``spec.channel`` selects the
    channel process: "iid" keeps the paper's Eq. 1-3 path (with the
    channel_params loss_rate override honored in place); the stateful
    models (Gilbert–Elliott bursts, Markov fading, trace replay) and FEC
    protection route through ``repro.net`` — including iid+FEC, which gets
    real block-recovery emulation and residual-rate compensation."""
    if spec.channel in ("", "iid") and spec.fec_m <= 0:
        # Paper path (Eq. 1-3), honoring spec.granularity.  A channel_params
        # loss_rate override just replaces the rate here, preserving the
        # element/packet statistics the caller configured.  The rate may be
        # a traced scalar (per-step curriculum); only a static zero takes
        # the shortcut.
        loss_rate = dict(spec.channel_params).get("loss_rate", spec.loss_rate)
        if isinstance(loss_rate, (int, float)) and loss_rate <= 0.0:
            obs_device.record_full_keep(x.size)
            return x
        if spec.adaptive_compensation:
            # Beyond-paper: compensate by the realized keep fraction p̂
            # rather than the nominal p — unbiased per-message instead of
            # in expectation.
            if spec.granularity == "element":
                mask = link_lib.element_loss_mask(key, x.shape, loss_rate)
            else:
                flat = link_lib.packet_loss_mask(
                    key, x.size, loss_rate, spec.elements_per_packet,
                    spec.shuffle,
                )
                mask = flat.reshape(x.shape)
            mask = jax.lax.stop_gradient(mask)
            obs_device.record_mask(mask)
            kept = jnp.maximum(mask.mean(), MIN_KEEP_FRACTION)
            return x * mask.astype(x.dtype) / kept.astype(x.dtype)
        return link_lib.apply_channel(
            key,
            x,
            loss_rate,
            granularity=spec.granularity,
            elements_per_packet=spec.elements_per_packet,
            shuffle=spec.shuffle,
            compensate=True,
        )
    mask, p_eff = _stateful_channel_mask(key, x, spec)
    mask = jax.lax.stop_gradient(mask)
    obs_device.record_mask(mask)
    if spec.adaptive_compensation:
        kept = jnp.maximum(mask.mean(), MIN_KEEP_FRACTION)
        return x * mask.astype(x.dtype) / kept.astype(x.dtype)
    keep = max(1.0 - p_eff, MIN_KEEP_FRACTION)
    return x * mask.astype(x.dtype) / jnp.asarray(keep, x.dtype)


def streamed_channel_link(key: jax.Array, msg: jax.Array, spec: LinkSpec) -> jax.Array:
    """Per-position transmission of a (B, S, F) message: position ``i`` is
    its own DI link round drawn with ``fold_in(key, i)`` — exactly the
    per-round channel a decode step sees for its (B, 1, F) message.

    This is the serving prefill's channel model: the prompt activation is
    uploaded as ``S`` per-token rounds rather than one giant message.  Two
    properties the continuous-batching engine relies on:

    * **padding invariance** — position ``i``'s draw depends only on
      ``(key, i, msg[:, i])``, so right-padding a prompt to a bucket length
      leaves the masks on the real positions bit-identical to the unpadded
      draw (the whole-message draw has no such prefix property: threefry
      bits depend on the total element count);
    * **decode-round consistency** — each round uses the same
      ``channel_link`` the per-token decode path uses, with a fresh
      stationary channel-state draw per round, so burst statistics match
      the decode rounds instead of one long intra-message burst.  Position
      0 uses the RAW key (later positions fold in their index), so a
      streamed single-position message is bit-identical to the
      non-streamed (B, 1, F) decode-round draw — a length-1 prompt padded
      into a bucket matches its unpadded reference exactly.
    """
    idx = jnp.arange(msg.shape[1], dtype=jnp.int32)
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(idx)
    keys = keys.at[0].set(key)

    if not obs_device.tapping():
        def one(k, m):  # m: (B, F) — one position's message
            return channel_link(k, m[:, None, :], spec)[:, 0]

        return jax.vmap(one, in_axes=(0, 1), out_axes=1)(keys, msg)

    # Tapped variant: a collector installed OUTSIDE the vmap would leak
    # batch tracers, so each position installs its own collector and the
    # per-position totals come out as vmap outputs; the position-summed
    # stats are re-published to the ambient collector.
    def one_tapped(k, m):
        with obs_device.tap_link_stats() as tap:
            out = channel_link(k, m[:, None, :], spec)[:, 0]
        return out, tap.totals()

    out, stats = jax.vmap(one_tapped, in_axes=(0, 1), out_axes=(1, 0))(
        keys, msg
    )
    obs_device.emit({k: jnp.sum(v) for k, v in stats.items()})
    return out


# ---------------------------------------------------------------------------
# The one differentiable link path (train + serve)
# ---------------------------------------------------------------------------

def emulate_link(
    key: Optional[jax.Array], x: jax.Array, spec: LinkSpec, mode: str
) -> jax.Array:
    """THE link-emulation entry point: one differentiable path through
    compression + channel + compensation, shared by the fine-tuning graph
    (Eq. 8) and the DI serving graph (Eq. 12).

    mode:
      "train" -> STE compression roundtrip, then the emulation selected by
                 ``spec.train_link``:
                   "dropout" — Eq. 7 inverted dropout at ``dropout_rate``
                               (bit-compatible with the legacy path);
                   "channel" — the full serving channel at ``loss_rate``
                               (stateful burst masks, shuffle=False
                               senders, trace replay, FEC residual-loss
                               patterns) with straight-through
                               identity-on-mask gradients, so fine-tuning
                               can target the deployment link.
      "serve" -> Eq. 12: compress -> channel(p) -> 1/(1-p) -> decompress,
                 including the fused Pallas egress fast path.
      "clean" -> compression roundtrip only (reliable-protocol reference).
      "off"   -> identity.
    """
    if mode == "off":
        return x
    if mode == "clean":
        return spec.compressor.decompress(spec.compressor.compress(x))
    if mode == "train":
        a = spec.compressor.roundtrip_train(x)
        if spec.train_link == "dropout":
            return dropout_link(key, a, spec.dropout_rate)
        if spec.train_link == "channel":
            # channel_link stop-gradients its masks and compensation, so
            # grads flow identity-on-mask exactly as through Eq. 7 dropout.
            return channel_link(key, a, spec)
        raise ValueError(f"unknown train_link: {spec.train_link!r}")
    if mode == "serve":
        if x.ndim == 3 and x.shape[1] > 1:
            # Prefill-shaped (B, S, F) message: stream it as S per-token
            # rounds (see streamed_channel_link) — padding-invariant and
            # consistent with the per-round decode path.
            msg = spec.compressor.compress(x)
            msg = streamed_channel_link(key, msg, spec)
            return spec.compressor.decompress(msg)
        # The fused egress kernel implements the plain iid channel only;
        # anything on the net path (bursty channels, FEC, loss-rate
        # override) must route through channel_link (which has its own
        # Pallas burst_mask path for GE).
        if (
            spec.use_kernel
            and spec.compressor.kind == "quant"
            and not spec.uses_net_path
        ):
            from repro.kernels.lossy_link import ops as ll_ops

            if obs_device.tapping():
                # The fused kernel draws its keep mask internally from the
                # same uniforms (kernel.py: keep = u >= loss_rate, bit-exact
                # vs the jnp reference); redraw it here purely to count.
                u = jax.random.uniform(
                    key, (x.size // x.shape[-1], x.shape[-1]), jnp.float32
                )
                obs_device.record_mask(u >= jnp.float32(spec.loss_rate))
            return ll_ops.lossy_link_egress(
                key, x, spec.compressor.quant, spec.loss_rate
            )
        msg = spec.compressor.compress(x)
        msg = channel_link(key, msg, spec)
        return spec.compressor.decompress(msg)
    raise ValueError(f"unknown link mode: {mode!r}")


# ---------------------------------------------------------------------------
# Split-model compositions
# ---------------------------------------------------------------------------

SubModel = Callable[..., jax.Array]  # (params, x, ...) -> activation / logits


def comtune_forward(
    f_in: SubModel,
    f_out: SubModel,
    params_in: Any,
    params_out: Any,
    x: jax.Array,
    key: jax.Array,
    spec: LinkSpec,
    train: bool = True,
) -> jax.Array:
    """Eq. (8): the fine-tuning graph.  ``spec.train_link`` selects the
    channel emulation (Eq. 7 dropout or the full deployment channel); the
    compressor is applied as a differentiable roundtrip (STE for quant)."""
    a = f_in(params_in, x)
    a = emulate_link(key, a, spec, "train" if train else "clean")
    return f_out(params_out, a)


def distributed_inference(
    f_in: SubModel,
    f_out: SubModel,
    params_in: Any,
    params_out: Any,
    x: jax.Array,
    key: jax.Array,
    spec: LinkSpec,
) -> jax.Array:
    """Eq. (12): the DI serving graph.

    device side:  a  = f_cmp(f_in(x))          -> transmitted message
    channel:      a' = f_c(a | p)              -> drops
    server side:  y  = f_out(f_dec(a' / (1-p)))
    """
    a_raw = f_in(params_in, x)
    return f_out(params_out, emulate_link(key, a_raw, spec, "serve"))


def message_bytes(spec: LinkSpec, feature_dim: int) -> float:
    """Size of one transmitted message (per activation vector)."""
    n = spec.compressor.message_elements(feature_dim)
    return n * spec.compressor.bytes_per_element()


def di_latency_s(
    spec: LinkSpec,
    feature_dim: int,
    batch: int,
    channel: link_lib.ChannelConfig,
    protocol=None,
) -> float:
    """Expected communication latency of one DI round.

    ``protocol`` selects the link-layer policy (``repro.net.protocol``):

    * ``None`` / ``"unreliable"`` — the paper's §III-B one-shot protocol:
      deterministic ``n_t * l / b``, with FEC expanding ``n_t`` by
      ``(k+m)/k``.
    * ``"arq"`` / ``"fec_arq"`` (or a policy instance) — the mean of the
      policy's analytic latency PMF at ``channel.loss_rate``.  ``"arq"``
      retransmits the (FEC-expanded, if any) packet stream; ``"fec_arq"``
      codes blocks itself, so it is handed the *raw* data-packet count and
      uses ``spec``'s FEC code (required for the string form — pass a
      ``HybridFECARQProtocol`` instance to choose the code explicitly).
    """
    total_bytes = message_bytes(spec, feature_dim) * batch
    n_data = -(-int(total_bytes) // channel.packet_bytes)
    fspec = spec.fec_spec
    n_tx = fspec.transmitted_packets(n_data) if fspec is not None else n_data

    if protocol is None or protocol == "unreliable":
        return n_tx * channel.slot_time_s()

    if isinstance(protocol, str):
        from repro.net import protocol as protocol_lib

        kwargs = {}
        if protocol == "fec_arq":
            if fspec is None:
                raise ValueError(
                    "protocol='fec_arq' needs the spec's FEC code (set "
                    "fec_k/fec_m) or pass a HybridFECARQProtocol instance"
                )
            kwargs["fec"] = fspec
        policy = protocol_lib.make_protocol(protocol, **kwargs)
    else:
        policy = protocol
    n_t = n_data if getattr(policy, "name", "") == "fec_arq" else n_tx
    return policy.expected_latency_s(n_t, channel)
