"""Lossy activation compression (paper Appendix A).

Two schemes, exactly as the paper defines them:

* **Quantization** (Eq. 13-17): per-element clip to calibrated
  ``[s_min, s_max]`` then uniform ``n``-bit integer quantization, where
  ``n = floor(32 * M / M_float)`` for a target message size ``M``.
* **Dimensional reduction** (Eq. 18-23): PCA — transmit ``D'`` principal
  coefficients, ``D' = floor(M * D / M_float)``; decompress with the
  transposed basis plus the residual-mean bias ``b`` (Eq. 23).

Both are exposed as ``Compressor`` objects with differentiable
``compress``/``decompress`` (quantization uses a straight-through estimator
so COMtune can fine-tune through it, matching the paper's "insert the
compression function into the division layer and train" procedure).

The channel acts on the *compressed* representation: for quantization each
transmitted element corresponds to one activation element; for PCA each
transmitted element is one principal coefficient (this asymmetry is what
makes PCA fragile under loss — the paper's Fig. 7b finding).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Quantization (Eq. 13-15)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Per-element scale factors; shapes broadcast against the activation's
    trailing feature dims (the paper calibrates per element of the
    activation vector)."""

    bits: int
    s_min: jax.Array
    s_max: jax.Array

    @staticmethod
    def bits_for_message_size(message_bytes: float, float_bytes: float) -> int:
        """n = floor(32 M / M_float), clamped to [1, 32]."""
        return int(max(1, min(32, np.floor(32.0 * message_bytes / float_bytes))))


def quantize(x: jax.Array, spec: QuantSpec) -> jax.Array:
    """Eq. (13)-(14): clip then round to n-bit integer grid. Returns the
    integer code as float (the code is what crosses the channel)."""
    levels = float(2**spec.bits - 1)
    s_min = spec.s_min.astype(x.dtype)
    s_max = spec.s_max.astype(x.dtype)
    rng = jnp.maximum(s_max - s_min, jnp.asarray(1e-8, x.dtype))
    clipped = jnp.clip(x, s_min, s_max)
    code = jnp.round((clipped - s_min) / rng * levels)
    return code


def dequantize(code: jax.Array, spec: QuantSpec) -> jax.Array:
    """Eq. (15)."""
    levels = float(2**spec.bits - 1)
    s_min = spec.s_min.astype(code.dtype)
    s_max = spec.s_max.astype(code.dtype)
    rng = jnp.maximum(s_max - s_min, jnp.asarray(1e-8, code.dtype))
    return code / levels * rng + s_min


def fake_quantize_ste(x: jax.Array, spec: QuantSpec) -> jax.Array:
    """Quantize+dequantize with a straight-through gradient, used inside the
    COMtune fine-tuning graph (the channel mask is applied between the two in
    serving; in training dropout stands in for the channel)."""
    y = dequantize(quantize(x, spec), spec)
    # Straight-through: forward y, backward identity (within the clip range).
    s_min = spec.s_min.astype(x.dtype)
    s_max = spec.s_max.astype(x.dtype)
    in_range = jnp.logical_and(x >= s_min, x <= s_max).astype(x.dtype)
    return x + jax.lax.stop_gradient(y - x) * 1.0 + 0.0 * in_range


# ---------------------------------------------------------------------------
# PCA dimensional reduction (Eq. 18-23)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PCASpec:
    """w: (D', D) top eigenvector rows; b: (D,) residual mean bias (Eq. 23)."""

    w: jax.Array
    b: jax.Array

    @property
    def reduced_dim(self) -> int:
        return int(self.w.shape[0])

    @staticmethod
    def reduced_dim_for_message_size(
        message_bytes: float, float_bytes: float, full_dim: int
    ) -> int:
        """Eq. D' = floor(M D / M_float) with M_float = D * float_bytes,
        i.e. D' = floor(M / float_bytes) coefficients, clamped to [1, D]."""
        return int(max(1, min(full_dim, int(np.floor(message_bytes / float_bytes)))))


def pca_compress(x: jax.Array, spec: PCASpec) -> jax.Array:
    """Eq. (18): a' = w a   (x: (..., D) -> (..., D'))."""
    return jnp.einsum("...d,kd->...k", x, spec.w.astype(x.dtype))


def pca_decompress(coeff: jax.Array, spec: PCASpec) -> jax.Array:
    """Eq. (19): a = w^T a' + b."""
    return (
        jnp.einsum("...k,kd->...d", coeff, spec.w.astype(coeff.dtype))
        + spec.b.astype(coeff.dtype)
    )


# ---------------------------------------------------------------------------
# Unified compressor interface used by core.comtune
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Compressor:
    """f_cmp / f_dec pair (paper Eq. 8).  kind in {identity, quant, pca}."""

    kind: str = "identity"
    quant: Optional[QuantSpec] = None
    pca: Optional[PCASpec] = None

    def compress(self, x: jax.Array) -> jax.Array:
        if self.kind == "identity":
            return x
        if self.kind == "quant":
            return quantize(x, self.quant)
        if self.kind == "pca":
            return pca_compress(x, self.pca)
        raise ValueError(self.kind)

    def decompress(self, z: jax.Array) -> jax.Array:
        if self.kind == "identity":
            return z
        if self.kind == "quant":
            return dequantize(z, self.quant)
        if self.kind == "pca":
            return pca_decompress(z, self.pca)
        raise ValueError(self.kind)

    def roundtrip_train(self, x: jax.Array) -> jax.Array:
        """Differentiable compress∘decompress used in the COMtune training
        graph (STE for quantization; PCA is already linear/differentiable)."""
        if self.kind == "identity":
            return x
        if self.kind == "quant":
            return fake_quantize_ste(x, self.quant)
        if self.kind == "pca":
            return pca_decompress(pca_compress(x, self.pca), self.pca)
        raise ValueError(self.kind)

    def message_elements(self, feature_dim: int) -> int:
        """How many scalars cross the channel per activation vector."""
        if self.kind == "pca":
            return self.pca.reduced_dim
        return feature_dim

    def bytes_per_element(self) -> float:
        if self.kind == "quant":
            return self.quant.bits / 8.0
        return 4.0
