"""Unreliable-communication-link model (paper §III-B, Eq. 1-5).

The paper abstracts a lossy IoT network as a channel that drops each packet
independently with probability ``p`` and never retransmits.  Because the
sender shuffles activation elements across packets (Eq. 2), the effective
channel at the element level is i.i.d. Bernoulli (Eq. 1):

    f_c(x | p) = x * m(p),        m_i ~ Bernoulli(1 - p)

We implement BOTH granularities:

* ``element_loss_mask`` — the paper's analytical model (Eq. 1).
* ``packet_loss_mask``  — the physical model: elements are permuted, packed
  ``s`` elements per packet, whole packets are dropped (Eq. 2-3).  With a
  random permutation this is distributionally equivalent to Eq. 1; without
  the shuffle it produces burst loss (useful for ablations beyond the paper).

Latency model (Eq. 4-5): binomial PMFs over received packets (unreliable
protocol) and over the number of slots needed to deliver all ``n_t`` packets
under retransmission (reliable protocol).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import device as obs_device

# Floor for every kept-fraction denominator (1 - p, mask.mean(), 1 - p_eff)
# so loss_rate -> 1.0 returns zeros (everything dropped) instead of
# 0 * inf = NaN.  The single constant shared by apply_channel and all of
# core.comtune's compensation paths.
MIN_KEEP_FRACTION = 1e-6


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """Physical channel constants (paper §IV-A)."""

    packet_bytes: int = 100          # packet size l, including MAC/net overhead
    throughput_bps: float = 9.0e6    # b = 9.0 Mbit/s
    loss_rate: float = 0.0           # p
    bytes_per_element: int = 4       # 32-bit float activations by default

    @property
    def elements_per_packet(self) -> int:
        return max(1, self.packet_bytes // self.bytes_per_element)

    def num_packets_for_bytes(self, num_bytes: float) -> int:
        return max(1, -(-int(num_bytes) // self.packet_bytes))  # ceil div

    def num_packets(self, num_elements: int) -> int:
        return -(-num_elements // self.elements_per_packet)  # ceil div

    def slot_time_s(self) -> float:
        """Time T to transmit one packet."""
        return self.packet_bytes * 8.0 / self.throughput_bps


# ---------------------------------------------------------------------------
# Loss masks (Eq. 1-3)
# ---------------------------------------------------------------------------

def element_loss_mask(key: jax.Array, shape, loss_rate) -> jax.Array:
    """Eq. (1): i.i.d. Bernoulli keep-mask with E[m] = 1 - p (float32 0/1)."""
    keep = jax.random.bernoulli(key, 1.0 - loss_rate, shape)
    return keep.astype(jnp.float32)


def element_mask_from_packets(
    pkt_keep: jax.Array, num_elements: int, elements_per_packet: int,
    key: jax.Array, shuffle: bool,
) -> jax.Array:
    """Expand a packet keep-mask to a flat element mask, optionally applying
    the paper's anti-burst interleaving permutation (Eq. 2).  This is THE
    single implementation of the repeat + scatter pipeline — every
    repro.net channel and the FEC emulation route through it too."""
    mask = jnp.repeat(pkt_keep.astype(jnp.float32), elements_per_packet)
    mask = mask[:num_elements]
    if shuffle:
        perm = jax.random.permutation(key, num_elements)
        mask = jnp.zeros((num_elements,), jnp.float32).at[perm].set(mask)
    return mask


def packet_loss_mask(
    key: jax.Array,
    num_elements: int,
    loss_rate,
    elements_per_packet: int,
    shuffle: bool = True,
) -> jax.Array:
    """Eq. (2)-(3): drop whole packets of ``s`` consecutive (post-shuffle)
    elements.  Returns a flat float32 0/1 keep-mask of length num_elements.

    With ``shuffle=True`` (the paper's anti-burst permutation) the marginal
    distribution of each element matches Eq. (1).  ``shuffle=False`` models a
    sender that does not interleave, giving burst loss.
    """
    # The sender permutes elements into packets; the receiver un-permutes.
    # Net effect on the activation vector: a permuted packet mask.
    kperm, kdrop = jax.random.split(key)
    n_packets = -(-num_elements // elements_per_packet)
    pkt_keep = jax.random.bernoulli(kdrop, 1.0 - loss_rate, (n_packets,))
    return element_mask_from_packets(
        pkt_keep, num_elements, elements_per_packet, kperm, shuffle
    )


def apply_channel(
    key: jax.Array,
    x: jax.Array,
    loss_rate,
    *,
    granularity: str = "element",
    elements_per_packet: int = 25,
    shuffle: bool = True,
    compensate: bool = True,
) -> jax.Array:
    """Transmit ``x`` through the lossy link (Eq. 1/10) and apply the
    receiver-side ``1/(1-p)`` compensation (Eq. 11) if requested.
    """
    if granularity == "element":
        mask = element_loss_mask(key, x.shape, loss_rate)
    elif granularity == "packet":
        flat = packet_loss_mask(
            key, int(np.prod(x.shape)), loss_rate, elements_per_packet, shuffle
        )
        mask = flat.reshape(x.shape)
    else:
        raise ValueError(f"unknown granularity: {granularity!r}")
    obs_device.record_mask(mask)
    y = x * mask.astype(x.dtype)
    if compensate:
        keep = jnp.maximum(
            1.0 - jnp.asarray(loss_rate, jnp.float32), MIN_KEEP_FRACTION
        )
        # Explicit reciprocal-multiply (not y / keep): with a STATIC rate
        # XLA folds the divide into this exact form anyway, so writing it
        # out keeps a TRACED rate (per-step curriculum) bit-identical to
        # the static-rate program instead of one ulp off.
        y = y * (1.0 / keep).astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# Latency model (Eq. 4-5) — pure NumPy analytics, used by benchmarks/fig4a.
# ---------------------------------------------------------------------------

def _gammaln(x: np.ndarray) -> np.ndarray:
    """Stirling-series log-gamma, accurate to ~1e-10 for x >= 1 (no scipy)."""
    x = np.asarray(x, dtype=np.float64)
    # Shift x up by 6 for series accuracy, then divide back down.
    shift = 6
    xs = x + shift
    series = (
        (xs - 0.5) * np.log(xs)
        - xs
        + 0.5 * np.log(2.0 * np.pi)
        + 1.0 / (12.0 * xs)
        - 1.0 / (360.0 * xs**3)
        + 1.0 / (1260.0 * xs**5)
    )
    corr = np.zeros_like(xs)
    for i in range(shift):
        corr += np.log(x + i)
    return series - corr


def log_binom_coeff(n, k):
    n = np.asarray(n, dtype=np.float64)
    k = np.asarray(k, dtype=np.float64)
    return _gammaln(n + 1.0) - _gammaln(k + 1.0) - _gammaln(n - k + 1.0)


def received_packets_pmf(n_t: int, loss_rate: float) -> np.ndarray:
    """Eq. (4): PMF of the number of received packets, support 0..n_t."""
    n_r = np.arange(n_t + 1)
    if loss_rate <= 0.0:
        pmf = np.zeros(n_t + 1)
        pmf[-1] = 1.0
        return pmf
    if loss_rate >= 1.0:
        pmf = np.zeros(n_t + 1)
        pmf[0] = 1.0
        return pmf
    logp = (
        log_binom_coeff(n_t, n_r)
        + (n_t - n_r) * np.log(loss_rate)
        + n_r * np.log1p(-loss_rate)
    )
    pmf = np.exp(logp)
    return pmf / pmf.sum()


def unreliable_latency_s(n_t: int, cfg: ChannelConfig) -> float:
    """No retransmission: deterministic n_t * l / b (paper §III-B)."""
    return n_t * cfg.slot_time_s()


def reliable_latency_pmf(n_t: int, cfg: ChannelConfig, max_slots: int | None = None):
    """Eq. (5): latency tau = (number of slots) * T until all n_t packets are
    delivered under stop-and-wait-style retransmission.  The slot count K
    follows a negative-binomial: P(K=k) = C(k-1, n_t-1) p^(k-n_t) (1-p)^n_t.

    Returns (latency_seconds, pmf) arrays over k = n_t .. max_slots.
    """
    p = cfg.loss_rate
    if max_slots is None:
        # Enough tail for p up to 0.9.
        max_slots = max(n_t + 1, int(n_t / max(1e-9, 1.0 - p) * 6))
    k = np.arange(n_t, max_slots + 1)
    if p <= 0.0:
        pmf = np.zeros_like(k, dtype=np.float64)
        pmf[0] = 1.0
    else:
        logp = (
            log_binom_coeff(k - 1, n_t - 1)
            + (k - n_t) * np.log(p)
            + n_t * np.log1p(-p)
        )
        pmf = np.exp(logp)
        pmf = pmf / pmf.sum()
    return k.astype(np.float64) * cfg.slot_time_s(), pmf


def latency_cdf(latency_s: np.ndarray, pmf: np.ndarray):
    order = np.argsort(latency_s)
    return latency_s[order], np.cumsum(pmf[order])
