"""Faithful reproduction harness for the paper's own experiments (§IV)."""
