"""Paper-experiment harness (§IV): trains the paper's CNN on the synthetic
CIFAR-10 stand-in and evaluates DI accuracy under packet loss, compression,
and both — shared by every figure benchmark.

Procedure follows the paper: a *pre-obtained* model is trained normally;
COMtune then fine-tunes it with the link layer (dropout r + compression)
inserted at the split (Eq. 8); "previous DI" is the same fine-tuning budget
without the dropout link.  Evaluation runs the DI graph (Eq. 12) with the
real simulated channel.

CPU budget note (DESIGN.md §2): the CNN is a width-reduced VGG variant and
the dataset is synthetic, so ABSOLUTE accuracies differ from the paper's
CIFAR-10 numbers; the claims validated are the paper's orderings and trends.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

import repro.data as data
from repro.core import calibration, comtune
from repro.core.compression import Compressor
from repro.models import cnn
from repro.optim import AdamConfig, adam_update, init_adam

# Benchmark-scale CNN: split after block 1 -> activation 16*16*16 = 4096 dims
# (16 kB fp32) — the 1/4-width analog of the paper's 16,384-dim / 65.5 kB.
CNN_CFG = cnn.CNNConfig(
    blocks=((1, 16), (1, 32)),
    fc=(64,),
    num_classes=10,
    image_size=32,
    split_block=1,
)

PRETRAIN_STEPS = 300
FINETUNE_STEPS = 200
BATCH = 64
LR = 2e-3


@functools.lru_cache(maxsize=1)
def dataset():
    return data.make_image_dataset(
        n_train=1500, n_test=600, num_classes=10, image_size=32, noise=2.0,
        signal_min=0.35, sub_prototypes=2,
    )


def uncompressed_bytes() -> int:
    return CNN_CFG.split_activation_dim * 4


def _train_steps(params, state, opt, key, steps, dropout_rate, compressor,
                 adam_cfg, it):
    @jax.jit
    def step(params, state, opt, xb, yb, k):
        def loss_fn(p):
            def link(a):
                a = compressor.roundtrip_train(a) if compressor else a
                if dropout_rate > 0:
                    a = comtune.dropout_link(k, a, dropout_rate)
                return a

            logits, new_state = cnn.forward(
                p, state, xb, CNN_CFG, train=True,
                link_fn=link if (dropout_rate > 0 or compressor) else None,
            )
            ll = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(ll, yb[:, None], axis=-1).mean(), new_state

        (l, new_state), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt, _ = adam_update(g, params, opt, adam_cfg)
        return params, new_state, opt, l

    for _ in range(steps):
        xb, yb = next(it)
        key, sub = jax.random.split(key)
        params, state, opt, _ = step(
            params, state, opt, jnp.asarray(xb), jnp.asarray(yb), sub
        )
    return params, state, opt, key


_PRETRAINED: Dict[int, Tuple] = {}
_MODELS: Dict[Tuple, Tuple] = {}


def pretrained(seed: int = 0):
    """The paper's 'pre-obtained model from the public repository'."""
    if seed not in _PRETRAINED:
        (xtr, ytr), _ = dataset()
        adam_cfg = AdamConfig(lr=LR)
        key = jax.random.PRNGKey(seed)
        params, state = cnn.init_cnn(key, CNN_CFG)
        opt = init_adam(params, adam_cfg)
        it = data.batch_iterator(xtr, ytr, BATCH, seed=seed)
        params, state, opt, _ = _train_steps(
            params, state, opt, key, PRETRAIN_STEPS, 0.0, None, adam_cfg, it
        )
        _PRETRAINED[seed] = (params, state)
    return _PRETRAINED[seed]


def split_activations(params, state, n: int = 512) -> np.ndarray:
    """Calibration activations at the split point (paper Appendix A)."""
    (xtr, _), _ = dataset()
    a, _ = cnn.forward_device(params, state, jnp.asarray(xtr[:n]), CNN_CFG)
    return np.asarray(a)


def make_compressor(kind: str, message_bytes: Optional[float], params, state
                    ) -> Optional[Compressor]:
    if kind == "none":
        return None
    acts = split_activations(params, state)
    return calibration.make_compressor(
        acts, kind=kind, message_bytes=message_bytes
    )


def finetuned(dropout_rate: float, comp_kind: str = "none",
              message_bytes: Optional[float] = None, seed: int = 0):
    """COMtune fine-tuning (or 'previous DI' when dropout_rate == 0)."""
    key_ = (round(dropout_rate, 3), comp_kind, message_bytes, seed)
    if key_ not in _MODELS:
        (xtr, ytr), _ = dataset()
        p0, s0 = pretrained(seed)
        compressor = make_compressor(comp_kind, message_bytes, p0, s0)
        adam_cfg = AdamConfig(lr=LR * 0.5)
        opt = init_adam(p0, adam_cfg)
        it = data.batch_iterator(xtr, ytr, BATCH, seed=seed + 1)
        params, state, _, _ = _train_steps(
            p0, s0, opt, jax.random.PRNGKey(seed + 100), FINETUNE_STEPS,
            dropout_rate, compressor, adam_cfg, it,
        )
        _MODELS[key_] = (params, state, compressor)
    return _MODELS[key_]


def di_accuracy(params, state, compressor: Optional[Compressor],
                loss_rate: float, seed: int = 0,
                granularity: str = "element") -> float:
    """One DI evaluation round over the test set (Eq. 12)."""
    _, (xte, yte) = dataset()
    key = jax.random.PRNGKey(1000 + seed)
    spec = comtune.LinkSpec(
        loss_rate=loss_rate,
        compressor=compressor or Compressor(),
        granularity=granularity,
    )

    def link(a):
        msg = spec.compressor.compress(a)
        msg = comtune.channel_link(key, msg, spec)
        return spec.compressor.decompress(msg)

    logits, _ = cnn.forward(
        params, state, jnp.asarray(xte), CNN_CFG, train=False,
        link_fn=link if (loss_rate > 0 or compressor) else None,
    )
    return float((jnp.argmax(logits, -1) == jnp.asarray(yte)).mean())


def accuracy_stats(params, state, compressor, loss_rate: float,
                   n_seeds: int = 10, granularity: str = "element"):
    accs = [
        di_accuracy(params, state, compressor, loss_rate, seed=s,
                    granularity=granularity)
        for s in range(n_seeds)
    ]
    return float(np.mean(accs)), float(np.std(accs)), accs
