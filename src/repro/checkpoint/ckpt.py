"""Pytree checkpointing to .npz (flattened key-paths), with step management.

Host-gathered (fine at the scales this container trains); the save path is
sharding-transparent because ``np.asarray`` fetches the addressable shards.
"""

from __future__ import annotations

import os
import re
from typing import Any, Optional, Tuple

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_key_str(k) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return f"[{k.idx}]"
    if isinstance(k, jax.tree_util.GetAttrKey):
        return k.name
    return str(k)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, name: str = "ckpt") -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"{name}_{step:08d}.npz")
    tmp = path + ".tmp.npz"  # np.savez appends .npz if missing
    flat = _flatten(tree)
    # bf16 isn't supported by np.savez: view as uint16 with a marker.
    packed = {}
    for k, v in flat.items():
        if v.dtype == jax.numpy.bfloat16:
            packed["BF16__" + k] = v.view(np.uint16)
        else:
            packed[k] = v
    np.savez(tmp, **packed)
    os.replace(tmp, path)
    return path


def restore_checkpoint(ckpt_dir: str, template: Any, step: Optional[int] = None,
                       name: str = "ckpt") -> Tuple[Any, int]:
    """Restore into the structure of ``template`` (shapes must match)."""
    if step is None:
        step = latest_step(ckpt_dir, name)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"{name}_{step:08d}.npz")
    data = np.load(path)
    loaded = {}
    for k in data.files:
        if k.startswith("BF16__"):
            loaded[k[len("BF16__"):]] = data[k].view(jax.numpy.bfloat16)
        else:
            loaded[k] = data[k]
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path_keys, leaf in paths:
        key = _SEP.join(_key_str(k) for k in path_keys)
        if key not in loaded:
            raise KeyError(f"checkpoint missing {key}")
        arr = loaded[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != template {leaf.shape}")
        leaves.append(arr)
    return treedef.unflatten(leaves), step


def latest_step(ckpt_dir: str, name: str = "ckpt") -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    pat = re.compile(rf"{re.escape(name)}_(\d+)\.npz$")
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir) if (m := pat.match(f))]
    return max(steps) if steps else None
