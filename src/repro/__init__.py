"""COMtune reproduction: packet-loss-resilient distributed inference as a
first-class feature of a multi-pod JAX training/serving framework.

Paper: Itahara, Nishio, Koda, Yamamoto — "Communication-oriented Model
Fine-tuning for Packet-loss Resilient Distributed Inference under Highly
Lossy IoT Networks" (arXiv:2112.09407, 2021).
"""

__version__ = "1.0.0"
