from repro.data.synthetic import (  # noqa: F401
    batch_iterator,
    lm_batch_iterator,
    make_image_dataset,
    make_lm_dataset,
)
