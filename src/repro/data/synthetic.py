"""Deterministic synthetic datasets (CIFAR-10 is not available offline).

* ``make_image_dataset`` — a CIFAR-like 10-class image task with controllable
  difficulty: each class is a random smooth "prototype" image; samples are
  prototype + structured noise + random shift.  A CNN must learn non-trivial
  spatial features to separate classes, so accuracy degrades smoothly with
  activation corruption — the property the paper's experiments measure.
* ``make_lm_dataset`` — a Zipfian Markov-chain token stream with per-class
  transition structure, enough signal for loss to fall during the ~100-step
  training driver.
"""

from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def make_image_dataset(
    n_train: int = 5000,
    n_test: int = 1000,
    num_classes: int = 10,
    image_size: int = 32,
    noise: float = 0.6,
    seed: int = 0,
    signal_min: float = 1.0,
    sub_prototypes: int = 1,
):
    """signal_min < 1 scales each sample's prototype by U[signal_min, 1]
    (intrinsically-hard samples); sub_prototypes > 1 makes classes
    multimodal.  Both raise the Bayes error, keeping accuracy off the
    ceiling so corruption effects are measurable."""
    rng = np.random.RandomState(seed)
    # Smooth class prototypes: low-frequency random fields.
    freq = 4
    base = rng.randn(num_classes * sub_prototypes, freq, freq, 3).astype(np.float32)
    protos = np.stack(
        [_upsample(base[c], image_size) for c in range(num_classes * sub_prototypes)],
        axis=0,
    ).reshape(num_classes, sub_prototypes, image_size, image_size, 3)
    protos /= protos.std(axis=(2, 3, 4), keepdims=True) + 1e-6

    def sample(n, rs):
        labels = rs.randint(0, num_classes, size=n)
        subs = rs.randint(0, sub_prototypes, size=n)
        imgs = protos[labels, subs].copy()
        if signal_min < 1.0:
            scale = rs.uniform(signal_min, 1.0, size=(n, 1, 1, 1)).astype(np.float32)
            imgs *= scale
        # random small translation
        for i in range(n):
            sx, sy = rs.randint(-3, 4, size=2)
            imgs[i] = np.roll(imgs[i], (sx, sy), axis=(0, 1))
        imgs += noise * rs.randn(*imgs.shape).astype(np.float32)
        return imgs.astype(np.float32), labels.astype(np.int32)

    x_train, y_train = sample(n_train, np.random.RandomState(seed + 1))
    x_test, y_test = sample(n_test, np.random.RandomState(seed + 2))
    return (x_train, y_train), (x_test, y_test)


def _upsample(small: np.ndarray, size: int) -> np.ndarray:
    """Bilinear upsample (freq, freq, C) -> (size, size, C) without scipy."""
    f = small.shape[0]
    xs = np.linspace(0, f - 1, size)
    x0 = np.clip(np.floor(xs).astype(int), 0, f - 2)
    w = (xs - x0)[:, None]
    rows = small[x0] * (1 - w[..., None]) + small[x0 + 1] * w[..., None]
    cols = rows[:, x0, :] * (1 - w[None, :, :]) + rows[:, x0 + 1, :] * w[None, :, :]
    return cols.astype(np.float32)


def make_lm_dataset(
    vocab_size: int,
    n_tokens: int = 200_000,
    order: int = 1,
    seed: int = 0,
) -> np.ndarray:
    """Markov token stream with Zipfian marginals; predictable enough that a
    small LM's loss drops well below log(vocab)."""
    rng = np.random.RandomState(seed)
    v_eff = min(vocab_size, 512)
    # Sparse transition table: each token strongly prefers ~8 successors.
    succ = rng.randint(0, v_eff, size=(v_eff, 8))
    toks = np.empty(n_tokens, np.int64)
    toks[0] = rng.randint(v_eff)
    u = rng.rand(n_tokens)
    choice = rng.randint(0, 8, size=n_tokens)
    for i in range(1, n_tokens):
        if u[i] < 0.85:
            toks[i] = succ[toks[i - 1], choice[i]]
        else:
            toks[i] = rng.randint(v_eff)
    return toks.astype(np.int32)


def batch_iterator(
    x: np.ndarray, y: np.ndarray, batch: int, seed: int = 0, epochs: int = 10**9
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.RandomState(seed)
    n = x.shape[0]
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            idx = order[i : i + batch]
            yield x[idx], y[idx]


def lm_batch_iterator(
    tokens: np.ndarray, batch: int, seq_len: int, seed: int = 0
) -> Iterator[np.ndarray]:
    rng = np.random.RandomState(seed)
    n = tokens.shape[0] - seq_len - 1
    while True:
        starts = rng.randint(0, n, size=batch)
        yield np.stack([tokens[s : s + seq_len] for s in starts]).astype(np.int32)
