"""Length-masked flash-decode attention (repro.kernels.decode_attention):

* triangulated equivalence — Pallas kernel (interpret mode) must be
  BIT-IDENTICAL to the pure-jnp ref fallback (same arithmetic, two
  implementations), and both must match the full-cache masked
  ``_naive_attn`` oracle numerically — across GQA group sizes, bf16/int8
  caches, and valid lengths straddling the block boundary;
* rotating sliding-window integration — decode steps through
  ``attention_forward`` across the window wrap point, flash_decode vs the
  naive oracle;
* the naive fallback's concrete-index prefix slice (satellite fix) matches
  the traced masked form;
* ``ContinuousEngine`` with ``attn_impl="flash_decode"``: greedy outputs
  token-identical to ``generate_reference`` under iid + Gilbert-Elliott
  links, and the AOT compile count stays ``num_buckets + 1`` with zero
  steady-state builds.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.kernels.decode_attention import (
    decode_attention,
    decode_block_kv,
    flash_decode_kernel,
    flash_decode_ref,
)
from repro.launch.serve import generate_reference
from repro.models import lm
from repro.models.attention import _naive_attn, _read_cache
from repro.serve import ContinuousEngine, PoolConfig

BKV = 8


def _make_qcache(seed, b, c, kvh, g, hd, quantized, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (b, 1, kvh, g, hd), dtype)
    if quantized:
        cache = {
            "k": jax.random.randint(ks[1], (b, c, kvh, hd), -127, 128, jnp.int8),
            "v": jax.random.randint(ks[2], (b, c, kvh, hd), -127, 128, jnp.int8),
            "k_scale": (jax.random.uniform(ks[3], (b, c, kvh)) * 0.05 + 0.01
                        ).astype(jnp.bfloat16),
            "v_scale": (jax.random.uniform(ks[4], (b, c, kvh)) * 0.05 + 0.01
                        ).astype(jnp.bfloat16),
        }
    else:
        cache = {
            "k": jax.random.normal(ks[1], (b, c, kvh, hd), dtype),
            "v": jax.random.normal(ks[2], (b, c, kvh, hd), dtype),
        }
    return q, cache


def _oracle(q, cache, n_valid, softcap=0.0):
    """Full-cache dequant + validity-masked naive softmax (the old path)."""
    k, v = _read_cache(cache, q.dtype)
    c = k.shape[1]
    mask = (jnp.arange(c)[None, :] < n_valid)[:, None, None, None, :]
    return _naive_attn(q, k, v, mask, softcap)


class TestKernelRefEquivalence:
    """Kernel (interpret) vs the jnp fallback: same arithmetic recipe, two
    lowered programs — agreement is float-ulp level (XLA fusion/FMA
    reassociation is the only difference), far below the ~1e-2 the bf16
    model dtype resolves."""

    @pytest.mark.parametrize("g", [1, 4])
    @pytest.mark.parametrize("quantized", [False, True])
    @pytest.mark.parametrize("n_valid", [1, BKV - 1, BKV, 32])
    def test_kernel_interpret_equals_ref(self, g, quantized, n_valid):
        b, c, kvh, hd = 2, 32, 2, 16
        q, cache = _make_qcache(0, b, c, kvh, g, hd, quantized)
        n = jnp.full((b, 1), n_valid, jnp.int32)
        args = (q[:, 0], cache["k"], cache["v"],
                cache.get("k_scale"), cache.get("v_scale"), n)
        out_k = flash_decode_kernel(*args, block_kv=BKV, interpret=True)
        out_r = flash_decode_ref(*args, block_kv=BKV)
        np.testing.assert_allclose(
            np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
            rtol=2e-6, atol=2e-6,
        )

    @pytest.mark.parametrize("softcap", [0.0, 30.0])
    def test_softcap_paths_agree(self, softcap):
        b, c, kvh, g, hd = 1, 16, 2, 2, 8
        q, cache = _make_qcache(1, b, c, kvh, g, hd, False)
        n = jnp.full((b, 1), 11, jnp.int32)
        args = (q[:, 0], cache["k"], cache["v"], None, None, n)
        out_k = flash_decode_kernel(*args, block_kv=BKV, softcap=softcap,
                                    interpret=True)
        out_r = flash_decode_ref(*args, block_kv=BKV, softcap=softcap)
        np.testing.assert_allclose(
            np.asarray(out_k), np.asarray(out_r), rtol=2e-6, atol=2e-6
        )

    def test_bf16_query_int8_cache(self):
        """Production serve dtype: bf16 activations over the int8 cache —
        outputs round to bf16, so the two paths agree to a bf16 ulp."""
        b, c, kvh, g, hd = 2, 32, 2, 4, 16
        q, cache = _make_qcache(4, b, c, kvh, g, hd, True,
                                dtype=jnp.bfloat16)
        n = jnp.full((b, 1), 13, jnp.int32)
        args = (q[:, 0], cache["k"], cache["v"],
                cache["k_scale"], cache["v_scale"], n)
        out_k = flash_decode_kernel(*args, block_kv=BKV, interpret=True)
        out_r = flash_decode_ref(*args, block_kv=BKV)
        assert out_k.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
            rtol=1e-2,
        )


class TestRefVsNaiveOracle:
    @pytest.mark.parametrize("g", [1, 4])
    @pytest.mark.parametrize("quantized", [False, True])
    @pytest.mark.parametrize("n_valid", [1, BKV - 1, BKV, 32])
    def test_matches_masked_naive(self, g, quantized, n_valid):
        b, c, kvh, hd = 2, 32, 2, 16
        q, cache = _make_qcache(2, b, c, kvh, g, hd, quantized)
        out = decode_attention(
            q, cache, jnp.int32(n_valid), block_kv=BKV, impl="ref"
        )
        want = _oracle(q, cache, jnp.int32(n_valid))
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            atol=1e-5,
        )

    def test_per_request_n_valid_vector(self):
        """Per-request lengths (the DecodeEngine batch case)."""
        b, c, kvh, g, hd = 3, 32, 2, 2, 16
        q, cache = _make_qcache(3, b, c, kvh, g, hd, True)
        n = jnp.array([1, 9, 32], jnp.int32)
        out = decode_attention(q, cache, n, block_kv=BKV, impl="ref")
        for i in range(b):
            want = _oracle(q[i : i + 1],
                           {k: v[i : i + 1] for k, v in cache.items()},
                           n[i])
            np.testing.assert_allclose(
                np.asarray(out[i : i + 1], np.float32),
                np.asarray(want, np.float32), atol=1e-5,
            )


class TestSlotVmap:
    @pytest.mark.parametrize("impl", ["ref", "kernel"])
    def test_vmap_over_slots_with_per_slot_index(self, impl):
        """The slot-pool contract: vmap over a leading slot axis with a
        per-slot cache_index equals the per-slot loop."""
        slots, c, kvh, g, hd = 3, 16, 2, 2, 8
        qs, caches = [], []
        for i in range(slots):
            q, cache = _make_qcache(10 + i, 1, c, kvh, g, hd, True)
            qs.append(q)
            caches.append(cache)
        q_sl = jnp.concatenate(qs)[:, None][:, 0]            # (S, 1, KV, G, hd)
        cache_sl = {k: jnp.concatenate([cc[k] for cc in caches])
                    for k in caches[0]}
        n_sl = jnp.array([1, 7, 16], jnp.int32)

        fn = lambda q, cache, n: decode_attention(
            q[None], {k: v[None] for k, v in cache.items()}, n,
            block_kv=BKV, impl=impl, interpret=True,
        )[0]
        out = jax.vmap(fn)(q_sl, cache_sl, n_sl)
        for i in range(slots):
            want = decode_attention(
                qs[i], caches[i], n_sl[i], block_kv=BKV, impl="ref"
            )
            np.testing.assert_allclose(
                np.asarray(out[i], np.float32),
                np.asarray(want[0], np.float32), atol=1e-6,
            )


class TestRotatingWindowIntegration:
    def test_windowed_decode_across_wrap(self):
        """Sliding-window layer stepped past the wrap point: flash_decode
        logits match the naive oracle at every step (window=8, 14 steps)."""
        from repro.models import cache as cache_lib

        cfg_n = ARCHITECTURES["gemma3-12b"].reduced()
        pat = tuple(dataclasses.replace(s, window=8) if s.window else s
                    for s in cfg_n.unit_pattern)
        cfg_n = cfg_n.with_updates(unit_pattern=pat, attn_decode_block_kv=4)
        cfg_f = cfg_n.with_updates(attn_impl="flash_decode")
        params = lm.init_lm(jax.random.PRNGKey(0), cfg_n)
        caches = {
            "naive": cache_lib.init_cache(cfg_n, 1, 16),
            "flash": cache_lib.init_cache(cfg_f, 1, 16),
        }
        tok = jnp.array([[3]], jnp.int32)
        for i in range(14):
            ln, caches["naive"], _ = lm.forward(
                params, tok, cfg_n, cache=caches["naive"],
                cache_index=jnp.int32(i), mode="decode",
            )
            lf, caches["flash"], _ = lm.forward(
                params, tok, cfg_f, cache=caches["flash"],
                cache_index=jnp.int32(i), mode="decode",
            )
            np.testing.assert_allclose(
                np.asarray(lf), np.asarray(ln), atol=2e-4,
                err_msg=f"step {i}",
            )
            tok = jnp.argmax(ln, -1).astype(jnp.int32)


class TestNaiveFallbackPrefixSlice:
    @pytest.mark.parametrize("quantized", [False, True])
    def test_prefix_slice_equals_masked_form(self, quantized):
        """Satellite fix: a concrete ``cache_index`` dequantizes/reads only
        the valid prefix.  Must match the traced (jitted) masked form —
        identical math, so agreement is pinned at ulp level."""
        from repro.models.attention import _masked_decode_attn

        b, c, kvh, g, hd = 2, 24, 2, 2, 16
        for idx in (0, 5, 23, 30):               # 30 > C: wrapped window
            q, cache = _make_qcache(20 + idx, b, c, kvh, g, hd, quantized)
            sliced = _masked_decode_attn(q, cache, idx, 0.0, q.dtype)
            masked = jax.jit(  # noqa: RPA001 — compile per idx is the point: the tracer must hit the masked branch
                lambda i, q=q, cache=cache: _masked_decode_attn(
                    q, cache, i, 0.0, q.dtype
                )
            )(jnp.int32(idx))                     # tracer -> masked branch
            np.testing.assert_allclose(
                np.asarray(sliced, np.float32), np.asarray(masked, np.float32),
                rtol=2e-6, atol=2e-6,
            )

    @pytest.mark.parametrize("kv_cache_dtype", ["", "int8"])
    def test_lm_decode_concrete_vs_traced_index(self, kv_cache_dtype):
        """End-to-end: un-jitted decode steps (concrete index -> prefix
        slice) track the jitted masked steps through the full stack."""
        from repro.models import cache as cache_lib

        cfg = ARCHITECTURES["qwen1.5-0.5b"].reduced(
            kv_cache_dtype=kv_cache_dtype
        )
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        step = jax.jit(
            lambda p, t, c, i: lm.forward(p, t, cfg, cache=c, cache_index=i,
                                          mode="decode"),
        )
        cache_c = cache_lib.init_cache(cfg, 1, 12)
        cache_t = cache_lib.init_cache(cfg, 1, 12)
        tok = jnp.array([[7]], jnp.int32)
        for i in range(5):
            lc, cache_c, _ = lm.forward(
                params, tok, cfg, cache=cache_c, cache_index=i, mode="decode"
            )  # Python int index -> prefix-slice path
            lt, cache_t, _ = step(params, tok, cache_t, jnp.int32(i))
            np.testing.assert_allclose(
                np.asarray(lc), np.asarray(lt), rtol=1e-4, atol=1e-4,
                err_msg=f"step {i}",
            )
            tok = jnp.argmax(lt, -1).astype(jnp.int32)


def _setup_engine(channel="iid", loss_rate=0.3, **overrides):
    cfg = ARCHITECTURES["qwen1.5-0.5b"].reduced(
        attn_impl="flash_decode", **overrides
    )
    cfg = cfg.with_updates(
        link=dataclasses.replace(cfg.link, loss_rate=loss_rate, channel=channel)
    )
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompt(i, length, vocab):
    return np.asarray(
        jax.random.randint(
            jax.random.fold_in(jax.random.PRNGKey(7), i), (length,), 0, vocab,
            jnp.int32,
        )
    )


class TestContinuousEngineFlashDecode:
    @pytest.mark.parametrize("channel", ["iid", "ge"])
    def test_token_identity_vs_reference(self, channel):
        """Acceptance: attn_impl="flash_decode" greedy outputs are
        token-identical to the reference loop, mixed buckets, iid + GE."""
        cfg, params = _setup_engine(channel=channel)
        eng = ContinuousEngine(
            cfg, PoolConfig(max_slots=2, max_new=4, max_prompt=16, min_bucket=4)
        )
        key = jax.random.PRNGKey(42)
        lengths = [1, 3, 6, 13]
        reqs = [
            eng.submit(_prompt(i, L, cfg.vocab_size), 4,
                       key=jax.random.fold_in(key, i))
            for i, L in enumerate(lengths)
        ]
        eng.run(params)
        for i, (L, req) in enumerate(zip(lengths, reqs)):
            ref, _ = generate_reference(
                params, cfg, jnp.asarray(_prompt(i, L, cfg.vocab_size))[None],
                4, key=jax.random.fold_in(key, i),
            )
            np.testing.assert_array_equal(
                np.asarray(ref)[0], req.tokens,
                err_msg=f"request {i} (len {L}, channel {channel})",
            )

    def test_int8_cache_token_identity(self):
        """flash_decode + int8 slot-pool cache (the config the perf win
        targets) still matches the reference loop exactly."""
        cfg, params = _setup_engine(kv_cache_dtype="int8")
        eng = ContinuousEngine(
            cfg, PoolConfig(max_slots=2, max_new=5, max_prompt=8, min_bucket=8)
        )
        key = jax.random.PRNGKey(9)
        reqs = [
            eng.submit(_prompt(i, 4 + i, cfg.vocab_size), 5,
                       key=jax.random.fold_in(key, i))
            for i in range(3)
        ]
        eng.run(params)
        for i, req in enumerate(reqs):
            ref, _ = generate_reference(
                params, cfg, jnp.asarray(_prompt(i, 4 + i, cfg.vocab_size))[None],
                5, key=jax.random.fold_in(key, i),
            )
            np.testing.assert_array_equal(np.asarray(ref)[0], req.tokens)

    def test_compiles_still_buckets_plus_one(self):
        """Zero-steady-state regression with the masked decode step."""
        cfg, params = _setup_engine()
        eng = ContinuousEngine(
            cfg, PoolConfig(max_slots=3, max_new=4, max_prompt=16, min_bucket=8)
        )
        key = jax.random.PRNGKey(0)
        for i, L in enumerate([5, 12, 7, 16]):    # buckets {8, 16}
            eng.submit(_prompt(i, L, cfg.vocab_size), 3,
                       key=jax.random.fold_in(key, i))
        eng.run(params)
        assert eng.num_buckets == 2
        assert eng.compiles == eng.num_buckets + 1
        warm = eng.compiles
        for i in range(8):
            eng.submit(_prompt(100 + i, 4 + (i % 13), cfg.vocab_size),
                       1 + (i % 4), key=jax.random.fold_in(key, 100 + i))
        done = eng.run(params)
        assert len(done) == 8
        assert eng.compiles == warm

    def test_attn_impl_override_arg(self):
        """Engine-level attn_impl override rebuilds the config."""
        cfg, params = _setup_engine()
        base = cfg.with_updates(attn_impl="naive")
        eng = ContinuousEngine(
            base, PoolConfig(max_slots=1, max_new=2, max_prompt=8),
            attn_impl="flash_decode",
        )
        assert eng.cfg.attn_impl == "flash_decode"
        req = eng.submit(_prompt(0, 4, cfg.vocab_size), 2)
        eng.run(params)
        assert req.tokens is not None and req.tokens.shape == (2,)


class TestHelpers:
    def test_decode_block_kv_divides_or_pads(self):
        assert decode_block_kv(1024, 64) == 64
        assert decode_block_kv(32, 64) == 32
        assert decode_block_kv(192, 64) == 64
        assert decode_block_kv(7, 64) == 7       # single block, no pad
        # Coprime-ish lengths keep a real block (ops pads the cache)
        # instead of collapsing to gcd-sized micro-blocks.
        assert decode_block_kv(100, 64) == 64
        assert decode_block_kv(65, 64) == 64
        for c, b in [(1024, 64), (192, 64), (24, 64), (7, 64), (48, 32)]:
            assert c % decode_block_kv(c, b) == 0

    @pytest.mark.parametrize("impl", ["ref", "kernel"])
    def test_degenerate_cache_length_pads_correctly(self, impl):
        """C=65 has no usable divisor of 64: the pad path must still match
        the full-cache oracle for valid lengths inside AND at C."""
        b, c, kvh, g, hd = 2, 65, 2, 2, 16
        q, cache = _make_qcache(30, b, c, kvh, g, hd, True)
        for n_valid in (3, 64, 65):
            out = decode_attention(
                q, cache, jnp.int32(n_valid), block_kv=64, impl=impl,
                interpret=True,
            )
            want = _oracle(q, cache, jnp.int32(n_valid))
            np.testing.assert_allclose(
                np.asarray(out, np.float32), np.asarray(want, np.float32),
                atol=1e-5, err_msg=f"n_valid={n_valid} impl={impl}",
            )

    def test_invalid_impl_raises(self):
        b, c, kvh, g, hd = 1, 16, 1, 1, 8
        q, cache = _make_qcache(31, b, c, kvh, g, hd, False)
        with pytest.raises(ValueError, match="unknown decode-attention"):
            decode_attention(q, cache, jnp.int32(4), impl="naive")

    def test_decode_read_bytes_scales_with_valid(self):
        from repro.models.cache import decode_read_bytes

        cfg = ARCHITECTURES["qwen1.5-0.5b"].with_updates(kv_cache_dtype="int8")
        full = decode_read_bytes(cfg, 1024, 1024, masked=False)
        assert decode_read_bytes(cfg, 1024, 1024, masked=True) == full
        small = decode_read_bytes(cfg, 1024, 16, masked=True)
        assert small * 8 <= full                  # 1/16 of the cache ±block
        assert decode_read_bytes(cfg, 1024, 16, masked=False) == full
