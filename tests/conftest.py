import os

# Tests run on the single real CPU device (the 512-device placeholder mesh is
# ONLY for launch/dryrun.py).  Keep XLA quiet and single-threaded-ish.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
