"""Static invariant checker (repro.analysis): the seven RPA rules, noqa
suppression, the baseline, the CLI, and the runtime compile guard.

Rule fixtures come in violation/clean pairs: the violation asserts the
rule has teeth, the clean twin pins the sanctioned idiom (split-then-use,
``pallas_interpret(...)``, sanctioned AOT factory files) so the rules
can't silently start flagging the patterns the repo is built on.

The self-check at the bottom is the acceptance bar from ISSUE 7:
``python -m repro.analysis src tests benchmarks`` exits 0 on the repo at
HEAD with the committed baseline, and exits nonzero on a seeded fixture
tree violating all seven rules.
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import Finding, RULES, analyze_source, baseline

REPO_ROOT = Path(__file__).resolve().parents[1]


def codes(src, path="mod.py", select=None):
    """Rule codes found in a dedented snippet, in report order."""
    return [f.code for f in
            analyze_source(path, textwrap.dedent(src), select=select)]


# ---------------------------------------------------------------------------
# RPA001 — retrace hazards
# ---------------------------------------------------------------------------

class TestRetraceHazard:
    def test_jit_in_loop_flags(self):
        assert codes("""
            import jax
            def run(fns, x):
                for f in fns:
                    jax.jit(f)(x)
        """) == ["RPA001"]

    def test_aot_compile_in_loop_flags(self):
        assert codes("""
            import jax
            def run(fns, aval):
                for f in fns:
                    prog = jax.jit(f).lower(aval).compile()
        """) == ["RPA001"]

    def test_jit_outside_loop_clean(self):
        assert codes("""
            import jax
            def run(f, xs):
                g = jax.jit(f)
                for x in xs:
                    g(x)
        """) == []

    def test_def_inside_loop_is_not_a_loop_body(self):
        # a def's body executes per *call*, not per loop iteration
        assert codes("""
            import jax
            def build(fns):
                out = []
                for f in fns:
                    def make(f=f):
                        return jax.jit(f)
                    out.append(make)
                return out
        """) == []

    def test_sanctioned_factory_file_exempt(self):
        src = """
            import jax
            def aot_all(fns, x):
                for f in fns:
                    jax.jit(f)(x)
        """
        assert codes(src, path="src/repro/serve/engine.py") == []
        assert codes(src, path="src/repro/launch/steps.py") == []
        assert codes(src) == ["RPA001"]

    def test_unhashable_static_arg_flags(self):
        assert codes("""
            import jax
            def step(x, buckets=[1, 2]):
                return x
            f = jax.jit(step, static_argnums=(1,))
        """) == ["RPA001"]

    def test_hashable_static_arg_clean(self):
        assert codes("""
            import jax
            def step(x, n: int = 4):
                return x
            f = jax.jit(step, static_argnames=("n",))
        """) == []


# ---------------------------------------------------------------------------
# RPA002 — PRNG key reuse
# ---------------------------------------------------------------------------

class TestKeyReuse:
    def test_double_consume_flags(self):
        assert codes("""
            import jax
            def sample(key):
                a = jax.random.normal(key, (3,))
                b = jax.random.uniform(key, (3,))
                return a + b
        """) == ["RPA002"]

    def test_split_then_use_clean(self):
        assert codes("""
            import jax
            def sample(key):
                key, sub = jax.random.split(key)
                a = jax.random.normal(sub, (3,))
                key, sub = jax.random.split(key)
                b = jax.random.uniform(sub, (3,))
                return a + b
        """) == []

    def test_fold_in_does_not_consume(self):
        assert codes("""
            import jax
            def per_request(key, n):
                k0 = jax.random.fold_in(key, 0)
                k1 = jax.random.fold_in(key, 1)
                return jax.random.normal(k0, (n,)) + jax.random.normal(k1, (n,))
        """) == []

    def test_loop_consume_without_reassign_flags(self):
        assert codes("""
            import jax
            def noisy(key, xs):
                out = []
                for x in xs:
                    out.append(x + jax.random.normal(key, x.shape))
                return out
        """) == ["RPA002"]

    def test_loop_with_split_reassign_clean(self):
        assert codes("""
            import jax
            def noisy(key, xs):
                out = []
                for x in xs:
                    key, sub = jax.random.split(key)
                    out.append(x + jax.random.normal(sub, x.shape))
                return out
        """) == []

    def test_alias_import_detected(self):
        assert codes("""
            from jax import random
            def sample(key):
                a = random.normal(key, (3,))
                b = random.normal(key, (3,))
                return a + b
        """) == ["RPA002"]

    def test_stdlib_random_not_confused(self):
        assert codes("""
            import random
            import jax
            def roll(key):
                a = random.random()
                b = random.random()
                return a + b
        """) == []

    def test_if_branches_do_not_cross_consume(self):
        assert codes("""
            import jax
            def sample(key, flag):
                if flag:
                    return jax.random.normal(key, (3,))
                else:
                    return jax.random.uniform(key, (3,))
        """) == []


# ---------------------------------------------------------------------------
# RPA003 — donation after use
# ---------------------------------------------------------------------------

class TestDonationAfterUse:
    def test_use_after_donate_flags(self):
        assert codes("""
            import jax
            def run(step_fn, state, x):
                step = jax.jit(step_fn, donate_argnums=(0,))
                out = step(state, x)
                return out + state.mean()
        """) == ["RPA003"]

    def test_direct_call_form_flags(self):
        assert codes("""
            import jax
            def run(step_fn, state, x):
                out = jax.jit(step_fn, donate_argnums=(0,))(state, x)
                return out, state
        """) == ["RPA003"]

    def test_donate_argnames_resolved_through_def(self):
        assert codes("""
            import jax
            def step(state, x):
                return state + x
            def run(state, x):
                f = jax.jit(step, donate_argnames=("state",))
                out = f(state, x)
                return out + state
        """) == ["RPA003"]

    def test_rebind_after_donate_clean(self):
        # the canonical donation idiom: overwrite the donated name
        assert codes("""
            import jax
            def run(step_fn, state, x):
                step = jax.jit(step_fn, donate_argnums=(0,))
                state = step(state, x)
                return state
        """) == []

    def test_no_donation_clean(self):
        assert codes("""
            import jax
            def run(step_fn, state, x):
                step = jax.jit(step_fn)
                out = step(state, x)
                return out + state
        """) == []


# ---------------------------------------------------------------------------
# RPA004 — Pallas discipline
# ---------------------------------------------------------------------------

class TestPallasDiscipline:
    def test_literal_interpret_flags(self):
        assert codes("""
            import jax.experimental.pallas as pl
            def op(kernel, shape):
                return pl.pallas_call(kernel, out_shape=shape, interpret=True)
        """) == ["RPA004"]

    def test_pallas_interpret_call_clean(self):
        assert codes("""
            import jax.experimental.pallas as pl
            from repro.kernels.runtime import pallas_interpret
            def op(kernel, shape, interpret=None):
                return pl.pallas_call(
                    kernel, out_shape=shape,
                    interpret=pallas_interpret(interpret),
                )
        """) == []

    def test_kernel_layer_import_violation(self):
        src = """
            from repro.models import lm
        """
        assert codes(src, path="src/repro/kernels/fake/kernel.py") == ["RPA004"]
        assert codes(src, path="src/repro/kernels/fake/ref.py") == ["RPA004"]
        # same import is fine outside the kernel layer
        assert codes(src, path="src/repro/serve/helper.py") == []

    def test_ops_layer_may_import_core(self):
        src = """
            from repro.core.compression import QuantSpec
            from repro.kernels.runtime import pallas_interpret
        """
        assert codes(src, path="src/repro/kernels/fake/ops.py") == []
        assert codes(src, path="src/repro/kernels/fake/kernel.py") == ["RPA004"]


# ---------------------------------------------------------------------------
# RPA005 — hidden host syncs
# ---------------------------------------------------------------------------

class TestHiddenHostSync:
    def test_item_in_jitted_def_flags(self):
        assert codes("""
            import jax
            @jax.jit
            def step(x):
                return x.sum().item()
        """) == ["RPA005"]

    def test_float_on_name_in_traced_scope_flags(self):
        assert codes("""
            import jax
            @jax.jit
            def step(x):
                y = x.sum()
                return float(y)
        """) == ["RPA005"]

    def test_np_asarray_in_transform_target_flags(self):
        # traced by name: step is passed to lax.scan
        assert codes("""
            import jax
            import numpy as np
            from jax import lax
            def step(carry, x):
                np.asarray(x)
                return carry, x
            def run(xs):
                return lax.scan(step, 0, xs)
        """) == ["RPA005"]

    def test_nested_def_in_make_factory_flags(self):
        assert codes("""
            import jax
            class Engine:
                def _make_decode_step(self):
                    def step(state, x):
                        jax.block_until_ready(state)
                        return state
                    return step
        """) == ["RPA005"]

    def test_steady_state_engine_path_flags(self):
        src = """
            import jax
            class Engine:
                def _decode_once(self):
                    jax.block_until_ready(self._state)
        """
        assert codes(src, path="src/repro/serve/continuous.py") == ["RPA005"]
        assert codes(src, path="src/repro/other.py") == []

    def test_host_side_code_clean(self):
        assert codes("""
            import numpy as np
            def harvest(out):
                return np.asarray(out)
        """) == []


# ---------------------------------------------------------------------------
# RPA006 — bare print
# ---------------------------------------------------------------------------

class TestBarePrint:
    def test_print_flags(self):
        assert codes("print('hi')\n", path="src/repro/x.py") == ["RPA006"]

    def test_benchmarks_and_examples_exempt(self):
        assert codes("print('hi')\n", path="benchmarks/b.py") == []
        assert codes("print('hi')\n", path="examples/e.py") == []


# ---------------------------------------------------------------------------
# RPA007 — host scheduler/chaos layer discipline
# ---------------------------------------------------------------------------

class TestHostLayerDiscipline:
    def test_engine_internal_access_flags(self):
        assert codes("""
            def tick(self, engine, params):
                engine._state["budget"] = 0
        """, path="src/repro/serve/scheduler.py",
            select=["RPA007"]) == ["RPA007"]

    def test_deaden_slot_reach_through_flags(self):
        assert codes("""
            def preempt(self, engine, slot):
                engine._deaden_slot(slot)
        """, path="src/repro/net/chaos.py",
            select=["RPA007"]) == ["RPA007"]

    def test_device_sync_calls_flag(self):
        assert codes("""
            import jax
            def peek(self, x):
                jax.block_until_ready(x)
                return x.item()
        """, path="src/repro/serve/scheduler.py",
            select=["RPA007"]) == ["RPA007", "RPA007"]

    def test_public_host_api_clean(self):
        """The sanctioned surface — try_admit / preempt_slot /
        running_slots / block accounting, and the chaos squeeze's
        documented ``_free_blocks`` allocator access — stays silent."""
        assert codes("""
            def tick(self, engine, params):
                for slot, vr in engine.running_slots():
                    if engine.free_block_count() < engine.blocks_needed(
                            vr.prompt.size, vr.max_tokens):
                        engine.preempt_slot(slot)
                engine._free_blocks.append(engine._free_blocks.pop())
        """, path="src/repro/net/chaos.py", select=["RPA007"]) == []

    def test_router_engine_internal_access_flags(self):
        """The sharded router is host-layer too: reaching into a shard's
        AOT internals is exactly the discipline breach RPA007 exists
        for."""
        assert codes("""
            def _place(self, req):
                return self.shards[0]._state["lengths"]
        """, path="src/repro/serve/router.py",
            select=["RPA007"]) == ["RPA007"]

    def test_router_sync_call_flags(self):
        assert codes("""
            import numpy as np
            def queue_depth(self, req):
                return np.asarray(req.prompt)
        """, path="src/repro/serve/router.py",
            select=["RPA007"]) == ["RPA007"]

    def test_router_public_surface_clean(self):
        """The real router drives shards through the public engine API
        only (occupancy probes + try_admit/preempt_slot) — that surface
        stays silent."""
        assert codes("""
            def _place(self, req):
                best = None
                for i, sh in enumerate(self.shards):
                    if sh.free_slot_count <= 0:
                        continue
                    if sh.free_block_count() < self.blocks_needed(
                            req.prompt.size, req.max_tokens):
                        continue
                    best = i
                return best

            def preempt_slot(self, gslot):
                shard_idx, local = self._locate(gslot)
                return self.shards[shard_idx].preempt_slot(local)
        """, path="src/repro/serve/router.py", select=["RPA007"]) == []

    def test_other_files_exempt(self):
        """The engine itself owns its internals; the rule only polices
        the host scheduling/chaos/router layer."""
        assert codes("""
            def step(self, params):
                self._state = self._decode_fn(params, self._state)
        """, path="src/repro/serve/continuous.py", select=["RPA007"]) == []


# ---------------------------------------------------------------------------
# suppression + baseline
# ---------------------------------------------------------------------------

class TestSuppression:
    def test_bare_noqa_suppresses_everything(self):
        assert codes("print('hi')  # noqa\n", path="src/repro/x.py") == []

    def test_code_specific_noqa(self):
        assert codes("print('hi')  # noqa: RPA006\n",
                     path="src/repro/x.py") == []
        # the wrong code does not suppress
        assert codes("print('hi')  # noqa: RPA001\n",
                     path="src/repro/x.py") == ["RPA006"]

    def test_noqa_with_justification_prose(self):
        assert codes(
            "print('hi')  # noqa: RPA006 — sanctioned CLI banner\n",
            path="src/repro/x.py",
        ) == []

    def test_noqa_on_multiline_call(self):
        assert codes("""
            import jax
            @jax.jit
            def step(x):
                return jax.block_until_ready(  # noqa: RPA005
                    x
                )
        """) == []


class TestBaseline:
    def _finding(self, path="a.py", code="RPA006", line=3,
                 text="print('x')"):
        return Finding(path=path, line=line, col=0, code=code,
                       message="m", line_text=text)

    def test_roundtrip_and_filter(self, tmp_path):
        f = self._finding()
        p = tmp_path / "base.txt"
        baseline.save(str(p), [f])
        loaded = baseline.load(str(p))
        new, absorbed = baseline.filter_new([f], loaded)
        assert new == [] and absorbed == 1

    def test_fingerprint_is_line_number_free(self, tmp_path):
        p = tmp_path / "base.txt"
        baseline.save(str(p), [self._finding(line=3)])
        moved = self._finding(line=30)          # same line text, moved
        new, absorbed = baseline.filter_new([moved], baseline.load(str(p)))
        assert new == [] and absorbed == 1

    def test_duplicate_lines_counted(self, tmp_path):
        p = tmp_path / "base.txt"
        baseline.save(str(p), [self._finding(line=3)])   # tolerates ONE
        two = [self._finding(line=3), self._finding(line=9)]
        new, absorbed = baseline.filter_new(two, baseline.load(str(p)))
        assert len(new) == 1 and absorbed == 1

    def test_changed_line_resurfaces(self, tmp_path):
        p = tmp_path / "base.txt"
        baseline.save(str(p), [self._finding(text="print('x')")])
        edited = self._finding(text="print('y')")
        new, _ = baseline.filter_new([edited], baseline.load(str(p)))
        assert new == [edited]

    def test_missing_baseline_is_empty(self, tmp_path):
        assert baseline.load(str(tmp_path / "nope.txt")) == {}


# ---------------------------------------------------------------------------
# CLI (subprocess, the real entry point)
# ---------------------------------------------------------------------------

def _run_cli(args, cwd):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=cwd, env=env, capture_output=True, text=True, timeout=120,
    )


class TestCLI:
    def test_repo_at_head_is_clean_with_baseline(self):
        """The ISSUE 7 self-check: HEAD + committed baseline -> exit 0."""
        r = _run_cli(["src", "tests", "benchmarks"], cwd=REPO_ROOT)
        assert r.returncode == 0, r.stdout + r.stderr

    def test_seeded_violations_all_seven_rules(self, tmp_path):
        fixtures = {
            "bad1.py": """
                import jax
                def run(fns, x):
                    for f in fns:
                        jax.jit(f)(x)
            """,
            "bad2.py": """
                import jax
                def sample(key):
                    a = jax.random.normal(key, (3,))
                    return a + jax.random.uniform(key, (3,))
            """,
            "bad3.py": """
                import jax
                def run(step_fn, state, x):
                    step = jax.jit(step_fn, donate_argnums=(0,))
                    out = step(state, x)
                    return out + state
            """,
            "src/repro/kernels/fake/kernel.py": """
                import jax.experimental.pallas as pl
                from repro.models import lm
                def op(k, shape):
                    return pl.pallas_call(k, out_shape=shape, interpret=True)
            """,
            "bad5.py": """
                import jax
                @jax.jit
                def step(x):
                    return float(x)
            """,
            "bad6.py": """
                def hello():
                    print('hi')
            """,
            "src/repro/serve/scheduler.py": """
                def tick(self, engine, params):
                    engine._state["budget"] = 0
            """,
        }
        for rel, src in fixtures.items():
            p = tmp_path / rel
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(textwrap.dedent(src))
        r = _run_cli([".", "--no-baseline"], cwd=tmp_path)
        assert r.returncode == 1, r.stdout + r.stderr
        for code in ("RPA001", "RPA002", "RPA003", "RPA004", "RPA005",
                     "RPA006", "RPA007"):
            assert code in r.stdout, (code, r.stdout)

    def test_write_baseline_then_clean(self, tmp_path):
        (tmp_path / "bad.py").write_text("print('hi')\n")
        r = _run_cli([".", "--write-baseline"], cwd=tmp_path)
        assert r.returncode == 0, r.stdout + r.stderr
        assert (tmp_path / ".rpa-baseline.txt").exists()
        r2 = _run_cli(["."], cwd=tmp_path)
        assert r2.returncode == 0, r2.stdout + r2.stderr
        # a NEW violation still fails
        (tmp_path / "worse.py").write_text("print('no')\n")
        r3 = _run_cli(["."], cwd=tmp_path)
        assert r3.returncode == 1 and "worse.py" in r3.stdout

    def test_select_limits_rules(self, tmp_path):
        (tmp_path / "bad.py").write_text(
            "import jax\ndef f(fns, x):\n    for g in fns:\n"
            "        jax.jit(g)(x)\nprint('hi')\n"
        )
        r = _run_cli([".", "--no-baseline", "--select", "RPA006"],
                     cwd=tmp_path)
        assert "RPA006" in r.stdout and "RPA001" not in r.stdout

    def test_report_file_written(self, tmp_path):
        (tmp_path / "bad.py").write_text("print('hi')\n")
        r = _run_cli([".", "--no-baseline", "--report", "out.txt"],
                     cwd=tmp_path)
        assert r.returncode == 1
        assert "RPA006" in (tmp_path / "out.txt").read_text()

    def test_list_rules(self, tmp_path):
        r = _run_cli(["--list-rules"], cwd=tmp_path)
        assert r.returncode == 0
        assert all(c in r.stdout for c in RULES)

    def test_syntax_error_reports_rpa000(self, tmp_path):
        (tmp_path / "broken.py").write_text("def f(:\n")
        r = _run_cli([".", "--no-baseline"], cwd=tmp_path)
        assert r.returncode == 1 and "RPA000" in r.stdout


def test_analysis_package_imports_without_jax():
    """The CI lint job installs nothing: the static half must not pull in
    jax (only ``repro.analysis.guards`` may)."""
    code = (
        "import sys\n"
        "sys.modules['jax'] = None\n"   # poison: any import jax explodes
        "import repro.analysis\n"
        "import repro.analysis.__main__\n"
        "print('ok')\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 0 and "ok" in r.stdout, r.stderr


# ---------------------------------------------------------------------------
# runtime half: guards.no_recompile
# ---------------------------------------------------------------------------

class TestNoRecompileGuard:
    def test_warmed_call_passes(self):
        import jax
        import jax.numpy as jnp
        from repro.analysis.guards import no_recompile

        f = jax.jit(lambda x: x * 2)
        f(jnp.ones(4))                      # warmup compile
        with no_recompile():
            for _ in range(3):
                f(jnp.ones(4))              # cache hits only

    def test_injected_retrace_is_caught(self):
        import jax
        import jax.numpy as jnp
        from repro.analysis.guards import RecompileError, no_recompile

        with pytest.raises(RecompileError):
            with no_recompile():
                # fresh wrapper -> guaranteed new trace + XLA build
                jax.jit(lambda x: x * 3 + 1)(jnp.ones(4))  # noqa: RPA001 — the injected retrace this test exists to catch

    def test_allowed_budget(self):
        import jax
        import jax.numpy as jnp
        from repro.analysis.guards import no_recompile

        with no_recompile(allowed=1):
            jax.jit(lambda x: x - 11)(jnp.ones(4))  # noqa: RPA001 — single budgeted compile under test

    def test_engine_counter_fallback(self):
        from repro.analysis.guards import RecompileError, no_recompile

        class FakeEngine:
            compiles = 0

        eng = FakeEngine()
        with pytest.raises(RecompileError) as ei:
            with no_recompile(engines=(eng,)):
                eng.compiles += 2           # engine-side builds, no jax
        assert "engine compile counters" in str(ei.value)

    def test_xla_builds_total_counter_feeds_registry(self):
        import jax
        import jax.numpy as jnp
        from repro import obs

        obs.enable()
        try:
            c = obs.registry().counter("xla_builds_total")
            before = c.value
            jax.jit(lambda x: x + 13)(jnp.ones(4))  # noqa: RPA001 — deliberate compile to tick the counter
            assert c.value == before + 1
            assert obs.xla.builds_total() >= c.value
        finally:
            obs.disable()
