"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas kernel
vs the pure-jnp ref.py oracle (assert_allclose)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.lossy_link.kernel import lossy_link_egress_kernel
from repro.kernels.lossy_link.ref import lossy_link_egress_ref
from repro.kernels.ssm_scan.kernel import ssm_scan_kernel
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref


class TestLossyLinkKernel:
    @pytest.mark.parametrize("shape", [(64, 256), (100, 300), (1, 128), (257, 513)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("loss_rate", [0.0, 0.3, 0.8])
    def test_matches_ref(self, shape, dtype, loss_rate):
        t, d = shape
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, shape, dtype) * 3
        u = jax.random.uniform(jax.random.PRNGKey(1), shape)
        smin = jnp.full((d,), -4.0)
        smax = jnp.full((d,), 4.0)
        y_k = lossy_link_egress_kernel(
            x, u, smin, smax, bits=8, loss_rate=loss_rate
        )
        y_r = lossy_link_egress_ref(
            x, u, smin, smax, bits=8, loss_rate=loss_rate
        )
        np.testing.assert_allclose(
            np.asarray(y_k, np.float32), np.asarray(y_r, np.float32), atol=1e-5
        )

    @pytest.mark.parametrize("bits", [1, 4, 8, 16])
    def test_bit_width_sweep(self, bits):
        x = jax.random.normal(jax.random.PRNGKey(0), (32, 128)) * 2
        u = jax.random.uniform(jax.random.PRNGKey(1), (32, 128))
        smin = jnp.full((128,), -3.0)
        smax = jnp.full((128,), 3.0)
        y_k = lossy_link_egress_kernel(x, u, smin, smax, bits=bits, loss_rate=0.2)
        y_r = lossy_link_egress_ref(x, u, smin, smax, bits=bits, loss_rate=0.2)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=1e-5)

    def test_ops_wrapper_statistics(self):
        """End-to-end wrapper: keep rate and compensation are correct."""
        from repro.core.compression import QuantSpec
        from repro.kernels.lossy_link import lossy_link_egress

        spec = QuantSpec(bits=8, s_min=jnp.full((256,), -4.0),
                         s_max=jnp.full((256,), 4.0))
        x = jnp.ones((400, 256))
        y = lossy_link_egress(jax.random.PRNGKey(0), x, spec, 0.5)
        kept = np.asarray(y) != 0
        assert abs(kept.mean() - 0.5) < 0.01
        np.testing.assert_allclose(np.asarray(y)[kept], 2.0, atol=0.05)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize(
        "sq,skv,hd,causal,window,q_offset",
        [
            (256, 256, 64, True, 0, 0),
            (256, 256, 64, True, 64, 0),
            (200, 200, 32, True, 0, 0),
            (1, 384, 64, True, 0, 383),      # decode
            (1, 384, 64, True, 128, 383),    # windowed decode
            (128, 128, 128, False, 0, 0),
        ],
    )
    def test_matches_ref(self, sq, skv, hd, causal, window, q_offset):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(k1, (2, sq, hd), jnp.float32)
        k = jax.random.normal(k2, (2, skv, hd), jnp.float32)
        v = jax.random.normal(k3, (2, skv, hd), jnp.float32)
        y_k = flash_attention_kernel(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            block_q=64, block_kv=64,
        )
        y_r = flash_attention_ref(
            q, k, v, causal=causal, window=window, q_offset=q_offset
        )
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, dtype):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(k1, (1, 128, 64), dtype)
        k = jax.random.normal(k2, (1, 128, 64), dtype)
        v = jax.random.normal(k3, (1, 128, 64), dtype)
        y_k = flash_attention_kernel(q, k, v, block_q=64, block_kv=64)
        y_r = flash_attention_ref(q, k, v)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(y_k, np.float32), np.asarray(y_r, np.float32), atol=tol
        )

    def test_gqa_wrapper_matches_grouped_ref(self):
        """ops.flash_attention with KV heads < Q heads."""
        b, s, h, kv, hd = 2, 128, 8, 2, 32
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, kv, hd))
        v = jax.random.normal(ks[2], (b, s, kv, hd))
        out = flash_attention(q, k, v, block_q=64, block_kv=64)
        # reference: expand kv and run per-head naive
        ke = jnp.repeat(k, h // kv, axis=2)
        ve = jnp.repeat(v, h // kv, axis=2)
        qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        kf = ke.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        vf = ve.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        ref = flash_attention_ref(qf, kf, vf).reshape(b, h, s, hd).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    def test_window_equals_model_blockwise_attn(self):
        """The pure-jnp blockwise attention used by the model layer agrees
        with the kernel (same recurrence, two implementations)."""
        from repro.models.attention import _blockwise_attn, _grouped

        b, s, h, hd = 1, 256, 4, 32
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, h, hd))
        v = jax.random.normal(ks[2], (b, s, h, hd))
        out_model = _blockwise_attn(
            _grouped(q, h), k, v, causal=True, window=64, q_offset=0,
            block_q=64, block_kv=64, softcap=0.0,
        ).reshape(b, s, h, hd)
        out_kernel = flash_attention(q, k, v, window=64, block_q=64, block_kv=64)
        np.testing.assert_allclose(
            np.asarray(out_model), np.asarray(out_kernel), atol=2e-5
        )


class TestSSMScanKernel:
    @pytest.mark.parametrize("t,d", [(64, 256), (100, 130), (300, 512), (1, 128)])
    def test_matches_ref(self, t, d):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        a = jax.random.uniform(k1, (t, d), minval=0.8, maxval=1.0)
        b = jax.random.normal(k2, (t, d)) * 0.1
        h0 = jax.random.normal(k3, (d,))
        y_k = ssm_scan_kernel(a, b, h0, block_t=32, block_d=128)
        y_r = ssm_scan_ref(a, b, h0)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=1e-5)

    @settings(deadline=None, max_examples=10)
    @given(
        t=st.integers(1, 80),
        d=st.integers(1, 200),
        seed=st.integers(0, 1000),
    )
    def test_property_shapes(self, t, d, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        a = jax.random.uniform(ks[0], (t, d), minval=0.5, maxval=1.0)
        b = jax.random.normal(ks[1], (t, d)) * 0.2
        h0 = jnp.zeros((d,))
        y_k = ssm_scan_kernel(a, b, h0, block_t=16, block_d=64)
        y_r = ssm_scan_ref(a, b, h0)
        assert y_k.shape == (t, d)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=1e-4)

    def test_batched_wrapper(self):
        a = jax.random.uniform(jax.random.PRNGKey(0), (3, 50, 64), minval=0.9, maxval=1.0)
        b = jax.random.normal(jax.random.PRNGKey(1), (3, 50, 64)) * 0.1
        h0 = jax.random.normal(jax.random.PRNGKey(2), (3, 64))
        y = ssm_scan(a, b, h0)
        y_r = jax.vmap(ssm_scan_ref)(a, b, h0)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_r), atol=1e-5)

    def test_matches_mamba_chunked_scan(self):
        """The kernel recurrence == the model's chunked associative scan."""
        from repro.models.mamba import _chunked_selective_scan

        bsz, s, di, n = 2, 40, 8, 4
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        dt = jax.nn.softplus(jax.random.normal(ks[0], (bsz, s, di)))
        a = -jnp.exp(jax.random.normal(ks[1], (di, n)) * 0.2)
        b_ssm = jax.random.normal(ks[2], (bsz, s, n))
        c_ssm = jax.random.normal(ks[3], (bsz, s, n))
        x = jax.random.normal(ks[4], (bsz, s, di))
        y_model, h_fin = _chunked_selective_scan(dt, a, b_ssm, c_ssm, x, chunk=16)
        # same recurrence via the kernel on flattened (di*n) state
        da = jnp.exp(dt[..., None] * a[None, None]).reshape(bsz, s, di * n)
        dbx = (dt[..., None] * b_ssm[:, :, None, :] * x[..., None]).reshape(
            bsz, s, di * n
        )
        h_all = ssm_scan(da, dbx, jnp.zeros((bsz, di * n)))
        y_kernel = jnp.einsum(
            "bsdn,bsn->bsd", h_all.reshape(bsz, s, di, n), c_ssm
        )
        np.testing.assert_allclose(
            np.asarray(y_model), np.asarray(y_kernel), atol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(h_fin.reshape(bsz, -1)), np.asarray(h_all[:, -1]), atol=1e-4
        )
