"""Paged KV slot pool (block-granular cache + block-table flash decode):

* paged kernel (interpret) vs the paged jnp ref — bit-level agreement
  (same arithmetic, two lowered programs) across GQA group sizes,
  bf16/int8 pools, and valid lengths straddling the block boundary;
* paged vs CONTIGUOUS flash decode — gathering a request's blocks into a
  contiguous cache and running the PR-5 ref must match the paged walk at
  float-ulp level, for any physical block permutation (the walk order is
  logical, so the outputs are permutation-invariant bit-for-bit);
* ``ContinuousEngine(PoolConfig(paged=True))``: greedy outputs
  token-identical to ``generate_reference`` under iid + GE links and int8
  pools, rotating windows wrapping across block boundaries included, with
  the AOT compile count pinned at ``num_buckets + 1`` under the
  ``no_recompile`` guard;
* host allocator edges — pool exhaustion blocks head-of-line without
  corrupting live slots, freed blocks are reallocated without stale-row
  leakage, never-admissible requests are rejected at submit;
* satellites — ``write_slot``/``write_prompt_blocks`` raise on dtype
  mismatch instead of silently casting, ``decode_read_bytes(paged=...)``
  and its traced twin agree exactly, admission bytes scale with the
  bucket, and the paged-pool obs gauges/counters match an eager oracle.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.analysis.guards import no_recompile
from repro.configs import ARCHITECTURES
from repro.kernels.decode_attention import (
    flash_decode_ref,
    paged_decode_attention,
    paged_flash_decode_kernel,
    paged_flash_decode_ref,
)
from repro.launch.serve import generate_reference
from repro.models import cache as cache_lib, lm
from repro.serve import ContinuousEngine, PoolConfig

BS = 8          # pool block size used by the kernel-level tests


def _make_paged(seed, b, n_blocks, bs, kvh, g, hd, quantized,
                dtype=jnp.float32):
    """Random query + block pool + a shuffled (never-0) block table."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (b, 1, kvh, g, hd), dtype)
    if quantized:
        pool = {
            "k": jax.random.randint(
                ks[1], (n_blocks, bs, kvh, hd), -127, 128, jnp.int8
            ),
            "v": jax.random.randint(
                ks[2], (n_blocks, bs, kvh, hd), -127, 128, jnp.int8
            ),
            "k_scale": (jax.random.uniform(ks[3], (n_blocks, bs, kvh)) * 0.05
                        + 0.01).astype(jnp.bfloat16),
            "v_scale": (jax.random.uniform(ks[4], (n_blocks, bs, kvh)) * 0.05
                        + 0.01).astype(jnp.bfloat16),
        }
    else:
        pool = {
            "k": jax.random.normal(ks[1], (n_blocks, bs, kvh, hd), dtype),
            "v": jax.random.normal(ks[2], (n_blocks, bs, kvh, hd), dtype),
        }
    return q, pool


def _shuffled_table(seed, b, j, n_blocks):
    """(b, j) table of distinct physical ids drawn from 1..n_blocks-1."""
    rng = np.random.RandomState(seed)
    ids = rng.permutation(np.arange(1, n_blocks))[: b * j]
    return jnp.asarray(ids.reshape(b, j), jnp.int32)


def _gathered(pool, bt):
    """A request-major contiguous cache holding the table's rows —
    the input the PR-5 contiguous ref expects."""
    out = {}
    for name, leaf in pool.items():
        g = jnp.take(leaf, bt.reshape(-1), axis=0)           # (b*j, bs, ...)
        b, j = bt.shape
        out[name] = g.reshape((b, j * leaf.shape[1]) + leaf.shape[2:])
    return out


class TestPagedKernelRefEquivalence:
    @pytest.mark.parametrize("g", [1, 4])
    @pytest.mark.parametrize("quantized", [False, True])
    @pytest.mark.parametrize("n_valid", [1, BS - 1, BS, 4 * BS])
    def test_kernel_interpret_equals_ref(self, g, quantized, n_valid):
        b, j, kvh, hd = 2, 4, 2, 16
        q, pool = _make_paged(0, b, 16, BS, kvh, g, hd, quantized)
        bt = _shuffled_table(0, b, j, 16)
        n = jnp.full((b,), n_valid, jnp.int32)
        args = (q[:, 0], pool["k"], pool["v"],
                pool.get("k_scale"), pool.get("v_scale"), bt, n)
        out_k = paged_flash_decode_kernel(*args, block_size=BS,
                                          interpret=True)
        out_r = paged_flash_decode_ref(*args, block_size=BS)
        np.testing.assert_allclose(
            np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
            rtol=2e-6, atol=2e-6,
        )

    @pytest.mark.parametrize("softcap", [0.0, 30.0])
    def test_softcap_paths_agree(self, softcap):
        b, j, kvh, g, hd = 1, 2, 2, 2, 8
        q, pool = _make_paged(1, b, 8, BS, kvh, g, hd, False)
        bt = _shuffled_table(1, b, j, 8)
        n = jnp.full((b,), 11, jnp.int32)
        args = (q[:, 0], pool["k"], pool["v"], None, None, bt, n)
        out_k = paged_flash_decode_kernel(*args, block_size=BS,
                                          softcap=softcap, interpret=True)
        out_r = paged_flash_decode_ref(*args, block_size=BS, softcap=softcap)
        np.testing.assert_allclose(
            np.asarray(out_k), np.asarray(out_r), rtol=2e-6, atol=2e-6
        )

    def test_bf16_query_int8_pool(self):
        """Production serve dtype: bf16 activations over the int8 pool."""
        b, j, kvh, g, hd = 2, 4, 2, 4, 16
        q, pool = _make_paged(4, b, 16, BS, kvh, g, hd, True,
                              dtype=jnp.bfloat16)
        bt = _shuffled_table(4, b, j, 16)
        n = jnp.full((b,), 13, jnp.int32)
        args = (q[:, 0], pool["k"], pool["v"],
                pool["k_scale"], pool["v_scale"], bt, n)
        out_k = paged_flash_decode_kernel(*args, block_size=BS,
                                          interpret=True)
        out_r = paged_flash_decode_ref(*args, block_size=BS)
        assert out_k.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
            rtol=1e-2,
        )


class TestPagedVsContiguous:
    @pytest.mark.parametrize("g", [1, 4])
    @pytest.mark.parametrize("quantized", [False, True])
    @pytest.mark.parametrize("n_valid", [1, BS - 1, BS, 4 * BS])
    def test_matches_contiguous_ref_on_gathered_cache(
        self, g, quantized, n_valid
    ):
        """Acceptance grid: the paged walk over shuffled physical blocks
        equals the PR-5 contiguous flash decode on the gathered cache —
        same online-softmax recipe, so agreement is float-ulp level."""
        b, j, kvh, hd = 2, 4, 2, 16
        q, pool = _make_paged(7, b, 16, BS, kvh, g, hd, quantized)
        bt = _shuffled_table(7, b, j, 16)
        n = jnp.full((b,), n_valid, jnp.int32)
        out_p = paged_flash_decode_ref(
            q[:, 0], pool["k"], pool["v"],
            pool.get("k_scale"), pool.get("v_scale"), bt, n, block_size=BS,
        )
        cache = _gathered(pool, bt)
        out_c = flash_decode_ref(
            q[:, 0], cache["k"], cache["v"],
            cache.get("k_scale"), cache.get("v_scale"),
            n[:, None], block_kv=BS,
        )
        np.testing.assert_allclose(
            np.asarray(out_p, np.float32), np.asarray(out_c, np.float32),
            rtol=2e-6, atol=2e-6,
        )

    def test_physical_permutation_invariance(self):
        """Two pools holding the same logical rows under different physical
        placements produce bitwise-identical outputs: the walk follows the
        table in logical order, so physical ids never affect arithmetic."""
        b, j, kvh, g, hd = 2, 4, 2, 2, 16
        q, pool = _make_paged(9, b, 16, BS, kvh, g, hd, True)
        bt1 = _shuffled_table(1, b, j, 16)
        bt2 = _shuffled_table(2, b, j, 16)
        # Re-scatter pool-1's logical rows into bt2's physical placement.
        pool2 = {
            name: jnp.zeros_like(leaf).at[bt2.reshape(-1)].set(
                jnp.take(leaf, bt1.reshape(-1), axis=0)
            )
            for name, leaf in pool.items()
        }
        n = jnp.array([5, 3 * BS + 2], jnp.int32)
        a = (q[:, 0], pool["k"], pool["v"], pool["k_scale"],
             pool["v_scale"], bt1, n)
        b_ = (q[:, 0], pool2["k"], pool2["v"], pool2["k_scale"],
              pool2["v_scale"], bt2, n)
        for fn, kw in (
            (paged_flash_decode_ref, {}),
            (paged_flash_decode_kernel, {"interpret": True}),
        ):
            np.testing.assert_array_equal(
                np.asarray(fn(*a, block_size=BS, **kw), np.float32),
                np.asarray(fn(*b_, block_size=BS, **kw), np.float32),
            )

    @pytest.mark.parametrize("impl", ["ref", "kernel"])
    def test_ops_windowed_layer_slices_table(self, impl):
        """``paged_decode_attention(seq_len=...)`` walks only the layer's
        own ``ceil(seq_len / block_size)`` table entries: garbage ids in
        the tail of a wider table row must not affect the output."""
        b, j, kvh, g, hd = 2, 4, 2, 2, 16
        q, pool = _make_paged(11, b, 16, BS, kvh, g, hd, False)
        bt = _shuffled_table(11, b, j, 16)
        seq_len = BS + 3                       # c_l of a window=11 layer
        for n_valid in (1, BS, seq_len):
            n = jnp.full((b,), n_valid, jnp.int32)
            out = paged_decode_attention(
                q, pool, bt, n, seq_len=seq_len, block_size=BS, impl=impl,
                interpret=True,
            )
            cache = _gathered(pool, bt[:, :2])
            want = flash_decode_ref(
                q[:, 0], cache["k"], cache["v"], None, None, n[:, None],
                block_kv=BS,
            )
            np.testing.assert_allclose(
                np.asarray(out[:, 0], np.float32),
                np.asarray(want, np.float32), rtol=2e-6, atol=2e-6,
                err_msg=f"n_valid={n_valid}",
            )


def _setup_engine(channel="iid", loss_rate=0.3, **overrides):
    cfg = ARCHITECTURES["qwen1.5-0.5b"].reduced(
        attn_impl="flash_decode", **overrides
    )
    cfg = cfg.with_updates(
        link=dataclasses.replace(cfg.link, loss_rate=loss_rate, channel=channel)
    )
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompt(i, length, vocab):
    return np.asarray(
        jax.random.randint(
            jax.random.fold_in(jax.random.PRNGKey(7), i), (length,), 0, vocab,
            jnp.int32,
        )
    )


def _check_identity(eng, params, cfg, lengths, tokens, key):
    reqs = [
        eng.submit(_prompt(i, L, cfg.vocab_size), tokens,
                   key=jax.random.fold_in(key, i))
        for i, L in enumerate(lengths)
    ]
    eng.run(params)
    for i, (L, req) in enumerate(zip(lengths, reqs)):
        ref, _ = generate_reference(
            params, cfg, jnp.asarray(_prompt(i, L, cfg.vocab_size))[None],
            tokens, key=jax.random.fold_in(key, i),
        )
        np.testing.assert_array_equal(
            np.asarray(ref)[0], req.tokens, err_msg=f"request {i} (len {L})"
        )
    return reqs


class TestPagedEngine:
    @pytest.mark.parametrize("channel", ["iid", "ge"])
    def test_token_identity_vs_reference(self, channel):
        """Acceptance: the paged engine's greedy outputs are token-for-token
        identical to the per-request reference loop, mixed buckets, with the
        block pool shared across slots."""
        cfg, params = _setup_engine(channel=channel)
        eng = ContinuousEngine(
            cfg,
            PoolConfig(max_slots=4, max_new=4, max_prompt=16, min_bucket=4,
                       paged=True, block_size=4),
        )
        _check_identity(eng, params, cfg, [1, 3, 6, 13], 4,
                        jax.random.PRNGKey(42))

    def test_int8_pool_token_identity(self):
        cfg, params = _setup_engine(kv_cache_dtype="int8")
        eng = ContinuousEngine(
            cfg,
            PoolConfig(max_slots=2, max_new=5, max_prompt=8, min_bucket=8,
                       paged=True, block_size=8),
        )
        _check_identity(eng, params, cfg, [4, 5, 6], 5, jax.random.PRNGKey(9))

    def test_rotating_window_wraps_across_block_boundary(self):
        """Sliding windows shorter than a block multiple: the per-layer
        rotating write (row = length % c_l) must wrap mid-block and across
        the block boundary without touching other slots' blocks.  window=6
        with block_size=4 puts the wrap at row 2 of the second block."""
        cfg = ARCHITECTURES["gemma3-12b"].reduced(attn_impl="flash_decode")
        pat = tuple(dataclasses.replace(s, window=6) if s.window else s
                    for s in cfg.unit_pattern)
        cfg = cfg.with_updates(unit_pattern=pat)
        cfg = cfg.with_updates(
            link=dataclasses.replace(cfg.link, loss_rate=0.3, channel="iid")
        )
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        eng = ContinuousEngine(
            cfg,
            PoolConfig(max_slots=2, max_new=8, max_prompt=8, min_bucket=4,
                       paged=True, block_size=4),
        )
        # length reaches 11 > 6: every windowed layer wraps.
        _check_identity(eng, params, cfg, [3, 5], 8, jax.random.PRNGKey(3))

    def test_compiles_buckets_plus_one_and_no_recompile(self):
        """Compile discipline: warm compiles == num_buckets + 1, and a
        saturated follow-up workload (admissions, retirements, block
        realloc) performs ZERO new XLA builds under the runtime guard."""
        cfg, params = _setup_engine()
        eng = ContinuousEngine(
            cfg,
            PoolConfig(max_slots=3, max_new=4, max_prompt=16, min_bucket=8,
                       paged=True, block_size=4),
        )
        key = jax.random.PRNGKey(0)
        for i, L in enumerate([5, 12, 7, 16]):        # buckets {8, 16}
            eng.submit(_prompt(i, L, cfg.vocab_size), 3,
                       key=jax.random.fold_in(key, i))
        eng.run(params)
        assert eng.num_buckets == 2
        assert eng.compiles == eng.num_buckets + 1
        # Precompute prompts/keys before arming the guard: host-side
        # randint dispatches must not count as engine work.
        work = [
            (_prompt(100 + i, 4 + (i % 13), cfg.vocab_size), 1 + (i % 4),
             jax.random.fold_in(key, 100 + i))
            for i in range(8)
        ]
        with no_recompile(engines=(eng,)):
            for p, t, k in work:
                eng.submit(p, t, key=k)
            done = eng.run(params)
        assert len(done) == 8
        assert eng.compiles == eng.num_buckets + 1


class TestAllocatorEdges:
    def _tight_engine(self, num_blocks=3):
        """max_seq=12, block_size=4 -> 3 blocks/slot; num_blocks=3 gives 2
        allocatable blocks — exactly one (prompt<=4, tokens<=4) request."""
        cfg, params = _setup_engine()
        eng = ContinuousEngine(
            cfg,
            PoolConfig(max_slots=2, max_new=4, max_prompt=8, min_bucket=8,
                       paged=True, block_size=4, num_blocks=num_blocks),
        )
        return cfg, params, eng

    def test_exhaustion_blocks_head_of_line_without_corruption(self):
        """Pool of 2 allocatable blocks, three 2-block requests: admissions
        serialize (a free slot alone is not enough), no live slot ever
        loses a block, and every request still matches the reference."""
        cfg, params, eng = self._tight_engine()
        _check_identity(eng, params, cfg, [2, 3, 4], 4, jax.random.PRNGKey(5))
        assert eng.stats()["active_peak"] == 1.0       # never co-resident
        assert eng.peak_blocks_used == 2
        # Head-of-line wait is bounded: everything completed and the full
        # free list is restored (no leaked blocks).
        assert sorted(eng._free_blocks) == [1, 2]
        assert all(not b for b in eng._slot_blocks)

    def test_free_then_realloc_no_stale_leakage(self):
        """Freed blocks are reused (LIFO) by later requests whose valid
        region is SHORTER than the previous tenant's — stale rows beyond
        n_valid must stay invisible.  Token identity against the reference
        is the oracle: any leaked row would change the softmax."""
        cfg, params, eng = self._tight_engine()
        key = jax.random.PRNGKey(17)
        # Long tenant first (fills both blocks to row 8), then a 1-token
        # prompt whose n_valid stays far below the stale rows.
        r_long = eng.submit(_prompt(0, 4, cfg.vocab_size), 4,
                            key=jax.random.fold_in(key, 0))
        eng.run(params)
        r_short = eng.submit(_prompt(1, 1, cfg.vocab_size), 2,
                             key=jax.random.fold_in(key, 1))
        eng.run(params)
        for i, (req, L, t) in enumerate(
            [(r_long, 4, 4), (r_short, 1, 2)]
        ):
            ref, _ = generate_reference(
                params, cfg, jnp.asarray(_prompt(i, L, cfg.vocab_size))[None],
                t, key=jax.random.fold_in(key, i),
            )
            np.testing.assert_array_equal(np.asarray(ref)[0], req.tokens)

    def test_never_admissible_request_rejected_at_submit(self):
        # 8-token prompt + 4 tokens needs 3 blocks > 2 allocatable.
        cfg, params, eng = self._tight_engine()
        with pytest.raises(ValueError, match="could never be admitted"):
            eng.submit(_prompt(0, 8, cfg.vocab_size), 4)

    def test_paged_rejects_recurrent_stacks(self):
        cfg = ARCHITECTURES["xlstm-350m"].reduced()
        with pytest.raises(ValueError, match="attention-only"):
            ContinuousEngine(cfg, PoolConfig(paged=True))

    def test_pool_needs_two_blocks(self):
        cfg, _ = _setup_engine()
        with pytest.raises(ValueError, match=">= 2 blocks"):
            ContinuousEngine(
                cfg, PoolConfig(paged=True, num_blocks=1)
            )


class TestWriteDtypeGuard:
    @staticmethod
    def _bf16_cache(cfg):
        """Same tree STRUCTURE as the int8 cache, bf16 leaves — the shape a
        miscalibrated producer hands the pool (structure mismatches are
        caught by tree_map itself; the dtype guard covers this case)."""
        return jax.tree_util.tree_map(
            lambda a: a.astype(jnp.bfloat16), cache_lib.init_cache(cfg, 1, 16)
        )

    def test_write_slot_rejects_dtype_mismatch(self):
        """Satellite regression: writing a bf16 cache into an int8 slot
        pool must raise at trace time, not silently cast values to codes."""
        cfg = ARCHITECTURES["qwen1.5-0.5b"].reduced(kv_cache_dtype="int8")
        pool = cache_lib.init_slot_pool(cfg, 2, 16)
        with pytest.raises(ValueError, match="does not match pool leaf dtype"):
            cache_lib.write_slot(pool, self._bf16_cache(cfg), jnp.int32(0))

    def test_write_prompt_blocks_rejects_dtype_mismatch(self):
        cfg = ARCHITECTURES["qwen1.5-0.5b"].reduced(kv_cache_dtype="int8")
        pool = cache_lib.init_block_pool(cfg, 8, 4)
        bt = jnp.arange(1, 5, dtype=jnp.int32)
        with pytest.raises(ValueError, match="does not match pool leaf dtype"):
            cache_lib.write_prompt_blocks(pool, self._bf16_cache(cfg), bt, 2, 4)

    def test_write_slot_same_config_still_works(self):
        cfg = ARCHITECTURES["qwen1.5-0.5b"].reduced(kv_cache_dtype="int8")
        pool = cache_lib.init_slot_pool(cfg, 2, 16)
        out = cache_lib.write_slot(
            pool, cache_lib.init_cache(cfg, 1, 16), jnp.int32(1)
        )
        assert jax.tree_util.tree_structure(out) == \
            jax.tree_util.tree_structure(pool)


class TestByteAccounting:
    @pytest.mark.parametrize("kv_cache_dtype", ["", "int8"])
    def test_paged_int_vs_jnp_exact(self, kv_cache_dtype):
        cfg = ARCHITECTURES["qwen1.5-0.5b"].reduced(
            kv_cache_dtype=kv_cache_dtype
        )
        for valid in (1, 3, 4, 7, 16, 33, 64):
            want = cache_lib.decode_read_bytes(
                cfg, 64, valid, paged=True, block_size=4
            )
            got = cache_lib.decode_read_bytes_jnp(
                cfg, 64, jnp.float32(valid), paged=True, block_size=4
            )
            assert float(got) == float(want), valid

    def test_paged_read_scales_with_valid_not_max_seq(self):
        cfg = ARCHITECTURES["qwen1.5-0.5b"].with_updates(kv_cache_dtype="int8")
        small = cache_lib.decode_read_bytes(
            cfg, 1024, 16, paged=True, block_size=16
        )
        full = cache_lib.decode_read_bytes(
            cfg, 1024, 1024, paged=True, block_size=16
        )
        assert small * 8 <= full

    def test_admission_bytes_scale_with_bucket(self):
        """Acceptance: admission writes scale with the prompt's bucket, not
        ``max_seq`` — the contiguous path is constant at the full slot."""
        cfg = ARCHITECTURES["qwen1.5-0.5b"].reduced(kv_cache_dtype="int8")
        max_seq = 96
        contiguous = cache_lib.admission_write_bytes(cfg, max_seq, 8)
        assert contiguous == cache_lib.cache_bytes(cfg, 1, max_seq)
        assert contiguous == cache_lib.admission_write_bytes(cfg, max_seq, 64)
        b8 = cache_lib.admission_write_bytes(
            cfg, max_seq, 8, paged=True, block_size=8
        )
        b64 = cache_lib.admission_write_bytes(
            cfg, max_seq, 64, paged=True, block_size=8
        )
        assert b8 * 8 == b64                      # linear in the bucket
        assert b64 < contiguous

    def test_block_pool_bytes_matches_contiguous_at_parity(self):
        """A derived (num_blocks=0) pool costs the contiguous pool's bytes
        plus exactly one trash block + padded-tail rows per layer."""
        cfg = ARCHITECTURES["qwen1.5-0.5b"].reduced(kv_cache_dtype="int8")
        p = PoolConfig(max_slots=4, max_new=8, max_prompt=8, min_bucket=8,
                       paged=True, block_size=4)
        paged = cache_lib.block_pool_bytes(cfg, p.total_blocks, p.block_size)
        contig = cache_lib.cache_bytes(cfg, p.max_slots, p.max_seq)
        # max_seq=16 divides block_size=4, so the only overhead is block 0.
        one_block = cache_lib.block_pool_bytes(cfg, 3, p.block_size) - \
            cache_lib.block_pool_bytes(cfg, 2, p.block_size)
        assert paged == contig + one_block


@pytest.fixture
def global_registry_enabled():
    """Enable the process-global registry for one test, restore after."""
    reg = obs.registry()
    was = reg.enabled
    reg.reset()
    reg.enable()
    yield reg
    reg.reset()
    reg.enabled = was


class TestPagedObs:
    def test_pool_gauges_and_blocks_written_vs_oracle(
        self, global_registry_enabled
    ):
        """The paged-pool gauges/counters published at admission/retirement
        sync points match an eager host oracle replaying the allocator
        arithmetic, and obs-on keeps compiles == num_buckets + 1."""
        reg = global_registry_enabled
        cfg, params = _setup_engine()
        pool = PoolConfig(max_slots=4, max_new=4, max_prompt=16, min_bucket=4,
                          paged=True, block_size=4)
        eng = ContinuousEngine(cfg, pool)
        key = jax.random.PRNGKey(21)
        lengths = [1, 3, 6, 13]
        for i, L in enumerate(lengths):
            eng.submit(_prompt(i, L, cfg.vocab_size), 4,
                       key=jax.random.fold_in(key, i))
        # One scheduler tick: all four admissions land, nothing retires.
        eng.step(params)
        oracle_used = sum(
            eng.blocks_needed(L, 4) for L in lengths
        )
        assert reg.gauge("serve.pool_blocks_used").value == float(oracle_used)
        assert reg.gauge("serve.pool_blocks_total").value == float(
            pool.total_blocks - 1
        )
        # Fresh admissions: bucket-padded reservations hold more rows than
        # the prompts fill, so fragmentation is strictly positive.
        assert 0.0 < reg.gauge("serve.pool_fragmentation").value < 1.0
        eng.run(params)
        # Drained: every block back on the free list, fragmentation zero.
        assert reg.gauge("serve.pool_blocks_used").value == 0.0
        assert reg.gauge("serve.pool_fragmentation").value == 0.0
        oracle_written = sum(
            min(cache_lib.blocks_for(eng.bucket_for(L), pool.block_size),
                pool.blocks_per_slot)
            for L in lengths
        )
        assert reg.counter("serve.blocks_written").value == float(
            oracle_written
        )
        assert eng.blocks_written == oracle_written
        assert eng.compiles == eng.num_buckets + 1

    def test_stats_surface_pool_fields(self):
        cfg, params = _setup_engine()
        eng = ContinuousEngine(
            cfg,
            PoolConfig(max_slots=2, max_new=4, max_prompt=8, min_bucket=8,
                       paged=True, block_size=4),
        )
        eng.submit(_prompt(0, 4, cfg.vocab_size), 2)
        eng.run(params)
        s = eng.stats()
        assert s["pool_blocks_total"] == float(eng.pool.total_blocks - 1)
        assert s["peak_blocks_used"] >= 1.0
        assert s["blocks_written"] >= 1.0
        assert s["active_peak"] >= 1.0
