"""Hypothesis import with a deterministic fallback for bare installs.

Test modules import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` directly.  When hypothesis is installed the real library is
used unchanged; when it is missing (the container only bakes in the
jax/pallas toolchain) a tiny shim runs each property test over a fixed
number of deterministically-sampled examples.  The shim covers only the
strategy surface these tests use (``st.integers``/``st.floats`` with
inclusive bounds) — it is NOT a general hypothesis replacement, and it does
no shrinking; it exists so the tier-1 suite collects and exercises the
properties on a bare install.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is present
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback shim
    import inspect
    import random

    HAVE_HYPOTHESIS = False

    _FALLBACK_MAX_EXAMPLES = 10  # cap so the shim stays fast

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class st:  # noqa: N801 - mimics `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.getrandbits(1)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements))

    def settings(**kwargs):
        def deco(fn):
            fn._shim_settings = kwargs
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                conf = getattr(wrapper, "_shim_settings", {})
                n = min(
                    int(conf.get("max_examples", _FALLBACK_MAX_EXAMPLES)),
                    _FALLBACK_MAX_EXAMPLES,
                )
                rng = random.Random(0xC0117)  # fixed seed: reproducible draws
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            # Hide the strategy-filled params from pytest's fixture resolver.
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(
                parameters=[
                    p for name, p in sig.parameters.items()
                    if name not in strategies
                ]
            )
            return wrapper

        return deco
