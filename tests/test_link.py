"""Channel model tests (paper Eq. 1-5) incl. hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import link


class TestMasks:
    def test_element_mask_rate(self):
        m = link.element_loss_mask(jax.random.PRNGKey(0), (200_000,), 0.3)
        assert abs(float(m.mean()) - 0.7) < 0.01

    def test_packet_mask_rate_with_shuffle(self):
        fr = [
            float(link.packet_loss_mask(jax.random.PRNGKey(i), 50_000, 0.4, 25).mean())
            for i in range(10)
        ]
        assert abs(np.mean(fr) - 0.6) < 0.02

    def test_packet_mask_burst_without_shuffle(self):
        """Without the paper's shuffle, losses are bursts of whole packets."""
        m = np.asarray(
            link.packet_loss_mask(
                jax.random.PRNGKey(0), 1000, 0.5, 25, shuffle=False
            )
        )
        blocks = m.reshape(-1, 25)
        # every 25-element packet is entirely kept or entirely dropped
        assert np.all((blocks.sum(axis=1) == 0) | (blocks.sum(axis=1) == 25))

    def test_zero_loss_is_identity(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (64,))
        y = link.apply_channel(jax.random.PRNGKey(0), x, 0.0)
        np.testing.assert_allclose(np.asarray(x), np.asarray(y))

    @settings(deadline=None, max_examples=25)
    @given(
        p=st.floats(0.05, 0.9),
        seed=st.integers(0, 2**31 - 1),
        n=st.integers(100, 5000),
    )
    def test_compensation_unbiased_property(self, p, seed, n):
        """E[f_c(x|p)/(1-p)] == x  (the paper's Eq. 11 compensation)."""
        x = jnp.ones((n,))
        y = link.apply_channel(jax.random.PRNGKey(seed), x, p, compensate=True)
        # mean of compensated mask ~ 1 with std sqrt(p/(1-p)/n)
        tol = 6.0 * np.sqrt(p / (1 - p) / n)
        assert abs(float(y.mean()) - 1.0) < tol

    @settings(deadline=None, max_examples=20)
    @given(p=st.floats(0.0, 0.95), seed=st.integers(0, 1000))
    def test_mask_is_binary_and_shape_preserving(self, p, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (17, 13))
        y = link.apply_channel(jax.random.PRNGKey(seed + 1), x, p, compensate=False)
        assert y.shape == x.shape
        kept = np.asarray(y) != 0
        np.testing.assert_allclose(
            np.asarray(y)[kept], np.asarray(x)[kept], rtol=1e-6
        )


class TestLatencyModel:
    def test_received_pmf_normalizes_and_mean(self):
        pmf = link.received_packets_pmf(200, 0.3)
        assert abs(pmf.sum() - 1.0) < 1e-9
        mean = (np.arange(201) * pmf).sum()
        assert abs(mean - 0.7 * 200) < 1e-6

    def test_reliable_latency_mean_matches_negative_binomial(self):
        cfg = link.ChannelConfig(loss_rate=0.5)
        lat, pmf = link.reliable_latency_pmf(100, cfg)
        mean_slots = (lat / cfg.slot_time_s() * pmf).sum()
        assert abs(mean_slots - 100 / 0.5) < 0.5

    def test_unreliable_latency_deterministic(self):
        cfg = link.ChannelConfig(loss_rate=0.9)
        # no retransmission: latency independent of loss rate
        assert link.unreliable_latency_s(100, cfg) == 100 * cfg.slot_time_s()

    def test_reliable_slower_than_unreliable(self):
        """Paper Fig. 4a: reliable protocol latency stochastically dominates."""
        cfg = link.ChannelConfig(loss_rate=0.5)
        n_t = 655  # 65.5 kB / 100 B
        unrel = link.unreliable_latency_s(n_t, cfg)
        lat, pmf = link.reliable_latency_pmf(n_t, cfg)
        mean_rel = (lat * pmf).sum()
        assert mean_rel > 1.9 * unrel  # ~2x at p=0.5

    def test_gammaln_accuracy(self):
        import math

        for x in [1.0, 2.5, 10.0, 100.5, 1000.0]:
            assert abs(link._gammaln(np.array(x)) - math.lgamma(x)) < 1e-8
