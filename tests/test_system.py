"""End-to-end system tests: sharded step builders on a host mesh, LM
COMtune training improves loss, serve loop generates, param-spec rules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHITECTURES, INPUT_SHAPES
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_sharded_step, input_specs
from repro.models import lm
from repro.optim import AdamConfig, init_adam
from repro.sharding import rules


class TestShardedSteps:
    """Exercise the exact jit+shardings machinery the dry-run uses, on the
    host mesh (1 device) with a reduced model — executes for real."""

    def _run(self, arch, kind):
        cfg = ARCHITECTURES[arch].reduced()
        shape_cfg = ShapeConfig("tiny", seq_len=16, global_batch=2, kind=kind)
        mesh = make_host_mesh()
        with mesh:
            jitted, args = build_sharded_step(cfg, shape_cfg, mesh)
            # materialize concrete inputs from the abstract specs
            concrete = jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), args
            )
            if kind == "train":
                params, opt, batch, key = concrete
                params = lm.init_lm(jax.random.PRNGKey(0), cfg)
                out = jitted(params, opt, batch, jnp.zeros((2,), jnp.uint32))
                assert np.isfinite(float(out[2]["loss"]))
            elif kind == "prefill":
                params, batch, cache, key = concrete
                params = lm.init_lm(jax.random.PRNGKey(0), cfg)
                logits, new_cache = jitted(
                    params, batch, cache, jnp.zeros((2,), jnp.uint32)
                )
                assert logits.shape == (2, cfg.vocab_size)
            else:
                params, token, cache, index, key = concrete
                params = lm.init_lm(jax.random.PRNGKey(0), cfg)
                logits, new_cache = jitted(
                    params, token, cache, jnp.int32(0), jnp.zeros((2,), jnp.uint32)
                )
                assert logits.shape == (2, cfg.vocab_size)
                assert bool(jnp.isfinite(logits).all())

    @pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "jamba-v0.1-52b", "xlstm-350m"])
    def test_train_step_executes(self, arch):
        self._run(arch, "train")

    @pytest.mark.parametrize("arch", ["gemma3-12b", "kimi-k2-1t-a32b"])
    def test_prefill_step_executes(self, arch):
        self._run(arch, "prefill")

    @pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "jamba-v0.1-52b"])
    def test_serve_step_executes(self, arch):
        self._run(arch, "decode")


class TestPartitionRules:
    def test_param_specs_full_config(self):
        """Rules on the FULL qwen2-vl config must 2D-shard the big matrices
        and replicate norms (structure only; no allocation)."""
        import repro.launch.steps as steps

        cfg = ARCHITECTURES["qwen2-vl-72b"]
        shapes = steps.abstract_params(cfg)
        mesh = jax.sharding.Mesh(
            np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model")
        )
        specs = rules.param_pspecs(shapes, mesh)
        flat = {
            "/".join(rules._path_names(p)): s
            for p, s in jax.tree_util.tree_flatten_with_path(specs)[0]
        }
        assert flat["embed"] == P("model", "data")
        assert flat["stack/units/[0]/mix/wq"] == P(None, "data", "model")
        assert flat["stack/units/[0]/mix/w_out"] == P(None, "model", "data")
        assert flat["stack/units/[0]/norm1/scale"] == P()

    def test_divisibility_guard_drops_axes(self):
        """xlstm per-head recurrent tensors replicate; fused projections
        still shard over 'model'."""
        import repro.launch.steps as steps

        cfg = ARCHITECTURES["xlstm-350m"]
        shapes = steps.abstract_params(cfg)
        mesh = jax.sharding.Mesh(
            np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model")
        )
        specs = rules.param_pspecs(shapes, mesh)
        flat = {
            "/".join(rules._path_names(p)): s
            for p, s in jax.tree_util.tree_flatten_with_path(specs)[0]
        }
        assert flat["stack/units/[0]/mix/wq"][-1] == "model"
        # recurrent per-head blocks replicate entirely (all-None spec)
        assert all(a is None for a in flat["stack/units/[7]/mix/rz"])

    def test_no_fsdp_drops_data_axis_from_params(self):
        import repro.launch.steps as steps

        cfg = ARCHITECTURES["qwen1.5-0.5b"]
        shapes = steps.abstract_params(cfg)
        mesh = jax.sharding.Mesh(
            np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model")
        )
        specs = rules.param_pspecs(shapes, mesh, fsdp=False)
        flat = {
            "/".join(rules._path_names(p)): s
            for p, s in jax.tree_util.tree_flatten_with_path(specs)[0]
        }
        assert flat["stack/units/[0]/mix/wq"] == P(None, None, "model")

    def test_input_specs_cover_all_shapes(self):
        for shape_name, shape_cfg in INPUT_SHAPES.items():
            args, kind = input_specs(
                ARCHITECTURES["qwen1.5-0.5b"].reduced(), shape_cfg
            )
            assert kind == shape_cfg.kind
            leaves = jax.tree_util.tree_leaves(args)
            assert all(hasattr(l, "shape") for l in leaves)


class TestLMComtuneTraining:
    def test_loss_decreases_with_link_active(self):
        """COMtune LM fine-tuning must actually learn through the lossy-link
        emulation (dropout + STE quantization at the split)."""
        from repro.launch.train import train

        _, losses, _ = train(
            "qwen1.5-0.5b", steps=150, batch=8, seq=64, lr=1e-3,
            link_mode="train", log_every=1000,
        )
        assert np.mean(losses[-10:]) < np.mean(losses[:5]) - 0.5, (
            np.mean(losses[:5]), np.mean(losses[-10:])
        )


class TestServeLoop:
    def test_generate_under_loss(self):
        from repro.launch.serve import generate

        cfg = ARCHITECTURES["xlstm-350m"].reduced()
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size, jnp.int32
        )
        toks, timings = generate(params, cfg, prompts, 6, loss_rate=0.3)
        assert toks.shape == (2, 6)
        assert timings["link_latency_s_per_round"] > 0
