"""Optimizer / checkpoint / data / calibration substrate tests."""

import math
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import (
    batch_iterator,
    lm_batch_iterator,
    make_image_dataset,
    make_lm_dataset,
)
from repro.optim import AdamConfig, adam_update, global_norm, init_adam, schedule


class TestAdam:
    def test_converges_on_quadratic(self):
        params = {"w": jnp.ones((8,)) * 5.0, "b": [jnp.ones((2, 2))]}
        cfg = AdamConfig(lr=0.05)
        st_ = init_adam(params, cfg)
        loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"][0] ** 2)
        for _ in range(400):
            g = jax.grad(loss)(params)
            params, st_, _ = adam_update(g, params, st_, cfg)
        assert float(loss(params)) < 1e-4

    def test_grad_clip(self):
        params = {"w": jnp.zeros((4,))}
        cfg = AdamConfig(lr=0.1, grad_clip_norm=1.0)
        st_ = init_adam(params, cfg)
        g = {"w": jnp.full((4,), 100.0)}
        _, _, gnorm = adam_update(g, params, st_, cfg)
        assert float(gnorm) == pytest.approx(200.0)

    def test_weight_decay_shrinks(self):
        params = {"w": jnp.ones((4,)) * 2.0}
        cfg = AdamConfig(lr=0.01, weight_decay=0.1)
        st_ = init_adam(params, cfg)
        g = {"w": jnp.zeros((4,))}
        p2, _, _ = adam_update(g, params, st_, cfg)
        assert float(p2["w"][0]) < 2.0

    def test_bf16_state_dtype(self):
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        cfg = AdamConfig(state_dtype="bfloat16")
        st_ = init_adam(params, cfg)
        assert st_.mu["w"].dtype == jnp.bfloat16

    @settings(deadline=None, max_examples=10)
    @given(seed=st.integers(0, 100))
    def test_global_norm_property(self, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (17,))
        tree = {"a": x[:5], "b": [x[5:10], x[10:]]}
        np.testing.assert_allclose(
            float(global_norm(tree)), float(jnp.linalg.norm(x)), rtol=1e-5
        )


class TestSchedules:
    def test_warmup_cosine_shape(self):
        fn = schedule.warmup_cosine(10, 100)
        assert float(fn(jnp.asarray(0))) == 0.0
        assert float(fn(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
        assert float(fn(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)

    def test_warmup_linear_endpoints(self):
        fn = schedule.warmup_linear(10, 110)
        assert float(fn(jnp.asarray(5))) == pytest.approx(0.5)
        assert float(fn(jnp.asarray(110))) == pytest.approx(0.0, abs=1e-6)


class TestCheckpoint:
    def test_roundtrip_with_bf16_and_nesting(self):
        tree = {
            "a": jnp.ones((3,), jnp.bfloat16),
            "b": [jnp.zeros((2, 2)), jnp.arange(3)],
            "c": {"d": jnp.full((1,), 7.0)},
        }
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 3, tree)
            save_checkpoint(d, 7, tree)
            assert latest_step(d) == 7
            restored, step = restore_checkpoint(d, tree)
            assert step == 7
            assert restored["a"].dtype == jnp.bfloat16
            np.testing.assert_allclose(np.asarray(restored["b"][1]), [0, 1, 2])

    def test_shape_mismatch_raises(self):
        tree = {"a": jnp.ones((3,))}
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 1, tree)
            with pytest.raises(ValueError):
                restore_checkpoint(d, {"a": jnp.ones((4,))})


class TestData:
    def test_image_dataset_learnable_structure(self):
        (xtr, ytr), (xte, yte) = make_image_dataset(n_train=500, n_test=100)
        assert xtr.shape == (500, 32, 32, 3)
        # classes are separable: nearest-prototype accuracy well above chance
        protos = np.stack([xtr[ytr == c].mean(0) for c in range(10)])
        d = ((xte[:, None] - protos[None]) ** 2).sum(axis=(2, 3, 4))
        acc = (d.argmin(1) == yte).mean()
        assert acc > 0.5

    def test_lm_dataset_markov_structure(self):
        toks = make_lm_dataset(512, 20_000, seed=0)
        # successor entropy must be far below uniform
        pairs = {}
        for a, b in zip(toks[:-1], toks[1:]):
            pairs.setdefault(int(a), []).append(int(b))
        ent = []
        for a, succs in pairs.items():
            if len(succs) < 20:
                continue
            _, counts = np.unique(succs, return_counts=True)
            p = counts / counts.sum()
            ent.append(-(p * np.log(p)).sum())
        assert np.mean(ent) < 0.7 * math.log(512)

    def test_batch_iterators(self):
        (xtr, ytr), _ = make_image_dataset(n_train=64, n_test=10)
        xb, yb = next(batch_iterator(xtr, ytr, 16))
        assert xb.shape == (16, 32, 32, 3)
        toks = make_lm_dataset(128, 5000)
        tb = next(lm_batch_iterator(toks, 4, 32))
        assert tb.shape == (4, 32) and tb.dtype == np.int32


class TestCalibration:
    def test_collect_activations(self):
        from repro.core.calibration import collect_activations

        apply = lambda p, b: b @ p
        w = jnp.eye(8)
        batches = [jnp.ones((4, 8)), jnp.ones((4, 8)) * 2]
        acts = collect_activations(apply, w, batches)
        assert acts.shape == (8, 8)

    def test_percentile_clipping(self):
        from repro.core.calibration import calibrate_quant

        rng = np.random.RandomState(0)
        acts = rng.randn(1000, 4).astype(np.float32)
        acts[0, 0] = 1000.0  # outlier
        spec_raw = calibrate_quant(acts, 8, percentile=0.0)
        spec_clip = calibrate_quant(acts, 8, percentile=1.0)
        assert float(spec_raw.s_max[0]) == pytest.approx(1000.0)
        assert float(spec_clip.s_max[0]) < 10.0
