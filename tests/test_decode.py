"""Decode-path integration: prefill + single-token decode must match the
full forward pass for every architecture family (KV caches, rotating
windows, SSM/xLSTM states, MoE with drop-free capacity)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.models import cache as cache_lib, lm

# High capacity factor so MoE capacity-dropping (a routing function of the
# token count) doesn't make full-vs-incremental genuinely differ.
CASES = [
    ("qwen1.5-0.5b", {}),
    ("codeqwen1.5-7b", {}),
    ("gemma-7b", {}),
    ("gemma3-12b", {}),                      # rotating sliding-window caches
    ("qwen2-vl-72b", {}),                    # M-RoPE
    ("musicgen-medium", {}),
    ("kimi-k2-1t-a32b", {"capacity_factor": 16.0}),
    ("arctic-480b", {"capacity_factor": 16.0}),
    ("jamba-v0.1-52b", {"capacity_factor": 16.0}),   # mamba states
    ("xlstm-350m", {}),                      # mLSTM closed-form state handoff
]


@pytest.mark.parametrize("arch,overrides", CASES)
def test_prefill_plus_decode_matches_full(arch, overrides):
    cfg = ARCHITECTURES[arch].reduced(**overrides)
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    full_logits, _, _ = lm.forward(params, toks, cfg, link_mode="off", mode="prefill")

    cache = cache_lib.init_cache(cfg, B, max_seq=32)
    _, cache, _ = lm.forward(
        params, toks[:, : S - 1], cfg, cache=cache, cache_index=0,
        link_mode="off", mode="prefill",
    )
    dec_logits, cache, _ = lm.forward(
        params, toks[:, S - 1 :], cfg, cache=cache, cache_index=S - 1,
        link_mode="off", mode="decode",
    )
    a = np.asarray(full_logits[:, -1])
    b = np.asarray(dec_logits[:, 0])
    np.testing.assert_allclose(a, b, atol=5e-4 * max(1.0, np.abs(a).max()))


def test_multi_step_decode_consistency():
    """Decode 4 tokens step-by-step == full forward on the whole sequence."""
    cfg = ARCHITECTURES["gemma3-12b"].reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    B, S, T = 2, 8, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + T), 0, cfg.vocab_size)

    full_logits, _, _ = lm.forward(
        params, toks, cfg, link_mode="off", mode="prefill"
    )

    cache = cache_lib.init_cache(cfg, B, max_seq=64)
    _, cache, _ = lm.forward(
        params, toks[:, :S], cfg, cache=cache, cache_index=0,
        link_mode="off", mode="prefill",
    )
    for i in range(T):
        dec_logits, cache, _ = lm.forward(
            params, toks[:, S + i : S + i + 1], cfg, cache=cache,
            cache_index=S + i, link_mode="off", mode="decode",
        )
        a = np.asarray(full_logits[:, S + i])
        b = np.asarray(dec_logits[:, 0])
        np.testing.assert_allclose(a, b, atol=5e-4 * max(1.0, np.abs(a).max()))


def test_rotating_window_cache_beyond_window():
    """Decoding past the window length must match the full windowed forward
    (the rotating buffer drops exactly the out-of-window entries)."""
    from repro.configs.base import LayerSpec

    cfg = ARCHITECTURES["qwen1.5-0.5b"].reduced(
        unit_pattern=(LayerSpec(kind="attn", window=8),),
    )
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 1, 20  # well past the window of 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = lm.forward(params, toks, cfg, link_mode="off", mode="prefill")

    cache = cache_lib.init_cache(cfg, B, max_seq=S)
    _, cache, _ = lm.forward(
        params, toks[:, : S - 3], cfg, cache=cache, cache_index=0,
        link_mode="off", mode="prefill",
    )
    for i in range(S - 3, S):
        dec_logits, cache, _ = lm.forward(
            params, toks[:, i : i + 1], cfg, cache=cache, cache_index=i,
            link_mode="off", mode="decode",
        )
        a = np.asarray(full_logits[:, i])
        b = np.asarray(dec_logits[:, 0])
        np.testing.assert_allclose(a, b, atol=5e-4 * max(1.0, np.abs(a).max()))


def test_serve_step_with_lossy_link_stays_finite():
    """The DI serve path (Eq. 12) with aggressive loss must stay numerically
    sane (compensation keeps activations in range)."""
    cfg = ARCHITECTURES["qwen1.5-0.5b"].reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    B = 2
    cache = cache_lib.init_cache(cfg, B, max_seq=16)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab_size)
    _, cache, _ = lm.forward(
        params, toks, cfg, cache=cache, cache_index=0,
        link_key=jax.random.PRNGKey(2), link_mode="serve", loss_rate=0.7,
        mode="prefill",
    )
    tok = toks[:, -1:]
    logits, cache, _ = lm.forward(
        params, tok, cfg, cache=cache, cache_index=8,
        link_key=jax.random.PRNGKey(3), link_mode="serve", loss_rate=0.7,
        mode="decode",
    )
    assert bool(jnp.isfinite(logits).all())
