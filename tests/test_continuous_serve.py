"""Continuous-batching slot-pool engine (repro.serve.continuous):

* bucketed-prefill equivalence — mixed prompt lengths across buckets must
  produce greedy outputs token-for-token equal to the per-request
  ``generate_reference`` loop, under iid and Gilbert-Elliott links;
* zero steady-state recompiles — AOT compile count is num_buckets + 1
  after warm-up and never grows under more traffic;
* mid-flight join/retire — more requests than slots, heterogeneous
  budgets, all complete correctly;
* ``launch.serve.generate`` rides the pool by default (per-request keys);
* the simulator's ``engine=`` hook and the LM checkpoint eval fn.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.guards import no_recompile
from repro.configs import ARCHITECTURES
from repro.launch.serve import generate, generate_reference
from repro.models import lm
from repro.serve import ContinuousEngine, PoolConfig


def _setup(channel="iid", loss_rate=0.3):
    cfg = ARCHITECTURES["qwen1.5-0.5b"].reduced()
    cfg = cfg.with_updates(
        link=dataclasses.replace(cfg.link, loss_rate=loss_rate, channel=channel)
    )
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompt(i, length, vocab):
    return np.asarray(
        jax.random.randint(
            jax.random.fold_in(jax.random.PRNGKey(7), i), (length,), 0, vocab,
            jnp.int32,
        )
    )


class TestBucketedPrefillEquivalence:
    @pytest.mark.parametrize("channel", ["iid", "ge"])
    def test_mixed_lengths_match_reference(self, channel):
        """Prompts spanning three buckets (4/8/16 with min_bucket=4), two
        slots — every request's greedy output must equal the per-token
        reference loop run unpadded at batch 1 with the request's key."""
        cfg, params = _setup(channel=channel)
        eng = ContinuousEngine(
            cfg, PoolConfig(max_slots=2, max_new=6, max_prompt=16, min_bucket=4)
        )
        key = jax.random.PRNGKey(42)
        # Length 1 is the regression case: the streamed prefill's position
        # 0 must use the raw key so a padded single-token prompt matches
        # the reference's non-streamed (1, 1, d) draw.
        lengths = [1, 3, 6, 13]
        reqs = [
            eng.submit(_prompt(i, L, cfg.vocab_size), 4,
                       key=jax.random.fold_in(key, i))
            for i, L in enumerate(lengths)
        ]
        done = eng.run(params)
        assert len(done) == len(lengths)
        assert eng.num_buckets == 3          # 4, 8, 16
        for i, (L, req) in enumerate(zip(lengths, reqs)):
            ref, _ = generate_reference(
                params, cfg, jnp.asarray(_prompt(i, L, cfg.vocab_size))[None],
                4, key=jax.random.fold_in(key, i),
            )
            np.testing.assert_array_equal(
                np.asarray(ref)[0], req.tokens,
                err_msg=f"request {i} (len {L}, channel {channel})",
            )


class TestZeroSteadyStateRecompiles:
    def test_compiles_bounded_by_buckets_plus_one(self):
        cfg, params = _setup()
        eng = ContinuousEngine(
            cfg, PoolConfig(max_slots=3, max_new=4, max_prompt=16, min_bucket=8)
        )
        key = jax.random.PRNGKey(0)
        for i, L in enumerate([5, 12, 7, 16]):    # buckets {8, 16}
            eng.submit(_prompt(i, L, cfg.vocab_size), 3,
                       key=jax.random.fold_in(key, i))
        eng.run(params)
        assert eng.num_buckets == 2
        assert eng.compiles == eng.num_buckets + 1
        assert eng.traces == eng.compiles
        warm = eng.compiles
        # Steady state: more traffic on the same buckets, varying lengths
        # and budgets — requests join and retire mid-flight, nothing
        # compiles or retraces.  Prompts and keys are computed BEFORE the
        # guard: _prompt's randint traces a tiny program per fresh length,
        # which is host-side test scaffolding, not engine steady state.
        traffic = [
            (_prompt(100 + i, 4 + (i % 13), cfg.vocab_size), 1 + (i % 4),
             jax.random.fold_in(key, 100 + i))
            for i in range(10)
        ]
        with no_recompile(engines=(eng,)):
            for prompt, budget, k in traffic:
                eng.submit(prompt, budget, key=k)
            done = eng.run(params)
        assert len(done) == 10
        assert eng.compiles == warm
        assert eng.traces == warm
        # AOT executables cannot silently retrace: they are Compiled stages.
        assert isinstance(eng._decode_fn, jax.stages.Compiled)
        for fn in eng._prefill_fns.values():
            assert isinstance(fn, jax.stages.Compiled)

    def test_more_requests_than_slots_heterogeneous_budgets(self):
        """7 requests through 2 slots with budgets 1..5: slot reuse plus
        per-slot stop bookkeeping, each output equal to its reference."""
        cfg, params = _setup(loss_rate=0.0)
        eng = ContinuousEngine(
            cfg, PoolConfig(max_slots=2, max_new=5, max_prompt=8, min_bucket=8)
        )
        key = jax.random.PRNGKey(3)
        spec = [(4, 1), (6, 3), (3, 5), (7, 2), (5, 4), (8, 1), (4, 5)]
        reqs = [
            eng.submit(_prompt(i, L, cfg.vocab_size), T,
                       key=jax.random.fold_in(key, i))
            for i, (L, T) in enumerate(spec)
        ]
        eng.run(params)
        for i, ((L, T), req) in enumerate(zip(spec, reqs)):
            assert req.tokens is not None and req.tokens.shape == (T,)
            ref, _ = generate_reference(
                params, cfg, jnp.asarray(_prompt(i, L, cfg.vocab_size))[None],
                T, key=jax.random.fold_in(key, i),
            )
            np.testing.assert_array_equal(np.asarray(ref)[0], req.tokens)


class TestSlotPoolCache:
    def test_write_read_slot_roundtrip(self):
        """write_slot/read_slot are exact inverses on every cache leaf."""
        from repro.models import cache as cache_lib

        cfg, _ = _setup()
        pool = cache_lib.init_slot_pool(cfg, 3, max_seq=8)
        one = jax.tree_util.tree_map(
            lambda s: jax.random.normal(
                jax.random.PRNGKey(1), s.shape, jnp.float32
            ).astype(s.dtype),
            cache_lib.cache_spec(cfg, 1, 8),
        )
        pool2 = cache_lib.write_slot(pool, one, jnp.int32(1))
        back = cache_lib.read_slot(pool2, jnp.int32(1))
        for a, b in zip(jax.tree_util.tree_leaves(one),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # Other slots untouched.
        for s in (0, 2):
            for a, b in zip(
                jax.tree_util.tree_leaves(cache_lib.read_slot(pool, s)),
                jax.tree_util.tree_leaves(cache_lib.read_slot(pool2, s)),
            ):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestGenerateRidesPool:
    def test_default_generate_matches_per_request_reference(self):
        """launch.serve.generate (no engine arg) serves the batch as B
        independent requests with keys fold_in(key, i)."""
        cfg, params = _setup(loss_rate=0.2)
        key = jax.random.PRNGKey(11)
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab_size, jnp.int32
        )
        toks, t = generate(params, cfg, prompts, 4, loss_rate=0.2, key=key)
        assert toks.shape == (2, 4)
        for i in range(2):
            ref, _ = generate_reference(
                params, cfg, prompts[i : i + 1], 4, loss_rate=0.2,
                key=jax.random.fold_in(key, i),
            )
            np.testing.assert_array_equal(np.asarray(ref)[0], np.asarray(toks)[i])
        # Timings contract (benchmarks / examples consume these keys).
        for k in ("generate_s", "tokens_per_s", "decode_s_per_token",
                  "compiles", "traces", "slot_occupancy",
                  "link_latency_s_per_round", "message_kb_per_token"):
            assert k in t, k

    def test_frontend_arch_falls_back_to_whole_generation_engine(self):
        """Frontend (VLM/audio) configs can't ride the slot pool yet;
        generate() must fall back instead of crashing (regression)."""
        cfg = ARCHITECTURES["qwen2-vl-72b"].reduced()
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (2, 4), 0, cfg.vocab_size, jnp.int32
        )
        toks, t = generate(params, cfg, prompts, 2, loss_rate=0.1)
        assert toks.shape == (2, 2)
        assert t["tokens_per_s"] > 0


class TestSimulatorEngineHook:
    def test_engine_busy_time_drives_latency(self):
        """run_sim(engine=...) uses the measured engine time as the server
        busy time, so reported latency floors at the engine's compute."""
        from repro.net import SimConfig, run_sim

        calls = []

        def fake_engine(batch):
            calls.append(len(batch))
            return 0.05

        rep = run_sim(
            SimConfig(n_clients=2, n_packets=4, duration_s=1.0,
                      min_delivered_fraction=0.0),
            arrivals=[(0.0, 0), (0.0, 1)],
            engine=fake_engine,
        )
        assert rep.served == 2
        assert calls, "engine hook was never called"
        assert rep.latency_p50_s >= 0.05

    def test_live_engine_smoke(self):
        """A real ContinuousEngine behind the sim: served batches hit the
        live engine; measured busy time is positive and finite."""
        from repro.net import SimConfig, run_sim
        from repro.serve import make_sim_server

        cfg, params = _setup(loss_rate=0.0)
        eng = ContinuousEngine(
            cfg, PoolConfig(max_slots=2, max_new=4, max_prompt=8, min_bucket=8)
        )
        server = make_sim_server(eng, params, prompt_lens=(4, 6), num_tokens=2)
        rep = run_sim(
            SimConfig(n_clients=2, n_packets=4, duration_s=1.0,
                      min_delivered_fraction=0.0),
            arrivals=[(0.0, 0), (0.2, 1)],
            engine=server,
        )
        assert rep.served == 2
        assert eng.tokens_generated >= 4
        assert np.isfinite(rep.latency_p99_s) and rep.latency_p99_s > 0


class TestLMRequestEval:
    def test_full_delivery_matches_clean_forward(self):
        """With every packet delivered, the eval fn's correctness equals
        the clean (mask-free) forward's next-token correctness."""
        from repro.net.evalhook import make_lm_request_eval_fn
        import repro.data as data

        cfg, params = _setup(loss_rate=0.0)
        seq_len, n_test, n_packets = 4, 8, 6
        fn = make_lm_request_eval_fn(
            params, cfg, n_packets, seq_len=seq_len, n_test=n_test
        )
        rids = np.arange(5)
        full = np.ones((5, n_packets), dtype=bool)
        got = fn(full, rids)
        assert got.shape == (5,) and got.dtype == bool

        toks = data.make_lm_dataset(
            cfg.vocab_size, n_tokens=n_test * (seq_len + 1) + 2, seed=0
        )
        seqs = toks[: n_test * (seq_len + 1)].reshape(n_test, seq_len + 1)
        idx = rids % n_test
        logits, _, _ = lm.forward(
            params, jnp.asarray(seqs[idx, :seq_len].astype(np.int32)), cfg,
            link_fn=lambda a: a, mode="prefill",
        )
        want = np.asarray(jnp.argmax(logits[:, -1], -1)) == seqs[idx, seq_len]
        np.testing.assert_array_equal(got, want)
