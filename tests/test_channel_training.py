"""Channel-aware COMtune training (this PR's tentpole): the unified
``emulate_link`` path, gradients through the channel-emulation train graph,
the scan-compiled train epoch, the kept-fraction clamp, protocol-aware
latency, and checkpoint/resume."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comtune, link
from repro.launch.steps import (
    build_sharded_epoch,
    make_train_epoch,
    make_train_step,
)
from repro.models import lm
from repro.optim import AdamConfig, init_adam

TINY = dict(
    d_model=32, num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
    vocab_size=64,
)


def tiny_cfg():
    from repro.configs import get_config

    return get_config("qwen1.5-0.5b").reduced(**TINY)


CHANNEL_SPEC = comtune.LinkSpec(
    train_link="channel", channel="ge", shuffle=False, loss_rate=0.4,
    fec_k=10, fec_m=2,
)


class TestEmulateLink:
    def test_train_dropout_bit_identical_to_legacy(self):
        """The ``link="dropout"`` train path must be bit-compatible with the
        seed's dropout_link under fixed keys (identity compressor)."""
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 128))
        key = jax.random.PRNGKey(3)
        spec = comtune.LinkSpec(dropout_rate=0.3)
        got = comtune.emulate_link(key, x, spec, "train")
        want = comtune.dropout_link(key, x, 0.3)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_serve_matches_channel_link(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (32, 64))
        key = jax.random.PRNGKey(5)
        spec = comtune.LinkSpec(loss_rate=0.4, channel="ge", shuffle=False)
        got = comtune.emulate_link(key, x, spec, "serve")
        want = comtune.channel_link(key, x, spec)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_train_channel_emulates_bursts_and_compensates(self):
        """shuffle=False GE emulation drops whole packets (bursts) and
        compensates by 1/(1-p_eff)."""
        x = jnp.ones((4000,))
        spec = comtune.LinkSpec(
            train_link="channel", channel="ge", shuffle=False, loss_rate=0.5,
        )
        y = np.asarray(comtune.emulate_link(jax.random.PRNGKey(0), x, spec, "train"))
        blocks = y[: (y.size // 25) * 25].reshape(-1, 25)
        nz = (blocks != 0).sum(axis=1)
        assert np.all((nz == 0) | (nz == 25))       # whole-packet erasures
        assert abs(np.asarray(y)[y != 0][0] - 2.0) < 0.2   # ~1/(1-0.5)

    def test_off_and_clean_modes(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
        spec = comtune.LinkSpec(loss_rate=0.9)
        assert comtune.emulate_link(None, x, spec, "off") is x
        np.testing.assert_array_equal(
            np.asarray(comtune.emulate_link(None, x, spec, "clean")),
            np.asarray(x),
        )

    def test_with_train_rate_overrides_channel_params(self):
        """A curriculum rate must actually reach the channel even when the
        spec carried a channel_params loss_rate override (which would
        otherwise shadow spec.loss_rate in resolve_channel)."""
        spec = comtune.LinkSpec(
            train_link="channel", channel="ge",
            channel_params=(("loss_rate", 0.3),),
        )
        ramped = spec.with_train_rate(0.6)
        assert ramped.loss_rate == 0.6
        assert "loss_rate" not in dict(ramped.channel_params)
        assert abs(ramped.resolve_channel().stationary_loss_rate - 0.6) < 1e-9
        # dropout specs ramp the dropout rate and keep channel_params
        drop = comtune.LinkSpec(dropout_rate=0.2).with_train_rate(0.5)
        assert drop.dropout_rate == 0.5

    def test_rate_overrides_and_noop_detection(self):
        """--train-loss-rate must strip a shadowing channel_params entry
        (like with_train_rate does), and supports_target_rate must flag
        channels whose loss rate is pinned by their own params."""
        from repro.configs import get_config
        from repro.launch.train import build_train_link_spec
        from repro.net.channels import supports_target_rate

        cfg = get_config("qwen1.5-0.5b").reduced()
        cfg = cfg.with_updates(link=dataclasses.replace(
            cfg.link, channel="ge", channel_params=(("loss_rate", 0.1),),
        ))
        spec = build_train_link_spec(cfg, train_link="channel", loss_rate=0.5)
        assert abs(spec.resolve_channel().stationary_loss_rate - 0.5) < 1e-9
        assert supports_target_rate("ge")
        assert not supports_target_rate("ge", (("p_gb", 0.05), ("p_bg", 0.4)))
        assert not supports_target_rate("fading")
        # asking for a train channel / FEC implies the channel emulation
        assert build_train_link_spec(cfg, train_channel="ge").train_link == "channel"
        assert build_train_link_spec(cfg, train_fec=(10, 2)).train_link == "channel"

    def test_curriculum_schedule_ramps(self):
        from repro.launch.train import curriculum_schedule

        chunks = curriculum_schedule(50, 10, (0.1, 0.5))
        assert [s for s, _, _ in chunks] == [0, 10, 20, 30, 40]
        np.testing.assert_allclose(
            [r for _, _, r in chunks], [0.1, 0.2, 0.3, 0.4, 0.5]
        )
        assert curriculum_schedule(50, 10, None) == [
            (s, 10, None) for s in range(0, 50, 10)
        ]

    def test_unknown_modes_raise(self):
        x = jnp.ones((4,))
        with pytest.raises(ValueError):
            comtune.emulate_link(jax.random.PRNGKey(0), x, comtune.LinkSpec(), "bogus")
        bad = comtune.LinkSpec(train_link="bogus")
        with pytest.raises(ValueError):
            comtune.emulate_link(jax.random.PRNGKey(0), x, bad, "train")


class TestChannelTrainGradients:
    def test_grads_flow_through_ge_fec_emulation(self):
        """The whole point of the tentpole: fine-tuning against the bursty
        FEC-protected channel must produce real gradients on BOTH sides of
        the split (device-side embed and server-side head included)."""
        cfg = tiny_cfg()
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        tokens = jax.random.randint(
            jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size, jnp.int32
        )

        def loss_fn(p):
            logits, _, aux = lm.forward(
                p, tokens, cfg, link_key=jax.random.PRNGKey(2),
                link_mode="train", link_spec=CHANNEL_SPEC, mode="train",
            )
            return lm.lm_loss(logits, tokens, aux, cfg.router_aux_coef)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        assert np.isfinite(float(loss))
        g_embed = float(jnp.abs(grads["embed"]).sum())        # device side
        g_norm = float(jnp.abs(grads["final_norm"]["scale"]).sum())  # server
        assert g_embed > 0.0 and np.isfinite(g_embed)
        assert g_norm > 0.0 and np.isfinite(g_norm)

    def test_train_step_accepts_link_spec(self):
        cfg = tiny_cfg()
        adam_cfg = AdamConfig(lr=1e-3)
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        opt = init_adam(params, adam_cfg)
        step = jax.jit(make_train_step(cfg, adam_cfg, link_spec=CHANNEL_SPEC))
        b = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size, jnp.int32
        )}
        _, _, metrics = step(params, opt, b, jax.random.PRNGKey(3))
        assert np.isfinite(float(metrics["loss"]))
        assert float(metrics["grad_norm"]) > 0.0


class TestScanEpoch:
    K, B, S = 6, 2, 16

    def _batches(self, cfg):
        return jax.random.randint(
            jax.random.PRNGKey(7), (self.K, self.B, self.S), 0,
            cfg.vocab_size, jnp.int32,
        )

    def _loop(self, cfg, adam_cfg, toks, link_spec=None):
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        opt = init_adam(params, adam_cfg)
        step = jax.jit(make_train_step(cfg, adam_cfg, link_spec=link_spec))
        key = jax.random.PRNGKey(42)
        losses = []
        for i in range(self.K):
            key, sub = jax.random.split(key)
            params, opt, m = step(params, opt, {"tokens": toks[i]}, sub)
            losses.append(np.asarray(m["loss"]))
        return params, np.asarray(losses), key

    def test_bit_identical_to_per_step_loop(self):
        """Acceptance: the scan epoch reproduces the per-step loop's loss
        trajectory bit-for-bit (same greedy key chain) for link=dropout,
        and returns the continued key."""
        cfg = tiny_cfg()
        adam_cfg = AdamConfig(lr=3e-4, grad_clip_norm=1.0)
        toks = self._batches(cfg)
        p1, losses_loop, key_loop = self._loop(cfg, adam_cfg, toks)

        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        opt = init_adam(params, adam_cfg)
        epoch = make_train_epoch(cfg, adam_cfg)
        p2, _, key_scan, metrics = epoch(
            params, opt, {"tokens": toks}, jax.random.PRNGKey(42)
        )
        np.testing.assert_array_equal(np.asarray(metrics["loss"]), losses_loop)
        np.testing.assert_array_equal(np.asarray(key_scan), np.asarray(key_loop))
        for a, b in zip(
            jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_channel_link_epoch_finite(self):
        cfg = tiny_cfg()
        adam_cfg = AdamConfig(lr=3e-4)
        toks = self._batches(cfg)
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        opt = init_adam(params, adam_cfg)
        epoch = make_train_epoch(cfg, adam_cfg, link_spec=CHANNEL_SPEC)
        _, _, _, metrics = epoch(
            params, opt, {"tokens": toks}, jax.random.PRNGKey(42)
        )
        assert np.all(np.isfinite(np.asarray(metrics["loss"])))

    def test_sharded_epoch_matches_unsharded(self):
        from repro.configs.base import ShapeConfig
        from repro.launch.mesh import make_host_mesh

        cfg = tiny_cfg()
        adam_cfg = AdamConfig(lr=3e-4, grad_clip_norm=1.0)
        toks = self._batches(cfg)
        _, losses_loop, _ = self._loop(cfg, adam_cfg, toks)
        mesh = make_host_mesh()
        shape_cfg = ShapeConfig("train_tiny", self.S, self.B, "train")
        epoch, _ = build_sharded_epoch(
            cfg, shape_cfg, mesh, self.K, adam_cfg=adam_cfg, fsdp="off"
        )
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        opt = init_adam(params, adam_cfg)
        _, _, _, metrics = epoch(
            params, opt, {"tokens": toks}, jax.random.PRNGKey(42)
        )
        np.testing.assert_allclose(
            np.asarray(metrics["loss"]), losses_loop, rtol=1e-6
        )


class TestKeptFractionClamp:
    """Satellite: ONE clamp constant (link.MIN_KEEP_FRACTION) everywhere —
    total loss must yield zeros, never NaN, on every compensation path."""

    def test_adaptive_compensation_total_loss(self):
        x = jnp.ones((64,))
        for gran in ("element", "packet"):
            spec = comtune.LinkSpec(
                loss_rate=1.0, adaptive_compensation=True, granularity=gran
            )
            y = np.asarray(comtune.channel_link(jax.random.PRNGKey(0), x, spec))
            assert np.all(np.isfinite(y)) and np.all(y == 0.0), gran

    def test_stateful_adaptive_total_loss(self):
        x = jnp.ones((64,))
        spec = comtune.LinkSpec(
            channel="ge", adaptive_compensation=True,
            channel_params=(
                ("p_gb", 1.0), ("p_bg", 0.0),
                ("loss_good", 1.0), ("loss_bad", 1.0),
            ),
        )
        y = np.asarray(comtune.channel_link(jax.random.PRNGKey(0), x, spec))
        assert np.all(np.isfinite(y)) and np.all(y == 0.0)

    def test_train_channel_total_loss(self):
        x = jnp.ones((64,))
        spec = comtune.LinkSpec(train_link="channel", loss_rate=1.0)
        y = np.asarray(comtune.emulate_link(jax.random.PRNGKey(0), x, spec, "train"))
        assert np.all(np.isfinite(y)) and np.all(y == 0.0)

    def test_single_constant(self):
        assert link.MIN_KEEP_FRACTION == comtune.MIN_KEEP_FRACTION


class TestProtocolLatency:
    FEAT, BATCH = 4096, 1

    def test_unreliable_default_unchanged(self):
        cfg = link.ChannelConfig(loss_rate=0.3)
        spec = comtune.LinkSpec()
        base = comtune.di_latency_s(spec, self.FEAT, self.BATCH, cfg)
        assert base == comtune.di_latency_s(
            spec, self.FEAT, self.BATCH, cfg, protocol="unreliable"
        )

    def test_arq_matches_pmf_mean(self):
        from repro.net import protocol as protocol_lib

        cfg = link.ChannelConfig(loss_rate=0.3)
        spec = comtune.LinkSpec()
        got = comtune.di_latency_s(
            spec, self.FEAT, self.BATCH, cfg, protocol="arq"
        )
        n_t = -(-int(comtune.message_bytes(spec, self.FEAT) * self.BATCH)
                // cfg.packet_bytes)
        lat, pmf = protocol_lib.ARQProtocol().latency_pmf(n_t, cfg)
        assert abs(got - float(np.dot(lat, pmf))) < 1e-12
        # retransmissions make ARQ slower than one-shot on a lossy link
        assert got > comtune.di_latency_s(spec, self.FEAT, self.BATCH, cfg)

    def test_hybrid_uses_spec_fec(self):
        from repro.net import protocol as protocol_lib
        from repro.net.fec import FECSpec

        cfg = link.ChannelConfig(loss_rate=0.3)
        spec = comtune.LinkSpec(fec_k=8, fec_m=2)
        got = comtune.di_latency_s(
            spec, self.FEAT, self.BATCH, cfg, protocol="fec_arq"
        )
        n_data = -(-int(comtune.message_bytes(spec, self.FEAT) * self.BATCH)
                   // cfg.packet_bytes)
        policy = protocol_lib.HybridFECARQProtocol(fec=FECSpec(k=8, m=2))
        lat, pmf = policy.latency_pmf(n_data, cfg)
        assert abs(got - float(np.dot(lat, pmf))) < 1e-12

    def test_fec_arq_without_spec_fec_rejected(self):
        cfg = link.ChannelConfig(loss_rate=0.3)
        with pytest.raises(ValueError, match="fec_arq"):
            comtune.di_latency_s(
                comtune.LinkSpec(), self.FEAT, self.BATCH, cfg,
                protocol="fec_arq",
            )

    def test_policy_instance_accepted(self):
        from repro.net import protocol as protocol_lib

        cfg = link.ChannelConfig(loss_rate=0.2)
        spec = comtune.LinkSpec()
        policy = protocol_lib.ARQProtocol(max_rounds=2)
        got = comtune.di_latency_s(
            spec, self.FEAT, self.BATCH, cfg, protocol=policy
        )
        assert got == policy.expected_latency_s(
            -(-int(comtune.message_bytes(spec, self.FEAT)) // cfg.packet_bytes),
            cfg,
        )


class TestCheckpointResume:
    def test_scan_epoch_saves_on_offgrid_ckpt_every(self, tmp_path):
        """Periodic saves must fire even when ckpt_every doesn't divide the
        chunk grid (a ckpt point inside a chunk saves at its boundary)."""
        from repro.launch.train import train

        d = str(tmp_path)
        train(
            "qwen1.5-0.5b", steps=9, batch=2, seq=16, log_every=1000,
            steps_per_epoch=4, ckpt_dir=d, ckpt_every=3,
        )
        # chunks end at 4, 8, 9; ckpt points 3, 6, 9 land inside them
        assert sorted(os.listdir(d)) == [
            "train_00000004.npz", "train_00000008.npz", "train_00000009.npz"
        ]

    def test_resume_reproduces_loss_curve(self, tmp_path):
        """Satellite: a run interrupted at step 4 and resumed must emit the
        same losses as the uninterrupted run (params/opt/key restored, data
        stream replayed)."""
        from repro.launch.train import train

        d = str(tmp_path)
        kw = dict(
            steps=8, batch=2, seq=16, log_every=1000, steps_per_epoch=4,
            ckpt_dir=d, ckpt_every=4,
        )
        _, full, _ = train("qwen1.5-0.5b", **kw)
        os.remove(os.path.join(d, "train_00000008.npz"))
        _, tail, _ = train("qwen1.5-0.5b", resume=True, **kw)
        np.testing.assert_array_equal(np.asarray(full[4:]), np.asarray(tail))


class TestPerStepCurriculum:
    """Satellite: --curriculum rates as TRACED per-step scan data for the
    iid/dropout train paths — one compiled epoch program per epoch shape,
    bit-identical to the static-rate program at a constant rate."""

    K, B, S = 4, 2, 16

    def _run_epoch(self, cfg, link_rate=None, link_spec=None):
        adam_cfg = AdamConfig(lr=3e-4)
        toks = jax.random.randint(
            jax.random.PRNGKey(7), (self.K, self.B, self.S), 0,
            cfg.vocab_size, jnp.int32,
        )
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        opt = init_adam(params, adam_cfg)
        epoch = make_train_epoch(cfg, adam_cfg, link_spec=link_spec)
        batches = {"tokens": toks}
        if link_rate is not None:
            batches["link_rate"] = jnp.asarray(link_rate, jnp.float32)
        _, _, _, metrics = epoch(params, opt, batches, jax.random.PRNGKey(42))
        return np.asarray(metrics["loss"])

    def test_constant_traced_rate_bit_identical_dropout(self):
        """Feeding the dropout rate as a constant (K,) traced schedule must
        reproduce the static-rate epoch bit-for-bit (bernoulli draws are
        rate-value-independent: uniform < p)."""
        cfg = tiny_cfg()
        r = cfg.link.dropout_rate
        static = self._run_epoch(cfg)
        traced = self._run_epoch(cfg, link_rate=np.full((self.K,), r))
        np.testing.assert_array_equal(static, traced)

    def test_constant_traced_rate_iid_channel(self):
        """The iid-channel emulation with a constant traced rate: the link
        layer itself is bit-identical to the static program (same masks,
        same reciprocal-multiply compensation); the end-to-end loss is
        allclose — XLA folds the static scalar through downstream fusions
        in a way a runtime scalar cannot match ulp-for-ulp."""
        spec = comtune.LinkSpec(train_link="channel", channel="iid",
                                loss_rate=0.3)
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64), jnp.float32)
        key = jax.random.PRNGKey(3)
        a = jax.jit(
            lambda x: comtune.emulate_link(key, x, spec, "train")
        )(x)
        b = jax.jit(
            lambda x, r: comtune.emulate_link(
                key, x, spec.with_train_rate(r), "train"
            )
        )(x, jnp.float32(0.3))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        cfg = tiny_cfg()
        static = self._run_epoch(cfg, link_spec=spec)
        traced = self._run_epoch(
            cfg, link_rate=np.full((self.K,), 0.3), link_spec=spec
        )
        np.testing.assert_allclose(static, traced, rtol=2e-6)

    def test_ramp_single_compile_per_epoch_shape(self):
        """Two different ramps through the same epoch program: the rate is
        data, so the program traces exactly once."""
        cfg = tiny_cfg()
        adam_cfg = AdamConfig(lr=3e-4)
        traces = []

        from repro.launch.steps import make_train_epoch as mke
        inner = mke(cfg, adam_cfg, jit=False)

        def counted(params, opt, batches, key):
            traces.append(1)
            return inner(params, opt, batches, key)

        epoch = jax.jit(counted, donate_argnums=(0, 1))
        toks = jax.random.randint(
            jax.random.PRNGKey(7), (self.K, self.B, self.S), 0,
            cfg.vocab_size, jnp.int32,
        )
        losses = []
        for ramp in (np.linspace(0.1, 0.4, self.K), np.linspace(0.4, 0.1, self.K)):
            params = lm.init_lm(jax.random.PRNGKey(0), cfg)
            opt = init_adam(params, adam_cfg)
            _, _, _, m = epoch(
                params, opt,
                {"tokens": toks, "link_rate": jnp.asarray(ramp, jnp.float32)},
                jax.random.PRNGKey(42),
            )
            losses.append(np.asarray(m["loss"]))
        assert sum(traces) == 1, "per-step rates must not retrace"
        assert not np.array_equal(losses[0], losses[1]), \
            "different ramps must actually change the emulation"
        assert np.isfinite(losses[0]).all() and np.isfinite(losses[1]).all()

    def test_trainer_per_step_path_end_to_end(self):
        """launch.train.train with --curriculum on the dropout path runs the
        traced per-step ramp (losses finite, right count)."""
        from repro.launch.train import per_step_curriculum_ok, train
        from repro.models.lm import link_spec_from_config

        assert per_step_curriculum_ok(link_spec_from_config(tiny_cfg()))
        _, losses, _ = train(
            "qwen1.5-0.5b", steps=4, batch=2, seq=16, log_every=1000,
            curriculum=(0.1, 0.4),
        )
        assert len(losses) == 4
        assert np.isfinite(losses).all()


# ---------------------------------------------------------------------------
# Sharded scan-epoch + MoE shard_map on a forced multi-device host mesh
# ---------------------------------------------------------------------------


class TestShardedEpochMultiDevice:
    """The ROADMAP-flagged untested combination: the sharded scan-epoch
    trainer with the shard_map MoE dispatch on a REAL 4-device mesh
    (``XLA_FLAGS=--xla_force_host_platform_device_count=4``, which must be
    set before the backend initializes — hence a subprocess).

    Identity contract, measured (2026-08, jax 0.4.37 CPU):

    * the shard_map MoE *forward* is bit-identical to the dense
      formulation on the 4-device mesh, for both expert-parallel group
      sizes (model_axis 1 and 4);
    * the 1-device-mesh sharded epoch (shard_map MoE on) is bit-identical
      to the plain unsharded ``make_train_epoch`` trajectory;
    * distributing the SAME program over 4 devices perturbs fp32
      reduction order (loss mean + grad psum split across devices), so
      the 4-device trajectories match the unsharded epoch to ~1 ulp
      (measured 9.5e-7 at loss ~4.5) — asserted at atol=5e-6, NOT
      bitwise, because split-sum psum cannot reproduce unsplit-sum
      rounding.
    """

    def test_forced_4dev_mesh_epoch_and_moe_shard_map(self):
        import subprocess
        import sys
        from pathlib import Path

        code = """
import numpy as np, jax, jax.numpy as jnp
from repro.configs import ARCHITECTURES
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_sharded_epoch, make_train_epoch
from repro.models import lm, moe
from repro.optim import AdamConfig, init_adam

assert len(jax.devices()) == 4, jax.devices()
K, B, S = 4, 4, 16
cfg = ARCHITECTURES["arctic-480b"].reduced(
    d_model=32, num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
    vocab_size=64, capacity_factor=16.0,
)
assert cfg.num_experts == 4
adam_cfg = AdamConfig(lr=3e-4, grad_clip_norm=1.0)
toks = jax.random.randint(
    jax.random.PRNGKey(7), (K, B, S), 0, cfg.vocab_size, jnp.int32
)

# MoE shard_map forward: bit-identical to dense on the real mesh, for
# both 1-way and 4-way expert grouping.
pm = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model)) * 0.5
out_d, _ = moe.moe_forward_dense(pm, x, cfg)
for ma in (1, 4):
    out_s, _ = moe.moe_forward_shard_map(pm, x, cfg, make_host_mesh(ma))
    np.testing.assert_array_equal(np.asarray(out_d), np.asarray(out_s))

epoch_ref = make_train_epoch(cfg, adam_cfg)
p = lm.init_lm(jax.random.PRNGKey(0), cfg)
o = init_adam(p, adam_cfg)
_, _, _, m_ref = epoch_ref(p, o, {"tokens": toks}, jax.random.PRNGKey(42))
ref = np.asarray(m_ref["loss"])
assert np.isfinite(ref).all()

shape_cfg = ShapeConfig("t4dev", S, B, "train")

def sharded_losses(mesh):
    ep, _ = build_sharded_epoch(
        cfg, shape_cfg, mesh, K, adam_cfg=adam_cfg, fsdp="off",
        moe_shard_map=True,
    )
    p = lm.init_lm(jax.random.PRNGKey(0), cfg)
    o = init_adam(p, adam_cfg)
    _, _, _, m = ep(p, o, {"tokens": toks}, jax.random.PRNGKey(42))
    return np.asarray(m["loss"])

# One-device mesh: the identical program single-device — bitwise.
one = sharded_losses(make_host_mesh(devices=jax.devices()[:1]))
np.testing.assert_array_equal(one, ref)

# Four devices, data-parallel (model_axis=1) and expert-parallel
# (model_axis=4): reduction-order tolerance only.
for ma in (1, 4):
    got = sharded_losses(make_host_mesh(ma))
    np.testing.assert_allclose(got, ref, atol=5e-6)

print("OK_4DEV_EPOCH")
"""
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        env["JAX_PLATFORMS"] = "cpu"
        src = str(Path(__file__).resolve().parent.parent / "src")
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
        )
        r = subprocess.run(
            [sys.executable, "-c", code], env=env,
            capture_output=True, text=True, timeout=540,
        )
        assert r.returncode == 0 and "OK_4DEV_EPOCH" in r.stdout, (
            r.stdout[-2000:], r.stderr[-4000:]
        )
