"""Chaos fault-injection harness (repro.net.chaos) + the analytic
deadline-feasibility oracle the scheduler sheds against.

* ``Fault`` / ``ChaosSchedule`` — constructor validation and point-in-time
  queries (override = worst active window, half-open ``[t0, t1)``);
* ``_OverrideChannel`` — i.i.d. overlay at the override rate with
  pass-through state, so a collapse never advances the real channel's
  burst state;
* ``run_sim(chaos=...)`` — a total collapse window kills every uplink
  inside it, a server stall inflates end-to-end latency by the remaining
  stall, a burst storm multiplies Poisson arrivals;
* ``EngineChaos`` — block-pool squeeze steals FREE blocks only, tops up
  as capacity frees, and hands everything back LIFO when the window
  closes (host-allocator surgery, verified on a ledger double);
* ``deadline_feasible`` — exact at both loss extremes for all three
  protocols: 1.0 at ``loss_rate=0.0`` under a covering deadline, exactly
  0.0 (never NaN) at ``loss_rate=1.0``.
"""

import math
import types

import numpy as np
import pytest

from repro.core import link
from repro.net import (
    ChaosSchedule,
    Fault,
    IIDChannel,
    SimConfig,
    block_pool_squeeze,
    burst_storm,
    channel_collapse,
    deadline_feasible,
    make_protocol,
    run_sim,
    server_stall,
)
from repro.net.chaos import EngineChaos, _OverrideChannel


class TestFaultValidation:
    def test_unknown_kind_raises(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            Fault("power_cut", 0.0, 1.0)

    def test_empty_window_raises(self):
        with pytest.raises(ValueError, match="empty fault window"):
            Fault("server_stall", 2.0, 2.0)

    def test_storm_below_one_raises(self):
        with pytest.raises(ValueError, match="arrival rate"):
            burst_storm(0.0, 1.0, rate_multiplier=0.5)

    @pytest.mark.parametrize("fraction", [0.0, 1.5])
    def test_squeeze_fraction_out_of_range_raises(self, fraction):
        with pytest.raises(ValueError, match="fraction"):
            block_pool_squeeze(0.0, 1.0, fraction=fraction)

    def test_collapse_clamps_loss_rate(self):
        assert channel_collapse(0.0, 1.0, loss_rate=7.0).loss_rate == 1.0
        assert channel_collapse(0.0, 1.0, loss_rate=-1.0).loss_rate == 0.0


class TestChaosSchedule:
    def test_empty_schedule_is_falsy_noop(self):
        sched = ChaosSchedule()
        assert not sched
        assert sched.loss_override(0.0) is None
        assert sched.stall_until(3.0) == 3.0
        assert sched.storm_multiplier(0.0) == 1.0
        assert sched.squeeze_fraction(0.0) == 0.0

    def test_window_is_half_open(self):
        sched = ChaosSchedule([channel_collapse(1.0, 2.0, 0.9)])
        assert sched.loss_override(0.999) is None
        assert sched.loss_override(1.0) == 0.9
        assert sched.loss_override(2.0) is None

    def test_overlapping_windows_take_the_worst(self):
        sched = ChaosSchedule([
            channel_collapse(0.0, 10.0, 0.5),
            channel_collapse(3.0, 5.0, 1.0),
            burst_storm(0.0, 10.0, 2.0),
            burst_storm(4.0, 6.0, 5.0),
            block_pool_squeeze(0.0, 10.0, 0.3),
            block_pool_squeeze(4.0, 5.0, 0.8),
        ])
        assert sched.loss_override(1.0) == 0.5
        assert sched.loss_override(4.0) == 1.0
        assert sched.storm_multiplier(4.5) == 5.0
        assert sched.storm_multiplier(7.0) == 2.0
        assert sched.squeeze_fraction(4.5) == 0.8
        assert sched.squeeze_fraction(8.0) == 0.3

    def test_stall_until_latest_covering_window(self):
        sched = ChaosSchedule([server_stall(1.0, 2.0), server_stall(2.0, 3.0)])
        assert sched.stall_until(2.5) == 5.0
        assert sched.stall_until(0.5) == 0.5


class TestOverrideChannel:
    def test_total_collapse_drops_everything(self):
        rng = np.random.RandomState(0)
        keep, state = _OverrideChannel(1.0).step(rng, "burst-state", 64)
        assert not keep.any()
        assert state == "burst-state"       # pass-through, never advanced

    def test_zero_rate_keeps_everything(self):
        rng = np.random.RandomState(0)
        keep, _ = _OverrideChannel(0.0).step(rng, None, 64)
        assert keep.all()

    def test_stationary_loss_rate_reports_override(self):
        assert _OverrideChannel(0.7).stationary_loss_rate == 0.7


class TestSimulatorChaos:
    """End-to-end fault effects through run_sim, hand-scheduled arrivals
    for determinism."""

    def _cfg(self, **kw):
        kw.setdefault("n_clients", 2)
        kw.setdefault("n_packets", 8)
        kw.setdefault("duration_s", 4.0)
        return SimConfig(**kw)

    def test_collapse_window_drops_covered_uplinks(self):
        cfg = self._cfg()
        arrivals = [(0.5, 0), (1.0, 1), (3.0, 0)]   # third is post-window
        chaos = ChaosSchedule([channel_collapse(0.0, 2.0, 1.0)])
        clean = [IIDChannel(0.0), IIDChannel(0.0)]
        rep = run_sim(cfg, channels=clean, arrivals=arrivals, chaos=chaos)
        assert rep.arrived == 3
        assert rep.dropped == 2             # both in-window uplinks died
        assert rep.served == 1              # the 3.0 s arrival sails through

    def test_stall_inflates_latency_by_remaining_stall(self):
        cfg = self._cfg(n_clients=1)
        arrivals = [(0.0, 0)]
        clean = [IIDChannel(0.0)]
        base = run_sim(cfg, channels=clean, arrivals=arrivals)
        stalled = run_sim(
            cfg, channels=[IIDChannel(0.0)], arrivals=arrivals,
            chaos=ChaosSchedule([server_stall(0.0, 2.0)]),
        )
        assert base.served == stalled.served == 1
        # The batch starts inside [0, 2) and pays the remaining stall.
        assert stalled.latency_p50_s > base.latency_p50_s + 1.5
        assert stalled.latency_p50_s < base.latency_p50_s + 2.0 + 1e-6

    def test_storm_multiplies_poisson_arrivals(self):
        cfg = self._cfg(n_clients=4, arrival_rate_hz=1.0, duration_s=6.0,
                        seed=3)
        base = run_sim(cfg, channels=[IIDChannel(0.0)] * 4)
        storm = run_sim(
            cfg, channels=[IIDChannel(0.0)] * 4,
            chaos=ChaosSchedule([burst_storm(0.0, 6.0, 6.0)]),
        )
        assert storm.arrived > 2 * base.arrived

    def test_conservation_holds_under_chaos(self):
        cfg = self._cfg(n_clients=3, arrival_rate_hz=2.0, duration_s=5.0)
        chaos = ChaosSchedule([
            channel_collapse(1.0, 2.0, 1.0),
            server_stall(2.5, 0.5),
            burst_storm(3.0, 4.0, 4.0),
        ])
        rep = run_sim(cfg, channels=[IIDChannel(0.1)] * 3, chaos=chaos)
        assert rep.arrived == rep.served + rep.dropped
        assert rep.arrived > 0


def _ledger_engine(allocatable=8, paged=True):
    """A host-allocator double with the two members EngineChaos touches:
    ``pool.(paged|total_blocks)`` and the ``_free_blocks`` LIFO."""
    return types.SimpleNamespace(
        pool=types.SimpleNamespace(paged=paged, total_blocks=allocatable + 1),
        _free_blocks=list(range(1, allocatable + 1)),
    )


class TestEngineChaosSqueeze:
    def test_steals_free_blocks_only_up_to_target(self):
        eng = _ledger_engine(allocatable=8)
        eng._free_blocks = eng._free_blocks[:3]      # 5 blocks are "live"
        chaos = EngineChaos(
            eng, ChaosSchedule([block_pool_squeeze(0.0, 10.0, 0.75)])
        )
        chaos.apply(1.0)
        # Target is 6 of 8 allocatable, but only the 3 free ones may move.
        assert chaos.held_blocks == 3
        assert eng._free_blocks == []

    def test_pressure_builds_as_blocks_free(self):
        eng = _ledger_engine(allocatable=8)
        eng._free_blocks = [1, 2]
        chaos = EngineChaos(
            eng, ChaosSchedule([block_pool_squeeze(0.0, 10.0, 0.5)])
        )
        chaos.apply(1.0)
        assert chaos.held_blocks == 2
        eng._free_blocks.extend([7, 8])              # a request retires
        chaos.apply(2.0)
        assert chaos.held_blocks == 4                # topped up to target
        assert len(eng._free_blocks) == 0

    def test_window_close_returns_blocks_lifo(self):
        eng = _ledger_engine(allocatable=4)
        before = list(eng._free_blocks)
        chaos = EngineChaos(
            eng, ChaosSchedule([block_pool_squeeze(0.0, 5.0, 1.0)])
        )
        chaos.apply(0.0)
        assert eng._free_blocks == []
        assert chaos.held_blocks == 4
        chaos.apply(5.0)                             # window over
        assert chaos.held_blocks == 0
        # LIFO steal + LIFO return restores the allocator's exact order.
        assert eng._free_blocks == before

    def test_release_all_and_contiguous_noop(self):
        eng = _ledger_engine(allocatable=4)
        chaos = EngineChaos(
            eng, ChaosSchedule([block_pool_squeeze(0.0, 5.0, 1.0)])
        )
        chaos.apply(1.0)
        chaos.release_all()
        assert chaos.held_blocks == 0
        assert sorted(eng._free_blocks) == [1, 2, 3, 4]

        flat = _ledger_engine(allocatable=4, paged=False)
        chaos2 = EngineChaos(
            flat, ChaosSchedule([block_pool_squeeze(0.0, 5.0, 1.0)])
        )
        chaos2.apply(1.0)                            # contiguous pool: no-op
        assert chaos2.held_blocks == 0
        assert len(flat._free_blocks) == 4


class TestDeadlineFeasible:
    """Satellite 2: exactness at the loss extremes, all three protocols."""

    PROTOS = ["unreliable", "arq", "fec_arq"]

    @pytest.mark.parametrize("name", PROTOS)
    def test_lossless_link_is_certain_within_deadline(self, name):
        cfg = link.ChannelConfig(loss_rate=0.0)
        p = deadline_feasible(make_protocol(name), 16, cfg, deadline_s=10.0)
        assert p == pytest.approx(1.0, abs=1e-9)

    @pytest.mark.parametrize("name", PROTOS)
    def test_total_loss_is_exactly_zero_not_nan(self, name):
        cfg = link.ChannelConfig(loss_rate=1.0)
        p = deadline_feasible(make_protocol(name), 16, cfg, deadline_s=10.0)
        assert p == 0.0
        assert not math.isnan(p)

    @pytest.mark.parametrize("name", PROTOS)
    def test_negative_deadline_is_zero(self, name):
        cfg = link.ChannelConfig(loss_rate=0.1)
        assert deadline_feasible(make_protocol(name), 16, cfg, -1.0) == 0.0

    def test_deadline_below_first_shot_latency_is_zero_when_lossless(self):
        cfg = link.ChannelConfig(loss_rate=0.0)
        proto = make_protocol("unreliable")
        first_shot = 16 * cfg.slot_time_s()
        assert deadline_feasible(proto, 16, cfg, first_shot / 2) == 0.0
        assert deadline_feasible(proto, 16, cfg, first_shot * 1.01) == \
            pytest.approx(1.0, abs=1e-9)

    def test_monotone_in_deadline_and_loss(self):
        cfg = link.ChannelConfig(loss_rate=0.3)
        proto = make_protocol("arq", max_rounds=4)
        deadlines = [0.0, 0.002, 0.01, 0.05, 1.0]
        ps = [deadline_feasible(proto, 16, cfg, d) for d in deadlines]
        assert all(b >= a - 1e-12 for a, b in zip(ps, ps[1:]))
        loose = deadline_feasible(proto, 16, cfg, 1.0, loss_rate=0.05)
        tight = deadline_feasible(proto, 16, cfg, 1.0, loss_rate=0.8)
        assert loose > tight

    def test_loss_rate_override_beats_config(self):
        cfg = link.ChannelConfig(loss_rate=0.0)
        proto = make_protocol("unreliable")
        assert deadline_feasible(proto, 16, cfg, 10.0, loss_rate=1.0) == 0.0
