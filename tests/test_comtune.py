"""COMtune core behaviour (paper §III-C/D): dropout emulates the channel,
and fine-tuning with it buys packet-loss robustness (the paper's headline
claim, on a tiny task)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comtune
from repro.core.compression import Compressor


class TestLinkLayers:
    def test_dropout_matches_channel_distribution(self):
        """Eq. 7 vs Eq. 1+11: same keep-rate and same compensation scale."""
        x = jnp.ones((100_000,))
        key = jax.random.PRNGKey(0)
        d = comtune.dropout_link(key, x, 0.4)
        spec = comtune.LinkSpec(loss_rate=0.4)
        c = comtune.channel_link(jax.random.PRNGKey(1), x, spec)
        # nonzero values are identical (1/(1-p)); keep rates agree
        assert abs(float((d != 0).mean()) - 0.6) < 0.01
        assert abs(float((c != 0).mean()) - 0.6) < 0.01
        np.testing.assert_allclose(
            np.unique(np.asarray(d))[-1], 1 / 0.6, rtol=1e-5
        )
        np.testing.assert_allclose(
            np.unique(np.asarray(c))[-1], 1 / 0.6, rtol=1e-5
        )

    def test_dropout_zero_rate_identity(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (32,))
        assert comtune.dropout_link(jax.random.PRNGKey(1), x, 0.0) is x

    def test_adaptive_compensation_unbiased_per_message(self):
        x = jnp.ones((10_000,))
        spec = comtune.LinkSpec(loss_rate=0.5, adaptive_compensation=True)
        y = comtune.channel_link(jax.random.PRNGKey(0), x, spec)
        # adaptive compensation renormalizes by the realized keep fraction
        assert abs(float(y.mean()) - 1.0) < 1e-3

    def test_latency_accounting(self):
        from repro.core.link import ChannelConfig

        spec = comtune.LinkSpec(compressor=Compressor())
        ch = ChannelConfig()
        # paper §IV-A: 65.5 kB at 9 Mbit/s -> 58.2 ms
        lat = comtune.di_latency_s(spec, 16384, 1, ch)
        assert abs(lat - 0.0582) < 0.001


class TestEndToEndRobustness:
    """The paper's core claim on a tiny synthetic task: a model fine-tuned
    with the dropout link layer (COMtune) degrades less under packet loss
    than one fine-tuned without it ('previous DI')."""

    @pytest.fixture(scope="class")
    def trained_models(self):
        import repro.data as data
        from repro.models import cnn
        from repro.optim import AdamConfig, adam_update, init_adam

        cfg = cnn.CNNConfig(
            blocks=((1, 16), (1, 32)), fc=(32,), num_classes=10,
            image_size=16, split_block=1,
        )
        (xtr, ytr), (xte, yte) = data.make_image_dataset(
            n_train=1500, n_test=400, num_classes=10, image_size=16, noise=1.2
        )
        adam_cfg = AdamConfig(lr=2e-3)

        def train(dropout_rate, seed=0):
            key = jax.random.PRNGKey(seed)
            params, state = cnn.init_cnn(key, cfg)
            opt = init_adam(params, adam_cfg)
            it = data.batch_iterator(xtr, ytr, 64, seed=seed)

            @jax.jit
            def step(params, state, opt, xb, yb, k):
                def loss_fn(p):
                    link = (
                        (lambda a: comtune.dropout_link(k, a, dropout_rate))
                        if dropout_rate > 0
                        else None
                    )
                    logits, new_state = cnn.forward(
                        p, state, xb, cfg, train=True, link_fn=link
                    )
                    ll = jax.nn.log_softmax(logits)
                    return -jnp.take_along_axis(
                        ll, yb[:, None], axis=-1
                    ).mean(), new_state

                (l, new_state), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
                params, opt, _ = adam_update(g, params, opt, adam_cfg)
                return params, new_state, opt, l

            for i in range(200):
                xb, yb = next(it)
                key, sub = jax.random.split(key)
                params, state, opt, _ = step(
                    params, state, opt, jnp.asarray(xb), jnp.asarray(yb), sub
                )
            return params, state

        return cfg, train(0.0), train(0.5), (xte, yte)

    def _accuracy(self, cfg, params, state, xte, yte, loss_rate, seed=0):
        from repro.models import cnn

        key = jax.random.PRNGKey(seed)
        link = (
            (lambda a: comtune.channel_link(
                key, a, comtune.LinkSpec(loss_rate=loss_rate)))
            if loss_rate > 0
            else None
        )
        logits, _ = cnn.forward(
            params, state, jnp.asarray(xte), cfg, train=False, link_fn=link
        )
        return float((jnp.argmax(logits, -1) == jnp.asarray(yte)).mean())

    def test_comtune_beats_baseline_under_loss(self, trained_models):
        cfg, (p0, s0), (p5, s5), (xte, yte) = trained_models
        accs0 = np.mean([self._accuracy(cfg, p0, s0, xte, yte, 0.7, s) for s in range(3)])
        accs5 = np.mean([self._accuracy(cfg, p5, s5, xte, yte, 0.7, s) for s in range(3)])
        # paper Fig. 5: at high loss rates COMtune is clearly better
        assert accs5 > accs0 + 0.03, (accs0, accs5)

    def test_comtune_degrades_gracefully(self, trained_models):
        cfg, _, (p5, s5), (xte, yte) = trained_models
        clean = self._accuracy(cfg, p5, s5, xte, yte, 0.0)
        lossy = np.mean(
            [self._accuracy(cfg, p5, s5, xte, yte, 0.5, s) for s in range(3)]
        )
        assert clean > 0.8  # learned the task
        assert clean - lossy < 0.1  # small degradation at p=0.5 (Fig. 5)
