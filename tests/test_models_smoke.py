"""Per-architecture smoke tests: REDUCED variant (2 units, d_model<=256,
<=4 experts) of each assigned config — one forward + one train step on CPU,
asserting output shapes and no NaNs.  The FULL configs are exercised only by
launch/dryrun.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.launch.steps import make_train_step
from repro.models import lm
from repro.optim import AdamConfig, init_adam

ARCHS = sorted(ARCHITECTURES)


def _batch(cfg, b=2, s=16, seed=1):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend:
        batch["frontend_embed"] = jnp.ones((b, cfg.frontend_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_shapes_and_finiteness(arch):
    cfg = ARCHITECTURES[arch].reduced()
    assert cfg.d_model <= 256 and cfg.resolved_num_units == 2
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, _, aux = lm.forward(
        params,
        batch["tokens"],
        cfg,
        frontend_embed=batch.get("frontend_embed"),
        link_key=jax.random.PRNGKey(2),
        link_mode="train",
        mode="train",
    )
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = ARCHITECTURES[arch].reduced()
    adam_cfg = AdamConfig(lr=1e-3, grad_clip_norm=1.0)
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    opt = init_adam(params, adam_cfg)
    step = jax.jit(make_train_step(cfg, adam_cfg))
    batch = _batch(cfg)
    new_params, new_opt, metrics = step(params, opt, batch, jax.random.PRNGKey(3))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0.0
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.abs(l).sum()),
        jax.tree_util.tree_map(
            lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
            new_params, params,
        ),
        0.0,
    )
    assert delta > 0.0
    assert int(new_opt.step) == 1


def test_all_ten_assigned_archs_present():
    kinds = {ARCHITECTURES[a].arch_type for a in ARCHS}
    assert len(ARCHS) == 10
    assert kinds == {"dense", "moe", "hybrid", "vlm", "audio", "ssm"}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL config fields must be exactly the assigned values."""
    expected = {
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    }[arch]
    c = ARCHITECTURES[arch]
    got = (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size)
    assert got == expected
    moe = {
        "jamba-v0.1-52b": (16, 2),
        "kimi-k2-1t-a32b": (384, 8),
        "arctic-480b": (128, 2),
    }.get(arch)
    if moe:
        assert (c.num_experts, c.top_k) == moe
