"""repro.serve engine: scan-compiled decode must be token-for-token
identical to the seed per-token loop, trace exactly once per signature,
and report compute (blocked) — not async-dispatch — timings."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.launch import serve as serve_mod
from repro.launch.serve import generate, generate_reference
from repro.models import lm
from repro.serve import DecodeEngine, default_engine


def _setup(arch="qwen1.5-0.5b", batch=2, s_prompt=6):
    cfg = ARCHITECTURES[arch].reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (batch, s_prompt), 0, cfg.vocab_size, jnp.int32
    )
    return cfg, params, prompts


class TestScanEquivalence:
    @pytest.mark.parametrize("channel", ["iid", "ge"])
    def test_matches_seed_per_token_loop(self, channel):
        """Same PRNG key -> identical tokens: the scan body replicates the
        legacy loop's split chain and per-round lossy link exactly."""
        cfg, params, prompts = _setup()
        key = jax.random.PRNGKey(42)
        ref, _ = generate_reference(
            params, cfg, prompts, 5, loss_rate=0.3, key=key, channel=channel
        )
        eng, _ = generate(
            params, cfg, prompts, 5, loss_rate=0.3, key=key, channel=channel,
            engine=DecodeEngine(),
        )
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(eng))

    def test_matches_across_keys_lossless(self):
        cfg, params, prompts = _setup()
        engine = DecodeEngine()
        for seed in (0, 7):
            key = jax.random.PRNGKey(seed)
            ref, _ = generate_reference(
                params, cfg, prompts, 4, loss_rate=0.0, key=key
            )
            eng, _ = generate(
                params, cfg, prompts, 4, loss_rate=0.0, key=key, engine=engine
            )
            np.testing.assert_array_equal(np.asarray(ref), np.asarray(eng))
        # Two calls, same signature: still a single trace.
        assert engine.total_traces() == 1

    def test_sampling_mode_shape_and_determinism(self):
        cfg, params, prompts = _setup()
        engine = DecodeEngine()
        key = jax.random.PRNGKey(3)
        a, _ = engine.generate(
            params, cfg, prompts, 6, key=key, greedy=False, temperature=0.8
        )
        b, _ = engine.generate(
            params, cfg, prompts, 6, key=key, greedy=False, temperature=0.8
        )
        assert a.shape == (prompts.shape[0], 6)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestCompileCache:
    def test_single_trace_across_repeated_calls(self):
        cfg, params, prompts = _setup()
        engine = DecodeEngine()
        for i in range(3):
            _, t = engine.generate(
                params, cfg, prompts, 4, key=jax.random.PRNGKey(i)
            )
        assert engine.num_compiled == 1
        assert engine.total_traces() == 1
        assert t["traces"] == 1.0
        assert t["compiled_this_call"] == 0.0

    def test_distinct_signatures_compile_separately(self):
        cfg, params, prompts = _setup()
        engine = DecodeEngine()
        engine.generate(params, cfg, prompts, 4)
        engine.generate(params, cfg, prompts, 5)                 # num_tokens
        engine.generate(params, cfg, prompts[:, :4], 4)          # prompt_len
        import dataclasses
        cfg2 = cfg.with_updates(
            link=dataclasses.replace(cfg.link, loss_rate=0.5)
        )
        engine.generate(params, cfg2, prompts, 4)                # link spec
        assert engine.num_compiled == 4
        assert engine.total_traces() == 4

    def test_greedy_ignores_temperature_in_cache_key(self):
        """Greedy decoding ignores temperature — identical programs must
        hit the same cache entry, not compile twice."""
        cfg, params, prompts = _setup()
        engine = DecodeEngine()
        engine.generate(params, cfg, prompts, 3, greedy=True, temperature=1.0)
        engine.generate(params, cfg, prompts, 3, greedy=True, temperature=0.7)
        assert engine.num_compiled == 1
        assert engine.total_traces() == 1

    def test_first_call_timing_excludes_compile(self):
        """The compiling call warms up internally: its generate_s is pure
        execution, with the one-off cost reported as compile_s."""
        cfg, params, prompts = _setup()
        engine = DecodeEngine()
        _, t_first = engine.generate(params, cfg, prompts, 8)
        _, t_second = engine.generate(params, cfg, prompts, 8)
        assert t_first["compiled_this_call"] == 1.0
        assert t_first["compile_s"] > t_first["generate_s"]
        assert t_second["compiled_this_call"] == 0.0
        assert t_second["compile_s"] == 0.0

    def test_default_engine_is_shared(self):
        assert default_engine() is default_engine()


class TestAOTCompile:
    def test_entries_are_compiled_executables_no_silent_recompile(self):
        """A cache miss AOT-compiles (lower().compile()) and stores the
        Compiled stage: exactly one trace and one XLA build per signature,
        no warm-up execution, and later calls *cannot* silently re-trace
        (a Compiled raises on signature mismatch instead)."""
        cfg, params, prompts = _setup()
        engine = DecodeEngine()
        _, t1 = engine.generate(params, cfg, prompts, 3)
        entry = next(iter(engine._compiled.values()))
        assert isinstance(entry.fn, jax.stages.Compiled)
        assert entry.traces == 1
        assert entry.compiles == 1
        assert t1["compiled_this_call"] == 1.0
        _, t2 = engine.generate(params, cfg, prompts, 3)
        assert entry.traces == 1
        assert entry.compiles == 1
        assert engine.total_compiles() == 1
        assert t2["compile_s"] == 0.0
        assert engine.stats()["compiles"] == 1


class TestComputeTiming:
    def test_reference_timing_includes_injected_compute(self, monkeypatch):
        """Sleep-injected serve step: per-token compute of ~delay seconds
        must show up in decode_s_per_token (the seed timed async dispatch,
        which returns before the step finishes)."""
        delay = 0.02
        num_tokens = 5
        orig = serve_mod.make_serve_step

        def _sleep_identity(x):
            time.sleep(delay)
            return x

        def slow_make_serve_step(cfg, **kw):
            real = orig(cfg, **kw)

            def step(params, token, cache, index, key):
                logits, new_cache = real(params, token, cache, index, key)
                logits = jax.pure_callback(
                    _sleep_identity,
                    jax.ShapeDtypeStruct(logits.shape, logits.dtype),
                    logits,
                )
                return logits, new_cache

            return step

        monkeypatch.setattr(serve_mod, "make_serve_step", slow_make_serve_step)
        cfg, params, prompts = _setup()
        _, t = generate_reference(
            params, cfg, prompts, num_tokens, loss_rate=0.0,
            key=jax.random.PRNGKey(0),
        )
        assert t["decode_s_per_token"] * num_tokens >= 0.8 * delay * num_tokens

    def test_engine_timing_monotone_in_tokens(self):
        """More decode rounds, more (blocked) time — trivially true for a
        compute-accurate timer, false for a dispatch timer."""
        cfg, params, prompts = _setup()
        engine = DecodeEngine()
        # Warm both signatures so neither timing includes compile.
        engine.generate(params, cfg, prompts, 2)
        engine.generate(params, cfg, prompts, 32)
        _, t_short = engine.generate(params, cfg, prompts, 2)
        _, t_long = engine.generate(params, cfg, prompts, 32)
        assert t_long["generate_s"] > t_short["generate_s"]


class TestServeDriver:
    def test_generate_timings_contract(self):
        """launch.serve.generate keeps the link-accounting keys the examples
        and system tests consume."""
        cfg, params, prompts = _setup()
        toks, t = generate(
            params, cfg, prompts, 4, loss_rate=0.3, engine=DecodeEngine()
        )
        assert toks.shape == (2, 4)
        assert t["link_latency_s_per_round"] > 0
        assert t["message_kb_per_token"] > 0
        assert t["tokens_per_s"] > 0
