"""Compression tests (paper Appendix A) incl. hypothesis properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import calibration, compression


def _acts(n=512, d=32, seed=1):
    return np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (n, d))) * 2.0


class TestQuantization:
    def test_bits_for_message_size(self):
        # paper: n = floor(32 M / M_float)
        assert compression.QuantSpec.bits_for_message_size(65536 / 4, 65536) == 8
        assert compression.QuantSpec.bits_for_message_size(65536, 65536) == 32
        assert compression.QuantSpec.bits_for_message_size(1, 65536) == 1

    @settings(deadline=None, max_examples=20)
    @given(bits=st.integers(2, 16), seed=st.integers(0, 100))
    def test_roundtrip_error_bound_property(self, bits, seed):
        """|dequant(quant(x)) - clip(x)| <= range / (2^n - 1)."""
        acts = _acts(seed=seed)
        comp = calibration.make_compressor(acts, kind="quant", bits=bits)
        x = jnp.asarray(acts[:64])
        xr = comp.decompress(comp.compress(x))
        step = (comp.quant.s_max - comp.quant.s_min) / (2**bits - 1)
        err = jnp.abs(xr - x)
        assert bool(jnp.all(err <= step * 0.51 + 1e-6))

    def test_quant_codes_in_range(self):
        acts = _acts()
        comp = calibration.make_compressor(acts, kind="quant", bits=4)
        code = comp.compress(jnp.asarray(acts[:10]) * 100.0)  # out-of-range input
        assert float(code.min()) >= 0.0
        assert float(code.max()) <= 15.0

    def test_ste_gradient_passthrough(self):
        acts = _acts()
        comp = calibration.make_compressor(acts, kind="quant", bits=8)
        g = jax.grad(lambda x: comp.roundtrip_train(x).sum())(jnp.zeros((32,)))
        np.testing.assert_allclose(np.asarray(g), 1.0)


class TestPCA:
    def test_full_rank_reconstruction(self):
        acts = _acts(d=16)
        comp = calibration.make_compressor(acts, kind="pca", reduced_dim=16)
        x = jnp.asarray(acts[:32])
        xr = comp.decompress(comp.compress(x))
        np.testing.assert_allclose(np.asarray(xr), np.asarray(x), atol=2e-4)

    def test_reduction_error_decreases_with_dim(self):
        acts = _acts(d=32)
        errs = []
        for d_red in [2, 8, 24, 32]:
            comp = calibration.make_compressor(acts, kind="pca", reduced_dim=d_red)
            x = jnp.asarray(acts[:64])
            xr = comp.decompress(comp.compress(x))
            errs.append(float(jnp.mean((xr - x) ** 2)))
        assert errs == sorted(errs, reverse=True)

    def test_basis_orthonormal(self):
        acts = _acts(d=24)
        spec = calibration.calibrate_pca(acts, 8)
        gram = np.asarray(spec.w) @ np.asarray(spec.w).T
        np.testing.assert_allclose(gram, np.eye(8), atol=1e-4)

    def test_reduced_dim_for_message_size(self):
        # D' = floor(M / 4 bytes)
        assert compression.PCASpec.reduced_dim_for_message_size(4096, 4.0, 16384) == 1024

    def test_gram_trick_matches_direct(self):
        """N < D path (gram trick) must give the same subspace."""
        rng = np.random.RandomState(0)
        acts = rng.randn(20, 64).astype(np.float32)
        spec = calibration.calibrate_pca(acts, 4)
        # reconstruction via the basis should match projecting onto top-4 PCs
        centered = acts - acts.mean(0)
        u, s, vt = np.linalg.svd(centered, full_matrices=False)
        proj_ref = centered @ vt[:4].T @ vt[:4]
        proj_ours = centered @ np.asarray(spec.w).T @ np.asarray(spec.w)
        np.testing.assert_allclose(proj_ours, proj_ref, atol=1e-3)


class TestCompressorInterface:
    def test_identity(self):
        comp = compression.Compressor()
        x = jnp.ones((4, 4))
        assert comp.compress(x) is x
        assert comp.message_elements(16) == 16

    def test_message_elements_pca(self):
        acts = _acts(d=32)
        comp = calibration.make_compressor(acts, kind="pca", reduced_dim=5)
        assert comp.message_elements(32) == 5
        assert comp.bytes_per_element() == 4.0

    def test_bytes_per_element_quant(self):
        acts = _acts()
        comp = calibration.make_compressor(acts, kind="quant", bits=4)
        assert comp.bytes_per_element() == 0.5
