"""Tests for the §Perf optimization features: shard_map MoE, chunked mLSTM,
int8 KV cache, sharded-vocab-safe loss, attention sharding constraints."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES
from repro.models import cache as cache_lib, lm, moe, xlstm
from repro.sharding import ctx as shard_ctx


class TestShardMapMoE:
    @pytest.mark.parametrize("arch", ["kimi-k2-1t-a32b", "arctic-480b", "jamba-v0.1-52b"])
    def test_matches_dense_path(self, arch):
        cfg = ARCHITECTURES[arch].reduced(capacity_factor=16.0)
        p = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)) * 0.5
        out_d, aux_d = moe.moe_forward_dense(p, x, cfg)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        out_s, aux_s = moe.moe_forward_shard_map(p, x, cfg, mesh)
        np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_s), atol=1e-5)
        np.testing.assert_allclose(float(aux_d), float(aux_s), rtol=1e-5)

    def test_dispatcher_uses_ctx(self):
        cfg = ARCHITECTURES["arctic-480b"].reduced(capacity_factor=16.0)
        p = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, cfg.d_model))
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        out_plain, _ = moe.moe_forward(p, x, cfg)
        with shard_ctx.use_shard_map_mesh(mesh):
            out_ctx, _ = moe.moe_forward(p, x, cfg)
        np.testing.assert_allclose(
            np.asarray(out_plain), np.asarray(out_ctx), atol=1e-5
        )

    def test_gradients_flow(self):
        cfg = ARCHITECTURES["kimi-k2-1t-a32b"].reduced(capacity_factor=16.0)
        p = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, cfg.d_model))
        mesh = jax.make_mesh((1, 1), ("data", "model"))

        def loss(p):
            o, a = moe.moe_forward_shard_map(p, x, cfg, mesh)
            return (o**2).mean() + 0.01 * a

        g = jax.grad(loss)(p)
        leaves = jax.tree_util.tree_leaves(g)
        assert all(bool(jnp.isfinite(l).all()) for l in leaves)
        assert float(sum(jnp.abs(l).sum() for l in leaves)) > 0


class TestChunkedMLSTM:
    def test_matches_parallel(self):
        cfg = ARCHITECTURES["xlstm-350m"].reduced()
        p = xlstm.init_mlstm(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 40, cfg.d_model)) * 0.5
        y_par = xlstm.mlstm_parallel(p, x, cfg)
        y_chk, _ = xlstm.mlstm_chunked(p, x, cfg)
        np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_chk), atol=1e-5)

    def test_state_matches_sequential_steps(self):
        cfg = ARCHITECTURES["xlstm-350m"].reduced()
        p = xlstm.init_mlstm(jax.random.PRNGKey(0), cfg, jnp.float32)
        B, S = 2, 37  # non-multiple of chunk: exercises padding masking
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5
        _, st = xlstm.mlstm_chunked(p, x, cfg)
        st_seq = xlstm.init_mlstm_cache(B, cfg)
        for t in range(S):
            _, st_seq = xlstm.mlstm_step(p, x[:, t : t + 1], cfg, st_seq)
        for k in ("c", "n", "m"):
            np.testing.assert_allclose(
                np.asarray(st[k]), np.asarray(st_seq[k]), atol=1e-4
            )

    def test_prefill_decode_handoff(self):
        cfg = ARCHITECTURES["xlstm-350m"].reduced()
        p = xlstm.init_mlstm(jax.random.PRNGKey(0), cfg, jnp.float32)
        B, S = 1, 33
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5
        y_full = xlstm.mlstm_parallel(p, x, cfg)
        _, st = xlstm.mlstm_chunked(p, x[:, : S - 1], cfg)
        y_dec, _ = xlstm.mlstm_step(p, x[:, S - 1 :], cfg, st)
        np.testing.assert_allclose(
            np.asarray(y_full[:, -1]), np.asarray(y_dec[:, 0]), atol=1e-4
        )

    def test_continuation_state(self):
        """chunked(x1) state feeding chunked(x2) == chunked(x1 ++ x2)."""
        cfg = ARCHITECTURES["xlstm-350m"].reduced()
        p = xlstm.init_mlstm(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 48, cfg.d_model)) * 0.5
        y_all, st_all = xlstm.mlstm_chunked(p, x, cfg)
        _, st1 = xlstm.mlstm_chunked(p, x[:, :20], cfg)
        y2, st2 = xlstm.mlstm_chunked(p, x[:, 20:], cfg, state=st1)
        np.testing.assert_allclose(
            np.asarray(y_all[:, 20:]), np.asarray(y2), atol=1e-4
        )
        for k in ("c", "n", "m"):
            np.testing.assert_allclose(
                np.asarray(st_all[k]), np.asarray(st2[k]), atol=1e-4
            )


class TestInt8KVCache:
    def test_decode_close_to_fp_cache(self):
        cfg8 = ARCHITECTURES["qwen1.5-0.5b"].reduced(kv_cache_dtype="int8")
        params = lm.init_lm(jax.random.PRNGKey(0), cfg8)
        B, S = 2, 12
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg8.vocab_size)
        full, _, _ = lm.forward(params, toks, cfg8, link_mode="off", mode="prefill")
        c = cache_lib.init_cache(cfg8, B, max_seq=32)
        assert c["units"][0]["k"].dtype == jnp.int8
        assert "k_scale" in c["units"][0]
        _, c, _ = lm.forward(
            params, toks[:, : S - 1], cfg8, cache=c, cache_index=0,
            link_mode="off", mode="prefill",
        )
        dec, _, _ = lm.forward(
            params, toks[:, S - 1 :], cfg8, cache=c, cache_index=S - 1,
            link_mode="off", mode="decode",
        )
        a = np.asarray(full[:, -1])
        b = np.asarray(dec[:, 0])
        rel = np.abs(a - b).max() / np.abs(a).max()
        assert rel < 0.05  # int8 rounding only
        assert (a.argmax(-1) == b.argmax(-1)).all()

    def test_int8_with_rotating_window(self):
        from repro.configs.base import LayerSpec

        cfg = ARCHITECTURES["qwen1.5-0.5b"].reduced(
            kv_cache_dtype="int8",
            unit_pattern=(LayerSpec(kind="attn", window=8),),
        )
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        B, S = 1, 20
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
        full, _, _ = lm.forward(params, toks, cfg, link_mode="off", mode="prefill")
        c = cache_lib.init_cache(cfg, B, max_seq=S)
        _, c, _ = lm.forward(
            params, toks[:, : S - 1], cfg, cache=c, cache_index=0,
            link_mode="off", mode="prefill",
        )
        dec, _, _ = lm.forward(
            params, toks[:, S - 1 :], cfg, cache=c, cache_index=S - 1,
            link_mode="off", mode="decode",
        )
        a = np.asarray(full[:, -1])
        b = np.asarray(dec[:, 0])
        assert np.abs(a - b).max() / np.abs(a).max() < 0.05

    def test_quantize_roundtrip_bound(self):
        from repro.models.attention import _dequantize_kv, _quantize_kv

        x = jax.random.normal(jax.random.PRNGKey(0), (4, 7, 3, 64)) * 3
        q, s = _quantize_kv(x)
        xr = _dequantize_kv(q, s, jnp.float32)
        # rounding error <= scale/2, plus bf16 storage of the scale adds up
        # to 2^-8 relative error amplified by |code| <= 127
        bound = np.asarray(s, np.float32)[..., None] * (0.5 + 127 / 256.0) + 1e-6
        assert np.all(np.abs(np.asarray(xr - x)) <= bound)


class TestShardedVocabLoss:
    def test_matches_naive_cross_entropy(self):
        logits = jax.random.normal(jax.random.PRNGKey(0), (2, 10, 37))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, 37)
        ours = lm.lm_loss(logits, toks, jnp.zeros(()), 0.0)
        lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        ref = -jnp.take_along_axis(lp, toks[:, 1:, None], axis=-1).mean()
        np.testing.assert_allclose(float(ours), float(ref), rtol=1e-6)

    def test_gradient_matches(self):
        logits = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 11))
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, 11)
        g1 = jax.grad(lambda l: lm.lm_loss(l, toks, jnp.zeros(()), 0.0))(logits)
        def ref(l):
            lp = jax.nn.log_softmax(l[:, :-1], axis=-1)
            return -jnp.take_along_axis(lp, toks[:, 1:, None], axis=-1).mean()
        g2 = jax.grad(ref)(logits)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)
