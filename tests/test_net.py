"""repro.net subsystem tests: channels, FEC, protocols, simulator, and the
Pallas burst_mask kernel."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comtune, link
from repro.kernels.lossy_link.kernel import burst_mask_kernel
from repro.kernels.lossy_link.ops import burst_mask
from repro.kernels.lossy_link.ref import burst_mask_ref
from repro.net import (
    ARQProtocol,
    FadingMarkovChannel,
    FECSpec,
    GilbertElliottChannel,
    HybridFECARQProtocol,
    IIDChannel,
    SimConfig,
    TraceChannel,
    UnreliableProtocol,
    accuracy_curve_fn,
    block_recovery_mask,
    decode,
    decode_floats,
    encode,
    encode_floats,
    fec_element_keep_jnp,
    make_channel,
    make_protocol,
    record_trace,
    run_sim,
    synthetic_burst_trace,
)


class TestChannels:
    def test_ge_stationary_matches_analytic(self):
        """Empirical loss over a long stateful run matches the closed-form
        stationary rate pi_g*loss_good + pi_b*loss_bad."""
        ch = GilbertElliottChannel(p_gb=0.08, p_bg=0.25, loss_good=0.05,
                                   loss_bad=0.8)
        analytic = ch.stationary_loss_rate
        emp = ch.mean_loss_over(np.random.RandomState(0), 200_000)
        assert abs(emp - analytic) < 0.01

    def test_ge_jnp_matches_stationary(self):
        ch = GilbertElliottChannel.from_target(0.3, burst_len=4)
        assert abs(ch.stationary_loss_rate - 0.3) < 1e-9
        keep = ch.packet_keep_jnp(jax.random.PRNGKey(0), 100_000)
        assert abs((1.0 - float(keep.mean())) - 0.3) < 0.02

    def test_ge_burstiness(self):
        """Burst channel must produce longer loss runs than iid at equal
        rate."""
        ch = GilbertElliottChannel.from_target(0.3, burst_len=8)
        keep, _ = ch.step(np.random.RandomState(1), False, 50_000)

        def mean_run(mask):
            runs, cur = [], 0
            for v in mask:
                if not v:
                    cur += 1
                elif cur:
                    runs.append(cur)
                    cur = 0
            return np.mean(runs)

        iid_keep = np.random.RandomState(2).rand(50_000) >= 0.3
        assert mean_run(keep) > 2.5 * mean_run(iid_keep)

    def test_ge_from_target_high_rate_clamped(self):
        """Targets demanding p_gb > 1 must clamp while keeping the
        stationary rate exact (else 1/(1-p) compensation is biased)."""
        ch = GilbertElliottChannel.from_target(0.9, burst_len=4)
        assert 0.0 < ch.p_gb <= 1.0 and 0.0 < ch.p_bg <= 1.0
        assert abs(ch.stationary_loss_rate - 0.9) < 1e-9
        emp = ch.mean_loss_over(np.random.RandomState(0), 200_000)
        assert abs(emp - 0.9) < 0.01

    def test_fading_stationary_matches_analytic(self):
        # Sticky chain (agility 0.25) mixes slowly; average several
        # independent runs to tame the Monte-Carlo error.
        ch = FadingMarkovChannel(distance_m=60.0)
        emp = np.mean([
            ch.mean_loss_over(np.random.RandomState(s), 50_000)
            for s in range(4)
        ])
        assert abs(emp - ch.stationary_loss_rate) < 0.01

    def test_fading_distance_monotone(self):
        rates = [
            FadingMarkovChannel(distance_m=d).stationary_loss_rate
            for d in (10.0, 40.0, 100.0)
        ]
        assert rates[0] < rates[1] < rates[2]

    def test_trace_replay(self):
        trace = synthetic_burst_trace(5000, 0.25, seed=0)
        ch = TraceChannel.from_array(trace)
        assert abs(ch.stationary_loss_rate - (1 - trace.mean())) < 1e-9
        rng = np.random.RandomState(0)
        state = 17
        keep, state = ch.step(rng, state, 100)
        assert np.array_equal(keep, trace[17:117].astype(bool))

    def test_record_trace_roundtrip(self):
        ch = GilbertElliottChannel.from_target(0.4)
        trace = record_trace(ch, 10_000, seed=0)
        replay = TraceChannel.from_array(trace)
        assert abs(replay.stationary_loss_rate - 0.4) < 0.05

    def test_registry(self):
        assert isinstance(make_channel("iid", 0.2), IIDChannel)
        ge = make_channel("ge", 0.2)
        assert abs(ge.stationary_loss_rate - 0.2) < 1e-9
        with pytest.raises(ValueError):
            make_channel("nope")


class TestBurstMaskKernel:
    @pytest.mark.parametrize("shape", [(8, 64), (5, 130), (1, 7), (17, 256)])
    def test_matches_ref_exactly(self, shape):
        r, n = shape
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(r * 777 + n), 3)
        ui = jax.random.uniform(k1, (r,), jnp.float32)
        ul = jax.random.uniform(k2, (r, n), jnp.float32)
        ut = jax.random.uniform(k3, (r, n), jnp.float32)
        kw = dict(p_gb=0.1, p_bg=0.3, loss_good=0.02, loss_bad=0.8)
        got = np.asarray(burst_mask_kernel(ui, ul, ut, **kw))
        want = np.asarray(burst_mask_ref(ui, ul, ut, **kw))
        assert np.array_equal(got, want)

    def test_op_stationary_rate(self):
        ch = GilbertElliottChannel.from_target(0.35, burst_len=5)
        m = burst_mask(
            jax.random.PRNGKey(0), 64, 512,
            p_gb=ch.p_gb, p_bg=ch.p_bg,
            loss_good=ch.loss_good, loss_bad=ch.loss_bad,
        )
        assert m.shape == (64, 512)
        assert abs((1.0 - float(m.mean())) - 0.35) < 0.03


class TestFEC:
    def test_rs_recovers_any_m_erasures_exactly(self):
        spec = FECSpec(k=5, m=3, kind="rs")
        data = np.random.RandomState(0).randint(0, 256, (5, 64)).astype(np.uint8)
        cw = encode(data, spec)
        for r in range(spec.m + 1):
            for erased in itertools.combinations(range(spec.block_packets), r):
                keep = [i for i in range(spec.block_packets) if i not in erased]
                rec = decode(cw[keep], keep, spec)
                assert np.array_equal(rec, data), erased

    def test_rs_raises_beyond_m(self):
        spec = FECSpec(k=4, m=2, kind="rs")
        data = np.zeros((4, 8), np.uint8)
        cw = encode(data, spec)
        keep = [0, 1, 2]  # only 3 of 4 needed rows
        with pytest.raises(ValueError):
            decode(cw[keep], keep, spec)

    def test_xor_single_erasure(self):
        spec = FECSpec(k=4, m=1, kind="xor")
        data = np.random.RandomState(1).randint(0, 256, (4, 32)).astype(np.uint8)
        cw = encode(data, spec)
        for miss in range(4):
            keep = [i for i in range(5) if i != miss]
            assert np.array_equal(decode(cw[keep], keep, spec), data)

    def test_float_payload_bit_exact(self):
        spec = FECSpec(k=6, m=2, kind="rs")
        acts = np.random.RandomState(2).randn(6, 25).astype(np.float32)
        cw = encode_floats(acts, spec)
        keep = [0, 2, 3, 5, 6, 7]   # rows 1 and 4 erased
        rec = decode_floats(cw[keep], keep, spec, 25)
        assert np.array_equal(rec, acts)

    def test_block_recovery_mask(self):
        spec = FECSpec(k=2, m=1)
        # block 0: all arrive; block 1: one data lost but recoverable;
        # block 2: two lost -> unrecoverable, only survivor kept.
        pkt = jnp.asarray([1, 1, 1,  0, 1, 1,  0, 1, 0], jnp.float32)
        out = np.asarray(block_recovery_mask(pkt, spec))
        assert np.array_equal(out, [1, 1, 1, 1, 0, 1])

    def test_fec_element_mask_raises_delivery(self):
        """On the iid channel FEC closes most of the delivery gap (the MDS
        analysis applies); the same code on an un-interleaved burst channel
        gains far less because bursts wipe whole blocks."""
        key = jax.random.PRNGKey(0)
        spec = FECSpec(k=4, m=2)

        def mean_mask(ch, protected):
            vals = []
            for s in range(20):
                k = jax.random.fold_in(key, s)
                if protected:
                    m = fec_element_keep_jnp(k, ch, 2000, 25, spec)
                else:
                    m = ch.element_keep_jnp(k, 2000, 25)
                vals.append(float(m.mean()))
            return float(np.mean(vals))

        iid = IIDChannel(0.3)
        ge = GilbertElliottChannel.from_target(0.3, burst_len=4)
        gain_iid = mean_mask(iid, True) - mean_mask(iid, False)
        gain_ge = mean_mask(ge, True) - mean_mask(ge, False)
        assert gain_iid > 0.1          # analytic: ~0.7 -> ~0.86
        assert gain_ge < gain_iid      # bursts defeat un-interleaved FEC


class TestProtocols:
    def test_unreliable_matches_eq4(self):
        cfg = link.ChannelConfig(loss_rate=0.3)
        proto = UnreliableProtocol()
        lat, pmf = proto.latency_pmf(20, cfg)
        assert lat.shape == (1,)
        assert abs(float(lat[0]) - 20 * cfg.slot_time_s()) < 1e-12

    def test_arq_unbounded_matches_eq5_mean(self):
        """With a huge round budget the ARQ mean latency approaches the
        reliable protocol's E[slots] = n / (1-p) (per-packet geometric)."""
        cfg = link.ChannelConfig(loss_rate=0.4)
        proto = ARQProtocol(max_rounds=60)
        lat, pmf = proto.latency_pmf(10, cfg)
        mean_slots = float(np.dot(lat, pmf)) / cfg.slot_time_s()
        assert abs(mean_slots - 10 / 0.6) < 0.1

    def test_arq_deadline_bounds_latency(self):
        cfg = link.ChannelConfig(loss_rate=0.5)
        proto = ARQProtocol(max_rounds=50, deadline_slots=30)
        lat, pmf = proto.latency_pmf(10, cfg)
        # One round may start at slot 29, adding at most 10 more slots.
        assert float(lat.max()) <= 40 * cfg.slot_time_s() + 1e-12
        assert abs(float(pmf.sum()) - 1.0) < 1e-9

    def test_fec_arq_beats_unreliable_delivery(self):
        ch = GilbertElliottChannel.from_target(0.3)
        rng = np.random.RandomState(0)
        fr_u, fr_f = [], []
        for _ in range(50):
            st_ = ch.init_state(rng)
            r, st_ = UnreliableProtocol().run_round(rng, ch, st_, 24)
            fr_u.append(r.delivered_fraction)
            st_ = ch.init_state(rng)
            r, st_ = HybridFECARQProtocol(
                fec=FECSpec(k=4, m=2), max_rounds=2
            ).run_round(rng, ch, st_, 24)
            fr_f.append(r.delivered_fraction)
        assert np.mean(fr_f) > np.mean(fr_u) + 0.1

    def test_arq_expected_delivery_rate(self):
        ch = IIDChannel(0.1)
        proto = ARQProtocol(max_rounds=4)
        # No deadline: exactly 1 - p^R, independent of message size.
        assert proto.expected_delivery_rate(10, ch) == pytest.approx(
            1.0 - 0.1**4
        )
        assert proto.expected_delivery_rate(1000, ch) == pytest.approx(
            1.0 - 0.1**4
        )
        # A 1-slot deadline stops retransmission after the first round.
        tight = ARQProtocol(max_rounds=4, deadline_slots=1)
        assert tight.expected_delivery_rate(100, IIDChannel(0.5)) == (
            pytest.approx(0.5)
        )

    def test_latency_pmfs_normalized(self):
        cfg = link.ChannelConfig(loss_rate=0.3)
        for name in ("unreliable", "arq", "fec_arq"):
            lat, pmf = make_protocol(name).latency_pmf(16, cfg)
            assert abs(float(pmf.sum()) - 1.0) < 1e-9
            assert np.all(np.diff(lat) > 0) or lat.size == 1


class TestSimulator:
    def test_conserves_requests(self):
        """arrived == served + dropped, across channel/protocol mixes."""
        for seed in range(3):
            channels = (
                [GilbertElliottChannel.from_target(0.5) for _ in range(3)]
                + [IIDChannel(0.2) for _ in range(3)]
                + [FadingMarkovChannel(distance_m=70.0) for _ in range(2)]
            )
            rep = run_sim(
                SimConfig(n_clients=8, arrival_rate_hz=5.0, duration_s=2.0,
                          seed=seed, min_delivered_fraction=0.7),
                channels=channels,
                protocol=UnreliableProtocol(),
            )
            assert rep.arrived == rep.served + rep.dropped
            assert rep.arrived > 0

    def test_arq_improves_delivery_lowers_drop(self):
        channels = lambda: [GilbertElliottChannel.from_target(0.45)  # noqa: E731
                            for _ in range(8)]
        base = SimConfig(n_clients=8, arrival_rate_hz=4.0, duration_s=2.0,
                         seed=0, min_delivered_fraction=0.8)
        rep_u = run_sim(base, channels=channels(), protocol=UnreliableProtocol())
        rep_a = run_sim(base, channels=channels(),
                        protocol=ARQProtocol(max_rounds=4))
        assert rep_a.dropped <= rep_u.dropped
        assert rep_a.mean_delivered_fraction > rep_u.mean_delivered_fraction

    def test_latency_percentiles_ordered(self):
        rep = run_sim(SimConfig(n_clients=16, arrival_rate_hz=4.0,
                                duration_s=2.0, seed=1))
        assert 0.0 < rep.latency_p50_s <= rep.latency_p99_s

    def test_accuracy_under_load(self):
        fn = accuracy_curve_fn([0.0, 0.5, 1.0], [0.1, 0.5, 0.9])
        assert abs(fn(0.25) - 0.3) < 1e-9
        rep = run_sim(
            SimConfig(n_clients=4, arrival_rate_hz=3.0, duration_s=2.0,
                      seed=2),
            accuracy_fn=fn,
        )
        assert rep.accuracy_under_load is not None
        assert 0.0 < rep.accuracy_under_load <= 0.9


class TestLinkSpecIntegration:
    def test_channel_link_ge_kernel_matches_reference_path(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 200))
        key = jax.random.PRNGKey(7)
        spec = comtune.LinkSpec(loss_rate=0.3).with_channel("ge")
        spec_k = comtune.LinkSpec(loss_rate=0.3, use_kernel=True).with_channel("ge")
        y_ref = comtune.channel_link(key, x, spec)
        y_ker = comtune.channel_link(key, x, spec_k)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ker),
                                   rtol=1e-6)

    def test_channel_link_fec_jit(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 100))
        spec = comtune.LinkSpec(loss_rate=0.4, fec_k=4, fec_m=2)
        spec = spec.with_channel("ge")
        fn = jax.jit(lambda k, x: comtune.channel_link(k, x, spec))
        y = fn(jax.random.PRNGKey(1), x)
        assert bool(jnp.all(jnp.isfinite(y)))

    def test_iid_fec_recovers_delivery(self):
        """iid + FEC must route through the net path: delivery rises above
        the raw 1-p and compensation uses the residual rate."""
        x = jnp.ones((2000,))
        key = jax.random.PRNGKey(3)
        raw = comtune.channel_link(key, x, comtune.LinkSpec(loss_rate=0.4))
        prot = comtune.channel_link(
            key, x, comtune.LinkSpec(loss_rate=0.4, fec_k=4, fec_m=2)
        )
        assert float((prot != 0).mean()) > float((raw != 0).mean()) + 0.1

    def test_iid_channel_params_loss_rate_override(self):
        x = jnp.ones((1000,))
        spec = comtune.LinkSpec().with_channel("iid", loss_rate=0.5)
        y = comtune.channel_link(jax.random.PRNGKey(0), x, spec)
        assert 0.3 < float((y == 0).mean()) < 0.7  # ~50% dropped, not 0%
        # The override must preserve the configured granularity: it is the
        # plain Eq. 1 path at the overridden rate, bit for bit.
        y_plain = comtune.channel_link(
            jax.random.PRNGKey(0), x, comtune.LinkSpec(loss_rate=0.5)
        )
        assert bool(jnp.all(y == y_plain))

    def test_di_latency_accounts_fec_overhead(self):
        cfg = link.ChannelConfig()
        plain = comtune.LinkSpec(loss_rate=0.1)
        fec = comtune.LinkSpec(loss_rate=0.1, fec_k=4, fec_m=2)
        t0 = comtune.di_latency_s(plain, 1024, 1, cfg)
        t1 = comtune.di_latency_s(fec, 1024, 1, cfg)
        assert t1 > t0 * 1.3  # (k+m)/k = 1.5 expansion (ceil effects aside)


class TestLossRateOneRegression:
    """loss_rate=1.0 must give zeros, not NaN/inf (satellite fix)."""

    def test_apply_channel(self):
        x = jnp.ones((64,))
        for gran in ("element", "packet"):
            y = link.apply_channel(
                jax.random.PRNGKey(0), x, 1.0, granularity=gran
            )
            assert bool(jnp.all(jnp.isfinite(y)))
            assert bool(jnp.all(y == 0.0))

    def test_channel_link(self):
        x = jnp.ones((8, 32))
        y = comtune.channel_link(
            jax.random.PRNGKey(0), x, comtune.LinkSpec(loss_rate=1.0)
        )
        assert bool(jnp.all(jnp.isfinite(y)))
        assert bool(jnp.all(y == 0.0))


class _RecordingChannel:
    """Channel wrapper logging the order in which clients' channels draw —
    the observable for the uplink-start (vs arrival) ordering fix."""

    def __init__(self, inner, label, log):
        self.inner = inner
        self.label = label
        self.log = log

    @property
    def stationary_loss_rate(self):
        return self.inner.stationary_loss_rate

    def init_state(self, rng):
        return self.inner.init_state(rng)

    def step(self, rng, state, n_packets):
        self.log.append(self.label)
        return self.inner.step(rng, state, n_packets)


class TestSimulatorFixes:
    """Regression tests for the serve/simulator correctness fixes: channel
    draws at uplink start, horizon covering dropped tails, and the
    model-in-the-loop accuracy path."""

    def test_channel_draw_order_follows_uplink_start_not_arrival(self):
        """Hand-scheduled two-client trace: client 0's second request
        ARRIVES before client 1's request but its uplink STARTS after
        (radio busy) — the stateful-channel draws must happen in on-air
        order [c0, c1, c0], not arrival order [c0, c0, c1]."""
        from repro.core.link import ChannelConfig

        channel_cfg = ChannelConfig()
        slot_t = channel_cfg.slot_time_s()
        n_packets = 50
        uplink_s = n_packets * slot_t
        log = []
        channels = [
            _RecordingChannel(IIDChannel(0.0), c, log) for c in range(2)
        ]
        # c0 req1 occupies c0's radio over [0, uplink_s); c0 req2 arrives
        # inside that window; c1's request arrives after c0 req2 but with a
        # free radio, so it transmits first.
        arrivals = [(0.0, 0), (0.4 * uplink_s, 0), (0.6 * uplink_s, 1)]
        rep = run_sim(
            SimConfig(n_clients=2, duration_s=1.0, n_packets=n_packets,
                      min_delivered_fraction=0.0),
            channels=channels,
            channel_cfg=channel_cfg,
            arrivals=arrivals,
        )
        assert rep.arrived == 3 and rep.served == 3
        assert log == [0, 1, 0], log

    def test_queued_uplinks_serialize_back_to_back(self):
        """A queued request starts exactly when the radio frees up."""
        from repro.core.link import ChannelConfig

        channel_cfg = ChannelConfig()
        slot_t = channel_cfg.slot_time_s()
        n_packets = 20
        rep = run_sim(
            SimConfig(n_clients=1, duration_s=1.0, n_packets=n_packets,
                      min_delivered_fraction=0.0, server_base_s=0.0,
                      server_per_item_s=0.0),
            channels=[IIDChannel(0.0)],
            channel_cfg=channel_cfg,
            arrivals=[(0.0, 0), (0.0, 0)],
        )
        # Request 2 waits for request 1's full uplink, then transmits:
        # latencies are exactly [uplink, 2 * uplink] (instant server), so
        # the mean is 1.5 uplinks.
        assert rep.served == 2
        np.testing.assert_allclose(
            rep.latency_mean_s, 1.5 * n_packets * slot_t, rtol=1e-6
        )

    def test_horizon_covers_dropped_tail(self):
        """A simulation whose last events are deadline drops must extend
        duration_s to the drops' completion and dilute throughput_rps."""
        from repro.core.link import ChannelConfig

        channel_cfg = ChannelConfig()
        slot_t = channel_cfg.slot_time_s()
        n_packets = 400
        cfg = SimConfig(n_clients=2, duration_s=0.05, n_packets=n_packets,
                        min_delivered_fraction=0.2)
        t_arr = 0.049
        rep = run_sim(
            cfg,
            channels=[IIDChannel(1.0), IIDChannel(1.0)],
            channel_cfg=channel_cfg,
            arrivals=[(t_arr, 0), (t_arr, 1)],
        )
        assert rep.arrived == 2 and rep.dropped == 2 and rep.served == 0
        t_drop_done = t_arr + n_packets * slot_t
        assert t_drop_done > cfg.duration_s  # the scenario has a real tail
        np.testing.assert_allclose(rep.duration_s, t_drop_done, rtol=1e-6)
        assert rep.throughput_rps == 0.0

    def test_horizon_dilutes_throughput_with_served_head(self):
        """Served head + all-drop tail: throughput divides by the full
        horizon (last drop), not the served-only window."""
        from repro.core.link import ChannelConfig

        channel_cfg = ChannelConfig()
        slot_t = channel_cfg.slot_time_s()
        n_packets = 200
        cfg = SimConfig(n_clients=2, duration_s=0.01, n_packets=n_packets,
                        min_delivered_fraction=0.5)
        rep = run_sim(
            cfg,
            channels=[IIDChannel(0.0), IIDChannel(1.0)],
            channel_cfg=channel_cfg,
            arrivals=[(0.0, 0), (0.009, 1)],
        )
        assert rep.served == 1 and rep.dropped == 1
        t_tail = 0.009 + n_packets * slot_t
        np.testing.assert_allclose(rep.duration_s, t_tail, rtol=1e-6)
        np.testing.assert_allclose(rep.throughput_rps, 1.0 / t_tail, rtol=1e-6)

    def test_conservation_with_drop_tail(self):
        for seed in range(3):
            rep = run_sim(
                SimConfig(n_clients=6, arrival_rate_hz=6.0, duration_s=1.0,
                          seed=seed, min_delivered_fraction=0.9),
                channels=[GilbertElliottChannel.from_target(0.6)
                          for _ in range(6)],
            )
            assert rep.arrived == rep.served + rep.dropped
            assert rep.duration_s >= 1.0

    def test_model_in_the_loop_uses_realized_masks(self):
        """The injected request_eval_fn sees one realized (served) mask per
        request with the configured packet count; accuracy is its mean."""
        seen = {"masks": [], "rids": []}

        def eval_fn(masks, rids):
            seen["masks"].append(np.asarray(masks))
            seen["rids"].append(np.asarray(rids))
            return np.asarray(rids) % 2 == 0

        cfg = SimConfig(n_clients=4, arrival_rate_hz=5.0, duration_s=1.0,
                        seed=3, n_packets=17, min_delivered_fraction=0.0)
        rep = run_sim(
            cfg,
            channels=[GilbertElliottChannel.from_target(0.3)
                      for _ in range(4)],
            model_in_the_loop=True,
            request_eval_fn=eval_fn,
        )
        assert rep.accuracy_mode == "model"
        masks = np.concatenate(seen["masks"])
        rids = np.concatenate(seen["rids"])
        assert masks.shape == (rep.served, cfg.n_packets)
        assert masks.dtype == bool
        # Bursty channel at 30% loss: realized masks are non-trivial.
        assert 0.0 < masks.mean() < 1.0
        np.testing.assert_allclose(
            rep.accuracy_under_load, float(np.mean(rids % 2 == 0))
        )

    def test_model_in_the_loop_lossless_equals_clean_accuracy(self):
        """With a loss-free channel the realized-mask accuracy equals the
        model's clean per-sample accuracy on the served request ids."""
        from repro.net import evalhook

        model = evalhook.train_tiny_model(
            steps=30, n_train=200, n_test=80, seed=1
        )
        cfg = SimConfig(n_clients=3, arrival_rate_hz=4.0, duration_s=1.0,
                        seed=5, n_packets=11)
        rep = run_sim(
            cfg,
            channels=[IIDChannel(0.0) for _ in range(3)],
            model_in_the_loop=True,
            model=model,
        )
        assert rep.served == rep.arrived and rep.served > 0
        expected = float(
            evalhook.accuracy_per_request_masks(
                model,
                np.ones((rep.served, cfg.n_packets), dtype=bool),
                np.arange(rep.served),
            ).mean()
        )
        np.testing.assert_allclose(rep.accuracy_under_load, expected)

    def test_accuracy_curve_mode_still_reported(self):
        fn = accuracy_curve_fn([0.0, 1.0], [0.1, 0.9])
        rep = run_sim(
            SimConfig(n_clients=4, arrival_rate_hz=3.0, duration_s=1.0,
                      seed=2),
            accuracy_fn=fn,
        )
        assert rep.accuracy_mode == "curve"
        assert rep.accuracy_under_load is not None
